"""Compressed posterior + active-set path: surrogate accuracy, M=K bitwise
parity with the dense program, kernel scatter write-back, selection policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compress, gibbs
from repro.core.moments import BetaParams, exponent_grid
from repro.kernels import ops
from repro import sched


def _fleet_telemetry(key, k=6, n=24, noise=0.05):
    kf, kt = jax.random.split(key)
    f = jax.random.uniform(kf, (k, n), minval=0.1, maxval=0.9)
    mu = jnp.linspace(5.0, 25.0, k)[:, None]
    t = f**0.8 * mu * jnp.exp(noise * jax.random.normal(kt, (k, n)))
    return t, f


def tree_equal(a, b):
    return jax.tree_util.tree_all(
        jax.tree_util.tree_map(lambda x, y: jnp.array_equal(x, y), a, b)
    )


# -----------------------------------------------------------------------
# surrogate accuracy
# -----------------------------------------------------------------------
def test_surrogate_moments_match_grid_on_converged_worker():
    """Acceptance bound: |E_grid - E_beta| < 1e-3 once a worker converges."""
    key = jax.random.PRNGKey(42)
    kf, kn = jax.random.split(key)
    f = jax.random.uniform(kf, (2048,), minval=0.1, maxval=0.9)
    t = f**0.8 * 10.0 * jnp.exp(0.02 * jax.random.normal(kn, (2048,)))
    state, _ = gibbs.fit(key, t, f, batch_size=64, n_iters=4, grid_size=256)

    # a fresh drain-sized batch must barely move the converged posterior
    k2f, k2n = jax.random.split(jax.random.PRNGKey(7))
    f2 = jax.random.uniform(k2f, (8,), minval=0.1, maxval=0.9)
    t2 = f2**0.8 * 10.0 * jnp.exp(0.02 * jax.random.normal(k2n, (8,)))
    mean_gap, var_gap = compress.surrogate_gap(state, t2, f2, grid_size=256)
    assert float(jnp.max(mean_gap)) < 1e-3
    assert float(jnp.max(var_gap)) < 1e-4


def test_surrogate_gap_large_for_cold_worker():
    """A cold worker's grid posterior is data-dominated: the frozen prior
    surrogate must NOT claim to match it (this is why cold workers belong
    in the active set)."""
    key = jax.random.PRNGKey(0)
    f = jax.random.uniform(key, (32,), minval=0.1, maxval=0.9)
    t = f**0.3 * 10.0  # strongly sub-linear: far from the Beta(2,2) prior
    state = gibbs.init_state(key, mu_guess=10.0)
    mean_gap, _ = compress.surrogate_gap(state, t, f, grid_size=128)
    assert float(jnp.max(mean_gap)) > 1e-2


def test_fit_surrogate_roundtrip():
    """Moment-fitting the grid then taking Beta moments reproduces the grid
    moments (the method-of-moments fit is exact in its first two moments)."""
    key = jax.random.PRNGKey(3)
    t, f = _fleet_telemetry(key, k=4)
    state, _ = gibbs.fit_fleet(key, t, f, n_iters=3, grid_size=128)
    a_fit, b_fit = compress.fit_surrogate(state, t, f, grid_size=128)
    ge, gv = compress.grid_moments(state, t, f, grid_size=128)
    ea, va = compress.beta_moments(a_fit)
    eb, vb = compress.beta_moments(b_fit)
    np.testing.assert_allclose(np.asarray(ea), np.asarray(ge[..., 0]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(eb), np.asarray(ge[..., 1]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(va), np.asarray(gv[..., 0]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(vb), np.asarray(gv[..., 1]), atol=2e-5)


def test_lognormal_moment_fit():
    m, s2 = compress.fit_lognormal_moments(jnp.asarray(3.0), jnp.asarray(0.5))
    mean = jnp.exp(m + 0.5 * s2)
    var = (jnp.exp(s2) - 1.0) * jnp.exp(2.0 * m + s2)
    assert abs(float(mean) - 3.0) < 1e-5
    assert abs(float(var) - 0.5) < 1e-5


# -----------------------------------------------------------------------
# active-subset advance: bitwise parity at M = K, frozen surrogate at M < K
# -----------------------------------------------------------------------
def test_gibbs_batch_active_full_set_bitwise_dense():
    key = jax.random.PRNGKey(1)
    t, f = _fleet_telemetry(key)
    k = t.shape[0]
    states, _ = gibbs.fit_fleet(key, t, f, n_iters=2, grid_size=64)

    dense, ll_d = gibbs.gibbs_batch(states, t, f, n_iters=3, grid_size=64)
    active, ll_a = gibbs.gibbs_batch(
        states, t, f, n_iters=3, grid_size=64, active_idx=jnp.arange(k)
    )
    assert tree_equal(dense, active)
    assert bool(jnp.array_equal(ll_d, ll_a))


def test_advance_fleet_active_full_set_bitwise_dense():
    """Through the scheduler path too — including the discount pairing."""
    key = jax.random.PRNGKey(2)
    t, f = _fleet_telemetry(key)
    k = t.shape[0]
    config = sched.SchedulerConfig(n_iters=3, grid_size=64)
    states, _ = gibbs.fit_fleet(key, t, f, n_iters=2, grid_size=64)

    dense, ll_d = sched.advance_fleet(states, t, f, config)
    active, ll_a = sched.advance_fleet(
        states, t, f, config, active_idx=jnp.arange(k)
    )
    assert tree_equal(dense, active)
    assert bool(jnp.array_equal(ll_d, ll_a))


def test_active_rows_match_dense_and_rest_keep_frozen_priors():
    key = jax.random.PRNGKey(4)
    t, f = _fleet_telemetry(key)
    states, _ = gibbs.fit_fleet(key, t, f, n_iters=2, grid_size=64)
    idx = jnp.asarray([1, 4])

    dense, _ = gibbs.gibbs_batch(states, t, f, n_iters=2, grid_size=64)
    part, _ = gibbs.gibbs_batch(
        states, t, f, n_iters=2, grid_size=64, active_idx=idx
    )
    # active rows: bitwise the dense program's same rows
    take = lambda tree: jax.tree_util.tree_map(lambda x: x[idx], tree)
    assert tree_equal(take(dense), take(part))
    # surrogate rows: exponent Beta priors frozen exactly
    rest = np.asarray([0, 2, 3, 5])
    for p_old, p_new in (
        (states.alpha_prior, part.alpha_prior),
        (states.beta_prior, part.beta_prior),
    ):
        assert bool(jnp.array_equal(p_old.a[rest], p_new.a[rest]))
        assert bool(jnp.array_equal(p_old.b[rest], p_new.b[rest]))
    # but their conjugate NG block still learned from the batch
    assert not bool(jnp.array_equal(states.ng.mu0[rest], part.ng.mu0[rest]))
    # and the PRNG stream advanced identically to the dense program
    assert bool(jnp.array_equal(dense.key, part.key))


def test_advance_fleet_discount_freezes_surrogate_priors():
    """Power-prior forgetting of the Beta priors pairs with the grid re-fit:
    surrogate workers must skip BOTH (no widening without re-learning)."""
    key = jax.random.PRNGKey(5)
    t, f = _fleet_telemetry(key)
    config = sched.SchedulerConfig(n_iters=2, grid_size=64, discount=0.7)
    states, _ = gibbs.fit_fleet(key, t, f, n_iters=2, grid_size=64)
    idx = jnp.asarray([0, 3])
    out, _ = sched.advance_fleet(states, t, f, config, active_idx=idx)
    rest = np.asarray([1, 2, 4, 5])
    assert bool(jnp.array_equal(states.alpha_prior.a[rest], out.alpha_prior.a[rest]))
    assert bool(jnp.array_equal(states.beta_prior.b[rest], out.beta_prior.b[rest]))


def test_gibbs_batch_active_rejects_sharding():
    key = jax.random.PRNGKey(0)
    t, f = _fleet_telemetry(key, k=2)
    states, _ = gibbs.fit_fleet(key, t, f, n_iters=1, grid_size=32)
    from repro.core.sharding import ShardingConfig

    with pytest.raises(ValueError):
        gibbs.gibbs_batch(
            states, t, f, n_iters=1, grid_size=32,
            active_idx=jnp.arange(2), sharding=ShardingConfig.auto(),
        )


# -----------------------------------------------------------------------
# kernel-layer active-subset launch
# -----------------------------------------------------------------------
def _kernel_args(key, k=5, n=16, g=32):
    t, f = _fleet_telemetry(key, k=k, n=n)
    grid = exponent_grid(g)
    mu = jnp.linspace(5.0, 25.0, k)
    lam = jnp.full((k,), 2.0)
    alpha = jnp.full((k,), 0.7)
    beta = jnp.full((k,), 0.4)
    pri = BetaParams(jnp.full((k,), 2.0), jnp.full((k,), 2.0))
    return grid, t, f, mu, lam, alpha, beta, pri, pri


def test_posterior_grid_fleet_active_full_set_bitwise():
    args = _kernel_args(jax.random.PRNGKey(6))
    k = args[1].shape[0]
    dense = ops.posterior_grid_fleet(*args)
    active = ops.posterior_grid_fleet(*args, active_idx=jnp.arange(k))
    assert bool(jnp.array_equal(dense, active))


def test_posterior_grid_fleet_active_scatter_writeback():
    args = _kernel_args(jax.random.PRNGKey(7))
    dense = ops.posterior_grid_fleet(*args)
    idx = jnp.asarray([0, 2])
    # fresh cache: non-active rows zero
    out = ops.posterior_grid_fleet(*args, active_idx=idx)
    assert bool(jnp.array_equal(out[idx], dense[idx]))
    assert bool(jnp.all(out[jnp.asarray([1, 3, 4])] == 0.0))
    # persistent cache: non-active rows keep their previous values
    prev = jnp.full_like(dense, 7.0)
    out2 = ops.posterior_grid_fleet(*args, active_idx=idx, out_prev=prev)
    assert bool(jnp.array_equal(out2[idx], dense[idx]))
    assert bool(jnp.all(out2[jnp.asarray([1, 3, 4])] == 7.0))


# -----------------------------------------------------------------------
# selection policy + footprint accounting
# -----------------------------------------------------------------------
def test_select_active_prefers_young_surprising_stale():
    k = 8
    age = jnp.zeros((k,), jnp.int32).at[5].set(100)  # 5: stale surrogate
    nu = jnp.full((k,), 200.0).at[2].set(1.0)  # 2: young
    surprise = jnp.zeros((k,)).at[6].set(50.0)  # 6: drifting
    idx, pri = compress.select_active(3, age=age, nu=nu, surprise=surprise)
    assert set(np.asarray(idx).tolist()) == {2, 5, 6}
    assert pri.shape == (k,)


def test_select_active_excludes_dead_slots():
    k = 6
    live = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0])
    idx, _ = compress.select_active(
        4, age=jnp.full((k,), 10, jnp.int32), live=live
    )
    assert set(np.asarray(idx).tolist()) == {0, 2, 3, 5}


def test_compression_report_hits_10x_at_fleet_scale():
    rep = compress.compression_report(100_000, 512, 4096)
    assert rep.ratio >= 10.0
    assert rep.dense_bytes > 400e6  # the ROADMAP's stated wall
