"""Per-architecture smoke tests + prefill/decode vs teacher-forced consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model_zoo
from repro.models.layers import ApplyCtx

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, b, t):
    batch = {"tokens": jnp.mod(jnp.arange(b * t).reshape(b, t), cfg.vocab_size - 1).astype(jnp.int32)}
    if cfg.vision_patches:
        batch["vision"] = 0.1 * jnp.ones((b, cfg.vision_patches, cfg.d_model))
        batch["tokens"] = batch["tokens"][:, : t - cfg.vision_patches]
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jnp.ones((b, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_and_finite(arch, rng_key):
    cfg = reduced(ARCHS[arch])
    params = model_zoo.init_model_params(rng_key, cfg)
    b, t = 2, 16
    batch = _batch(cfg, b, t)
    logits, aux = model_zoo.forward_train(cfg, params, batch, ctx=ApplyCtx(mode="train"))
    assert logits.shape == (b, t, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step_no_nans(arch, rng_key):
    from repro.configs import RunConfig
    from repro.configs.base import ShapeConfig
    from repro.optim import adamw
    from repro.train import train_step as ts

    cfg = reduced(ARCHS[arch])
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    run = RunConfig(model=cfg, shape=shape)
    params = model_zoo.init_model_params(rng_key, cfg)
    opt = adamw.init(params)
    b = _batch(cfg, 4, 16)
    b["labels"] = jnp.ones_like(b["tokens"])
    mb = ts.split_microbatches(b, 2)
    step = ts.make_train_step(cfg, run, ctx=ApplyCtx(mode="train"), num_microbatches=2)
    params2, opt2, metrics = jax.jit(step)(params, opt, mb, jnp.asarray(0))
    assert np.isfinite(float(metrics["loss"]))
    leaves = jax.tree_util.tree_leaves(params2)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_teacher_forcing(arch, rng_key):
    """prefill(t[:k]) + decode steps must reproduce the full-sequence forward
    logits — the strongest cache-correctness property we can test."""
    cfg = reduced(ARCHS[arch])
    params = model_zoo.init_model_params(rng_key, cfg)
    b, t, k = 2, 12, 8
    batch = _batch(cfg, b, t)
    full_logits, _ = model_zoo.forward_train(
        cfg, params, batch, ctx=ApplyCtx(mode="train")
    )

    # prefill on the first k tokens
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :k]
    cache = model_zoo.init_cache(cfg, b, 32, jnp.float32)
    lg, cache = model_zoo.prefill(cfg, params, pre, cache, ctx=ApplyCtx(mode="prefill"))
    offset = cfg.vision_patches if cfg.vision_patches else 0
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full_logits[:, offset + k - 1]),
        rtol=2e-2, atol=2e-3,
    )

    # decode the next tokens teacher-forced; logits must match the full pass
    toks = batch["tokens"]
    n_text = toks.shape[1]
    for j in range(k, min(n_text, k + 3)):
        lg, cache = model_zoo.decode_step(
            cfg, params, toks[:, j : j + 1], cache, ctx=ApplyCtx(mode="decode")
        )
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, offset + j]),
            rtol=2e-2, atol=2e-3,
            err_msg=f"{arch} decode step {j}",
        )


def test_local_attention_window_masking(rng_key):
    """recurrentgemma's local attention: token far outside the window must
    not influence the output."""
    cfg = reduced(ARCHS["recurrentgemma-2b"], local_window=4, num_layers=3)
    params = model_zoo.init_model_params(rng_key, cfg)
    b, t = 1, 12
    base = _batch(cfg, b, t)
    pert = dict(base)
    pert["tokens"] = base["tokens"].at[:, 0].set(
        (base["tokens"][:, 0] + 7) % cfg.vocab_size
    )
    lg1, _ = model_zoo.forward_train(cfg, params, base, ctx=ApplyCtx(mode="train"))
    lg2, _ = model_zoo.forward_train(cfg, params, pert, ctx=ApplyCtx(mode="train"))
    # attention part is windowed, but the RG-LRU recurrence legitimately
    # carries long-range state; perturbing tokens must keep outputs finite
    # and equal at position 0 neighborhoods is NOT required.  Instead check:
    # last-position logits change little vs changing the last token.
    pert_last = dict(base)
    pert_last["tokens"] = base["tokens"].at[:, -1].set(
        (base["tokens"][:, -1] + 7) % cfg.vocab_size
    )
    lg3, _ = model_zoo.forward_train(cfg, params, pert_last, ctx=ApplyCtx(mode="train"))
    d_far = float(jnp.max(jnp.abs(lg2[:, -1] - lg1[:, -1])))
    d_near = float(jnp.max(jnp.abs(lg3[:, -1] - lg1[:, -1])))
    assert d_near > d_far  # recent context dominates


def test_moe_router_load_balance_loss_positive(rng_key):
    from repro.models import moe as moe_lib

    cfg = reduced(ARCHS["granite-moe-3b-a800m"])
    probs = jax.nn.softmax(jax.random.normal(rng_key, (64, cfg.num_experts)))
    aux = moe_lib.load_balance_loss(cfg, probs)
    assert 0.5 < float(aux) < 4.0  # ~1 near balance, grows with skew
    # perfectly collapsed routing is maximally penalized
    collapsed = jnp.zeros((64, cfg.num_experts)).at[:, 0].set(1.0)
    assert float(moe_lib.load_balance_loss(cfg, collapsed)) >= float(aux)
