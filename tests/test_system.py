"""End-to-end behaviour: the paper's scheduler inside the training loop.

These are the system-level claims of the reproduction:
  1. training converges while the Bayesian partitioner rebalances work;
  2. the learned split beats a naive equal split on makespan;
  3. a worker failure is detected, the fleet shrinks, training continues;
  4. checkpoint/restart resumes exactly (params + data cursor).
"""
import numpy as np
import pytest

from repro.configs import RunConfig, get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.distributed.simulated_cluster import SimulatedCluster, WorkerSpec
from repro.train.trainer import Trainer


def _run_cfg(tmp_path, steps=24, **kw):
    cfg = reduced(get_arch("smollm-135m"))
    shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
    return RunConfig(
        model=cfg, shape=shape, checkpoint_dir=str(tmp_path),
        total_steps=steps, warmup_steps=2, checkpoint_every=8,
        partitioner_refit_every=6, **kw,
    )


def test_training_converges_and_rebalances(tmp_path):
    run = _run_cfg(tmp_path, steps=24)
    cluster = SimulatedCluster(
        [WorkerSpec(5.0, 0.5), WorkerSpec(20.0, 1.0)], seed=0
    )
    tr = Trainer(run, cluster=cluster, num_microbatches=8)
    rep = tr.train(24)
    assert rep.losses[-1] < rep.losses[0]
    # learned split favors the 4x-faster worker 0
    assert rep.splits, "partitioner refits must have occurred"
    final = rep.splits[-1]
    assert final[0] > final[1]
    # makespan improves vs the initial equal split
    k = max(len(rep.makespans) // 4, 1)
    assert np.mean(rep.makespans[-k:]) < np.mean(rep.makespans[:k])


def test_failure_detection_and_elastic_continue(tmp_path):
    run = _run_cfg(tmp_path, steps=20)
    run = __import__("dataclasses").replace(
        run, shape=ShapeConfig("t", seq_len=32, global_batch=12, kind="train")
    )
    cluster = SimulatedCluster(
        [WorkerSpec(5.0, 0.5), WorkerSpec(6.0, 0.5), WorkerSpec(5.5, 0.5)], seed=1
    )
    tr = Trainer(run, cluster=cluster, num_microbatches=6)
    tr.train(6)
    assert tr.partitioner.num_workers == 3
    cluster.fail(2)
    rep = tr.train(8)
    assert tr.partitioner.num_workers == 2  # evicted
    assert any(e["type"] == "failure" for e in tr.monitor.events)
    assert np.isfinite(rep.losses[-1])
    # all microbatches now assigned to survivors
    assert set(np.unique(tr._worker_of_mb)) <= {0, 1}


def test_checkpoint_restart_resumes_exactly(tmp_path):
    run = _run_cfg(tmp_path, steps=16)
    cluster = SimulatedCluster([WorkerSpec(5.0, 0.5), WorkerSpec(7.0, 0.5)], seed=2)
    tr1 = Trainer(run, cluster=cluster, num_microbatches=4)
    tr1.train(8)
    tr1.save()
    tr1.ckpt.wait()
    loss_ref = tr1.train(4).losses

    tr2 = Trainer(run, cluster=SimulatedCluster(
        [WorkerSpec(5.0, 0.5), WorkerSpec(7.0, 0.5)], seed=2), num_microbatches=4)
    assert tr2.try_restore()
    assert tr2.step == 8
    loss_resumed = tr2.train(4).losses
    np.testing.assert_allclose(loss_resumed, loss_ref, rtol=1e-4)


def test_try_restore_salvages_params_from_shape_drifted_checkpoint(tmp_path):
    """A checkpoint whose scheduler leaves have a drifted shape (e.g. the
    pre-PR-4 fleet-global scalar ewma_count) must still give back its
    perfectly valid model params: the name-keyed subset restore resets only
    the drifted leaf, adopts everything else, and training resumes — no
    crash, no silent wrong-shaped beliefs, and no fresh start for the model."""
    import jax
    import jax.numpy as jnp

    run = _run_cfg(tmp_path, steps=8)
    mk_cluster = lambda: SimulatedCluster(
        [WorkerSpec(5.0, 0.5), WorkerSpec(6.0, 0.5)], seed=4
    )
    tr = Trainer(run, cluster=mk_cluster(), num_microbatches=4)
    tr.train(2)
    legacy_sched = tr.partitioner.state._replace(
        ewma_count=jnp.zeros((), jnp.int32)  # the old fleet-global scalar
    )
    tr.ckpt.save(
        tr.step,
        {"params": tr.params, "opt_state": tr.opt_state, "sched": legacy_sched},
        {"step": tr.step, "data_state": tr.data.state_dict()},
    )
    tr.ckpt.wait()

    tr2 = Trainer(run, cluster=mk_cluster(), num_microbatches=4)
    assert tr2.try_restore() is True  # model params salvaged by name
    ref_leaves = jax.tree_util.tree_leaves(tr.params)
    got_leaves = jax.tree_util.tree_leaves(tr2.params)
    assert all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(ref_leaves, got_leaves)
    )
    # the drifted leaf reset to the fresh template shape, rest adopted
    assert tr2.partitioner.state.ewma_count.shape == (2,)
    assert np.all(np.asarray(tr2.partitioner.state.ewma_count) == 0)
    rep = tr2.train(2)
    assert np.isfinite(rep.losses[-1])


def test_try_restore_fresh_start_on_pre_keypath_checkpoint(tmp_path):
    """Checkpoints written before key-path manifests (no ``keypaths`` entry)
    cannot be matched by name; with a drifted structure the positional
    model-only fallback is tried, and an unusable layout means a fresh
    start — reported honestly as False, never a crash."""
    import json

    import jax.numpy as jnp

    run = _run_cfg(tmp_path, steps=8)
    mk_cluster = lambda: SimulatedCluster(
        [WorkerSpec(5.0, 0.5), WorkerSpec(6.0, 0.5)], seed=4
    )
    tr = Trainer(run, cluster=mk_cluster(), num_microbatches=4)
    tr.train(2)
    legacy_sched = tr.partitioner.state._replace(
        ewma_count=jnp.zeros((), jnp.int32)
    )
    tr.ckpt.save(
        tr.step,
        {"params": tr.params, "opt_state": tr.opt_state, "sched": legacy_sched},
        {"step": tr.step, "data_state": tr.data.state_dict()},
    )
    tr.ckpt.wait()
    # age the manifest back to the pre-keypath era
    mpath = tmp_path / f"step_{tr.step:08d}" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    del manifest["keypaths"]
    mpath.write_text(json.dumps(manifest))

    tr2 = Trainer(run, cluster=mk_cluster(), num_microbatches=4)
    assert tr2.try_restore() is False  # unusable, reported honestly
    rep = tr2.train(2)  # fresh start still trains
    assert np.isfinite(rep.losses[-1])


def test_straggler_soft_detection(tmp_path):
    run = _run_cfg(tmp_path, steps=30, straggler_threshold_sigma=2.0)
    cluster = SimulatedCluster(
        [WorkerSpec(5.0, 0.3), WorkerSpec(5.0, 0.3), WorkerSpec(5.0, 0.3),
         WorkerSpec(5.0, 0.3)], seed=3
    )
    tr = Trainer(run, cluster=cluster, num_microbatches=8)
    tr.train(12)  # learn the healthy regime
    cluster.degrade(1, mu_factor=6.0)  # worker 1 becomes a straggler
    tr.train(12)
    assert any(e["type"] == "straggler" and 1 in e["workers"]
               for e in tr.monitor.events)
