"""Stochastic workflows locked down by the Monte-Carlo simulator oracle.

Every analytic composition rule the stochastic DAG layer adds — Bernoulli
branch mixtures, truncated-geometric rework counts, compound (rework) sums,
and their composition through the topology — is pinned against
``repro.sim.workflow``, which samples the SAME generative process with none
of the closed forms.  Fast tier-1 variants run seed-pinned at 2e5 samples;
``-m slow`` counterparts push 1e6.  The degenerate-annotation path (p = 1
branches, zero rework) is pinned BITWISE to the deterministic proposal.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched, sim
from repro.core import frontier
from repro.core.frontier import UnitParams

KEY = jax.random.PRNGKey(0)


def _stage_params(seed, s, k, mu_lo=4.0, mu_hi=20.0, sig_lo=0.5, sig_hi=3.0):
    rng = np.random.default_rng(seed)
    return UnitParams.of(
        rng.uniform(mu_lo, mu_hi, (s, k)).astype(np.float32),
        rng.uniform(sig_lo, sig_hi, (s, k)).astype(np.float32),
        np.full((s, k), 0.9, np.float32),
        np.full((s, k), 0.7, np.float32),
    )


def _analytic_dag_moments(dag, fracs, params, num_points=2048):
    """Per-stage quadrature -> stochastic transforms -> topological reduce."""
    e, v = jax.vmap(
        lambda fr, p: frontier.mean_var_completion(fr, p, num_points)
    )(fracs, params)
    e, v = sched.effective_stage_moments(dag, e, v)
    return frontier.dag_completion_moments(
        dag.preds, e, v, num_points=num_points
    )


def _mc_check(dag, fracs, params, num_samples, rtol_mean, rtol_var, seed=0):
    e_a, v_a = _analytic_dag_moments(dag, fracs, params)
    e_mc, v_mc = sim.simulate_moments(
        jax.random.PRNGKey(seed), dag, fracs, params, num_samples=num_samples
    )
    np.testing.assert_allclose(float(e_a), float(e_mc), rtol=rtol_mean)
    np.testing.assert_allclose(float(v_a), float(v_mc), rtol=rtol_var)


# --------------------------------------------------------------------------
# composition rules vs the MC oracle
# --------------------------------------------------------------------------
def test_mixture_moments_match_monte_carlo():
    """Bernoulli branch thinning: E = p mu, Var = p v + p(1-p) mu^2."""
    dag = sched.WorkflowDAG.chain(1, 4).with_stochastic(exec_probs=(0.3,))
    params = _stage_params(1, 1, 4)
    fracs = jnp.full((1, 4), 0.25)
    _mc_check(dag, fracs, params, 200_000, 1e-2, 1e-2, seed=11)


def test_truncated_geometric_moments_match_monte_carlo():
    """Attempt counts: near-constant unit attempts isolate (E[N], Var[N])."""
    r, cap = 0.45, 5
    dag = sched.WorkflowDAG.chain(1, 2).with_stochastic(
        rework_probs=(r,), max_retries=(cap,)
    )
    # sigma ~ 0 makes every attempt take ~mu, so T ~ N * mu exactly.
    params = UnitParams.of(
        np.full((1, 2), 2.0, np.float32), np.full((1, 2), 1e-4, np.float32)
    )
    fracs = jnp.full((1, 2), 0.5)
    n_mean, n_var = frontier.truncated_geometric_moments(1.0 - r, cap)
    t = sim.simulate_workflow(
        jax.random.PRNGKey(12), dag, fracs, params, num_samples=200_000
    )
    mu_attempt = float(
        frontier.mean_var_completion(fracs[0], jax.tree_util.tree_map(
            lambda x: x[0], params), 2048)[0]
    )
    np.testing.assert_allclose(
        float(n_mean) * mu_attempt, float(jnp.mean(t)), rtol=1e-2
    )
    np.testing.assert_allclose(
        float(n_var) * mu_attempt**2, float(jnp.var(t)), rtol=1e-2
    )


def test_compound_sum_moments_match_monte_carlo():
    """Geometric rework over noisy attempts: the full Wald-style compound."""
    dag = sched.WorkflowDAG.chain(1, 4).with_stochastic(
        rework_probs=(0.35,), max_retries=(6,)
    )
    params = _stage_params(3, 1, 4)
    fracs = jnp.full((1, 4), 0.25)
    _mc_check(dag, fracs, params, 200_000, 1e-2, 1e-2, seed=13)


def test_stochastic_stage_moments_match_monte_carlo():
    """Rework THEN branch mixture on one stage — the composed transform."""
    dag = sched.WorkflowDAG.chain(1, 4).with_stochastic(
        exec_probs=(0.6,), rework_probs=(0.3,), max_retries=(4,)
    )
    params = _stage_params(4, 1, 4)
    fracs = jnp.full((1, 4), 0.25)
    _mc_check(dag, fracs, params, 200_000, 1e-2, 1e-2, seed=14)


def test_stochastic_chain_matches_monte_carlo():
    """Serial composition of mixed deterministic/branch/rework stages."""
    dag = sched.WorkflowDAG.chain(4, 4).with_stochastic(
        exec_probs=(1.0, 0.4, 1.0, 0.8),
        rework_probs=(0.0, 0.0, 0.5, 0.2),
        max_retries=(1, 1, 5, 3),
    )
    params = _stage_params(5, 4, 4)
    fracs = jnp.full((4, 4), 0.25)
    _mc_check(dag, fracs, params, 200_000, 1e-2, 1e-2, seed=15)


def test_stochastic_join_matches_monte_carlo():
    """Fork-free join (in-tree): two independent stochastic branches meeting
    at a max, then a tail stage — exercises the PERT branch-max on EFFECTIVE
    moments.  The branches share no ancestors, so independence is exact and
    the only approximation is the Normal-matched max."""
    dag = sched.WorkflowDAG(
        preds=((), (), (0, 1), (2,)), num_workers=4
    ).with_stochastic(
        exec_probs=(1.0, 0.5, 1.0, 1.0),
        rework_probs=(0.3, 0.0, 0.0, 0.25),
        max_retries=(4, 1, 1, 3),
    )
    params = _stage_params(6, 4, 4)
    fracs = jnp.full((4, 4), 0.25)
    _mc_check(dag, fracs, params, 200_000, 1e-2, 5e-2, seed=16)


@pytest.mark.slow
@pytest.mark.parametrize(
    "exec_probs,rework_probs,max_retries",
    [
        ((0.3, 1.0, 1.0, 1.0), None, None),
        (None, (0.0, 0.45, 0.0, 0.2), (1, 6, 1, 3)),
        ((1.0, 0.4, 0.7, 1.0), (0.0, 0.0, 0.5, 0.3), (1, 1, 5, 4)),
    ],
)
def test_stochastic_chain_monte_carlo_high_sample(
    exec_probs, rework_probs, max_retries
):
    """Slow counterpart: 1e6 samples shrink MC noise well under the 1e-2
    tolerance, so a failure is an analytic bug, not sampling luck."""
    dag = sched.WorkflowDAG.chain(4, 4).with_stochastic(
        exec_probs=exec_probs,
        rework_probs=rework_probs,
        max_retries=max_retries,
    )
    params = _stage_params(7, 4, 4)
    fracs = jnp.full((4, 4), 0.25)
    _mc_check(dag, fracs, params, 1_000_000, 1e-2, 1e-2, seed=17)


# --------------------------------------------------------------------------
# degenerate annotations are BITWISE the deterministic path
# --------------------------------------------------------------------------
_REG_CFG = sched.SchedulerConfig(
    n_iters=4, grid_size=64, mu_guess=10.0, opt_steps=60, num_points=256
)


def _learned_state(dag, cfg, seed=0):
    s, k = dag.num_stages, dag.num_workers
    params = _stage_params(seed + 300, s, k)
    state = sched.init_dag(cfg, dag, jax.random.PRNGKey(seed))
    fracs = jnp.full((s, k, 32), 1.0 / k)
    times = sim.simulate_telemetry(
        jax.random.PRNGKey(seed + 1), fracs[..., 0], params, num_obs=32
    )
    state, _ = sched.observe_dag(
        state, sched.Telemetry(fracs=fracs, times=times), cfg
    )
    return state


@pytest.mark.parametrize(
    "objective",
    [
        sched.Objective.mean(),
        sched.Objective.mean_var(1.5),
        sched.Objective.variance_budget(0.5),
        sched.Objective.deadline_quantile(12.0),
    ],
    ids=["mean", "mean_var", "var_budget", "deadline"],
)
def test_degenerate_annotations_propose_bitwise(objective):
    """p = 1.0 branches and zero rework ARE the deterministic proposal,
    leaf for leaf — the stochastic machinery is routed around statically,
    never evaluated-and-cancelled numerically."""
    plain = sched.WorkflowDAG.from_edges(
        4, ((0, 1), (0, 2), (1, 3), (2, 3)), num_workers=3
    )
    degenerate = plain.with_stochastic(
        exec_probs=(1.0,) * 4, rework_probs=(0.0,) * 4, max_retries=(1,) * 4
    )
    assert not degenerate.is_stochastic
    cfg = dataclasses.replace(_REG_CFG, objective=objective)
    state = _learned_state(plain, cfg)
    f_plain, st_plain = sched.propose_dag(state, plain, cfg)
    f_degen, st_degen = sched.propose_dag(state, degenerate, cfg)
    np.testing.assert_array_equal(np.asarray(f_plain), np.asarray(f_degen))
    for a, b in zip(st_plain, st_degen):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_degenerate_effective_moments_are_identity():
    dag = sched.WorkflowDAG.chain(3, 2).with_stochastic(
        exec_probs=(1.0, 1.0, 1.0), rework_probs=(0.0, 0.0, 0.0)
    )
    e = jnp.asarray([1.0, 2.0, 3.0])
    v = jnp.asarray([0.1, 0.2, 0.3])
    ee, vv = sched.effective_stage_moments(dag, e, v)
    assert ee is e and vv is v  # passthrough: same arrays, not same values


# --------------------------------------------------------------------------
# ISSUE acceptance: stochastic-aware allocation beats blind allocation
# --------------------------------------------------------------------------
def _acceptance_fixture():
    """4-stage diamond, K = 8 heterogeneous fleet (fast-noisy vs
    slow-precise workers), one p = 0.3 conditional stage, one geometric
    rework stage.  Under an end-to-end variance budget the
    deterministic-assumption allocator misprices stage variances — the
    conditional branch thins them x0.3, the rework loop amplifies them
    x E[N] — and pays expected time where it buys nothing."""
    s, k = 4, 8
    dag = sched.WorkflowDAG.from_edges(
        s, ((0, 1), (0, 2), (1, 3), (2, 3)), num_workers=k
    )
    dag_sto = dag.with_stochastic(
        exec_probs=(1.0, 0.3, 1.0, 1.0),
        rework_probs=(0.0, 0.0, 0.4, 0.0),
        max_retries=(1, 1, 4, 1),
    )
    base_mu = np.asarray([5.0] * 4 + [9.0] * 4, np.float32)
    base_sig = np.asarray([6.0] * 4 + [0.3] * 4, np.float32)
    stage_scale = np.asarray([0.4, 1.6, 0.5, 0.4], np.float32)
    true = UnitParams.of(
        stage_scale[:, None] * base_mu[None, :],
        stage_scale[:, None] * base_sig[None, :],
        np.full((s, k), 0.9, np.float32),
        np.full((s, k), 0.55, np.float32),
    )
    cfg = sched.SchedulerConfig(
        objective=sched.Objective.variance_budget(2.0),
        opt_steps=200,
        num_points=256,
    )
    return dag, dag_sto, true, cfg


def _acceptance_gaps(num_samples):
    dag, dag_sto, true, cfg = _acceptance_fixture()
    state = sched.init_dag(cfg, dag, jax.random.PRNGKey(0))
    f_det, _ = sched.propose_dag(state, dag, cfg, params=true)
    f_sto, _ = sched.propose_dag(state, dag_sto, cfg, params=true)
    f_uni = sched.uniform_fractions(dag)
    # Common random numbers: the SAME key prices all three proposals on the
    # SAME sampled world, so the paired gaps have ~20x less MC noise than
    # independent runs and strict ordering is assertable.
    key = jax.random.PRNGKey(42)
    t_det = sim.simulate_workflow(
        key, dag_sto, f_det, true, num_samples=num_samples
    )
    t_sto = sim.simulate_workflow(
        key, dag_sto, f_sto, true, num_samples=num_samples
    )
    t_uni = sim.simulate_workflow(
        key, dag_sto, f_uni, true, num_samples=num_samples
    )
    return float(jnp.mean(t_det - t_sto)), float(jnp.mean(t_uni - t_sto))


def test_stochastic_aware_propose_beats_deterministic_and_uniform():
    """ISSUE acceptance: simulator-measured expected completion of the
    stochastic-aware proposal is strictly below both baselines, by margins
    far above the paired-MC standard error (~6e-4 at 2e5 samples)."""
    gap_det, gap_uni = _acceptance_gaps(200_000)
    assert gap_det > 0.01, f"det-assumption gap {gap_det:.4f} not positive"
    assert gap_uni > 0.5, f"uniform gap {gap_uni:.4f} not positive"


@pytest.mark.slow
def test_stochastic_aware_propose_beats_baselines_high_sample():
    gap_det, gap_uni = _acceptance_gaps(1_000_000)
    assert gap_det > 0.02
    assert gap_uni > 0.5


# --------------------------------------------------------------------------
# per-stage objectives
# --------------------------------------------------------------------------
def test_per_stage_objectives_solve_each_stage_locally():
    """A per-stage tuple gives each stage its own objective: the budgeted
    stage meets ITS budget, the mean stages reuse the presolve rows."""
    dag = sched.WorkflowDAG.chain(3, 4)
    cfg = _REG_CFG
    state = _learned_state(dag, cfg, seed=7)
    f_mean, _ = sched.propose_dag(state, dag, cfg)
    params = sched.stage_params(state)
    take = lambda i: jax.tree_util.tree_map(lambda x: x[i], params)
    # bracket stage 1's achievable variance: [min-var split, mean split]
    f_minv, _ = sched.propose_dag(
        state, dag, cfg,
        objectives=(sched.Objective.mean(),
                    sched.Objective.variance_budget(1e-8),
                    sched.Objective.mean()),
    )
    _, v1_min = frontier.mean_var_completion(f_minv[1], take(1), 512)
    _, v1_mean = frontier.mean_var_completion(f_mean[1], take(1), 512)
    budget = 0.5 * (float(v1_min) + float(v1_mean))  # strictly feasible
    objs = (
        sched.Objective.mean(),
        sched.Objective.variance_budget(budget),
        sched.Objective.mean(),
    )
    f_mixed, _ = sched.propose_dag(state, dag, cfg, objectives=objs)
    np.testing.assert_allclose(np.asarray(f_mixed.sum(-1)), 1.0, atol=1e-5)
    # mean stages are BITWISE the shared-mean proposal rows
    np.testing.assert_array_equal(np.asarray(f_mixed[0]), np.asarray(f_mean[0]))
    np.testing.assert_array_equal(np.asarray(f_mixed[2]), np.asarray(f_mean[2]))
    # the budgeted stage meets its own budget, below its unconstrained var
    _, v1 = frontier.mean_var_completion(f_mixed[1], take(1), 512)
    assert float(v1) <= budget * 1.05
    assert float(v1) <= float(v1_mean) + 1e-6


def test_per_stage_objectives_broadcast_matches_shared_mean():
    dag = sched.WorkflowDAG.chain(3, 4)
    state = _learned_state(dag, _REG_CFG, seed=8)
    f_shared, _ = sched.propose_dag(state, dag, _REG_CFG)
    f_bcast, _ = sched.propose_dag(
        state, dag, _REG_CFG, objectives=(sched.Objective.mean(),) * 3
    )
    np.testing.assert_array_equal(np.asarray(f_shared), np.asarray(f_bcast))


def test_per_stage_objectives_validate_length_and_type():
    dag = sched.WorkflowDAG.chain(3, 4)
    state = _learned_state(dag, _REG_CFG, seed=9)
    with pytest.raises(ValueError):
        sched.propose_dag(
            state, dag, _REG_CFG, objectives=(sched.Objective.mean(),) * 2
        )
    with pytest.raises(TypeError):
        sched.as_stage_objectives(("mean", "mean", "mean"), 3)


# --------------------------------------------------------------------------
# heterogeneous per-stage widths (pad + mask)
# --------------------------------------------------------------------------
def test_heterogeneous_widths_dead_columns_exactly_zero():
    dag = sched.WorkflowDAG.chain(3, 4).with_stage_workers((2, 3, 4))
    cfg = _REG_CFG
    state = _learned_state(dag, cfg, seed=10)
    live = np.asarray(dag.stage_live())
    np.testing.assert_array_equal(
        live, [[1, 1, 0, 0], [1, 1, 1, 0], [1, 1, 1, 1]]
    )
    for objective in (sched.Objective.mean(), sched.Objective.mean_var(1.0)):
        c = dataclasses.replace(cfg, objective=objective)
        fracs, _ = sched.propose_dag(state, dag, c)
        assert np.all(np.asarray(fracs)[live == 0] == 0.0)  # exactly, not ~0
        np.testing.assert_allclose(np.asarray(fracs.sum(-1)), 1.0, atol=1e-5)
    f_uni = np.asarray(sched.uniform_fractions(dag))
    np.testing.assert_allclose(f_uni[0], [0.5, 0.5, 0.0, 0.0])
    np.testing.assert_allclose(f_uni[1, :3], 1.0 / 3, atol=1e-6)


def test_heterogeneous_widths_observe_masks_dead_columns():
    """Whatever garbage telemetry a padded column carries is an exact no-op
    on its parked posterior: two observes differing ONLY in dead-column
    junk produce bitwise-identical states."""
    dag = sched.WorkflowDAG.chain(2, 3).with_stage_workers((1, 3))
    cfg = _REG_CFG
    state = sched.init_dag(cfg, dag, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    f = rng.uniform(0.1, 0.9, (2, 3, 16)).astype(np.float32)
    t = rng.uniform(1.0, 9.0, (2, 3, 16)).astype(np.float32)
    t_junk = t.copy()
    t_junk[0, 1:] = 1e6  # dead columns of stage 0
    s1, ll1 = sched.observe_dag(
        state, sched.Telemetry(fracs=jnp.asarray(f), times=jnp.asarray(t)),
        cfg, dag=dag,
    )
    s2, ll2 = sched.observe_dag(
        state,
        sched.Telemetry(fracs=jnp.asarray(f), times=jnp.asarray(t_junk)),
        cfg, dag=dag,
    )
    for a, b in zip(jax.tree_util.tree_leaves(s1), jax.tree_util.tree_leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ll1), np.asarray(ll2))


def test_quantize_dag_fractions_respects_widths_and_totals():
    dag = sched.WorkflowDAG.chain(3, 4).with_stage_workers((2, 3, 4))
    rng = np.random.default_rng(4)
    fracs = rng.dirichlet(np.ones(4), size=3)
    fracs *= np.asarray(dag.stage_live())
    fracs /= fracs.sum(-1, keepdims=True)
    counts = sched.quantize_dag_fractions(
        fracs, (12, 16, 20), live=np.asarray(dag.stage_live()) > 0
    )
    np.testing.assert_array_equal(counts.sum(-1), [12, 16, 20])
    assert np.all(counts[np.asarray(dag.stage_live()) == 0] == 0)
    live = np.asarray(dag.stage_live()) > 0
    assert np.all(counts[live] >= 1)


# --------------------------------------------------------------------------
# simulator self-checks
# --------------------------------------------------------------------------
def test_simulator_degenerate_chain_matches_serial_moments():
    """No annotations at all: the simulator is the PR 4 deterministic MC."""
    dag = sched.WorkflowDAG.chain(3, 4)
    params = _stage_params(20, 3, 4)
    fracs = jnp.full((3, 4), 0.25)
    _mc_check(dag, fracs, params, 200_000, 1e-2, 1e-2, seed=21)


def test_simulator_skipped_stage_contributes_zero():
    """exec_prob = 0 removes the stage's duration but keeps its edges."""
    chain = sched.WorkflowDAG.chain(3, 2)
    skip = chain.with_stochastic(exec_probs=(1.0, 0.0, 1.0))
    params = _stage_params(22, 3, 2)
    fracs = jnp.full((3, 2), 0.5)
    e_skip, _ = sim.simulate_moments(
        jax.random.PRNGKey(23), skip, fracs, params, num_samples=100_000
    )
    two = sched.WorkflowDAG.chain(2, 2)
    take = lambda x: jnp.asarray(np.asarray(x)[[0, 2]])
    e_two, _ = sim.simulate_moments(
        jax.random.PRNGKey(24), two, fracs[:2],
        jax.tree_util.tree_map(take, params), num_samples=100_000,
    )
    np.testing.assert_allclose(float(e_skip), float(e_two), rtol=1.5e-2)


def test_simulator_zero_rework_is_single_attempt():
    """r = 0 must take EXACTLY one attempt (the inverse-CDF edge case)."""
    dag = sched.WorkflowDAG.chain(1, 2)
    annotated = dag.with_stochastic(rework_probs=(0.0,), max_retries=(5,))
    params = _stage_params(25, 1, 2)
    fracs = jnp.full((1, 2), 0.5)
    key = jax.random.PRNGKey(26)
    t_plain = sim.simulate_workflow(key, dag, fracs, params, num_samples=8192)
    t_ann = sim.simulate_workflow(
        key, annotated, fracs, params, num_samples=8192
    )
    # same key, same single attempt -> identical first-attempt draws
    np.testing.assert_allclose(
        float(jnp.mean(t_ann)), float(jnp.mean(t_plain)), rtol=2e-2
    )


def test_simulate_telemetry_feeds_estimator():
    """The fixture generator round-trips: telemetry from true params drives
    the posterior means toward those params."""
    dag = sched.WorkflowDAG.chain(2, 3)
    true = _stage_params(27, 2, 3, sig_lo=0.2, sig_hi=0.5)
    cfg = dataclasses.replace(_REG_CFG, n_iters=6)
    state = sched.init_dag(cfg, dag, jax.random.PRNGKey(5))
    # Per-observation fraction levels (a single level cannot identify mu vs
    # the exponent): (N, S, K) fracs broadcast against the (S, K) params.
    rng = np.random.default_rng(27)
    fr = jnp.asarray(rng.uniform(0.05, 0.95, (96, 2, 3)).astype(np.float32))
    times = sim.simulate_telemetry(jax.random.PRNGKey(6), fr, true, num_obs=1)
    assert times.shape == (96, 2, 3, 1) and bool(jnp.all(times > 0))
    state, _ = sched.observe_dag(
        state,
        sched.Telemetry(
            fracs=jnp.transpose(fr, (1, 2, 0)),
            times=jnp.transpose(times[..., 0], (1, 2, 0)),
        ),
        cfg,
    )
    np.testing.assert_allclose(
        np.asarray(state.gibbs.ng.mu0), np.asarray(true.mu), rtol=0.15
    )


def test_dag_stats_on_stochastic_dag_reports_effective_contributions():
    dag = sched.WorkflowDAG.chain(2, 3)
    dag_half = dag.with_stochastic(exec_probs=(0.5, 1.0))
    params = _stage_params(28, 2, 3)
    fracs = jnp.full((2, 3), 1.0 / 3)
    st_det = sched.dag_stats(dag, fracs, params)
    st_half = sched.dag_stats(dag_half, fracs, params)
    np.testing.assert_allclose(
        float(st_half.stage_e[0]), 0.5 * float(st_det.stage_e[0]), rtol=1e-6
    )
    np.testing.assert_allclose(
        float(st_half.stage_e[1]), float(st_det.stage_e[1]), rtol=1e-6
    )
    assert float(st_half.e_t) < float(st_det.e_t)
