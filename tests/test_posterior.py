"""Normal-Gamma conjugate updates (Eqs 6-9) against closed forms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.posterior import (
    NormalGammaParams,
    log_likelihood,
    update_normal_gamma,
)


def test_f_equal_one_reduces_to_standard_normal_gamma():
    """With f_n = 1 the model is iid N(mu, 1/lam): Eqs 6-9 must reduce to the
    textbook Normal-Gamma posterior."""
    rng = np.random.default_rng(0)
    t = jnp.asarray(rng.normal(5.0, 2.0, size=200), jnp.float32)
    f = jnp.ones_like(t)
    prior = NormalGammaParams(
        mu0=jnp.float32(0.0), kappa0=jnp.float32(1.0),
        nu0=jnp.float32(2.0), psi0=jnp.float32(2.0),
    )
    post = update_normal_gamma(prior, t, f, jnp.float32(1.0), jnp.float32(1.0))
    n = t.shape[0]
    tbar = float(jnp.mean(t))
    mu_exp = (prior.mu0 * prior.kappa0 + n * tbar) / (prior.kappa0 + n)
    kappa_exp = prior.kappa0 + n
    nu_exp = prior.nu0 + n / 2
    # psi: psi0 + 0.5*(sum t^2 + mu0^2 k0 - muN^2 kN)
    psi_exp = prior.psi0 + 0.5 * (
        float(jnp.sum(t * t)) + float(prior.mu0) ** 2 * float(prior.kappa0)
        - mu_exp**2 * kappa_exp
    )
    np.testing.assert_allclose(float(post.mu0), mu_exp, rtol=1e-5)
    np.testing.assert_allclose(float(post.kappa0), kappa_exp, rtol=1e-6)
    np.testing.assert_allclose(float(post.nu0), nu_exp, rtol=1e-6)
    np.testing.assert_allclose(float(post.psi0), psi_exp, rtol=1e-4)


def test_posterior_concentrates_on_truth():
    """Posterior mean -> true mu as N grows (alpha, beta known)."""
    rng = np.random.default_rng(1)
    mu, sigma, alpha, beta = 30.0, 2.0, 0.9, 0.8
    for n, tol in [(50, 1.0), (2000, 0.2)]:
        f = rng.uniform(0.1, 1.0, size=n).astype(np.float32)
        t = f**alpha * mu + f**beta * sigma * rng.normal(size=n)
        post = update_normal_gamma(
            NormalGammaParams.default(1.0),
            jnp.asarray(t, jnp.float32), jnp.asarray(f, jnp.float32),
            jnp.float32(alpha), jnp.float32(beta),
        )
        assert abs(float(post.mu0) - mu) < tol


def test_mask_matches_truncation():
    rng = np.random.default_rng(2)
    t = jnp.asarray(rng.normal(10, 1, size=64), jnp.float32)
    f = jnp.asarray(rng.uniform(0.2, 1.0, size=64), jnp.float32)
    prior = NormalGammaParams.default(10.0)
    a, b = jnp.float32(0.9), jnp.float32(0.7)
    mask = (jnp.arange(64) < 40).astype(jnp.float32)
    p1 = update_normal_gamma(prior, t, f, a, b, mask)
    p2 = update_normal_gamma(prior, t[:40], f[:40], a, b)
    for x, y in zip(p1, p2):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4)


def test_log_likelihood_peaks_at_truth():
    rng = np.random.default_rng(3)
    mu, sigma, alpha, beta = 20.0, 1.5, 0.85, 0.75
    f = jnp.asarray(rng.uniform(0.1, 1.0, 512), jnp.float32)
    t = f**alpha * mu + f**beta * sigma * jnp.asarray(rng.normal(size=512), jnp.float32)
    lam = 1.0 / sigma**2
    ll_true = float(log_likelihood(t, f, mu, lam, alpha, beta))
    for d_mu in (-3.0, 3.0):
        assert float(log_likelihood(t, f, mu + d_mu, lam, alpha, beta)) < ll_true
    for d_a in (-0.2, 0.1):
        assert float(log_likelihood(t, f, mu, lam, alpha + d_a, beta)) < ll_true
