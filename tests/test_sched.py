"""The pure-functional scheduler API: pytree state, pure transitions,
jit/vmap compatibility, and checkpoint round-trips."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched
from repro.checkpoint.checkpoint import CheckpointManager
from repro.core.frontier import UnitParams


CFG = sched.SchedulerConfig(n_iters=8, grid_size=64, mu_guess=10.0, opt_steps=60)


def _telemetry(rng, state, true_mu, n=16, alpha=0.9):
    k = len(true_mu)
    fr = np.asarray(sched.propose(state, CFG)[0])
    fmat = np.tile(fr[:, None], (1, n))
    tmat = np.stack([
        np.maximum(f[0] ** alpha * m + 0.3 * rng.normal(size=n), 1e-3)
        for f, m in zip(fmat, true_mu)
    ])
    return sched.Telemetry(jnp.asarray(fmat), jnp.asarray(tmat))


def test_state_is_pytree_of_arrays():
    state = sched.init(CFG, 3, jax.random.PRNGKey(0))
    leaves = jax.tree_util.tree_leaves(state)
    assert leaves and all(hasattr(l, "shape") for l in leaves)
    # per-worker leaves carry the K axis
    assert state.ewma_ll.shape == (3,)
    assert state.gibbs.mu.shape == (3,)


@pytest.mark.no_host_sync
def test_jitted_observe_propose_roundtrip(host_staging):
    """observe ∘ propose composes under one jax.jit — and, via the
    ``no_host_sync`` marker, the composed call runs under
    ``jax.transfer_guard("disallow")``: an accidental host sync inside the
    jitted path fails here instead of shipping."""
    with host_staging():  # eager setup mints keys and device telemetry
        state = sched.init(CFG, 2, jax.random.PRNGKey(0))
        telem = _telemetry(np.random.default_rng(0), state, [5.0, 20.0])

    @jax.jit
    def step(state, telem):
        state, ll = sched.observe(state, telem, CFG)
        fracs, stats = sched.propose(state, CFG)
        return state, ll, fracs, stats

    state2, ll, fracs, stats = step(state, telem)
    with host_staging():  # readbacks for assertions
        assert int(state2.step) == 1
        assert ll.shape == (2,) and np.isfinite(np.asarray(ll)).all()
        np.testing.assert_allclose(float(jnp.sum(fracs)), 1.0, atol=1e-5)
        assert float(stats.e_t) > 0


def test_online_learning_rebalances_functional():
    """The ISSUE's acceptance scenario through the pure API: a 4x-faster
    worker ends up with the bulk of the work."""
    rng = np.random.default_rng(0)
    state = sched.init(CFG, 2, jax.random.PRNGKey(0))
    for _ in range(6):
        state, _ = sched.observe(
            state, _telemetry(rng, state, [5.0, 20.0], n=32), CFG
        )
    fracs, _ = sched.propose(state, CFG)
    assert float(fracs[0]) > 0.6


def test_checkpoint_roundtrip_bit_exact(tmp_path):
    rng = np.random.default_rng(1)
    state = sched.init(CFG, 3, jax.random.PRNGKey(7))
    for _ in range(2):
        state, _ = sched.observe(
            state, _telemetry(rng, state, [4.0, 8.0, 16.0]), CFG
        )

    ckpt = CheckpointManager(str(tmp_path), async_write=False)
    ckpt.save(0, state)
    fresh = sched.init(CFG, 3, jax.random.PRNGKey(0))  # structure template
    restored, _ = ckpt.restore(fresh)

    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_checkpoint_shape_drift_raises(tmp_path):
    """A checkpoint written with the old fleet-global scalar ``ewma_count``
    must fail restore with ValueError (leaf shape drift), so the trainer's
    legacy fallback path — model-only restore, fresh scheduler beliefs —
    triggers instead of a silent wrong-shape restore crashing mid-run at the
    first eviction."""
    state = sched.init(CFG, 3, jax.random.PRNGKey(0))
    legacy = state._replace(ewma_count=jnp.zeros((), jnp.int32))
    ckpt = CheckpointManager(str(tmp_path), async_write=False)
    ckpt.save(0, legacy)
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(sched.init(CFG, 3, jax.random.PRNGKey(0)))


def test_restored_trajectory_matches_unrestored(tmp_path):
    """observe -> propose after restore reproduces the unrestored run."""
    rng = np.random.default_rng(2)
    state = sched.init(CFG, 2, jax.random.PRNGKey(3))
    state, _ = sched.observe(state, _telemetry(rng, state, [5.0, 20.0]), CFG)

    ckpt = CheckpointManager(str(tmp_path), async_write=False)
    ckpt.save(0, state)
    restored, _ = ckpt.restore(sched.init(CFG, 2, jax.random.PRNGKey(0)))

    telem = _telemetry(rng, state, [5.0, 20.0])
    s1, ll1 = sched.observe(state, telem, CFG)
    s2, ll2 = sched.observe(restored, telem, CFG)
    np.testing.assert_array_equal(np.asarray(ll1), np.asarray(ll2))
    f1, _ = sched.propose(s1, CFG)
    f2, _ = sched.propose(s2, CFG)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))


def test_vmap_multi_tenant_fleet():
    """One device program schedules several tenants at once."""
    tenants, k = 3, 2
    keys = jax.random.split(jax.random.PRNGKey(0), tenants)
    states = jax.vmap(lambda key: sched.init(CFG, k, key))(keys)
    assert states.gibbs.mu.shape == (tenants, k)

    rng = np.random.default_rng(0)
    fr = np.full((tenants, k, 8), 0.5, np.float32)
    t = np.abs(rng.normal(5.0, 0.5, (tenants, k, 8))).astype(np.float32)
    states, ll = jax.vmap(
        lambda s, tt, ff: sched.observe(s, sched.Telemetry(ff, tt), CFG)
    )(states, jnp.asarray(t), jnp.asarray(fr))
    assert ll.shape == (tenants, k)

    fracs, stats = jax.vmap(lambda s: sched.propose(s, CFG))(states)
    assert fracs.shape == (tenants, k)
    np.testing.assert_allclose(np.asarray(fracs).sum(axis=-1), 1.0, atol=1e-5)
    assert np.isfinite(np.asarray(stats.e_t)).all()


def test_observe_pallas_matches_reference_path():
    """Acceptance: ``observe`` through the fused Pallas kernel (interpret mode
    on CPU) reproduces the reference-path posteriors to <= 1e-4 — same PRNG
    streams, numerically matching grid posteriors, one launch per sweep."""
    cfg_pal = dataclasses.replace(CFG, use_pallas=True)
    cfg_ref = dataclasses.replace(CFG, use_pallas=False)
    state = sched.init(CFG, 3, jax.random.PRNGKey(11))
    rng = np.random.default_rng(4)
    telem = _telemetry(rng, state, [4.0, 10.0, 25.0], n=24)

    s_pal, ll_pal = sched.observe(state, telem, cfg_pal)
    s_ref, ll_ref = sched.observe(state, telem, cfg_ref)

    mean = lambda p: np.asarray(p.a / (p.a + p.b))
    np.testing.assert_allclose(
        mean(s_pal.gibbs.alpha_prior), mean(s_ref.gibbs.alpha_prior),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(
        mean(s_pal.gibbs.beta_prior), mean(s_ref.gibbs.beta_prior),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(s_pal.gibbs.ng.mu0), np.asarray(s_ref.gibbs.ng.mu0),
        rtol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(ll_pal), np.asarray(ll_ref), rtol=1e-3, atol=1e-2
    )


def test_config_use_pallas_auto_resolves():
    """use_pallas=None (auto) resolves by backend and still observes fine."""
    from repro.kernels.ops import use_pallas_default

    assert CFG.use_pallas is None
    assert isinstance(use_pallas_default(), bool)
    state = sched.init(CFG, 2, jax.random.PRNGKey(0))
    telem = _telemetry(np.random.default_rng(1), state, [5.0, 20.0])
    state, ll = sched.observe(state, telem, CFG)
    assert np.isfinite(np.asarray(ll)).all()


def test_anomaly_flags_degraded_worker():
    rng = np.random.default_rng(3)
    state = sched.init(CFG, 4, jax.random.PRNGKey(1))
    for _ in range(3):
        fr = np.full((4, 16), 0.25, np.float32)
        t = np.abs(rng.normal(5.0, 0.3, (4, 16))).astype(np.float32)
        state, _ = sched.observe(
            state, sched.Telemetry(jnp.asarray(fr), jnp.asarray(t)), CFG
        )
    # worker 2 suddenly runs 6x slower than its learned model
    for _ in range(4):
        times = np.abs(rng.normal(5.0, 0.3, 4))
        times[2] *= 6.0
        state, scores = sched.anomaly(
            state,
            sched.Telemetry(jnp.full(4, 0.25), jnp.asarray(times)),
            CFG,
        )
    scores = np.asarray(scores)
    assert scores[2] == scores.max()
    assert bool(np.asarray(sched.flag_stragglers(state.ewma_ll, 2.0))[2])


def test_admitted_worker_ewma_seeds_at_first_score():
    """Regression: freshness is per worker.  A worker admitted AFTER the
    fleet's first anomaly update must have its EWMA initialized at its own
    first score — the old fleet-global ``ewma_count`` blended it with the
    zero placeholder, biasing fresh admits "healthy" and delaying straggler
    detection."""
    state = sched.init(CFG, 3, jax.random.PRNGKey(2))
    rng = np.random.default_rng(5)
    for _ in range(3):
        fr = np.full((3, 8), 1 / 3, np.float32)
        t = np.abs(rng.normal(5.0, 0.3, (3, 8))).astype(np.float32)
        state, _ = sched.observe(
            state, sched.Telemetry(jnp.asarray(fr), jnp.asarray(t)), CFG
        )
    state, _ = sched.anomaly(
        state, sched.Telemetry(jnp.full(3, 1 / 3), jnp.full(3, 5.0)), CFG
    )
    assert np.asarray(state.ewma_count).shape == (3,)

    state = sched.add_workers(state, 1, CFG)
    assert int(state.ewma_count[3]) == 0  # fresh admit

    # the admit runs 10x slower than the incumbent fleet's behaviour
    times = jnp.asarray([5.0, 5.0, 5.0, 50.0])
    state, scores = sched.anomaly(
        state, sched.Telemetry(jnp.full(4, 0.25), times), CFG
    )
    # EWMA == raw first score for the admit (no zero-blend): recompute it
    p = sched.unit_params(state)
    from repro.core.posterior import posterior_predictive_logpdf

    raw = -float(
        posterior_predictive_logpdf(
            times[3], jnp.asarray(0.25), p.mu[3],
            1.0 / jnp.maximum(p.sigma[3] ** 2, 1e-30), p.alpha[3], p.beta[3],
        )
    )
    np.testing.assert_allclose(float(scores[3]), raw, rtol=1e-5)
    # and the straggling admit is flaggable immediately, not EWMA-lagged
    assert bool(np.asarray(sched.flag_stragglers(state.ewma_ll, 2.0))[3])


def test_anomaly_valid_mask_freezes_failed_worker():
    """Invalid telemetry (hard failures) must leave both the EWMA and the
    freshness counter of the failed worker untouched."""
    state = sched.init(CFG, 3, jax.random.PRNGKey(4))
    rng = np.random.default_rng(6)
    for _ in range(2):
        fr = np.full((3, 8), 1 / 3, np.float32)
        t = np.abs(rng.normal(5.0, 0.3, (3, 8))).astype(np.float32)
        state, _ = sched.observe(
            state, sched.Telemetry(jnp.asarray(fr), jnp.asarray(t)), CFG
        )
    state, _ = sched.anomaly(
        state, sched.Telemetry(jnp.full(3, 1 / 3), jnp.full(3, 5.0)), CFG
    )
    before_ewma = np.asarray(state.ewma_ll).copy()
    before_count = np.asarray(state.ewma_count).copy()

    times = jnp.asarray([5.0, np.inf, 5.0])
    valid = jnp.asarray([True, False, True])
    state, scores = sched.anomaly(
        state, sched.Telemetry(jnp.full(3, 1 / 3), times), CFG, valid
    )
    assert np.isfinite(np.asarray(scores)).all()
    np.testing.assert_array_equal(float(state.ewma_ll[1]), before_ewma[1])
    assert int(state.ewma_count[1]) == int(before_count[1])
    assert int(state.ewma_count[0]) == int(before_count[0]) + 1

    # a per-worker (K,) mask also applies to a batched (K, N) telemetry
    tb = jnp.stack([jnp.full(4, 5.0), jnp.full(4, jnp.inf), jnp.full(4, 5.0)])
    frozen = float(state.ewma_ll[1])
    state, scores = sched.anomaly(
        state, sched.Telemetry(jnp.full((3, 4), 1 / 3), tb), CFG, valid
    )
    assert np.isfinite(np.asarray(scores)).all()
    np.testing.assert_array_equal(float(state.ewma_ll[1]), frozen)


def test_flag_stragglers_valid_mask_excludes_dead_from_baseline():
    """A dead worker's huge stale score must not inflate the median/MAD the
    live fleet is judged against, and the dead worker is never flagged."""
    scores = jnp.asarray([1.0, 1.1, 0.9, 1.05, 500.0, 500.0])
    valid = jnp.asarray([True, True, True, True, False, False])
    flags = np.asarray(sched.flag_stragglers(scores, 3.0, valid))
    assert not flags[4:].any()
    assert not flags[:4].any()
    # two dead workers drag the unmasked median/MAD so far that a genuine
    # live straggler (2.5 vs a ~1.0 pack) escapes; the mask restores detection
    scores2 = jnp.asarray([1.0, 1.1, 0.9, 2.5, 500.0, 500.0])
    assert not np.asarray(sched.flag_stragglers(scores2, 3.0))[3]
    assert np.asarray(sched.flag_stragglers(scores2, 3.0, valid))[3]


def test_elastic_membership_pure():
    state = sched.init(CFG, 4, jax.random.PRNGKey(0))
    state = sched.remove_workers(state, np.array([False, True, False, False]))
    assert sched.num_workers(state) == 3
    assert state.gibbs.mu.shape == (3,)
    state = sched.add_workers(state, 2, CFG)
    assert sched.num_workers(state) == 5
    fracs, _ = sched.propose(state, CFG)
    assert fracs.shape == (5,)
    np.testing.assert_allclose(float(jnp.sum(fracs)), 1.0, atol=1e-5)


def test_objective_plumbing():
    """One Objective value drives the simplex solver consistently."""
    p = UnitParams.of([30.0, 20.0], [2.0, 6.0])
    f_m, st_m = sched.solve_fractions(p, objective=sched.Objective.mean())
    f_r, st_r = sched.solve_fractions(
        p, objective=sched.Objective.mean_var(2.0)
    )
    assert float(st_r.var) <= float(st_m.var) + 1e-6
    assert float(st_r.e_t) >= float(st_m.e_t) - 1e-6

    budget = float(st_m.var) * 0.5
    f_b, st_b = sched.solve_fractions(
        p, objective=sched.Objective.variance_budget(budget)
    )
    assert float(st_b.var) <= budget + 1e-4

    f_d, st_d = sched.solve_fractions(
        p, objective=sched.Objective.deadline_quantile(1.2 * float(st_m.e_t))
    )
    p_meet = -float(st_d.score)
    assert 0.0 <= p_meet <= 1.0 + 1e-6
    np.testing.assert_allclose(float(jnp.sum(f_d)), 1.0, atol=1e-5)


def test_scheduler_shell_delegates():
    """The imperative shell is a view over the pure core."""
    sh = sched.Scheduler(2, config=CFG, seed=0)
    rng = np.random.default_rng(0)
    telem = _telemetry(rng, sh.state, [5.0, 20.0])
    sh.observe(telem)
    assert int(sh.state.step) == 1
    fr, e_t, var = sh.propose_fractions()
    np.testing.assert_allclose(fr.sum(), 1.0, atol=1e-5)
    counts = sh.propose_microbatches(8)
    assert counts.sum() == 8
    # swapping the objective never touches the beliefs
    step_before = int(sh.state.step)
    sh.objective = sched.Objective.mean_var(3.0)
    assert int(sh.state.step) == step_before
