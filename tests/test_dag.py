"""Stage-structured workflow DAGs: stacked (S, K, N) estimation, serial /
parallel composition of completion moments, and stage-wise partitioning."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched
from repro.core import frontier, gibbs
from repro.core.frontier import UnitParams

S, K, N = 3, 4, 48
CFG = sched.SchedulerConfig(n_iters=6, grid_size=64, mu_guess=15.0, opt_steps=60)


def _pipeline_telemetry(seed=0, n=N, true_mu=None):
    """Synthetic (S, K, N) telemetry for a 3-stage x 4-worker pipeline."""
    rng = np.random.default_rng(seed)
    if true_mu is None:
        true_mu = rng.uniform(5.0, 30.0, (S, K)).astype(np.float32)
    f = rng.uniform(0.05, 0.95, (S, K, n)).astype(np.float32)
    t = np.maximum(
        f**0.9 * true_mu[..., None] + 0.3 * rng.normal(size=(S, K, n)), 1e-3
    ).astype(np.float32)
    return jnp.asarray(t), jnp.asarray(f), true_mu


# --------------------------------------------------------------------------
# topology
# --------------------------------------------------------------------------
def test_dag_validates_topological_numbering():
    with pytest.raises(ValueError):
        sched.WorkflowDAG(preds=((1,), ()), num_workers=2)  # pred >= index
    with pytest.raises(ValueError):
        sched.WorkflowDAG(preds=((0,), ()), num_workers=2)  # self-loop
    chain = sched.WorkflowDAG.chain(4, 3)
    assert chain.num_stages == 4 and chain.is_chain and chain.sinks == (3,)


def test_dag_from_edges_diamond():
    #     1
    #   /   \
    #  0     3
    #   \   /
    #     2
    dag = sched.WorkflowDAG.from_edges(
        4, ((0, 1), (0, 2), (1, 3), (2, 3)), num_workers=2
    )
    assert dag.preds == ((), (0,), (0,), (1, 2))
    assert not dag.is_chain
    assert dag.sinks == (3,)
    assert dag.succs(0) == (1, 2)


def test_critical_path_lengths():
    dag = sched.WorkflowDAG.from_edges(
        4, ((0, 1), (0, 2), (1, 3), (2, 3)), num_workers=2
    )
    means = jnp.asarray([1.0, 5.0, 2.0, 1.0])
    through, crit = sched.path_lengths(dag, means)
    np.testing.assert_allclose(np.asarray(through), [7.0, 7.0, 4.0, 7.0])
    assert float(crit) == 7.0


# --------------------------------------------------------------------------
# stacked estimation
# --------------------------------------------------------------------------
def test_stacked_estimation_matches_per_stage_calls():
    """ISSUE acceptance: one stacked (S*K)-fleet gibbs_batch bitwise-matches
    S independent per-stage gibbs_batch calls on the corresponding state
    slices — folding the stage axis into the fleet axis changes nothing."""
    t, f, _ = _pipeline_telemetry(seed=1)
    keys = jax.random.split(jax.random.PRNGKey(5), S * K)
    init_flat = jax.vmap(gibbs.init_state)(keys)

    stacked, ll_stacked = gibbs.gibbs_batch(
        init_flat, t.reshape(S * K, N), f.reshape(S * K, N),
        n_iters=5, grid_size=64,
    )
    for si in range(S):
        sl = slice(si * K, (si + 1) * K)
        ref, ll_ref = gibbs.gibbs_batch(
            jax.tree_util.tree_map(lambda x: x[sl], init_flat),
            t[si], f[si], n_iters=5, grid_size=64,
        )
        got = jax.tree_util.tree_map(lambda x: x[sl], stacked)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(ll_stacked[sl]), np.asarray(ll_ref))


def test_fit_dag_recovers_stage_parameters():
    """One fit_dag call (no Python loop over stages) estimates the whole
    3-stage x 4-worker pipeline."""
    t, f, true_mu = _pipeline_telemetry(seed=2, n=96)
    states, ll = gibbs.fit_dag(jax.random.PRNGKey(0), t, f, n_iters=8, grid_size=96)
    assert states.mu.shape == (S, K)
    assert ll.shape == (S, K)
    # posterior means land near the true per-stage-per-worker speeds
    np.testing.assert_allclose(np.asarray(states.ng.mu0), true_mu, rtol=0.25)


def test_fit_dag_matches_fit_fleet_on_folded_axes():
    """fit_dag == fit_fleet on the stage-folded telemetry (same key): the
    stacked program IS the fleet program."""
    t, f, _ = _pipeline_telemetry(seed=3)
    key = jax.random.PRNGKey(9)
    st_dag, ll_dag = gibbs.fit_dag(key, t, f, n_iters=5, grid_size=64)
    st_fleet, ll_fleet = gibbs.fit_fleet(
        key, t.reshape(S * K, N), f.reshape(S * K, N), n_iters=5, grid_size=64
    )
    for a, b in zip(jax.tree_util.tree_leaves(st_dag),
                    jax.tree_util.tree_leaves(st_fleet)):
        np.testing.assert_array_equal(
            np.asarray(a).reshape(np.asarray(b).shape), np.asarray(b)
        )
    np.testing.assert_array_equal(
        np.asarray(ll_dag).reshape(-1), np.asarray(ll_fleet)
    )


def test_fit_dag_pallas_parity():
    """Acceptance: the stacked program through the fused kernel (interpret
    mode on CPU) matches the oracle path to <= 1e-4."""
    t, f, _ = _pipeline_telemetry(seed=4)
    key = jax.random.PRNGKey(2)
    st_ref, _ = gibbs.fit_dag(key, t, f, n_iters=5, grid_size=64, use_pallas=False)
    st_pal, _ = gibbs.fit_dag(key, t, f, n_iters=5, grid_size=64, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(st_ref.ng.mu0), np.asarray(st_pal.ng.mu0), rtol=1e-4, atol=1e-4
    )
    mean = lambda p: np.asarray(p.a / (p.a + p.b))
    np.testing.assert_allclose(
        mean(st_ref.alpha_prior), mean(st_pal.alpha_prior), rtol=1e-4, atol=1e-4
    )


def test_observe_dag_jits_and_advances():
    t, f, _ = _pipeline_telemetry(seed=5)
    dag = sched.WorkflowDAG.chain(S, K)
    state = sched.init_dag(CFG, dag, jax.random.PRNGKey(1))
    assert state.gibbs.mu.shape == (S, K)

    @jax.jit
    def step(st, telem):
        return sched.observe_dag(st, telem, CFG)

    state2, ll = step(state, sched.Telemetry(fracs=f, times=t))
    assert int(state2.step) == 1
    assert ll.shape == (S, K) and bool(jnp.all(jnp.isfinite(ll)))


# --------------------------------------------------------------------------
# composition
# --------------------------------------------------------------------------
def test_chain_moments_match_monte_carlo():
    """ISSUE acceptance: chain-composed (E, Var) matches Monte-Carlo of
    summed stage makespans to <= 1e-2 relative."""
    rng = np.random.default_rng(7)
    params = UnitParams.of(
        rng.uniform(8.0, 25.0, (S, K)).astype(np.float32),
        rng.uniform(0.5, 2.0, (S, K)).astype(np.float32),
    )
    fracs = jnp.full((S, K), 1.0 / K, jnp.float32)
    stage_e, stage_v = jax.vmap(
        lambda fr, p: frontier.mean_var_completion(fr, p, 2048)
    )(fracs, params)
    e_chain, v_chain = frontier.serial_moments(stage_e, stage_v)

    n_mc = 400_000
    total = np.zeros(n_mc)
    for si in range(S):
        mean, std = frontier.component_mean_std(fracs[si], jax.tree_util.tree_map(lambda x: x[si], params))
        draws = rng.normal(
            np.asarray(mean), np.asarray(std), size=(n_mc, K)
        )
        total += draws.max(axis=1)
    np.testing.assert_allclose(float(e_chain), total.mean(), rtol=1e-2)
    np.testing.assert_allclose(float(v_chain), total.var(), rtol=5e-2)


def test_parallel_max_moments_match_monte_carlo():
    rng = np.random.default_rng(8)
    means = jnp.asarray([10.0, 12.0, 9.0])
    variances = jnp.asarray([4.0, 1.0, 9.0])
    e_q, v_q = frontier.parallel_max_moments(means, variances, 2048)
    draws = rng.normal(
        np.asarray(means), np.sqrt(np.asarray(variances)), size=(400_000, 3)
    ).max(axis=1)
    np.testing.assert_allclose(float(e_q), draws.mean(), rtol=1e-2)
    np.testing.assert_allclose(float(v_q), draws.var(), rtol=5e-2)


def test_dag_moments_chain_reduces_to_serial_sum():
    preds = sched.WorkflowDAG.chain(S, K).preds
    stage_e = jnp.asarray([3.0, 5.0, 2.0])
    stage_v = jnp.asarray([0.5, 0.2, 0.1])
    e_dag, v_dag = frontier.dag_completion_moments(preds, stage_e, stage_v)
    np.testing.assert_allclose(float(e_dag), 10.0, rtol=1e-6)
    np.testing.assert_allclose(float(v_dag), 0.8, rtol=1e-6)


def test_dag_moments_diamond_matches_monte_carlo():
    """Fork/join: end-to-end = t0 + max(t1, t2) + t3 (PERT independence)."""
    dag = sched.WorkflowDAG.from_edges(
        4, ((0, 1), (0, 2), (1, 3), (2, 3)), num_workers=2
    )
    stage_e = jnp.asarray([4.0, 6.0, 5.0, 3.0])
    stage_v = jnp.asarray([0.4, 1.0, 2.0, 0.3])
    e_dag, v_dag = frontier.dag_completion_moments(dag.preds, stage_e, stage_v, num_points=2048)
    rng = np.random.default_rng(9)
    n_mc = 400_000
    t_s = rng.normal(
        np.asarray(stage_e), np.sqrt(np.asarray(stage_v)), size=(n_mc, 4)
    )
    # exact fork/join: branches share t0 (positively correlated)
    total = t_s[:, 0] + np.maximum(t_s[:, 1], t_s[:, 2]) + t_s[:, 3]
    np.testing.assert_allclose(float(e_dag), total.mean(), rtol=1e-2)
    # the reduction's own model: branch finishes treated independent (PERT)
    fin1 = rng.normal(float(stage_e[0] + stage_e[1]),
                      float(jnp.sqrt(stage_v[0] + stage_v[1])), n_mc)
    fin2 = rng.normal(float(stage_e[0] + stage_e[2]),
                      float(jnp.sqrt(stage_v[0] + stage_v[2])), n_mc)
    pert = np.maximum(fin1, fin2) + t_s[:, 3]
    np.testing.assert_allclose(float(e_dag), pert.mean(), rtol=1e-2)
    np.testing.assert_allclose(float(v_dag), pert.var(), rtol=5e-2)
    # PERT independence errs conservative on the mean vs the correlated truth
    assert float(e_dag) >= total.mean() - 0.05


# --------------------------------------------------------------------------
# partitioning
# --------------------------------------------------------------------------
def test_propose_dag_beats_uniform_end_to_end():
    """ISSUE acceptance: stage-wise Bayesian splits achieve lower expected
    end-to-end completion than uniform splits (evaluated at TRUE params)."""
    rng = np.random.default_rng(10)
    true_mu = np.stack([  # heterogeneous: each stage has a 4x speed spread
        rng.permutation([4.0, 8.0, 16.0, 24.0]) for _ in range(S)
    ]).astype(np.float32)
    t, f, _ = _pipeline_telemetry(seed=10, n=96, true_mu=true_mu)
    dag = sched.WorkflowDAG.chain(S, K)
    state = sched.init_dag(CFG, dag, jax.random.PRNGKey(3))
    for _ in range(3):
        state, _ = sched.observe_dag(state, sched.Telemetry(fracs=f, times=t), CFG)

    fracs, stats = sched.propose_dag(state, dag, CFG)
    assert fracs.shape == (S, K)
    np.testing.assert_allclose(np.asarray(fracs.sum(-1)), 1.0, atol=1e-5)

    true_params = UnitParams.of(true_mu, np.full((S, K), 1.0, np.float32),
                                np.full((S, K), 0.9, np.float32),
                                np.full((S, K), 0.9, np.float32))
    e_bayes = sched.dag_stats(dag, fracs, true_params).e_t
    e_uni = sched.dag_stats(dag, sched.uniform_fractions(dag), true_params).e_t
    assert float(e_bayes) < float(e_uni)
    # each stage shifts work toward its faster workers
    for si in range(S):
        assert float(fracs[si, np.argmin(true_mu[si])]) > float(
            fracs[si, np.argmax(true_mu[si])]
        )


def test_propose_dag_var_budget_allocates_across_stages():
    """A feasible end-to-end variance budget is met by stage-wise allocation,
    paying expected time relative to the unconstrained optimum."""
    t, f, _ = _pipeline_telemetry(seed=11, n=96)
    dag = sched.WorkflowDAG.chain(S, K)
    state = sched.init_dag(CFG, dag, jax.random.PRNGKey(4))
    for _ in range(2):
        state, _ = sched.observe_dag(state, sched.Telemetry(fracs=f, times=t), CFG)

    _, st_mean = sched.propose_dag(state, dag, CFG)
    # min achievable variance: drive var_budget -> 0 (every stage clips)
    cfg0 = dataclasses.replace(
        CFG, objective=sched.Objective.variance_budget(1e-8)
    )
    _, st_min = sched.propose_dag(state, dag, cfg0)
    budget = 0.5 * (float(st_min.var) + float(st_mean.var))  # strictly feasible

    cfg_b = dataclasses.replace(
        CFG, objective=sched.Objective.variance_budget(budget)
    )
    fr_b, st_b = sched.propose_dag(state, dag, cfg_b)
    # donor/receiver slices sum to <= budget, so the composed variance meets
    # it up to quadrature error
    assert float(st_b.var) <= budget * 1.01
    assert float(st_b.e_t) >= float(st_mean.e_t) - 1e-5


def test_propose_dag_critical_path_spends_risk_where_it_hurts():
    """On a diamond, the long branch is critical: the critical-path-aware
    mean_var split tolerates more variance on the slack branch than the
    uniform-risk split does — risk budget goes where latency lives."""
    rng = np.random.default_rng(12)
    true_mu = np.stack([
        [5.0, 10.0], [40.0, 60.0], [4.0, 6.0], [5.0, 8.0]
    ]).astype(np.float32)  # stage 1 dominates; stage 2 is the slack branch
    dag = sched.WorkflowDAG.from_edges(
        4, ((0, 1), (0, 2), (1, 3), (2, 3)), num_workers=2
    )
    f = rng.uniform(0.05, 0.95, (4, 2, 96)).astype(np.float32)
    t = np.maximum(
        f**0.9 * true_mu[..., None] + 0.5 * rng.normal(size=(4, 2, 96)), 1e-3
    ).astype(np.float32)
    cfg = dataclasses.replace(CFG, objective=sched.Objective.mean_var(2.0))
    state = sched.init_dag(cfg, dag, jax.random.PRNGKey(6))
    for _ in range(2):
        state, _ = sched.observe_dag(
            state, sched.Telemetry(fracs=jnp.asarray(f), times=jnp.asarray(t)), cfg
        )

    _, st_cp = sched.propose_dag(state, dag, cfg, critical_path_aware=True)
    _, st_flat = sched.propose_dag(state, dag, cfg, critical_path_aware=False)
    # both meet the same API; the critical-path variant never pays MORE
    # end-to-end expected time to suppress slack-branch variance
    assert float(st_cp.e_t) <= float(st_flat.e_t) + 1e-3
    assert np.isfinite(float(st_cp.var)) and np.isfinite(float(st_flat.var))


def test_propose_dag_deadline_lower_bound_is_valid():
    """Per-stage deadline slices sum to <= d along every path, so the
    composed completion must meet the deadline at least as often as the
    per-stage product bound suggests (checked by Monte Carlo)."""
    t, f, true_mu = _pipeline_telemetry(seed=13, n=96)
    dag = sched.WorkflowDAG.chain(S, K)
    state = sched.init_dag(CFG, dag, jax.random.PRNGKey(8))
    for _ in range(2):
        state, _ = sched.observe_dag(state, sched.Telemetry(fracs=f, times=t), CFG)
    _, st_mean = sched.propose_dag(state, dag, CFG)

    deadline = 1.15 * float(st_mean.e_t)
    cfg_d = dataclasses.replace(
        CFG, objective=sched.Objective.deadline_quantile(deadline)
    )
    fr_d, st_d = sched.propose_dag(state, dag, cfg_d)
    np.testing.assert_allclose(np.asarray(fr_d.sum(-1)), 1.0, atol=1e-5)
    # score is -P(T <= d) under the Normal-matched composition: a probability
    assert -1.0 - 1e-6 <= float(st_d.score) <= 0.0


def test_kernel_reshape_shim_folds_stage_axes():
    """ops.posterior_grid_fleet accepts stacked (S, K, N) blocks and matches
    the unified oracle on every stage."""
    from repro.core.moments import BetaParams, exponent_grid, log_posterior_grid
    from repro.kernels import ops

    t, f, _ = _pipeline_telemetry(seed=14, n=32)
    rng = np.random.default_rng(14)
    mu = jnp.asarray(rng.uniform(5, 25, (S, K)).astype(np.float32))
    lam = jnp.asarray(rng.uniform(0.5, 2.0, (S, K)).astype(np.float32))
    alpha = jnp.full((S, K), 0.8, jnp.float32)
    beta = jnp.full((S, K), 0.7, jnp.float32)
    prior = BetaParams(jnp.full((S, K), 2.0), jnp.full((S, K), 2.0))
    grid = exponent_grid(64)

    out = ops.posterior_grid_fleet(grid, t, f, mu, lam, alpha, beta, prior, prior)
    assert out.shape == (S, K, 2, 64)
    oracle = log_posterior_grid(grid, t, f, mu, lam, alpha, beta, prior, prior)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=2e-4, atol=2e-4)
