"""Dry-run regression: one real cell compiles end-to-end in a subprocess
(the subprocess owns its own 512-device XLA_FLAGS; never set here)."""
import json
import pathlib
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    repo = pathlib.Path(__file__).resolve().parent.parent
    out = tmp_path / "dryrun"
    r = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "tinyllama-1.1b", "--shape", "decode_32k",
            "--mesh", "single", "--out", str(out), "--force",
        ],
        cwd=repo,
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"},
        capture_output=True, text=True, timeout=420,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    cell = json.loads((out / "tinyllama-1.1b__decode_32k__single.json").read_text())
    assert cell["chips"] == 256
    assert cell["full"]["memory"]["peak_bytes_est"] > 0
    rf = cell["roofline"]
    assert rf["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert rf["per_device"]["flops"] > 0
    # decode must be memory-bound with a single-digit-ms bound at this size
    assert rf["dominant"] == "memory_s"
    assert rf["roofline_bound_s"] < 0.05
