"""Algorithm 1: Gibbs sampling recovery + Fig 5 convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gibbs
from repro.core.posterior import log_likelihood


def _synth(key, n, mu, sigma, alpha, beta):
    kf, kt = jax.random.split(key)
    f = jax.random.uniform(kf, (n,), minval=0.05, maxval=0.95)
    t = f**alpha * mu + f**beta * sigma * jax.random.normal(kt, (n,))
    return f, t


def test_gibbs_recovers_parameters():
    mu, sigma, alpha, beta = 30.0, 2.0, 0.9, 0.8
    f, t = _synth(jax.random.PRNGKey(0), 512, mu, sigma, alpha, beta)
    state, lls = gibbs.fit(
        jax.random.PRNGKey(1), t, f, batch_size=64, n_iters=15, grid_size=256
    )
    assert abs(float(state.mu) - mu) < 1.5
    assert abs(float(state.sigma) - sigma) < 1.0
    assert abs(float(state.alpha) - alpha) < 0.08
    assert abs(float(state.beta) - beta) < 0.15


@pytest.mark.slow
def test_convergence_loglik():
    """Paper Fig 5: the log-likelihood under the running estimate increases
    with the number of observed batches (held-out evaluation).

    Marked slow (10-batch chained run): parameter recovery stays tier-1 via
    ``test_gibbs_recovers_parameters``; run with ``-m slow``."""
    mu, sigma, alpha, beta = 20.0, 3.0, 0.85, 0.7
    f, t = _synth(jax.random.PRNGKey(2), 640, mu, sigma, alpha, beta)
    f_ho, t_ho = _synth(jax.random.PRNGKey(3), 256, mu, sigma, alpha, beta)

    state = gibbs.init_state(jax.random.PRNGKey(4), mu_guess=float(t.mean() / f.mean()))
    ll_prior = float(
        log_likelihood(t_ho, f_ho, state.mu, state.lam, state.alpha, state.beta)
    )
    holdout = []
    for b in range(10):
        sl = slice(b * 16, (b + 1) * 16)
        state, _ = gibbs.gibbs_batch(state, t[sl], f[sl], n_iters=10, grid_size=128)
        holdout.append(
            float(log_likelihood(t_ho, f_ho, state.mu, state.lam, state.alpha, state.beta))
        )
    # data-informed estimates beat the prior sample decisively, and the tail
    # of the chain is no worse than the earliest batches (Fig 5 shape); exact
    # per-batch monotonicity is not expected of Gibbs SAMPLES.
    assert max(holdout) > ll_prior
    assert np.mean(holdout[-3:]) >= np.mean(holdout[:2]) - 5.0
    assert np.mean(holdout[-3:]) > ll_prior


def test_fleet_vmap_matches_single():
    """Vmapped fleet estimation must match per-worker estimation exactly
    (same keys, same data)."""
    keys = jax.random.PRNGKey(7)
    f1, t1 = _synth(jax.random.PRNGKey(8), 128, 25.0, 2.0, 0.9, 0.8)
    f2, t2 = _synth(jax.random.PRNGKey(9), 128, 10.0, 1.0, 0.8, 0.9)
    t = jnp.stack([t1, t2])
    f = jnp.stack([f1, f2])
    states, ll = gibbs.fit_fleet(keys, t, f, n_iters=8, grid_size=128)
    assert states.mu.shape == (2,)
    # ordering: worker 0 is the slow unit (mu 25 vs 10)
    assert float(states.mu[0]) > float(states.mu[1])
    assert jnp.all(jnp.isfinite(ll))


def test_discount_tracks_drift_fast():
    """Tier-1 drift coverage (the Fig-5-scale versions below are slow-marked):
    power-prior forgetting must move the estimate decisively when the
    system's speed changes mid-stream, with only a handful of small batches.
    Also pins the rho >= 1 identity (paper-exact chaining untouched)."""
    k = jax.random.PRNGKey(60)
    f1, t1 = _synth(k, 96, 30.0, 2.0, 0.9, 0.8)
    f2, t2 = _synth(jax.random.PRNGKey(61), 96, 10.0, 2.0, 0.9, 0.8)
    state = gibbs.init_state(jax.random.PRNGKey(62), mu_guess=30.0)
    assert gibbs.discount_state(state, 1.0) is state  # rho=1 is a no-op
    for b in range(3):
        sl = slice(b * 32, (b + 1) * 32)
        state = gibbs.discount_state(state, 0.7)
        state, _ = gibbs.gibbs_batch(state, t1[sl], f1[sl], n_iters=6, grid_size=64)
    mu_before = float(state.ng.mu0)
    for b in range(3):
        sl = slice(b * 32, (b + 1) * 32)
        state = gibbs.discount_state(state, 0.7)
        state, _ = gibbs.gibbs_batch(state, t2[sl], f2[sl], n_iters=6, grid_size=64)
    mu_after = float(state.ng.mu0)
    assert abs(mu_before - 30.0) < 5.0  # locked onto the first regime
    assert mu_after < mu_before - 8.0  # and moved decisively toward the new one


@pytest.mark.slow
def test_chained_priors_adapt_to_drift():
    """The paper's motivation: chaining posterior->prior tracks a system
    whose speed changes mid-stream.  The power-prior forgetting factor
    (beyond-paper, DESIGN.md §8) makes the adaptation decisive.

    Marked slow (20 chained gibbs_batch programs); run with ``-m slow``."""
    k = jax.random.PRNGKey(11)
    f1, t1 = _synth(k, 320, 30.0, 2.0, 0.9, 0.8)
    f2, t2 = _synth(jax.random.PRNGKey(12), 320, 10.0, 2.0, 0.9, 0.8)  # 3x faster now
    state = gibbs.init_state(jax.random.PRNGKey(13), mu_guess=30.0)
    for b in range(5):
        sl = slice(b * 64, (b + 1) * 64)
        state = gibbs.discount_state(state, 0.7)
        state, _ = gibbs.gibbs_batch(state, t1[sl], f1[sl], n_iters=10, grid_size=128)
    mu_before = float(state.mu)
    for b in range(5):
        sl = slice(b * 64, (b + 1) * 64)
        state = gibbs.discount_state(state, 0.7)
        state, _ = gibbs.gibbs_batch(state, t2[sl], f2[sl], n_iters=10, grid_size=128)
    mu_after = float(state.mu)
    assert abs(mu_before - 30.0) < 3.0
    assert mu_after < 16.0  # moved decisively toward the new regime

    # paper-exact chaining (rho=1) adapts too, just more slowly
    state2 = gibbs.init_state(jax.random.PRNGKey(13), mu_guess=30.0)
    for b in range(5):
        sl = slice(b * 64, (b + 1) * 64)
        state2, _ = gibbs.gibbs_batch(state2, t1[sl], f1[sl], n_iters=10, grid_size=128)
    for b in range(5):
        sl = slice(b * 64, (b + 1) * 64)
        state2, _ = gibbs.gibbs_batch(state2, t2[sl], f2[sl], n_iters=10, grid_size=128)
    assert float(state2.mu) < mu_before  # direction correct
    assert mu_after < float(state2.mu) + 1.0  # forgetting at least as fast


def test_fit_uses_tail_observations():
    """Regression: the legacy batch driver silently dropped the final
    n % batch_size observations; the scan driver pads + masks them instead,
    so every observation influences the posterior."""
    mu, sigma, alpha, beta = 25.0, 1.5, 0.9, 0.8
    f, t = _synth(jax.random.PRNGKey(30), 48, mu, sigma, alpha, beta)
    # Same head, wildly different tail: only the tail distinguishes the runs.
    t_fast = t.at[32:].set(t[32:] * 0.2)

    st_full, lls = gibbs.fit(
        jax.random.PRNGKey(31), t, f, batch_size=32, n_iters=10, grid_size=128
    )
    st_fast, _ = gibbs.fit(
        jax.random.PRNGKey(31), t_fast, f, batch_size=32, n_iters=10, grid_size=128
    )
    # ceil(48/32) = 2 batches — the tail is processed as its own masked batch
    assert lls.shape == (2,)
    # the tail's 5x-faster observations must pull the estimate down
    assert float(st_fast.ng.mu0) < float(st_full.ng.mu0) - 1.0


def test_fit_exact_multiple_unchanged_by_padding():
    """When N divides batch_size the scan driver adds no padding: the mask is
    all-ones and results stay finite and sane."""
    f, t = _synth(jax.random.PRNGKey(33), 128, 20.0, 2.0, 0.9, 0.8)
    state, lls = gibbs.fit(
        jax.random.PRNGKey(34), t, f, batch_size=32, n_iters=8, grid_size=128
    )
    assert lls.shape == (4,)
    assert np.isfinite(np.asarray(lls)).all()
    assert abs(float(state.mu) - 20.0) < 4.0


def test_fleet_native_matches_vmapped_chains():
    """The fleet-native gibbs_batch (one fused grid evaluation for all K
    workers) must reproduce vmap-of-single-unit chains bitwise: identical
    per-worker PRNG splits, identical math."""
    f1, t1 = _synth(jax.random.PRNGKey(40), 96, 25.0, 2.0, 0.9, 0.8)
    f2, t2 = _synth(jax.random.PRNGKey(41), 96, 10.0, 1.0, 0.8, 0.9)
    t = jnp.stack([t1, t2])
    f = jnp.stack([f1, f2])
    keys = jax.random.split(jax.random.PRNGKey(42), 2)
    states = jax.vmap(lambda k: gibbs.init_state(k, mu_guess=15.0))(keys)

    fleet, ll_fleet = gibbs.gibbs_batch(states, t, f, n_iters=6, grid_size=64)
    vmapped, ll_v = jax.vmap(
        lambda st, ti, fi: gibbs.gibbs_batch(st, ti, fi, n_iters=6, grid_size=64)
    )(states, t, f)

    for a, b in zip(jax.tree_util.tree_leaves(fleet), jax.tree_util.tree_leaves(vmapped)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ll_fleet), np.asarray(ll_v), rtol=1e-4, atol=1e-3)


def test_fit_composes_under_jit_and_vmap():
    """Regression: ``fit`` with the default mu_guess forced a float() host
    sync on a traced array, raising TracerConversionError under jit/vmap.
    The guess now stays a traced array (mirroring fit_fleet)."""
    f, t = _synth(jax.random.PRNGKey(50), 64, 12.0, 1.0, 0.9, 0.8)

    jit_fit = jax.jit(
        lambda key, tt, ff: gibbs.fit(
            key, tt, ff, batch_size=32, n_iters=4, grid_size=64
        )
    )
    state, lls = jit_fit(jax.random.PRNGKey(51), t, f)
    assert np.isfinite(np.asarray(lls)).all()
    # identical to the eager path — the fix changes tracing, not numerics
    state_e, lls_e = gibbs.fit(
        jax.random.PRNGKey(51), t, f, batch_size=32, n_iters=4, grid_size=64
    )
    np.testing.assert_allclose(
        np.asarray(lls), np.asarray(lls_e), rtol=1e-5, atol=1e-5
    )

    # vmap over independent telemetry streams compiles and runs too
    f2, t2 = _synth(jax.random.PRNGKey(52), 64, 25.0, 2.0, 0.8, 0.9)
    keys = jax.random.split(jax.random.PRNGKey(53), 2)
    states, _ = jax.vmap(
        lambda key, tt, ff: gibbs.fit(
            key, tt, ff, batch_size=32, n_iters=4, grid_size=64
        )
    )(keys, jnp.stack([t, t2]), jnp.stack([f, f2]))
    assert states.mu.shape == (2,)
    assert float(states.ng.mu0[1]) > float(states.ng.mu0[0])


def test_pallas_path_matches_ref_path():
    f, t = _synth(jax.random.PRNGKey(21), 256, 15.0, 1.0, 0.9, 0.8)
    s_ref, _ = gibbs.fit(jax.random.PRNGKey(22), t, f, batch_size=128,
                         n_iters=8, grid_size=128, use_pallas=False)
    s_pal, _ = gibbs.fit(jax.random.PRNGKey(22), t, f, batch_size=128,
                         n_iters=8, grid_size=128, use_pallas=True)
    # same PRNG keys + numerically equal grid evals -> same chain
    np.testing.assert_allclose(float(s_ref.mu), float(s_pal.mu), rtol=1e-3)
    np.testing.assert_allclose(float(s_ref.alpha), float(s_pal.alpha), rtol=1e-2, atol=1e-2)
