"""reprolint: fixture expectations per rule, historical regressions, the
suppression/baseline machinery, the layer map, and a whole-repo smoke run.

Every rule ships a true-positive (``tp.py``) and false-positive (``fp.py``)
fixture under ``tools/reprolint/testdata/<rule>/``; this module asserts the
TP is flagged by exactly that rule and the FP produces *zero* findings, so
both the detection and the precision of each rule are pinned.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))

from tools.reprolint import toml_compat  # noqa: E402
from tools.reprolint.engine import (  # noqa: E402
    Finding,
    Linter,
    apply_baseline,
    load_baseline,
    write_baseline,
)
from tools.reprolint.layers import LayerMap  # noqa: E402
from tools.reprolint.rules import all_rules  # noqa: E402

TESTDATA = ROOT / "tools" / "reprolint" / "testdata"
RULE_IDS = ("rl001", "rl002", "rl003", "rl004", "rl005", "rl006", "rl007")

# RL005 keys on the module's repo path, so its fixtures are linted under
# synthetic in-tree paths rather than their on-disk testdata location.
_SYNTHETIC_PATHS = {
    ("rl005", "tp"): "src/repro/core/bad_upward.py",
    ("rl005", "fp"): "src/repro/serve/good_imports.py",
}


@pytest.fixture(scope="module")
def linter():
    return Linter(all_rules(), repo_root=ROOT)


def _lint_fixture(linter, rule, kind):
    path = TESTDATA / rule / f"{kind}.py"
    lint_path = _SYNTHETIC_PATHS.get((rule, kind), str(path))
    return linter.lint_source(path.read_text(), lint_path)


# ----------------------------------------------------------- per-rule fixtures
@pytest.mark.parametrize("rule", RULE_IDS)
def test_true_positive_fixture_is_flagged(linter, rule):
    findings = _lint_fixture(linter, rule, "tp")
    hits = [f for f in findings if f.rule == rule.upper()]
    assert hits, f"{rule}/tp.py: expected {rule.upper()} findings, got none"
    # the fixture marks each expected finding with an `# RL00x:` comment
    source = (TESTDATA / rule / "tp.py").read_text()
    assert rule.upper() + ":" in source  # fixture documents what it expects


@pytest.mark.parametrize("rule", RULE_IDS)
def test_false_positive_fixture_is_clean(linter, rule):
    findings = _lint_fixture(linter, rule, "fp")
    assert findings == [], (
        f"{rule}/fp.py must lint clean, got: "
        + "; ".join(f.format_text() for f in findings)
    )


# ------------------------------------------------------ historical regressions
def test_pr4_float_mu_guess_regression_is_flagged(linter):
    """PR 4 shipped ``float(mu_guess)`` on a traced mean inside ``fit``;
    RL001 must catch that shape of bug forever."""
    path = TESTDATA / "regressions" / "pr4_float_mu_guess.py"
    findings = linter.lint_source(path.read_text(), str(path))
    assert any(
        f.rule == "RL001" and "float" in f.snippet for f in findings
    ), findings


def test_pr7_cond_dtype_regression_is_flagged(linter):
    """PR 7 hit a ``lax.cond`` whose hold branch returned a different dtype
    than the refit branch; RL003 must catch structural branch drift."""
    path = TESTDATA / "regressions" / "pr7_cond_dtype.py"
    findings = linter.lint_source(path.read_text(), str(path))
    assert any(f.rule == "RL003" for f in findings), findings


# ------------------------------------------------------------ taint precision
def test_static_config_through_call_graph_stays_clean(linter):
    """Call-site-aware taint: a helper reached via the call graph whose
    branching argument is jit-static at the call site must not trip RL007
    (this is the service/gibbs `config` threading pattern)."""
    src = (
        "import functools\n"
        "import jax\n"
        "\n"
        "def _body(x, config):\n"
        "    if config.use_fast_path:\n"
        "        return x * 2.0\n"
        "    return x + x\n"
        "\n"
        "@functools.partial(jax.jit, static_argnames=('config',))\n"
        "def tick(x, config):\n"
        "    return _body(x, config)\n"
    )
    assert linter.lint_source(src, "src/repro/serve/example.py") == []


def test_traced_value_through_call_graph_is_still_flagged(linter):
    """...but the same helper branching on a value that IS traced at the
    call site must still be flagged."""
    src = (
        "import jax\n"
        "\n"
        "def _body(x, gate):\n"
        "    if gate:\n"
        "        return x * 2.0\n"
        "    return x + x\n"
        "\n"
        "@jax.jit\n"
        "def tick(x):\n"
        "    return _body(x, x.sum() > 0)\n"
    )
    findings = linter.lint_source(src, "src/repro/serve/example.py")
    assert any(f.rule == "RL007" for f in findings), findings


# --------------------------------------------------------------- suppressions
_SUPPRESSED_SRC = (
    "import jax\n"
    "\n"
    "@jax.jit\n"
    "def f(x):\n"
    "    return float(x)  # reprolint: disable=RL001 -- {why}\n"
)


def test_justified_suppression_silences_the_finding(linter):
    src = _SUPPRESSED_SRC.format(why="fixture: documented exception")
    assert linter.lint_source(src, "x.py") == []


def test_bare_suppression_raises_meta_finding(linter):
    src = _SUPPRESSED_SRC.replace(" -- {why}", "")
    findings = linter.lint_source(src, "x.py")
    assert [f.rule for f in findings] == ["RL000"]
    assert "justification" in findings[0].message


def test_directive_inside_string_literal_is_not_a_directive(linter):
    src = 'HELP = "# reprolint: disable=RL001 -- example syntax"\n'
    assert linter.lint_source(src, "x.py") == []


def test_unused_suppression_raises_meta_finding(linter):
    src = "x = 1  # reprolint: disable=RL001 -- nothing here needs it\n"
    findings = linter.lint_source(src, "x.py")
    assert [f.rule for f in findings] == ["RL000"]
    assert "unused suppression" in findings[0].message


# -------------------------------------------------------------------- baseline
def _finding(line=3):
    return Finding(
        rule="RL001", path="src/x.py", line=line, col=4,
        message="m", snippet="y = float(x)",
    )


def test_fingerprint_is_line_number_insensitive():
    assert _finding(line=3).fingerprint == _finding(line=99).fingerprint


def test_baseline_roundtrip_filters_and_reports_stale(tmp_path):
    known, new = _finding(), Finding(
        rule="RL006", path="src/y.py", line=8, col=0,
        message="m", snippet="a = jax.random.normal(key, ())",
    )
    baseline_path = tmp_path / "baseline.json"
    write_baseline(baseline_path, [known])
    baseline = load_baseline(baseline_path)

    kept, stale = apply_baseline([known, new], baseline)
    assert kept == [new] and stale == []

    kept, stale = apply_baseline([new], baseline)  # known finding fixed
    assert kept == [new]
    assert [e["fingerprint"] for e in stale] == [known.fingerprint]


def test_baseline_rejects_unknown_version(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"version": 2, "entries": []}))
    with pytest.raises(ValueError, match="version"):
        load_baseline(p)


# ------------------------------------------------------------------- layer map
def test_layer_map_flags_upward_and_allows_downward():
    layer_map = LayerMap.load()
    up = layer_map.violation("repro.core.partitioner", "repro.sched.scheduler")
    assert up is not None and "upward import" in up
    assert layer_map.violation("repro.sched.compat", "repro.core.frontier") is None
    assert layer_map.violation("repro.serve.service", "repro.hier.pool") is None


def test_importing_core_does_not_import_sched():
    """The RL005 fix in the flesh: the legacy partitioner wrapper moved to
    `repro.sched.compat`, so importing the core layer must no longer pull
    the sched layer into the process (the PEP 562 shim defers it)."""
    proc = subprocess.run(
        [sys.executable, "-c",
         "import sys, repro.core; "
         "bad = [m for m in sys.modules if m.startswith('repro.sched')]; "
         "sys.exit(1 if bad else 0)"],
        cwd=ROOT, env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=120,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_layer_doc_section_in_sync():
    """docs/architecture.md's generated table must match layers.toml —
    regenerate with `python -m tools.reprolint --sync-layer-docs`."""
    assert LayerMap.load().sync_doc(ROOT / "docs" / "architecture.md", write=False)


def test_toml_subset_parser_matches_tomllib():
    text = (ROOT / "tools" / "reprolint" / "layers.toml").read_text()
    subset = toml_compat.parse_subset(text)
    tomllib = pytest.importorskip("tomllib")
    assert subset == tomllib.loads(text)


# ------------------------------------------------------------------ smoke gate
def test_shipped_tree_lints_clean():
    """The acceptance gate: reprolint over the shipped tree exits 0 with no
    baseline.  A finding here means a new invariant violation landed."""
    proc = subprocess.run(
        [sys.executable, "-m", "tools.reprolint",
         "src", "tests", "benchmarks", "--format=json"],
        cwd=ROOT, capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    assert report["findings"] == [] and report["checked_files"] > 50
