"""Training runtime: checkpointing, data pipeline, compression, sharding rules."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataIterator
from repro.distributed.compression import init_error_feedback, make_compressor


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    mgr.save(5, tree, {"step": 5, "note": "x"})
    restored, extra = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10.0))
    assert extra["step"] == 5


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"w": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, {"step": s})
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_ignores_partial_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    tree = {"w": jnp.zeros(4)}
    mgr.save(1, tree, {"step": 1})
    # simulate a crash mid-write
    bad = tmp_path / "step_00000002.tmp"
    bad.mkdir()
    (bad / "arr_00000.npy").write_bytes(b"garbage")
    mgr2 = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    assert mgr2.latest_step() == 1
    assert not bad.exists()  # purged


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1, async_write=True)
    tree = {"w": jnp.full((8,), 7.0)}
    mgr.save(3, tree, {"step": 3})
    mgr.wait()
    restored, _ = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.full(8, 7.0))


def test_checkpoint_manifest_records_keypaths(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(4.0), "b": {"c": jnp.ones(2)}}
    mgr.save(1, tree, {"step": 1})
    manifest = json.loads(
        (tmp_path / "step_00000001" / "manifest.json").read_text()
    )
    assert manifest["keypaths"] == ["['a']", "['b']['c']"]


def test_restore_by_name_subset_on_shape_drift(tmp_path):
    """A drifted leaf keeps its template value; matching leaves restore by
    name even though positional order shifted — and the report says which."""
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    saved = {
        "params": {"w": jnp.full((3,), 7.0)},
        "sched": {"ewma_count": jnp.zeros((), jnp.int32)},  # legacy scalar
    }
    mgr.save(1, saved, {"step": 1})
    template = {
        "params": {"w": jnp.zeros((3,))},
        "sched": {"ewma_count": jnp.ones((2,), jnp.int32)},  # now per-worker
    }
    tree, extra, report = mgr.restore_by_name(template)
    np.testing.assert_array_equal(np.asarray(tree["params"]["w"]), np.full(3, 7.0))
    np.testing.assert_array_equal(  # template kept, not the drifted scalar
        np.asarray(tree["sched"]["ewma_count"]), np.ones(2)
    )
    assert report["restored"] == ["['params']['w']"]
    assert report["skipped"] == ["['sched']['ewma_count']"]
    assert extra["step"] == 1
    # positional restore must refuse the same checkpoint (shape mismatch)
    with pytest.raises(ValueError):
        mgr.restore(template)


def test_restore_by_name_rejects_dtype_drift_and_prekeypath(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    mgr.save(1, {"x": jnp.arange(4, dtype=jnp.int32)}, {"step": 1})
    tree, _, report = mgr.restore_by_name({"x": jnp.zeros(4, jnp.float32)})
    assert report["skipped"] == ["['x']"]  # same shape, wrong dtype
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.zeros(4))
    # pre-keypath checkpoints are explicit: positional restore only
    mpath = tmp_path / "step_00000001" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    del manifest["keypaths"]
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="predates key-path"):
        mgr.restore_by_name({"x": jnp.zeros(4, jnp.int32)})


def test_data_iterator_deterministic_and_resumable():
    it1 = DataIterator(vocab_size=100, seq_len=16, global_batch=8,
                       num_microbatches=2, seed=3)
    b1 = next(it1)
    state = it1.state_dict()
    b2 = next(it1)

    it2 = DataIterator(vocab_size=100, seq_len=16, global_batch=8,
                       num_microbatches=2, seed=3)
    next(it2)
    it2.load_state_dict(json.loads(json.dumps(state)))  # survives JSON
    b2b = next(it2)
    np.testing.assert_array_equal(b2["tokens"], b2b["tokens"])
    assert b1["tokens"].shape == (2, 4, 16)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 100).all()
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_data_iterator_shards_disjoint():
    a = DataIterator(vocab_size=50, seq_len=8, global_batch=8,
                     num_microbatches=2, seed=1, shard_index=0, shard_count=2)
    b = DataIterator(vocab_size=50, seq_len=8, global_batch=8,
                     num_microbatches=2, seed=1, shard_index=1, shard_count=2)
    ba, bb = next(a), next(b)
    assert ba["tokens"].shape == (2, 2, 8)
    assert not np.array_equal(ba["tokens"], bb["tokens"])


@pytest.mark.parametrize("kind", ["int8_ef", "topk_ef"])
def test_compression_error_feedback(kind):
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                              jnp.float32)}
    compress, init_ef = make_compressor(kind, None, ratio=0.05)
    ef = init_ef(grads)
    sent, ef2 = compress(grads, ef)
    # EF invariant: sent + residual == original (+ old residual)
    np.testing.assert_allclose(
        np.asarray(sent["w"] + ef2["w"]), np.asarray(grads["w"]),
        rtol=1e-5, atol=1e-5,
    )
    if kind == "topk_ef":
        nz = float(jnp.mean((sent["w"] != 0).astype(jnp.float32)))
        assert nz <= 0.08  # ~5% density requested


def test_sharding_rules_divisibility_fallback():
    import os
    from repro.distributed.sharding import default_rules, spec_for
    # build a small host mesh without touching device count: reuse real device
    mesh = jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])

    # fake a 16x16 mesh via a stub object exposing shape/axis_names
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rules = default_rules(FakeMesh())
    # divisible: vocab 64000 -> model; embed 4096 -> data
    spec = spec_for((64000, 4096), ("vocab", "embed"), FakeMesh(), rules)
    assert spec[0] == "model" and spec[1] == "data"
    # 9 heads not divisible by 16 -> replicated
    spec = spec_for((576, 9, 64), ("embed", "heads", "head_dim"), FakeMesh(), rules)
    assert spec[1] is None and spec[2] is None
    # experts 40 not divisible -> replicated, mlp 512 -> model
    spec = spec_for((40, 1536, 512), ("experts", "embed", "mlp"), FakeMesh(), rules)
    assert spec[0] is None and spec[2] == "model"
    # experts 128 divisible by data -> data
    spec = spec_for((128, 7168, 4864), ("experts", "embed", "mlp"), FakeMesh(), rules)
    assert spec[0] == "data" and spec[2] == "model"


def test_cache_rules_prefer_kv_heads_then_seq():
    from repro.distributed.sharding import cache_rules, spec_for

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rules = cache_rules(FakeMesh())
    # kvh=16 divisible -> kv_heads claims model, seq untouched
    spec = spec_for((128, 32768, 16, 64), ("batch", "seq", "kv_heads", "head_dim"),
                    FakeMesh(), rules)
    assert spec[2] == "model" and spec[1] is None
    # kvh=4 not divisible -> seq claims model (flash-decode sharding)
    spec = spec_for((128, 32768, 4, 64), ("batch", "seq", "kv_heads", "head_dim"),
                    FakeMesh(), rules)
    assert spec[1] == "model" and spec[2] is None
