"""Hypothesis property tests: random stochastic DAGs vs the MC oracle.

Separate module so the deterministic suite in ``test_stochastic.py`` still
runs where hypothesis is absent (same ``importorskip`` discipline as
``test_property.py``)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro import sched
from tests.test_stochastic import _mc_check, _stage_params

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


@given(
    s=st.integers(2, 4),
    seed=st.integers(0, 50),
    exec_lo=st.floats(0.25, 1.0),
    rework_hi=st.floats(0.0, 0.5),
    cap=st.integers(1, 5),
)
@settings(max_examples=6, deadline=None, derandomize=True)
def test_random_stochastic_chain_matches_oracle(
    s, seed, exec_lo, rework_hi, cap
):
    """Property: for ANY chain with random branch/rework annotations, the
    analytic moments land within 1e-2 relative of the MC oracle (mean AND
    variance) — serial composition of the stochastic transforms is exact in
    moments, so the tolerance is MC noise + quadrature only."""
    rng = np.random.default_rng(seed)
    dag = sched.WorkflowDAG.chain(s, 3).with_stochastic(
        exec_probs=tuple(
            round(float(x), 3) for x in rng.uniform(exec_lo, 1.0, s)
        ),
        rework_probs=tuple(
            round(float(x), 3) for x in rng.uniform(0.0, rework_hi, s)
        ),
        max_retries=(cap,) * s,
    )
    params = _stage_params(seed + 100, s, 3)
    fracs = jnp.asarray(
        rng.dirichlet(np.ones(3), size=s).astype(np.float32)
    )
    _mc_check(dag, fracs, params, 200_000, 1e-2, 1e-2, seed=seed + 1000)


@given(s=st.integers(3, 5), seed=st.integers(0, 50))
@settings(max_examples=6, deadline=None, derandomize=True)
def test_random_stochastic_intree_matches_oracle(s, seed):
    """Property: random in-trees (every stage feeds exactly one successor,
    so branch finishes are genuinely independent) with random stochastic
    annotations.  Joins go through the Normal-matched PERT max, so the
    variance tolerance is wider than the exact-in-moments chain case."""
    rng = np.random.default_rng(seed)
    # parent[i] in (i, s): an in-tree onto the single sink s-1.
    preds = [[] for _ in range(s)]
    for i in range(s - 1):
        preds[int(rng.integers(i + 1, s))].append(i)
    dag = sched.WorkflowDAG(
        preds=tuple(tuple(p) for p in preds), num_workers=3
    ).with_stochastic(
        exec_probs=tuple(
            round(float(x), 3) for x in rng.uniform(0.4, 1.0, s)
        ),
        rework_probs=tuple(
            round(float(x), 3) for x in rng.uniform(0.0, 0.4, s)
        ),
        max_retries=tuple(int(c) for c in rng.integers(1, 6, s)),
    )
    params = _stage_params(seed + 200, s, 3)
    fracs = jnp.asarray(
        rng.dirichlet(np.ones(3), size=s).astype(np.float32)
    )
    _mc_check(dag, fracs, params, 200_000, 1.5e-2, 8e-2, seed=seed + 2000)
