"""Completion-time statistics of max-of-K and the efficient frontier (paper §1)."""
import jax.numpy as jnp
import numpy as np

from repro.core.frontier import (
    UnitParams,
    completion_cdf,
    mean_var_completion,
    optimal_two_way_fraction,
    pareto_mask,
    sweep_two_way,
)


def test_cdf_is_product_of_unit_cdfs():
    p = UnitParams.of([10.0, 20.0], [1.0, 2.0])
    fr = jnp.asarray([0.5, 0.5])
    eps = jnp.linspace(0.0, 30.0, 64)
    from repro.core.distributions import normal_cdf

    c1 = normal_cdf(eps, 0.5 * 10.0, 0.5 * 1.0)
    c2 = normal_cdf(eps, 0.5 * 20.0, 0.5 * 2.0)
    np.testing.assert_allclose(
        np.asarray(completion_cdf(eps, fr, p)), np.asarray(c1 * c2), rtol=1e-5
    )


def test_max_statistics_against_monte_carlo():
    rng = np.random.default_rng(0)
    p = UnitParams.of([30.0, 20.0], [2.0, 6.0])
    fr = jnp.asarray([0.4, 0.6])
    e, v = mean_var_completion(fr, p)
    x = rng.normal(0.4**1.0 * 30, 0.4**1.0 * 2, size=200_000)
    y = rng.normal(0.6**1.0 * 20, 0.6**1.0 * 6, size=200_000)
    mc = np.maximum(x, y)
    np.testing.assert_allclose(float(e), mc.mean(), rtol=1e-2)
    np.testing.assert_allclose(float(v), mc.var(), rtol=5e-2)


def test_mean_of_max_at_least_max_of_means():
    p = UnitParams.of([15.0, 10.0, 12.0], [1.0, 3.0, 2.0])
    fr = jnp.asarray([0.3, 0.4, 0.3])
    e, _ = mean_var_completion(fr, p)
    means = np.asarray([0.3 * 15, 0.4 * 10, 0.3 * 12])
    assert float(e) >= means.max() - 1e-3


def test_paper_illustration_frontier():
    """Paper Figs 1-2 hypothetical: mu_i=30 s_i=2, mu_j=20 s_j=6 — the curve
    is parabola-like and the min-mean point is interior."""
    p = UnitParams.of([30.0, 20.0], [2.0, 6.0])
    fg, mu_f, var_f = sweep_two_way(p, num_f=101)
    i = int(jnp.argmin(mu_f))
    assert 0.2 < float(fg[i]) < 0.6  # interior optimum
    # endpoints (all work on one unit) are worse than the optimum
    assert float(mu_f[0]) > float(mu_f[i])
    assert float(mu_f[-1]) > float(mu_f[i])
    # pareto frontier is non-empty and excludes dominated points
    mask = pareto_mask(mu_f, var_f)
    assert 0 < int(mask.sum()) < len(fg)
    mu_np, var_np = np.asarray(mu_f), np.asarray(var_f)
    for i_ in np.where(np.asarray(mask))[0]:
        dominated = np.any(
            (mu_np <= mu_np[i_]) & (var_np <= var_np[i_])
            & ((mu_np < mu_np[i_]) | (var_np < var_np[i_]))
        )
        assert not dominated


def test_objectives():
    p = UnitParams.of([30.0, 20.0], [2.0, 6.0])
    f_mean, mu_m, var_m = optimal_two_way_fraction(p, objective="mean")
    f_rav, mu_r, var_r = optimal_two_way_fraction(
        p, objective="mean_var", risk_aversion=2.0
    )
    # risk-averse point trades mean for variance
    assert float(var_r) <= float(var_m) + 1e-6
    assert float(mu_r) >= float(mu_m) - 1e-6
    f_con, mu_c, var_c = optimal_two_way_fraction(
        p, objective="constrained", var_budget=float(var_m) * 0.5
    )
    assert float(var_c) <= float(var_m) * 0.5 + 1e-4


def test_scaling_exponents_shift_optimum():
    """Sub-linear scaling (alpha<1) penalizes large fractions: the optimal
    split moves toward balance when overhead grows."""
    ideal = UnitParams.of([10.0, 10.0], [1.0, 1.0], [1.0, 1.0], [1.0, 1.0])
    f_i, _, _ = optimal_two_way_fraction(ideal)
    np.testing.assert_allclose(float(f_i), 0.5, atol=0.02)
