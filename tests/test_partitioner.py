"""K-way partitioner: frontier optimization, quantization, online API."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.frontier import UnitParams, mean_var_completion
from repro.core.partitioner import (
    HeterogeneityAwarePartitioner,
    WorkerTelemetry,
    optimize_fractions,
    quantize_fractions,
)


def test_faster_worker_gets_more_work():
    p = UnitParams.of([10.0, 30.0], [1.0, 1.0])
    fr, e, v = optimize_fractions(p)
    assert float(fr[0]) > float(fr[1])  # unit 0 is 3x faster
    # beats equal split
    e_eq, _ = mean_var_completion(jnp.asarray([0.5, 0.5]), p)
    assert float(e) < float(e_eq)


def test_optimizer_near_closed_form_linear_case():
    """With alpha=beta=1 and zero variance-aversion the optimal split for
    K linear units equalizes f_k * mu_k -> f_k proportional to 1/mu_k."""
    mus = [8.0, 16.0, 32.0]
    p = UnitParams.of(mus, [0.01, 0.01, 0.01])
    fr, _, _ = optimize_fractions(p)
    inv = np.array([1 / m for m in mus])
    np.testing.assert_allclose(np.asarray(fr), inv / inv.sum(), atol=0.02)


def test_quantize_sums_and_bounds():
    fr = np.array([0.61, 0.29, 0.10])
    counts = quantize_fractions(fr, 16)
    assert counts.sum() == 16
    assert (counts >= 1).all()
    assert counts[0] > counts[1] > counts[2]


def test_quantize_refinement_improves_objective():
    p = UnitParams.of([10.0, 20.0, 40.0], [1.0, 2.0, 4.0])
    fr, _, _ = optimize_fractions(p)
    counts = quantize_fractions(np.asarray(fr), 8, p)
    naive = np.array([3, 3, 2])

    def obj(c):
        e, _ = mean_var_completion(jnp.asarray(c / 8.0, jnp.float32), p)
        return float(e)

    assert obj(counts) <= obj(naive) + 1e-6


@pytest.mark.slow
def test_online_partitioner_learns_and_rebalances():
    # Slow: 6 full observe rounds through the DEPRECATED wrapper; the same
    # scenario stays tier-1 through the pure API
    # (test_sched.py::test_online_learning_rebalances_functional).
    rng = np.random.default_rng(0)
    true_mu = np.array([5.0, 20.0])  # worker 0 is 4x faster
    part = HeterogeneityAwarePartitioner(2, seed=0, n_iters=10, grid_size=128,
                                         mu_guess=10.0)
    for _ in range(6):
        fracs = np.tile(part.propose_fractions()[0][:, None], (1, 32))
        times = np.stack([
            np.maximum(f**0.9 * m + 0.5 * rng.normal(size=32), 1e-3)
            for f, m in zip(fracs, true_mu)
        ])
        part.observe(WorkerTelemetry(jnp.asarray(fracs), jnp.asarray(times)))
    fr, e, v = part.propose_fractions()
    assert fr[0] > 0.6  # the fast worker carries most of the load
    counts = part.propose_microbatches(8)
    assert counts.sum() == 8 and counts[0] > counts[1]


def test_elastic_add_remove():
    part = HeterogeneityAwarePartitioner(4, seed=1)
    part.remove_workers(np.array([False, True, False, False]))
    assert part.num_workers == 3
    part.add_workers(2)
    assert part.num_workers == 5
    fr, _, _ = part.propose_fractions()
    assert len(fr) == 5 and abs(fr.sum() - 1.0) < 1e-5
