"""Async propose path + compressed active-set serving: publish-on-completion
semantics, dispatch suppression, sync-path equivalence, and the
``hierarchical=False`` bitwise-legacy guarantee."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import sched, serve

SCHED = sched.SchedulerConfig(n_iters=2, grid_size=32, num_points=64,
                              opt_steps=10)


def _config(**kw):
    base = dict(sched=SCHED, capacity=16, drift_threshold=0.05,
                max_staleness=4)
    base.update(kw)
    return serve.ServeConfig(**base)


def _feed(loop, rounds=2, rows=8, k=3, seed=1):
    """Push ``rows`` telemetry rows then tick, ``rounds`` times."""
    rng = jax.random.PRNGKey(seed)
    mu = jnp.linspace(5.0, 20.0, k)
    infos = []
    for r in range(rounds):
        for i in range(rows):
            kk = jax.random.fold_in(rng, r * rows + i)
            f = jax.random.uniform(kk, (k,), minval=0.1, maxval=0.9)
            loop.push(f, f**0.9 * mu)
        infos.append(loop.tick())
    return infos


class _NeverReady:
    """Stands in for an in-flight device array the solve has not finished."""

    def is_ready(self):
        return False


# -----------------------------------------------------------------------
# async propose: publish-on-completion
# -----------------------------------------------------------------------
def test_async_tick_does_not_publish_until_poll():
    loop = serve.ServiceLoop(3, config=_config(async_propose=True), seed=0)
    infos = _feed(loop, rounds=1)
    assert bool(infos[0].proposed)
    # the solve was dispatched off the tick path but NOT published yet:
    # readers still see the placeholder split at version 0
    assert loop._pending is not None
    assert loop.version == 0
    np.testing.assert_allclose(loop.fractions(), 1 / 3)

    jax.block_until_ready(loop._pending[0])
    assert loop.poll() is True
    assert loop.version == 1
    fr = loop.fractions()
    assert abs(float(fr.sum()) - 1.0) < 1e-5
    assert np.all(fr > 0)
    assert np.isfinite(float(loop.state.stats.e_t))
    # drained once more with nothing new: no spurious publish
    assert loop.poll() is False


def test_async_pending_solve_suppresses_redispatch():
    loop = serve.ServiceLoop(3, config=_config(async_propose=True), seed=0)
    marker = (_NeverReady(), None)
    loop._pending = marker
    infos = _feed(loop, rounds=1)
    assert bool(infos[0].proposed)  # the gate fired...
    assert loop._pending is marker  # ...but the in-flight solve was kept
    assert loop.version == 0
    loop._pending = None  # drop the stub before the loop is GC'd


def test_async_bookkeeping_matches_sync_decisions():
    """Gate decisions, staleness resets, and counters are identical in the
    two modes — only WHERE the solve runs differs."""
    sync = serve.ServiceLoop(3, config=_config(), seed=0)
    kasync = serve.ServiceLoop(3, config=_config(async_propose=True), seed=0)
    s_infos = _feed(sync, rounds=3)
    a_infos = _feed(kasync, rounds=3)
    for s, a in zip(s_infos, a_infos):
        assert bool(s.proposed) == bool(a.proposed)
        assert int(s.drained) == int(a.drained)
    assert sync.counters()["proposes"] == kasync.counters()["proposes"]
    assert int(jnp.sum(sync.state.staleness)) == int(
        jnp.sum(kasync.state.staleness)
    )
    # and the eventually-published splits agree (same solve, same params)
    while kasync.poll() or kasync._pending is not None:
        if kasync._pending is not None:
            jax.block_until_ready(kasync._pending[0])
    np.testing.assert_allclose(
        kasync.fractions(), sync.fractions(), rtol=1e-5, atol=1e-6
    )


def test_async_with_hierarchical_and_elastic():
    config = _config(
        async_propose=True,
        sched=sched.SchedulerConfig(
            n_iters=2, grid_size=32, num_points=64, opt_steps=10,
            hierarchical=True, hyper_refit_every=2,
        ),
    )
    loop = serve.ServiceLoop(3, config=config, seed=0)
    _feed(loop, rounds=3)
    if loop._pending is not None:
        jax.block_until_ready(loop._pending[0])
        loop.poll()
    assert loop.version >= 1
    assert abs(float(loop.fractions().sum()) - 1.0) < 1e-5


# -----------------------------------------------------------------------
# compressed active set in the serve loop
# -----------------------------------------------------------------------
def test_active_set_tick_refreshes_every_worker_round_robin():
    config = _config(active_size=2)
    loop = serve.ServiceLoop(4, config=config, seed=0)
    assert loop.state.refresh_age is not None
    _feed(loop, rounds=4, k=4)
    ages = np.asarray(loop.state.refresh_age)
    # with M=2 of K=4 refreshed per drain, no worker waits more than ~K/M
    # drains: every age is small and at least M workers are freshly zero
    assert ages.max() <= 3
    assert int((ages == 0).sum()) >= 2
    assert abs(float(loop.fractions().sum()) - 1.0) < 1e-5


def test_active_set_none_is_structurally_legacy():
    loop = serve.ServiceLoop(3, config=_config(), seed=0)
    assert loop.state.refresh_age is None
    # active_size >= K short-circuits to the dense path as well
    full = serve.ServiceLoop(3, config=_config(active_size=3), seed=0)
    _feed(full, rounds=1)
    assert abs(float(full.fractions().sum()) - 1.0) < 1e-5


def test_active_set_with_async_propose_end_to_end():
    config = _config(active_size=2, async_propose=True)
    loop = serve.ServiceLoop(4, config=config, seed=0)
    _feed(loop, rounds=3, k=4)
    if loop._pending is not None:
        jax.block_until_ready(loop._pending[0])
        loop.poll()
    assert loop.version >= 1
    fr = loop.fractions()
    assert abs(float(fr.sum()) - 1.0) < 1e-5 and np.all(fr > 0)


# -----------------------------------------------------------------------
# hierarchical=False stays bitwise-legacy
# -----------------------------------------------------------------------
def test_non_hierarchical_tick_ignores_hyper_knobs_bitwise():
    """Satellite regression: with ``hierarchical=False`` the mid-life
    shrinkage branch must be dead code — changing its cadence/strength
    knobs cannot perturb a single bit of the tick."""
    a_cfg = _config(sched=sched.SchedulerConfig(
        n_iters=2, grid_size=32, num_points=64, opt_steps=10,
        hierarchical=False, hyper_refit_every=1, hyper_strength=0.9,
    ))
    b_cfg = _config(sched=sched.SchedulerConfig(
        n_iters=2, grid_size=32, num_points=64, opt_steps=10,
        hierarchical=False, hyper_refit_every=64, hyper_strength=0.1,
    ))
    a = serve.ServiceLoop(3, config=a_cfg, seed=0)
    b = serve.ServiceLoop(3, config=b_cfg, seed=0)
    _feed(a, rounds=3)
    _feed(b, rounds=3)

    # hyper_age mirrors the configured cadence at init; everything else —
    # posteriors, splits, gate, staleness — must be bitwise identical
    sa = a.state._replace(hyper_age=jnp.zeros((), jnp.int32))
    sb = b.state._replace(hyper_age=jnp.zeros((), jnp.int32))
    eq = jax.tree_util.tree_map(lambda x, y: bool(jnp.array_equal(x, y)), sa, sb)
    flat = jax.tree_util.tree_leaves(eq)
    assert all(flat), eq
    np.testing.assert_array_equal(a.fractions(), b.fractions())
