"""FaultToleranceMonitor: hard failures must never corrupt the soft-anomaly
statistics (regression for the fabricated-1e6 bug)."""
import jax.numpy as jnp
import numpy as np

from repro import sched
from repro.distributed.fault_tolerance import FaultToleranceMonitor

CFG = sched.SchedulerConfig(n_iters=6, grid_size=64, mu_guess=5.0, opt_steps=40)


def _warm_scheduler(k=4, steps=3, seed=0):
    rng = np.random.default_rng(seed)
    part = sched.Scheduler(k, config=CFG, seed=seed)
    for _ in range(steps):
        fr = np.full((k, 16), 1.0 / k, np.float32)
        t = np.abs(rng.normal(5.0, 0.3, (k, 16))).astype(np.float32)
        part.observe(sched.Telemetry(jnp.asarray(fr), jnp.asarray(t)))
    return part, rng


def test_hard_failure_never_enters_soft_anomaly_stats():
    """Regression: a worker reporting inf used to be fed to anomaly_scores
    as a fabricated 1e6 observation, permanently corrupting its EWMA and
    skewing the fleet median/MAD.  Now non-finite telemetry is masked out:
    the dead worker's EWMA is untouched and the live fleet's scores match a
    run that never saw the failure."""
    part, rng = _warm_scheduler()
    mon = FaultToleranceMonitor(part, heartbeat_timeout=1e9)
    fr = np.full(4, 0.25)
    base = np.abs(rng.normal(5.0, 0.3, 4))
    mon.observe_step(fr, base, now=0.0)
    ewma_before = np.asarray(part.state.ewma_ll).copy()

    dead_times = base.copy()
    dead_times[1] = np.inf
    out = mon.observe_step(fr, dead_times, now=1.0)
    assert out["failures"][1]
    assert not out["stragglers"][1]  # failed, not straggling

    # the dead worker's EWMA and freshness counter are frozen
    np.testing.assert_allclose(float(part.state.ewma_ll[1]), ewma_before[1])
    # live workers' scores stay finite and uncorrupted
    assert np.isfinite(np.asarray(part.state.ewma_ll)).all()
    assert float(part.state.ewma_ll.max()) < 1e3


def test_live_fleet_scores_match_failure_free_run():
    """The surviving workers' anomaly statistics must be bit-identical
    whether or not a dead peer reported inf alongside them."""
    part_a, rng_a = _warm_scheduler(seed=1)
    part_b, _ = _warm_scheduler(seed=1)
    fr = np.full(4, 0.25)
    times = np.abs(rng_a.normal(5.0, 0.3, 4))

    mon_a = FaultToleranceMonitor(part_a, heartbeat_timeout=1e9)
    mon_b = FaultToleranceMonitor(part_b, heartbeat_timeout=1e9)
    mon_a.observe_step(fr, times, now=0.0)
    broken = times.copy()
    broken[2] = np.nan
    mon_b.observe_step(fr, broken, now=0.0)

    a = np.asarray(part_a.state.ewma_ll)
    b = np.asarray(part_b.state.ewma_ll)
    keep = [0, 1, 3]
    np.testing.assert_array_equal(a[keep], b[keep])


def test_straggler_detection_survives_concurrent_failure():
    """A slow-but-alive worker is still flagged while another worker is hard
    down — the failure no longer inflates the MAD baseline."""
    part, rng = _warm_scheduler(k=5, seed=2)
    mon = FaultToleranceMonitor(part, heartbeat_timeout=1e9, straggler_sigma=2.0)
    fr = np.full(5, 0.2)
    for step in range(4):
        times = np.abs(rng.normal(5.0, 0.3, 5))
        times[3] *= 6.0  # persistent straggler
        times[4] = np.inf  # hard failure alongside
        out = mon.observe_step(fr, times, now=float(step))
    assert out["failures"][4]
    assert out["stragglers"][3]
    assert not out["stragglers"][4]
