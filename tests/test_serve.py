"""The always-on serving loop (repro.serve): ring parity, cadence, memory.

The load-bearing claims:
  1. a sequence of ring drains advanced through ``gibbs_batch`` is BITWISE
     the synchronous ``gibbs.fit`` over the same observations — push-mode
     buffering changes when estimation runs, never what it computes;
  2. wrap-around and overflow preserve push order and mask exactly;
  3. the propose cadence fires on posterior drift (a worker changing
     regime), not on steady-state sampling noise;
  4. the donated tick/push path re-uses buffers: no per-step growth in
     live device arrays;
  5. the service state checkpoints and restores through CheckpointManager.
"""
import gc
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched, serve
from repro.core import gibbs

N_ITERS, GRID = 3, 64


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _stream(n, seed=0):
    rng = np.random.default_rng(seed)
    f = rng.uniform(0.1, 0.9, n).astype(np.float32)
    t = (f**0.85 * 10.0 + f**0.8 * 0.5 * rng.standard_normal(n)).astype(np.float32)
    return t, f


# ---------------------------------------------------------------- ring parity
def test_ring_drains_bitwise_match_synchronous_fit():
    """N pushes + whole-batch drains == one synchronous ``fit``: bitwise."""
    cap = 32
    t, f = _stream(2 * cap)
    key = jax.random.PRNGKey(7)

    state = gibbs.init_state(key, mu_guess=10.0)
    ring = serve.ring_init(cap)
    for i in range(len(t)):
        ring = serve.push(ring, f[i], t[i])
        if (i + 1) % cap == 0:
            batch, ring = serve.drain(ring)
            state, _ = gibbs.gibbs_batch(
                state, batch.times, batch.fracs, batch.mask,
                n_iters=N_ITERS, grid_size=GRID,
            )

    ref, _ = gibbs.fit(
        key, jnp.asarray(t), jnp.asarray(f),
        batch_size=cap, n_iters=N_ITERS, grid_size=GRID, mu_guess=10.0,
    )
    assert _leaves_equal(state, ref)


def test_ring_wraparound_drain_is_bitwise_batch_sequence():
    """A drain that wraps the buffer still presents observations oldest-first
    with a masked tail — bitwise against hand-padded ``gibbs_batch`` calls
    over the same batch boundaries."""
    cap = 32
    t, f = _stream(20 + cap, seed=1)
    key = jax.random.PRNGKey(3)

    state = gibbs.init_state(key, mu_guess=10.0)
    ring = serve.ring_init(cap)
    for i in range(20):  # partial drain: head at 20, then wraps
        ring = serve.push(ring, f[i], t[i])
    batch, ring = serve.drain(ring)
    assert int(batch.count) == 20
    state, _ = gibbs.gibbs_batch(
        state, batch.times, batch.fracs, batch.mask,
        n_iters=N_ITERS, grid_size=GRID,
    )
    for i in range(20, 20 + cap):  # slots 20..31 then 0..19: wrapped
        ring = serve.push(ring, f[i], t[i])
    batch, ring = serve.drain(ring)
    np.testing.assert_array_equal(np.asarray(batch.times), t[20:])  # push order
    state, _ = gibbs.gibbs_batch(
        state, batch.times, batch.fracs, batch.mask,
        n_iters=N_ITERS, grid_size=GRID,
    )

    # reference: the same boundaries, hand-padded exactly like the ring pads
    ref = gibbs.init_state(key, mu_guess=10.0)
    t0 = np.concatenate([t[:20], np.full(12, 1.0, np.float32)])
    f0 = np.concatenate([f[:20], np.full(12, 0.5, np.float32)])
    m0 = np.concatenate([np.ones(20, np.float32), np.zeros(12, np.float32)])
    ref, _ = gibbs.gibbs_batch(
        ref, jnp.asarray(t0), jnp.asarray(f0), jnp.asarray(m0),
        n_iters=N_ITERS, grid_size=GRID,
    )
    ref, _ = gibbs.gibbs_batch(
        ref, jnp.asarray(t[20:]), jnp.asarray(f[20:]),
        jnp.ones(cap, jnp.float32), n_iters=N_ITERS, grid_size=GRID,
    )
    assert _leaves_equal(state, ref)


def test_ring_overflow_drops_oldest_and_counts():
    ring = serve.ring_init(4)
    for i in range(6):
        ring = serve.push(ring, 0.5, 10.0 + i)
    assert int(ring.dropped) == 2
    assert int(ring.total) == 6
    batch, ring = serve.drain(ring)
    # the two OLDEST entries (10, 11) were overwritten; order preserved
    np.testing.assert_array_equal(np.asarray(batch.times), [12.0, 13.0, 14.0, 15.0])
    np.testing.assert_array_equal(np.asarray(batch.mask), np.ones(4))
    assert int(ring.count) == 0


def test_fleet_ring_layout_and_validity_mask():
    """Fleet drains come out worker-major with per-element validity folded
    into the mask — the exact telemetry layout ``sched.observe`` accepts."""
    ring = serve.ring_init(3, num_workers=2)
    ring = serve.push(ring, [0.6, 0.4], [3.0, np.inf], valid=[1.0, 0.0])
    ring = serve.push(ring, [0.5, 0.5], [2.0, 4.0])
    batch, _ = serve.drain(ring)
    assert batch.times.shape == (2, 3)  # (K, capacity)
    np.testing.assert_array_equal(np.asarray(batch.times[0]), [3.0, 2.0, 1.0])
    np.testing.assert_array_equal(np.asarray(batch.mask), [[1, 1, 0], [0, 1, 0]])
    # the invalid inf never got stored (0 * inf = nan would leak)
    assert np.isfinite(np.asarray(batch.times)).all()


# ------------------------------------------------------------------- cadence
def _steady_cfg(**kw):
    base = dict(
        sched=sched.SchedulerConfig(n_iters=4, grid_size=64, num_points=128,
                                    opt_steps=40, mu_guess=3.0),
        capacity=8, drift_threshold=0.25, max_staleness=100,
    )
    base.update(kw)
    return serve.ServeConfig(**base)


def _push_rounds(loop, mu, rounds, rng):
    fr = np.full(len(mu), 1.0 / len(mu), np.float32)
    infos = []
    for _ in range(rounds):
        for _ in range(loop.config.capacity):
            times = fr**0.9 * mu + fr**0.8 * 0.05 * mu * rng.standard_normal(len(mu))
            loop.push(fr, times.astype(np.float32))
        infos.append(loop.tick())
    return infos


def test_cadence_fires_on_drift_not_steady_state_noise():
    rng = np.random.default_rng(0)
    mu = np.array([2.0, 4.0, 6.0])
    loop = serve.ServiceLoop(3, config=_steady_cfg(), seed=2)

    infos = _push_rounds(loop, mu, 8, rng)
    assert bool(infos[0].proposed)  # saturated staleness: first drain solves
    late = [bool(i.proposed) for i in infos[4:]]
    assert not all(late), "steady-state sampling noise must not re-solve"

    v0 = loop.version
    mu_shift = mu * np.array([4.0, 1.0, 1.0])  # worker 0 changes regime
    infos = _push_rounds(loop, mu_shift, 2, rng)
    assert any(bool(i.proposed) for i in infos), "regime change must re-solve"
    assert max(float(i.drift) for i in infos) > loop.config.drift_threshold
    assert loop.version > v0  # the new split was published


@pytest.mark.no_host_sync
def test_empty_tick_is_noop_on_beliefs(host_staging):
    with host_staging():  # constructing the loop mints device state
        loop = serve.ServiceLoop(2, config=_steady_cfg(), seed=0)
        before = jax.tree_util.tree_map(
            lambda x: np.asarray(x).copy(), loop.state.sched
        )
    info = loop.tick()  # nothing buffered; guarded: no implicit transfers
    assert int(info.drained) == 0 and not bool(info.proposed)
    with host_staging():
        assert _leaves_equal(before, loop.state.sched)  # not even the PRNG moved
    assert loop.counters()["drains"] == 0


@pytest.mark.no_host_sync
def test_service_loop_learns_split_end_to_end(host_staging):
    """End-to-end split learning, with every ``tick`` (the production hot
    path: drain -> observe -> maybe-propose under one jit) running under
    ``jax.transfer_guard("disallow")`` — telemetry staging in ``push`` is
    the only sanctioned host edge."""
    rng = np.random.default_rng(1)
    mu = np.array([2.0, 8.0])  # worker 0 is 4x faster
    with host_staging():
        loop = serve.ServiceLoop(2, config=_steady_cfg(max_staleness=4), seed=3)
    fr_eq = np.full(2, 0.5, np.float32)
    for _ in range(10):
        with host_staging():  # host-side telemetry staging
            for _ in range(loop.config.capacity):
                times = (
                    fr_eq**0.9 * mu
                    + fr_eq**0.8 * 0.05 * mu * rng.standard_normal(2)
                )
                loop.push(fr_eq, times.astype(np.float32))
        loop.tick()  # guarded: the jitted path must stay on device
    fr = loop.fractions()
    assert fr[0] > fr[1]  # the fast worker carries more
    np.testing.assert_array_equal(fr, np.asarray(loop.state.fractions))
    c = loop.counters()
    assert c["drains"] == 10 and 1 <= c["proposes"] <= c["drains"]
    assert c["pushes"] == 10 * loop.config.capacity and c["dropped"] == 0


# ------------------------------------------------------------ donation/memory
def test_no_live_buffer_growth_across_ticks():
    """The donated push/tick path must re-use state buffers: the number of
    live device arrays is flat across service cycles (no per-step growth)."""
    rng = np.random.default_rng(0)
    mu = np.array([2.0, 4.0])
    loop = serve.ServiceLoop(2, config=_steady_cfg(), seed=0)
    _push_rounds(loop, mu, 2, rng)  # warm both cond branches + caches
    gc.collect()
    base = len(jax.live_arrays())
    for _ in range(6):
        _push_rounds(loop, mu, 1, rng)
    gc.collect()
    assert len(jax.live_arrays()) <= base


# -------------------------------------------------------------- checkpointing
def test_serve_state_checkpoints_and_resumes_bitwise(tmp_path):
    from repro.checkpoint.checkpoint import CheckpointManager

    rng = np.random.default_rng(2)
    mu = np.array([3.0, 5.0])
    loop = serve.ServiceLoop(2, config=_steady_cfg(), seed=4)
    _push_rounds(loop, mu, 3, rng)
    # leave telemetry BUFFERED so restore must bring the ring back too
    fr = np.full(2, 0.5, np.float32)
    loop.push(fr, (fr**0.9 * mu).astype(np.float32))

    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, loop.state._asdict(), {"step": 1})
    ckpt.wait()

    template = serve.init(loop.config, 2, jax.random.PRNGKey(4))._asdict()
    restored, _ = ckpt.restore(template)
    state2 = serve.ServeState(**restored)
    assert _leaves_equal(loop.state, state2)

    # both copies tick identically from here
    loop2 = serve.ServiceLoop(2, config=loop.config, state=state2)
    i1, i2 = loop.tick(), loop2.tick()
    assert int(i1.drained) == int(i2.drained) == 1
    assert _leaves_equal(loop.state, loop2.state)


# ------------------------------------------------------------------ the driver
def test_launch_serve_smoke_subprocess():
    """``python -m repro.launch.serve --serve-smoke`` is the shippable proof:
    real model serving rounds fed through the service, at least one propose
    AND at least one drift-gated skip, exit 0."""
    repo = Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--serve-smoke"],
        capture_output=True, text=True, timeout=600,
        cwd=repo, env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "serve-smoke OK" in proc.stdout
