"""Grid posteriors + method-of-moments Beta approximation (Eqs 10-18)."""
import jax.numpy as jnp
import numpy as np
import scipy.integrate
import scipy.stats

from repro.core.moments import (
    BetaParams,
    exponent_grid,
    fit_beta_method_of_moments,
    log_posterior_alpha_ref,
    log_posterior_beta_ref,
    moments_from_log_density,
)


def test_moment_fit_recovers_beta():
    """Feeding an exact Beta log-density through the grid pipeline must
    recover its parameters (method of moments is exact for Beta)."""
    grid = exponent_grid(2048)
    for a, b in [(2.0, 5.0), (8.0, 3.0), (1.5, 1.5)]:
        logp = (a - 1) * jnp.log(grid) + (b - 1) * jnp.log1p(-grid)
        e, v = moments_from_log_density(grid, logp)
        fit = fit_beta_method_of_moments(e, v)
        np.testing.assert_allclose(float(fit.a), a, rtol=2e-2)
        np.testing.assert_allclose(float(fit.b), b, rtol=2e-2)


def test_grid_moments_match_scipy_quad():
    """E(alpha), Var(alpha) of Eq 10 vs adaptive quadrature ground truth."""
    rng = np.random.default_rng(0)
    n = 128
    f = rng.uniform(0.1, 0.95, n).astype(np.float32)
    t = f**0.9 * 25.0 + f**0.8 * 2.0 * rng.normal(size=n)
    prior = BetaParams(jnp.float32(2.0), jnp.float32(2.0))
    mu, lam, beta = 25.0, 1 / 4.0, 0.8

    grid = exponent_grid(1024)
    logp = log_posterior_alpha_ref(
        grid, jnp.asarray(t, jnp.float32), jnp.asarray(f), jnp.float32(mu),
        jnp.float32(lam), jnp.float32(beta), prior,
    )
    e_grid, v_grid = moments_from_log_density(grid, logp)

    # scipy ground truth (normalize the same unnormalized density)
    logf = np.log(f)

    def log_post(a):
        z = (t - np.exp(a * logf) * mu) * np.exp(-beta * logf)
        return (
            -0.5 * lam * np.sum(z * z)
            + (2.0 - 1) * np.log(a)
            + (2.0 - 1) * np.log1p(-a)
        )

    m = max(log_post(a) for a in np.linspace(1e-3, 1 - 1e-3, 200))
    z0 = scipy.integrate.quad(lambda a: np.exp(log_post(a) - m), 1e-4, 1 - 1e-4)[0]
    e_ref = scipy.integrate.quad(
        lambda a: a * np.exp(log_post(a) - m), 1e-4, 1 - 1e-4
    )[0] / z0
    e2_ref = scipy.integrate.quad(
        lambda a: a * a * np.exp(log_post(a) - m), 1e-4, 1 - 1e-4
    )[0] / z0
    np.testing.assert_allclose(float(e_grid), e_ref, rtol=1e-3)
    np.testing.assert_allclose(float(v_grid), e2_ref - e_ref**2, rtol=5e-2)


def test_beta_posterior_includes_jacobian_term():
    """Eq 11 vs Eq 10: the beta posterior has the extra -beta*sum(log f)
    term; with all f=1 the term vanishes and the quad parts coincide."""
    grid = exponent_grid(256)
    t = jnp.asarray([1.0, 2.0, 1.5], jnp.float32)
    f = jnp.ones(3, jnp.float32)
    prior = BetaParams(jnp.float32(2.0), jnp.float32(2.0))
    la = log_posterior_alpha_ref(grid, t, f, 1.5, 1.0, 0.5, prior)
    lb = log_posterior_beta_ref(grid, t, f, 1.5, 1.0, 0.5, prior)
    # identical when f == 1 (exponent irrelevant, jacobian zero) up to the
    # roles of alpha/beta in the residual — here both reduce to the same form
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-5, atol=1e-5)


def test_moment_fit_clamps_invalid_variance():
    fit = fit_beta_method_of_moments(jnp.float32(0.5), jnp.float32(10.0))
    assert float(fit.a) > 0 and float(fit.b) > 0
