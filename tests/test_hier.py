"""Hierarchical empirical-Bayes fleet pooling (repro.hier) + calibrated gate.

The load-bearing claims:
  1. the empirical-Bayes refit centers the pooled prior on the fleet;
  2. ``shrink``: weight 0 is a bitwise no-op, a cold worker (ess 0) lands
     exactly on the pool, a mature worker keeps its own data;
  3. cold-start transfer: a hierarchically-admitted worker proposes
     near-fleet-mean in its first cycle and reaches its oracle fraction
     in <= half the observations of a global-prior admit (the ISSUE's
     acceptance scenario, also recorded as a BENCH_7 row);
  4. ``hierarchical=False`` admission is bitwise the legacy global-prior
     path, and the fixed-threshold serve gate never touches the new
     gate/hyperprior state;
  5. ``surprise`` flags the drifted worker, and the calibrated gate's
     skip rate is stable across K = 10^2 and K = 10^4 — where any fixed
     threshold tuned at one K breaks at the other;
  6. sharded shrink/surprise/refit match single-device (same subprocess
     re-run pattern as test_sharding.py on single-device machines).
"""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import hier, sched, serve
from repro.core import gibbs
from repro.core.sharding import ShardingConfig

multidevice = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs >= 2 devices (see test_sharding)"
)

CFG = sched.SchedulerConfig(
    n_iters=3, grid_size=32, num_points=64, opt_steps=30, mu_guess=1.0
)
# True worker speed far from the global prior (mu_guess=1): a cold admit
# believes it is ~800x faster than the fleet, so the optimizer overloads
# it at birth — the cold-start failure hierarchical pooling removes.
TRUE_MU, TRUE_ALPHA = 800.0, 0.9


def _times(rng, fmat, mu=TRUE_MU):
    return fmat**TRUE_ALPHA * mu * (1.0 + 0.02 * rng.standard_normal(fmat.shape))


def _telemetry(rng, fracs, mu=TRUE_MU, n=16):
    fmat = np.tile(np.asarray(fracs, np.float32)[:, None], (1, n))
    return sched.Telemetry(
        jnp.asarray(fmat, jnp.float32),
        jnp.asarray(_times(rng, fmat, mu), jnp.float32),
    )


def _explore_telemetry(rng, k, mu=TRUE_MU, n=16):
    """Varied per-observation fractions: identifies (mu, alpha) jointly —
    telemetry at one fixed fraction cannot separate them."""
    fmat = rng.uniform(0.05, 0.9, (k, n)).astype(np.float32)
    return sched.Telemetry(
        jnp.asarray(fmat, jnp.float32),
        jnp.asarray(_times(rng, fmat, mu), jnp.float32),
    )


def _clone(scheduler, **overrides):
    """Fork a Scheduler: immutable pytree state is safe to share-then-diverge."""
    s = sched.Scheduler(
        1, config=dataclasses.replace(scheduler.config, **overrides)
    )
    s.state = scheduler.state
    return s


@pytest.fixture(scope="module")
def fleet16():
    """A converged 16-worker fleet of identical mu=8 workers."""
    rng = np.random.default_rng(0)
    s = sched.Scheduler(16, config=CFG, seed=0)
    for _ in range(8):
        s.observe(_explore_telemetry(rng, 16))
    return s


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _tree_close(a, b, tol):
    for la, lb in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    ):
        np.testing.assert_allclose(
            np.asarray(la, np.float64), np.asarray(lb, np.float64),
            atol=tol, rtol=tol,
        )


# ------------------------------------------------------------------- refit
def test_refit_centers_on_fleet(fleet16):
    hyper = fleet16.fit_hyperprior()
    assert float(hyper.n_workers) == 16.0
    # The pool sits inside the fleet's posterior cloud: within the spread
    # of the per-worker means, not at the (far away) global prior.
    mus = np.asarray(fleet16.state.gibbs.ng.mu0)
    assert mus.min() - 1e-3 <= float(hyper.ng.mu0) <= mus.max() + 1e-3
    a_mean = float(
        hyper.alpha_prior.a / (hyper.alpha_prior.a + hyper.alpha_prior.b)
    )
    a_k = np.asarray(fleet16.state.gibbs.alpha_prior.a) / (
        np.asarray(fleet16.state.gibbs.alpha_prior.a)
        + np.asarray(fleet16.state.gibbs.alpha_prior.b)
    )
    assert a_k.min() - 1e-3 <= a_mean <= a_k.max() + 1e-3


# ------------------------------------------------------------------ shrink
def test_shrink_weight_zero_is_bitwise_noop(fleet16):
    hyper = fleet16.fit_hyperprior()
    out = hier.shrink(fleet16.state.gibbs, hyper, weight=0.0)
    assert _leaves_equal(out, fleet16.state.gibbs)


def test_cold_lands_on_pool_mature_keeps_own_data(fleet16):
    hyper = fleet16.fit_hyperprior()
    w = np.asarray(hier.shrinkage_weight(fleet16.state.gibbs))
    assert (w < 0.35).all()  # 8 rounds x 16 obs: the fleet is mature

    cold = jax.tree_util.tree_map(
        lambda x: x[None],
        gibbs.init_state(jax.random.PRNGKey(3), mu_guess=1.0),
    )
    assert float(hier.effective_sample_size(cold)[0]) == 0.0
    assert float(hier.shrinkage_weight(cold)[0]) == 1.0
    warm = hier.shrink(cold, hyper)
    np.testing.assert_allclose(
        float(warm.ng.mu0[0]), float(hyper.ng.mu0), rtol=1e-6
    )
    np.testing.assert_allclose(
        float(warm.ng.kappa0[0]), float(hyper.ng.kappa0), rtol=1e-5
    )

    mature = hier.shrink(fleet16.state.gibbs, hyper)
    own, blended = np.asarray(fleet16.state.gibbs.ng.mu0), np.asarray(
        mature.ng.mu0
    )
    pool = float(hyper.ng.mu0)
    # each mature worker moved strictly less than 35% of the way to the pool
    assert (np.abs(blended - own) <= 0.35 * np.abs(pool - own) + 1e-6).all()


def test_scheduler_shrink_pulls_cold_admit_to_first_cycle_accuracy(fleet16):
    """Satellite: a fresh worker shrunk toward a fast fleet proposes
    near-fleet-mean fractions in its very first propose cycle."""
    s = _clone(fleet16)
    s.add_workers(1, seed=11)  # legacy global-prior admission (mu_guess=1)
    fr_cold, _, _ = s.propose_fractions()
    oracle = 1.0 / 17.0
    assert fr_cold[-1] > 3 * oracle  # cold admit grossly overloaded

    s.shrink()  # ESS-weighted: only the newcomer moves appreciably
    fr_warm, _, _ = s.propose_fractions()
    assert abs(fr_warm[-1] - oracle) < 0.2 * oracle


# ------------------------------------------------- cold-start acceptance
def _obs_to_band(scheduler, oracle, rng, n=4, max_cycles=15):
    """Observations the NEWCOMER needs before its fraction is within 10%
    of oracle; propose happens before each batch, so 0 means 'born ready'."""
    for cycle in range(max_cycles + 1):
        fr, _, _ = scheduler.propose_fractions()
        if abs(fr[-1] - oracle) <= 0.1 * oracle:
            return cycle * n
        scheduler.observe(_telemetry(rng, fr, n=n))
    return (max_cycles + 1) * n


def test_cold_start_transfer_halves_observations(fleet16):
    """ISSUE acceptance: with pooling, a cold worker joining a converged
    K=16 fleet reaches within 10% of its oracle fraction in <= half the
    observations required from the global prior."""
    oracle = 1.0 / 17.0

    pooled = _clone(fleet16, hierarchical=True)
    pooled.add_workers(1, seed=7)
    pooled_obs = _obs_to_band(pooled, oracle, np.random.default_rng(1))

    legacy = _clone(fleet16, hierarchical=False)
    legacy.add_workers(1, seed=7)
    legacy_obs = _obs_to_band(legacy, oracle, np.random.default_rng(1))

    assert pooled_obs <= 15 * 4, "pooled admit never reached the band"
    assert legacy_obs > 0, "global-prior admit was born converged?!"
    assert pooled_obs <= legacy_obs / 2, (pooled_obs, legacy_obs)


def test_add_workers_hierarchical_false_is_bitwise_legacy(fleet16):
    """The default-off path is byte-for-byte the PR 6 admission code."""
    st = fleet16.state
    out = sched.add_workers(st, 2, CFG)

    key, sub = jax.random.split(st.key)
    keys = jax.random.split(sub, 2)
    fresh = jax.vmap(
        lambda k: gibbs.init_state(k, mu_guess=CFG.mu_guess)
    )(keys)
    cat = lambda a, b: jnp.concatenate([jnp.asarray(a), b], axis=0)
    ref = st._replace(
        gibbs=jax.tree_util.tree_map(cat, st.gibbs, fresh),
        ewma_ll=jnp.concatenate([jnp.asarray(st.ewma_ll), jnp.zeros(2)]),
        ewma_count=jnp.concatenate(
            [jnp.asarray(st.ewma_count), jnp.zeros(2, jnp.int32)]
        ),
        key=key,
    )
    assert _leaves_equal(out, ref)


# ---------------------------------------------------------------- surprise
def test_surprise_flags_the_drifted_worker(fleet16):
    hyper = fleet16.fit_hyperprior()
    base = np.asarray(hier.surprise(fleet16.state.gibbs, hyper))
    assert base.shape == (16,)

    g = fleet16.state.gibbs
    mu0 = np.asarray(g.ng.mu0).copy()
    mu0[3] *= 4.0  # worker 3 silently became 4x slower
    drifted = g._replace(ng=g.ng._replace(mu0=jnp.asarray(mu0)))
    scores = np.asarray(hier.surprise(drifted, hyper))
    assert scores.argmax() == 3
    assert scores[3] > np.delete(scores, 3).max() + 1.0


def test_calibrated_gate_skip_rate_stable_across_fleet_sizes():
    """Satellite: the same gate configuration yields the same (near-zero)
    fire rate on the null at K=10^2 and K=10^4 — while a fixed threshold
    tuned at K=10^2 fires almost always at K=10^4."""
    rates = {}
    for k in (100, 10_000):
        rng = np.random.default_rng(0)
        gate, fires, ticks = serve.gate_init(), 0, 120
        for _ in range(ticks):
            fired, gate = serve.gate_update(gate, rng.standard_normal(k).max())
            fires += int(fired)
        rates[k] = fires / ticks
    assert abs(rates[100] - rates[10_000]) <= 0.05, rates
    assert max(rates.values()) <= 0.1, rates

    rng = np.random.default_rng(1)
    small = np.array([rng.standard_normal(100).max() for _ in range(120)])
    fixed_thr = np.quantile(small, 0.95)  # "tuned" on the small fleet
    big = np.array([rng.standard_normal(10_000).max() for _ in range(120)])
    assert (big > fixed_thr).mean() > 0.5  # the fixed gate melts down


def test_gate_warmup_and_no_absorb_on_fire():
    gate = serve.gate_init()
    for stat in (1.0, 1.0, 1.0):  # warmup: calibrate, never fire
        fired, gate = serve.gate_update(gate, stat)
        assert not bool(fired)
    fired, gate = serve.gate_update(gate, 50.0)  # clear regime change
    assert bool(fired)
    assert float(gate.mean) <= 1.0 + 1e-6  # the spike was NOT absorbed
    fired, gate = serve.gate_update(gate, 1.0, update=False)  # masked tick
    assert not bool(fired) and int(gate.count) == 3


# ------------------------------------------------------------- serve wiring
def test_serve_fixed_threshold_never_touches_gate_or_hyper():
    cfg = serve.ServeConfig(
        sched=sched.SchedulerConfig(
            n_iters=2, grid_size=32, num_points=64, opt_steps=10
        ),
        capacity=4, drift_threshold=0.25, max_staleness=4,
    )
    loop = serve.ServiceLoop(2, config=cfg, seed=0)
    rng = np.random.default_rng(0)
    for _ in range(2):
        for _ in range(4):
            f = rng.uniform(0.2, 0.8, 2).astype(np.float32)
            loop.push(f, f**0.9 * np.array([4.0, 8.0], np.float32))
        loop.tick()
    assert int(loop.state.gate.count) == 0  # baseline never calibrated
    assert float(loop.state.hyper.n_workers) == 0.0  # hyper never refit
    assert loop.counters()["proposes"] >= 1


def test_serve_hierarchical_tick_end_to_end():
    """The jitted tick on the hierarchical path: the hyperprior refits on
    cadence, the surprise statistic drives the calibrated gate, and the
    loop still learns a sensible split."""
    cfg = serve.ServeConfig(
        sched=sched.SchedulerConfig(
            n_iters=2, grid_size=32, num_points=64, opt_steps=10,
            hierarchical=True, hyper_refit_every=2,
        ),
        capacity=4, max_staleness=4,
    )
    loop = serve.ServiceLoop(2, config=cfg, seed=0)
    rng = np.random.default_rng(0)
    for _ in range(6):
        for _ in range(4):
            f = rng.uniform(0.2, 0.8, 2).astype(np.float32)
            loop.push(f, f**0.9 * np.array([2.0, 8.0], np.float32))
        info = loop.tick()
    assert float(loop.state.hyper.n_workers) == 2.0  # refit happened
    assert int(loop.state.gate.count) >= 1  # gate is calibrating
    assert np.isfinite(float(info.drift))
    assert loop.counters()["proposes"] >= 1
    fr = loop.fractions()
    assert abs(float(fr.sum()) - 1.0) < 1e-5 and fr[0] > fr[1]


# ------------------------------------------------------------------ sharded
@multidevice
def test_hier_sharded_parity_refit_shrink_surprise():
    """Sharded refit (psum of 13 scalars), shrink and surprise match the
    single-device program; K chosen non-divisible to exercise padding."""
    cfg = ShardingConfig.auto()
    k = cfg.num_shards + 1
    key = jax.random.PRNGKey(0)
    f = jax.random.uniform(key, (k, 48), minval=0.1, maxval=0.9)
    t = f**0.9 * 10.0
    fleet, _ = gibbs.fit_fleet(key, t, f, n_iters=2, grid_size=32)

    h0 = hier.fit_hyperprior(fleet)
    h1 = hier.fit_hyperprior_sharded(fleet, cfg)
    _tree_close(h0, h1, 1e-4)

    s0 = hier.shrink(fleet, h0)
    s1 = hier.shrink(fleet, h0, sharding=cfg)
    assert bool(jnp.all(s0.key == s1.key))  # PRNG leaf untouched
    _tree_close(
        s0._replace(key=s0.key * 0), s1._replace(key=s1.key * 0), 1e-4
    )

    r0 = hier.surprise(fleet, h0)
    r1 = hier.surprise(fleet, h0, sharding=cfg)
    assert r1.shape == (k,)
    _tree_close(r0, r1, 1e-4)


@pytest.mark.skipif(
    jax.device_count() >= 2, reason="parity suite already ran in-process"
)
def test_hier_multidevice_subprocess():
    repo = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env.update(
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        PYTHONPATH=str(repo / "src"),
        JAX_PLATFORMS="cpu",
    )
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "-k", "sharded", "-p", "no:cacheprovider"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "passed" in r.stdout, r.stdout[-3000:]
