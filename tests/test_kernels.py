"""Pallas kernels vs pure-jnp oracles (interpret=True): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.moments import BetaParams, log_posterior_grid
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.posterior_grid import (
    posterior_grid_fleet_pallas,
    posterior_grid_pallas,
)


def _fleet_case(k, n, seed=0, zero_cols=False):
    """Synthetic K-worker telemetry with per-worker params and ragged masks."""
    key = jax.random.PRNGKey(seed)
    kf, kt, kp = jax.random.split(key, 3)
    f = jax.random.uniform(kf, (k, n), minval=0.05, maxval=0.95)
    mu = jnp.linspace(5.0, 40.0, k)
    t = f**0.9 * mu[:, None] + f**0.7 * 2.0 * jax.random.normal(kt, (k, n))
    # per-worker ragged validity + (optionally) whole zeroed columns
    mask = (jnp.arange(n)[None, :] < jnp.linspace(n // 2, n, k)[:, None]).astype(
        jnp.float32
    )
    if zero_cols:
        mask = mask * (jnp.arange(n) % 5 != 0).astype(jnp.float32)[None, :]
    lam = jnp.linspace(0.1, 0.5, k)
    alpha = jnp.linspace(0.6, 0.95, k)
    beta = jnp.linspace(0.5, 0.9, k)
    ap = BetaParams(jnp.linspace(1.5, 4.0, k), jnp.linspace(2.0, 3.0, k))
    bp = BetaParams(jnp.linspace(2.0, 5.0, k), jnp.linspace(1.5, 2.5, k))
    return t, f, mask, mu, lam, alpha, beta, ap, bp


def _assert_logp_close(got, want, rtol=2e-5):
    scale = 1.0 + float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=rtol, atol=rtol * scale
    )


@pytest.mark.parametrize("zero_cols", [False, True])
@pytest.mark.parametrize("k,g,n", [(1, 64, 100), (3, 300, 777), (4, 512, 128), (5, 17, 33)])
def test_posterior_grid_fleet_parity(k, g, n, zero_cols):
    """One fused launch (interpret mode) == unified oracle, both modes, for
    odd/padded G and N, per-worker priors, and zero-mask columns."""
    t, f, mask, mu, lam, alpha, beta, ap, bp = _fleet_case(k, n, zero_cols=zero_cols)
    grid = jnp.linspace(1e-4, 1 - 1e-4, g, dtype=jnp.float32)
    got = posterior_grid_fleet_pallas(
        grid, t, f, mask, mu, lam, alpha, beta, ap.a, ap.b, bp.a, bp.b,
        interpret=True, block_g=64, block_n=256,
    )
    want = log_posterior_grid(grid, t, f, mu, lam, alpha, beta, ap, bp, mask)
    assert got.shape == (k, 2, g)
    _assert_logp_close(got, want)


def test_oracle_symmetric_grid_identity():
    """On the (symmetric) exponent grid, the mirrored-pg^2 beta mode —
    the production fast path — must agree with the general reciprocal form."""
    from repro.core.moments import exponent_grid

    k, n = 3, 250
    t, f, mask, mu, lam, alpha, beta, ap, bp = _fleet_case(k, n, seed=9)
    for g in (64, 257):  # even and odd (padded) grid sizes
        grid = exponent_grid(g)
        general = log_posterior_grid(
            grid, t, f, mu, lam, alpha, beta, ap, bp, mask, symmetric_grid=False
        )
        mirrored = log_posterior_grid(
            grid, t, f, mu, lam, alpha, beta, ap, bp, mask, symmetric_grid=True
        )
        _assert_logp_close(mirrored, general, rtol=1e-5)


def test_posterior_grid_fleet_matches_vmapped_oracle():
    """The fleet axis of one launch == vmapping the oracle worker by worker."""
    k, g, n = 4, 96, 200
    t, f, mask, mu, lam, alpha, beta, ap, bp = _fleet_case(k, n, seed=3)
    grid = jnp.linspace(1e-4, 1 - 1e-4, g, dtype=jnp.float32)
    got = posterior_grid_fleet_pallas(
        grid, t, f, mask, mu, lam, alpha, beta, ap.a, ap.b, bp.a, bp.b,
        interpret=True,
    )
    want = jax.vmap(
        lambda ti, fi, mi, mui, lami, ai, bi, apa, apb, bpa, bpb: log_posterior_grid(
            grid, ti, fi, mui, lami, ai, bi,
            BetaParams(apa, apb), BetaParams(bpa, bpb), mi,
        )
    )(t, f, mask, mu, lam, alpha, beta, ap.a, ap.b, bp.a, bp.b)
    _assert_logp_close(got, want)


def test_posterior_grid_single_unit_is_fleet_slice():
    """The legacy single-unit, single-mode entry == the matching row of the
    fused fleet launch with K=1."""
    g, n = 128, 300
    t, f, mask, mu, lam, alpha, beta, ap, bp = _fleet_case(1, n, seed=5)
    grid = jnp.linspace(1e-4, 1 - 1e-4, g, dtype=jnp.float32)
    fleet = posterior_grid_fleet_pallas(
        grid, t, f, mask, mu, lam, alpha, beta, ap.a, ap.b, bp.a, bp.b,
        interpret=True,
    )
    got_a = posterior_grid_pallas(
        grid, t[0], f[0], mask[0], mu[0], lam[0], beta[0], ap.a[0], ap.b[0],
        mode="alpha", interpret=True,
    )
    got_b = posterior_grid_pallas(
        grid, t[0], f[0], mask[0], mu[0], lam[0], alpha[0], bp.a[0], bp.b[0],
        mode="beta", interpret=True,
    )
    _assert_logp_close(got_a, fleet[0, 0], rtol=1e-6)
    _assert_logp_close(got_b, fleet[0, 1], rtol=1e-6)


def test_posterior_grid_fleet_fully_masked_worker():
    """A worker with zero valid observations must fall back to its prior
    (finite everywhere, no NaN/Inf from the dead telemetry)."""
    k, g, n = 3, 64, 150
    t, f, mask, mu, lam, alpha, beta, ap, bp = _fleet_case(k, n, seed=7)
    mask = mask.at[1].set(0.0)
    grid = jnp.linspace(1e-4, 1 - 1e-4, g, dtype=jnp.float32)
    got = posterior_grid_fleet_pallas(
        grid, t, f, mask, mu, lam, alpha, beta, ap.a, ap.b, bp.a, bp.b,
        interpret=True,
    )
    assert bool(jnp.all(jnp.isfinite(got)))
    want = log_posterior_grid(grid, t, f, mu, lam, alpha, beta, ap, bp, mask)
    _assert_logp_close(got, want)
    # prior-only: the dead worker's alpha posterior is exactly the Beta prior
    gc = jnp.clip(grid, 1e-6, 1 - 1e-6)
    prior_only = (ap.a[1] - 1.0) * jnp.log(gc) + (ap.b[1] - 1.0) * jnp.log1p(-gc)
    np.testing.assert_allclose(
        np.asarray(got[1, 0]), np.asarray(prior_only), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("mode", ["alpha", "beta"])
@pytest.mark.parametrize("g,n", [(64, 100), (300, 777), (512, 2048), (17, 33)])
def test_posterior_grid_shapes(mode, g, n):
    key = jax.random.PRNGKey(g * 1000 + n)
    kf, kt = jax.random.split(key)
    f = jax.random.uniform(kf, (n,), minval=0.05, maxval=0.95)
    t = f**0.9 * 25.0 + f**0.7 * 2.0 * jax.random.normal(kt, (n,))
    grid = jnp.linspace(1e-4, 1 - 1e-4, g, dtype=jnp.float32)
    mask = (jnp.arange(n) % 7 != 0).astype(jnp.float32)
    args = (jnp.float32(25.0), jnp.float32(0.25), jnp.float32(0.7),
            jnp.float32(2.0), jnp.float32(3.0))
    got = posterior_grid_pallas(
        grid, t, f, mask, *args, mode=mode, interpret=True,
        block_g=64, block_n=256,
    )
    want = ref.posterior_grid_ref(
        grid, t, f, args[0], args[1], args[2], args[3], args[4], mask, mode=mode
    )
    scale = 1.0 + float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5 * scale)


@pytest.mark.parametrize("block_g,block_n", [(8, 128), (128, 512), (256, 1024)])
def test_posterior_grid_block_invariance(block_g, block_n):
    """Result must not depend on the tiling."""
    key = jax.random.PRNGKey(5)
    kf, kt = jax.random.split(key)
    n, g = 513, 100
    f = jax.random.uniform(kf, (n,), minval=0.1, maxval=0.9)
    t = f * 10.0 + jax.random.normal(kt, (n,))
    grid = jnp.linspace(1e-4, 1 - 1e-4, g, dtype=jnp.float32)
    mask = jnp.ones((n,), jnp.float32)
    out = posterior_grid_pallas(
        grid, t, f, mask, 10.0, 1.0, 0.9, 2.0, 2.0,
        mode="alpha", interpret=True, block_g=block_g, block_n=block_n,
    )
    want = ref.posterior_grid_ref(
        grid, t, f, jnp.float32(10.0), jnp.float32(1.0), jnp.float32(0.9),
        jnp.float32(2.0), jnp.float32(2.0), mask, mode="alpha",
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=1e-3)


def test_posterior_grid_ref_deprecation_names_unified_oracle():
    """The shim's DeprecationWarning must point callers at the CURRENT
    replacement — ``repro.core.moments.log_posterior_grid`` — and the
    equivalence the message promises must actually hold."""
    grid = jnp.linspace(1e-4, 1 - 1e-4, 8, dtype=jnp.float32)
    t = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    f = jnp.full((4,), 0.5, jnp.float32)
    args = (jnp.float32(1.0), jnp.float32(1.0), jnp.float32(0.5),
            jnp.float32(2.0), jnp.float32(2.0))
    with pytest.warns(
        DeprecationWarning, match=r"repro\.core\.moments\.log_posterior_grid"
    ) as rec:
        out = ref.posterior_grid_ref(grid, t, f, *args, mode="alpha")
    assert "log_posterior_{alpha,beta}_ref" in str(rec[0].message)
    from repro.core.moments import log_posterior_alpha_ref

    want = log_posterior_alpha_ref(
        grid, t, f, args[0], args[1], args[2], BetaParams(args[3], args[4])
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kvh,d,s", [(2, 8, 2, 64, 300), (1, 4, 4, 32, 128), (3, 9, 3, 16, 1000)]
)
def test_decode_attention(b, h, kvh, d, s, dtype):
    key = jax.random.PRNGKey(b + h + s)
    kq, kk, kv, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, h, d), dtype)
    k = jax.random.normal(kk, (b, s, kvh, d), dtype)
    v = jax.random.normal(kv, (b, s, kvh, d), dtype)
    length = jax.random.randint(kl, (b,), 1, s + 1)
    got = decode_attention_pallas(q, k, v, length, block_s=128, interpret=True)
    want = ref.decode_attention_ref(q, k, v, length)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_decode_attention_empty_tail_blocks_skipped():
    """Cache fill far below capacity: blocks past length must not contribute."""
    b, h, kvh, d, s = 2, 4, 1, 32, 2048
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, d))
    k = jax.random.normal(kk, (b, s, kvh, d))
    v = jax.random.normal(kv, (b, s, kvh, d))
    length = jnp.asarray([5, 17], jnp.int32)
    got = decode_attention_pallas(q, k, v, length, block_s=256, interpret=True)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,t,r,bt", [(2, 64, 128, 16), (1, 100, 300, 32), (3, 17, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lru_scan(b, t, r, bt, dtype):
    from repro.kernels.lru_scan import lru_scan_pallas

    key = jax.random.PRNGKey(b * t + r)
    ka, kb, kh = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(ka, (b, t, r))).astype(dtype)
    x = jax.random.normal(kb, (b, t, r), dtype)
    h0 = jax.random.normal(kh, (b, r), dtype)
    got = lru_scan_pallas(a, x, h0, block_t=bt, interpret=True)
    want = ref.lru_scan_ref(a, x, h0)
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_lru_scan_continuation_matches_single_pass():
    """Scanning [0:k] then [k:] with the carried state == one pass (the
    prefill->decode state-handoff property)."""
    from repro.kernels.lru_scan import lru_scan_pallas

    key = jax.random.PRNGKey(0)
    ka, kb = jax.random.split(key)
    b, t, r, k = 2, 48, 64, 20
    a = jax.nn.sigmoid(jax.random.normal(ka, (b, t, r)))
    x = jax.random.normal(kb, (b, t, r))
    h0 = jnp.zeros((b, r))
    full = lru_scan_pallas(a, x, h0, block_t=16, interpret=True)
    first = lru_scan_pallas(a[:, :k], x[:, :k], h0, block_t=16, interpret=True)
    second = lru_scan_pallas(a[:, k:], x[:, k:], first[:, -1], block_t=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(second), np.asarray(full[:, k:]), rtol=1e-5, atol=1e-5
    )
