"""Sharded-vs-single-device parity of the estimation engine.

The multi-device tests need >= 2 devices: CI provides them via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on the tier-1 step
(the dry-run subprocess is unaffected — it overwrites its own XLA_FLAGS).
On a plain single-device run, ``test_multidevice_suite_subprocess`` re-runs
this file in an 8-fake-device subprocess instead, so the parity suite is
exercised either way.
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched
from repro.core import gibbs
from repro.core.moments import BetaParams, exponent_grid
from repro.core.sharding import (
    ShardingConfig,
    constrain_fleet,
    pad_fleet_axis,
    unpad_fleet_axis,
)
from repro.kernels import ops

multidevice = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs >= 2 devices (see module docstring)"
)


def _fleet(k: int, n: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    kt, kf, ks = jax.random.split(key, 3)
    f = jax.random.uniform(kf, (k, n), minval=0.05, maxval=0.95)
    t = f**0.9 * 25.0 + f**0.7 * 2.0 * jax.random.normal(kt, (k, n))
    states = jax.vmap(lambda kk: gibbs.init_state(kk, mu_guess=25.0))(
        jax.random.split(ks, k)
    )
    return states, t, f


def _tree_close(a, b, tol):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(
            np.asarray(la, np.float64), np.asarray(lb, np.float64), atol=tol, rtol=tol
        )


# --------------------------------------------------------------------------
# sharding-config plumbing (device-count independent)
# --------------------------------------------------------------------------
def test_sharding_config_validates_axis():
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()), ("model",))
    with pytest.raises(ValueError, match="workers"):
        ShardingConfig(mesh=mesh)
    assert ShardingConfig(mesh=mesh, axis="model").num_shards == jax.device_count()


def test_sharding_config_is_jit_static():
    cfg = ShardingConfig.auto()
    assert hash(cfg) == hash(ShardingConfig.auto())
    sc = sched.SchedulerConfig(mesh=cfg)
    assert hash(sc) == hash(sched.SchedulerConfig(mesh=cfg))
    # a bare Mesh is accepted and normalized by SchedulerConfig
    assert sched.SchedulerConfig(mesh=cfg.mesh).mesh == cfg


def test_pad_unpad_roundtrip():
    tree = {"a": jnp.arange(6.0).reshape(3, 2), "b": jnp.arange(3)}
    padded = pad_fleet_axis(tree, 2)
    assert padded["a"].shape == (5, 2)
    assert jnp.all(padded["a"][3:] == padded["a"][2])  # edge rows, finite
    _tree_close(unpad_fleet_axis(padded, 3), tree, 0.0)


def test_constrain_fleet_none_is_noop():
    x = jnp.ones((4, 3))
    assert constrain_fleet(x, None) is x


# --------------------------------------------------------------------------
# multi-device parity
# --------------------------------------------------------------------------
@multidevice
def test_gibbs_batch_sharded_bitwise_chains():
    """Chains advance bitwise-identically: per-worker PRNG splits make the
    sharded program a pure re-layout of the single-device one."""
    cfg = ShardingConfig.auto()
    k = 2 * cfg.num_shards
    states, t, f = _fleet(k, 64)
    r_st, r_ll = gibbs.gibbs_batch(states, t, f, n_iters=3, grid_size=64)
    s_st, s_ll = gibbs.gibbs_batch(
        states, t, f, n_iters=3, grid_size=64, sharding=cfg
    )
    assert bool(jnp.all(r_st.key == s_st.key))  # PRNG stream: exactly equal
    _tree_close(r_st._replace(key=r_st.key * 0), s_st._replace(key=s_st.key * 0), 1e-4)
    _tree_close(r_ll, s_ll, 1e-4)


@multidevice
def test_gibbs_batch_sharded_padding_parity():
    """K % n_shards != 0: dummy workers are masked out and sliced off."""
    cfg = ShardingConfig.auto()
    k = cfg.num_shards + max(cfg.num_shards - 3, 1)  # never divisible
    assert k % cfg.num_shards != 0
    states, t, f = _fleet(k, 48)
    r_st, r_ll = gibbs.gibbs_batch(states, t, f, n_iters=3, grid_size=64)
    s_st, s_ll = gibbs.gibbs_batch(
        states, t, f, n_iters=3, grid_size=64, sharding=cfg
    )
    assert r_ll.shape == s_ll.shape == (k,)
    assert bool(jnp.all(r_st.key == s_st.key))
    _tree_close(r_ll, s_ll, 1e-4)


@multidevice
def test_gibbs_batch_sharded_pallas_parity():
    """The fused Pallas launch runs per-shard; posteriors match <= 1e-4."""
    cfg = ShardingConfig.auto()
    states, t, f = _fleet(2 * cfg.num_shards, 64)
    r_st, r_ll = gibbs.gibbs_batch(
        states, t, f, n_iters=2, grid_size=64, use_pallas=True
    )
    s_st, s_ll = gibbs.gibbs_batch(
        states, t, f, n_iters=2, grid_size=64, use_pallas=True, sharding=cfg
    )
    assert bool(jnp.all(r_st.key == s_st.key))
    _tree_close(r_ll, s_ll, 1e-4)


@multidevice
def test_fit_dag_sharded_parity():
    """The folded S*K stage-fleet axis shards like any fleet axis."""
    cfg = ShardingConfig.auto()
    _, t, f = _fleet(12, 48)
    td, fd = t.reshape(3, 4, 48), f.reshape(3, 4, 48)
    r_st, r_ll = gibbs.fit_dag(jax.random.PRNGKey(7), td, fd, n_iters=2, grid_size=64)
    s_st, s_ll = gibbs.fit_dag(
        jax.random.PRNGKey(7), td, fd, n_iters=2, grid_size=64, sharding=cfg
    )
    assert s_ll.shape == (3, 4)
    assert bool(jnp.all(r_st.key == s_st.key))
    _tree_close(r_ll, s_ll, 1e-4)


@multidevice
def test_posterior_grid_fleet_sharded_parity():
    """Kernel wrapper: per-shard launches + gathered (K, 2, G) output."""
    cfg = ShardingConfig.auto()
    k, n, g = cfg.num_shards + 1, 48, 64  # exercises the pad path too
    _, t, f = _fleet(k, n)
    grid = exponent_grid(g)
    ones = jnp.ones((k,), jnp.float32)
    prior = BetaParams(2.0 * ones, 2.0 * ones)
    args = (grid, t, f, 25.0 * ones, 0.25 * ones, 0.9 * ones, 0.7 * ones,
            prior, prior)
    ref = ops.posterior_grid_fleet(*args)
    out = ops.posterior_grid_fleet(*args, sharding=cfg)
    assert out.shape == (k, 2, g)
    _tree_close(ref, out, 1e-5)


@multidevice
def test_observe_sharded_parity_and_state_shardings():
    cfg = ShardingConfig.auto()
    k = 2 * cfg.num_shards
    config0 = sched.SchedulerConfig(n_iters=2, grid_size=32)
    config1 = sched.SchedulerConfig(n_iters=2, grid_size=32, mesh=cfg)
    _, t, f = _fleet(k, 32)
    tel = sched.Telemetry(fracs=f, times=t)
    st0 = sched.init(config0, k, jax.random.PRNGKey(1))
    st1 = sched.init(config1, k, jax.random.PRNGKey(1))
    # divisible fleet: the state leaves carry workers-axis shardings
    assert st1.gibbs.mu.sharding.spec == cfg.spec()
    st0, ll0 = sched.observe(st0, tel, config0)
    st1, ll1 = sched.observe(st1, tel, config1)
    assert st1.gibbs.mu.sharding.spec == cfg.spec()  # preserved by observe
    _tree_close(ll0, ll1, 1e-4)
    # propose consumes the sharded state transparently (auto-gather)
    f0, _ = sched.propose(st0, config0)
    f1, _ = sched.propose(st1, config1)
    _tree_close(f0, f1, 1e-4)


@multidevice
def test_observe_dag_sharded_parity():
    cfg = ShardingConfig.auto()
    dag = sched.WorkflowDAG.chain(3, 4)
    config0 = sched.SchedulerConfig(n_iters=2, grid_size=32)
    config1 = sched.SchedulerConfig(n_iters=2, grid_size=32, mesh=cfg)
    _, t, f = _fleet(12, 32)
    tel = sched.Telemetry(fracs=f.reshape(3, 4, 32), times=t.reshape(3, 4, 32))
    d0 = sched.init_dag(config0, dag, jax.random.PRNGKey(2))
    d1 = sched.init_dag(config1, dag, jax.random.PRNGKey(2))
    d0, ll0 = sched.observe_dag(d0, tel, config0)
    d1, ll1 = sched.observe_dag(d1, tel, config1)
    assert ll1.shape == (3, 4)
    _tree_close(ll0, ll1, 1e-4)


@multidevice
def test_vmapped_multi_tenant_on_mesh_path():
    """One more vmap axis on top of the sharded fleet program: a multi-tenant
    deployment estimates T independent fleets through the SAME mesh."""
    cfg = ShardingConfig.auto()
    k = cfg.num_shards
    config = sched.SchedulerConfig(n_iters=2, grid_size=32, mesh=cfg)
    states = jax.vmap(
        lambda kk: sched.init(config, k, kk)
    )(jax.random.split(jax.random.PRNGKey(3), 2))
    _, t, f = _fleet(k, 32)
    tel = sched.Telemetry(
        fracs=jnp.stack([f, f]), times=jnp.stack([t, 1.3 * t])
    )
    obs = jax.vmap(lambda s, tl: sched.observe(s, tl, config))
    new_states, ll = obs(states, tel)
    assert ll.shape == (2, k)
    # per-tenant results match the unvmapped sharded transition
    st0 = jax.tree_util.tree_map(lambda x: x[0], states)
    _, ll0 = sched.observe(st0, sched.Telemetry(fracs=f, times=t), config)
    _tree_close(ll[0], ll0, 1e-4)
    # tenants really are independent: different telemetry, different beliefs
    assert not np.allclose(np.asarray(ll[0]), np.asarray(ll[1]))


@multidevice
def test_sharded_state_checkpoint_roundtrip(tmp_path):
    """CheckpointManager gathers sharded leaves on save and restores into a
    fresh (sharded) template — the trainer path survives unchanged."""
    from repro.checkpoint.checkpoint import CheckpointManager

    cfg = ShardingConfig.auto()
    k = cfg.num_shards
    config = sched.SchedulerConfig(n_iters=2, grid_size=32, mesh=cfg)
    state = sched.init(config, k, jax.random.PRNGKey(4))
    _, t, f = _fleet(k, 32)
    state, _ = sched.observe(state, sched.Telemetry(fracs=f, times=t), config)
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, {"sched": state})
    restored, _ = mgr.restore({"sched": sched.init(config, k, jax.random.PRNGKey(9))})
    _tree_close(restored["sched"], state, 0.0)


# --------------------------------------------------------------------------
# single-device driver: run the suite above under 8 fake devices
# --------------------------------------------------------------------------
@pytest.mark.skipif(
    jax.device_count() >= 2, reason="parity suite already ran in-process"
)
def test_multidevice_suite_subprocess():
    repo = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env.update(
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        PYTHONPATH=str(repo / "src"),
        JAX_PLATFORMS="cpu",
    )
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "-p", "no:cacheprovider"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "passed" in r.stdout, r.stdout[-3000:]
