"""Invariants of microbatch quantization, incl. adversarial fraction vectors
and the batched on-device refinement."""
import numpy as np
import pytest

from repro import sched
from repro.core.frontier import UnitParams, mean_var_completion


def _check_invariants(counts, total, min_per_worker=1):
    assert counts.sum() == total
    assert (counts >= min_per_worker).all()


def test_counts_sum_and_floor():
    counts = sched.quantize_fractions(np.array([0.61, 0.29, 0.10]), 16)
    _check_invariants(counts, 16)
    assert counts[0] > counts[1] > counts[2]


def test_min_per_worker_respected():
    fr = np.array([0.97, 0.01, 0.01, 0.01])
    counts = sched.quantize_fractions(fr, 12, min_per_worker=2)
    _check_invariants(counts, 12, min_per_worker=2)


def test_k_near_total_terminates():
    """K workers, total barely above K*min: the over-allocation shed loop
    must terminate and land exactly on the total."""
    k = 16
    fr = np.full(k, 1.0 / k)
    counts = sched.quantize_fractions(fr, k, min_per_worker=1)
    _check_invariants(counts, k)
    assert (counts == 1).all()

    counts = sched.quantize_fractions(fr, k + 1, min_per_worker=1)
    _check_invariants(counts, k + 1)


def test_near_zero_fractions_terminate():
    """Degenerate simplex corners: min_per_worker floors force shedding from
    the dominant worker without infinite-looping."""
    k = 8
    fr = np.zeros(k)
    fr[0] = 1.0  # everything on one worker
    counts = sched.quantize_fractions(fr, 10, min_per_worker=1)
    _check_invariants(counts, 10)
    assert counts[0] == 10 - (k - 1)

    fr = np.full(k, 1e-12)
    fr[3] = 1.0 - 7e-12
    counts = sched.quantize_fractions(fr, k, min_per_worker=1)
    _check_invariants(counts, k)


def test_random_adversarial_vectors():
    rng = np.random.default_rng(0)
    for _ in range(25):
        k = int(rng.integers(2, 12))
        total = int(rng.integers(k, 4 * k))
        # spiky dirichlet: most mass on few workers
        fr = rng.dirichlet(np.full(k, 0.05))
        counts = sched.quantize_fractions(fr, total)
        _check_invariants(counts, total)


def test_total_too_small_raises():
    with pytest.raises(ValueError):
        sched.quantize_fractions(np.array([0.5, 0.5]), 3, min_per_worker=2)


def test_batched_refinement_improves_objective():
    p = UnitParams.of([10.0, 20.0, 40.0], [1.0, 2.0, 4.0])
    fracs, _ = sched.solve_fractions(p)
    counts = sched.quantize_fractions(np.asarray(fracs), 8, p)
    _check_invariants(counts, 8)
    naive = np.array([3, 3, 2])

    def obj(c):
        import jax.numpy as jnp

        e, _ = mean_var_completion(jnp.asarray(c / 8.0, jnp.float32), p)
        return float(e)

    assert obj(counts) <= obj(naive) + 1e-6


def test_refinement_preserves_invariants():
    rng = np.random.default_rng(1)
    k = 6
    p = UnitParams.of(list(rng.uniform(5, 40, k)), list(rng.uniform(0.5, 3, k)))
    fr = rng.dirichlet(np.full(k, 0.2))
    for total, minw in ((k, 1), (13, 1), (24, 2)):
        counts = sched.quantize_fractions(
            fr, total, p, min_per_worker=minw
        )
        _check_invariants(counts, total, min_per_worker=minw)


# ---------------------------------------------------------------------------
# fleet-scale rounding (water-fill shed/top-up) and live-masked quantization
# ---------------------------------------------------------------------------
def test_large_fleet_rounding_invariants_and_proportionality():
    """The vectorized water-fill replaces the O(K^2 log K) greedy loops: at
    K in the thousands the invariants must hold and counts must track the
    real-valued allocation to within the one-unit rounding granularity."""
    rng = np.random.default_rng(2)
    # spiky fleets: invariants only (the min floor forces redistribution)
    for k, total in ((512, 4096), (2000, 2000), (2000, 6000)):
        fr = rng.dirichlet(np.full(k, 0.3))
        counts = sched.quantize_fractions(fr, total)
        _check_invariants(counts, total)
    # near-uniform fleet where the floor never binds: counts must track the
    # real-valued allocation to within the one-unit rounding granularity
    k = 4096
    fr = rng.dirichlet(np.full(k, 50.0))
    counts = sched.quantize_fractions(fr, 8 * k)
    _check_invariants(counts, 8 * k)
    assert np.max(np.abs(counts - fr * 8 * k)) <= 2.0


def test_large_fleet_rounding_deterministic():
    rng = np.random.default_rng(3)
    fr = rng.dirichlet(np.full(1024, 0.1))
    a = sched.quantize_fractions(fr, 8192)
    b = sched.quantize_fractions(fr, 8192)
    np.testing.assert_array_equal(a, b)


def test_spiky_large_fleet_sheds_to_floor():
    """One dominant worker at K=1000: shedding must pull thousands of units
    off it in one water-fill, not one unit per pass."""
    k = 1000
    fr = np.full(k, 1e-9)
    fr[7] = 1.0 - (k - 1) * 1e-9
    counts = sched.quantize_fractions(fr, k + 50)
    _check_invariants(counts, k + 50)
    assert counts[7] == 51  # everyone else pinned at the floor


def test_live_mask_zeroes_dead_and_preserves_invariants():
    rng = np.random.default_rng(4)
    k = 12
    fr = rng.dirichlet(np.full(k, 0.5))
    live = np.ones(k, bool)
    live[[2, 5, 9]] = False
    counts = sched.quantize_fractions(fr, 64, live=live)
    assert counts.shape == (k,)
    assert (counts[~live] == 0).all()
    assert counts.sum() == 64
    assert (counts[live] >= 1).all()


def test_live_mask_with_params_and_refinement():
    k = 6
    p = UnitParams.of([10.0, 20.0, 40.0, 15.0, 25.0, 30.0],
                      [1.0, 2.0, 4.0, 1.5, 2.5, 3.0])
    fr = np.full(k, 1.0 / k)
    live = np.asarray([True, True, False, True, True, False])
    counts = sched.quantize_fractions(fr, 24, p, live=live, min_per_worker=2)
    assert (counts[~live] == 0).all()
    assert counts.sum() == 24
    assert (counts[live] >= 2).all()


def test_all_live_mask_matches_no_mask():
    rng = np.random.default_rng(5)
    k = 10
    fr = rng.dirichlet(np.full(k, 0.4))
    a = sched.quantize_fractions(fr, 40)
    b = sched.quantize_fractions(fr, 40, live=np.ones(k, bool))
    np.testing.assert_array_equal(a, b)


def test_slab_refinement_improves_objective_at_scale():
    """Above the exact-sweep cutoff the donor/receiver slab refinement must
    still only ever improve the objective while keeping the invariants."""
    import jax.numpy as jnp

    rng = np.random.default_rng(6)
    k = 48  # > _REFINE_SLAB: slab path, not the exact K x K sweep
    p = UnitParams.of(list(rng.uniform(5, 50, k)), list(rng.uniform(0.5, 4, k)))
    fr = rng.dirichlet(np.full(k, 0.5))
    total = 480
    counts = sched.quantize_fractions(fr, total, p)
    _check_invariants(counts, total)

    def obj(c):
        e, _ = mean_var_completion(jnp.asarray(c / total, jnp.float32), p)
        return float(e)

    # naive proportional rounding (largest-remainder) as the no-refinement bar
    raw = fr * total
    naive = np.maximum(np.floor(raw).astype(int), 1)
    gap = total - naive.sum()
    order = np.argsort(raw - np.floor(raw))[::-1]
    for i in range(abs(gap)):
        naive[order[i % k]] += 1 if gap > 0 else -1
    assert obj(counts) <= obj(naive) + 1e-6
