"""Invariants of microbatch quantization, incl. adversarial fraction vectors
and the batched on-device refinement."""
import numpy as np
import pytest

from repro import sched
from repro.core.frontier import UnitParams, mean_var_completion


def _check_invariants(counts, total, min_per_worker=1):
    assert counts.sum() == total
    assert (counts >= min_per_worker).all()


def test_counts_sum_and_floor():
    counts = sched.quantize_fractions(np.array([0.61, 0.29, 0.10]), 16)
    _check_invariants(counts, 16)
    assert counts[0] > counts[1] > counts[2]


def test_min_per_worker_respected():
    fr = np.array([0.97, 0.01, 0.01, 0.01])
    counts = sched.quantize_fractions(fr, 12, min_per_worker=2)
    _check_invariants(counts, 12, min_per_worker=2)


def test_k_near_total_terminates():
    """K workers, total barely above K*min: the over-allocation shed loop
    must terminate and land exactly on the total."""
    k = 16
    fr = np.full(k, 1.0 / k)
    counts = sched.quantize_fractions(fr, k, min_per_worker=1)
    _check_invariants(counts, k)
    assert (counts == 1).all()

    counts = sched.quantize_fractions(fr, k + 1, min_per_worker=1)
    _check_invariants(counts, k + 1)


def test_near_zero_fractions_terminate():
    """Degenerate simplex corners: min_per_worker floors force shedding from
    the dominant worker without infinite-looping."""
    k = 8
    fr = np.zeros(k)
    fr[0] = 1.0  # everything on one worker
    counts = sched.quantize_fractions(fr, 10, min_per_worker=1)
    _check_invariants(counts, 10)
    assert counts[0] == 10 - (k - 1)

    fr = np.full(k, 1e-12)
    fr[3] = 1.0 - 7e-12
    counts = sched.quantize_fractions(fr, k, min_per_worker=1)
    _check_invariants(counts, k)


def test_random_adversarial_vectors():
    rng = np.random.default_rng(0)
    for _ in range(25):
        k = int(rng.integers(2, 12))
        total = int(rng.integers(k, 4 * k))
        # spiky dirichlet: most mass on few workers
        fr = rng.dirichlet(np.full(k, 0.05))
        counts = sched.quantize_fractions(fr, total)
        _check_invariants(counts, total)


def test_total_too_small_raises():
    with pytest.raises(ValueError):
        sched.quantize_fractions(np.array([0.5, 0.5]), 3, min_per_worker=2)


def test_batched_refinement_improves_objective():
    p = UnitParams.of([10.0, 20.0, 40.0], [1.0, 2.0, 4.0])
    fracs, _ = sched.solve_fractions(p)
    counts = sched.quantize_fractions(np.asarray(fracs), 8, p)
    _check_invariants(counts, 8)
    naive = np.array([3, 3, 2])

    def obj(c):
        import jax.numpy as jnp

        e, _ = mean_var_completion(jnp.asarray(c / 8.0, jnp.float32), p)
        return float(e)

    assert obj(counts) <= obj(naive) + 1e-6


def test_refinement_preserves_invariants():
    rng = np.random.default_rng(1)
    k = 6
    p = UnitParams.of(list(rng.uniform(5, 40, k)), list(rng.uniform(0.5, 3, k)))
    fr = rng.dirichlet(np.full(k, 0.2))
    for total, minw in ((k, 1), (13, 1), (24, 2)):
        counts = sched.quantize_fractions(
            fr, total, p, min_per_worker=minw
        )
        _check_invariants(counts, total, min_per_worker=minw)
