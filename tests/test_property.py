"""Hypothesis property tests on the system's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.frontier import UnitParams, completion_cdf, pareto_mask
from repro.core.moments import fit_beta_method_of_moments
from repro.core.partitioner import quantize_fractions
from repro.core.posterior import NormalGammaParams, update_normal_gamma
from repro.train.train_step import cross_entropy

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")

pos_floats = st.floats(0.1, 100.0, allow_nan=False)
exponents = st.floats(0.05, 1.0, allow_nan=False)


@given(
    n=st.integers(1, 64),
    mu0=st.floats(-10, 10),
    kappa0=st.floats(1e-3, 10),
    alpha=exponents,
    beta=exponents,
    seed=st.integers(0, 1000),
)
def test_normal_gamma_update_invariants(n, mu0, kappa0, alpha, beta, seed):
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.normal(5, 2, n), jnp.float32)
    f = jnp.asarray(rng.uniform(0.05, 1.0, n), jnp.float32)
    prior = NormalGammaParams(
        jnp.float32(mu0), jnp.float32(kappa0), jnp.float32(1.0), jnp.float32(1.0)
    )
    post = update_normal_gamma(prior, t, f, jnp.float32(alpha), jnp.float32(beta))
    # precision-count only grows; nu grows by exactly N/2; psi stays positive
    assert float(post.kappa0) > float(prior.kappa0)
    np.testing.assert_allclose(float(post.nu0), 1.0 + n / 2, rtol=1e-6)
    assert float(post.psi0) > 0
    assert np.isfinite(float(post.mu0))


@given(
    k=st.integers(1, 4),
    g=st.integers(3, 70),
    n=st.integers(2, 90),
    mu=st.floats(0.5, 50.0),
    lam=st.floats(0.05, 2.0),
    alpha=exponents,
    beta=exponents,
    mask_stride=st.integers(0, 4),
    seed=st.integers(0, 100),
)
@settings(max_examples=12, deadline=None)
def test_fused_kernel_oracle_parity_property(k, g, n, mu, lam, alpha, beta, mask_stride, seed):
    """Fused fleet kernel (interpret mode) == unified oracle for arbitrary
    odd/padded shapes, parameters, and masks, including zeroed columns."""
    from repro.core.moments import BetaParams, log_posterior_grid
    from repro.kernels.posterior_grid import posterior_grid_fleet_pallas

    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.uniform(0.05, 0.95, (k, n)), jnp.float32)
    t = jnp.asarray(
        np.asarray(f) ** alpha * mu
        + np.asarray(f) ** beta * rng.normal(0, 1.0, (k, n)),
        jnp.float32,
    )
    mask = np.ones((k, n), np.float32)
    if mask_stride:
        mask[:, ::mask_stride + 1] = 0.0
    mask = jnp.asarray(mask)
    grid = jnp.linspace(1e-4, 1 - 1e-4, g, dtype=jnp.float32)
    ones = jnp.ones((k,), jnp.float32)
    prior = BetaParams(2.0 * ones, 2.0 * ones)
    got = posterior_grid_fleet_pallas(
        grid, t, f, mask, mu * ones, lam * ones, alpha * ones, beta * ones,
        prior.a, prior.b, prior.a, prior.b, interpret=True,
    )
    want = log_posterior_grid(
        grid, t, f, mu * ones, lam * ones, alpha * ones, beta * ones,
        prior, prior, mask,
    )
    scale = 1.0 + float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=5e-5, atol=5e-5 * scale
    )


@given(
    mean=st.floats(0.05, 0.95),
    var_frac=st.floats(0.01, 0.95),
)
def test_beta_fit_valid_and_mean_preserving(mean, var_frac):
    var = var_frac * mean * (1 - mean)
    fit = fit_beta_method_of_moments(jnp.float32(mean), jnp.float32(var))
    a, b = float(fit.a), float(fit.b)
    assert a > 0 and b > 0
    np.testing.assert_allclose(a / (a + b), mean, rtol=5e-3, atol=5e-3)


@given(
    k=st.integers(2, 6),
    total=st.integers(8, 128),
    seed=st.integers(0, 100),
)
def test_quantize_partition_of_unity(k, total, seed):
    if total < k:
        return
    rng = np.random.default_rng(seed)
    fr = rng.dirichlet(np.ones(k))
    counts = quantize_fractions(fr, total)
    assert counts.sum() == total
    assert (counts >= 1).all()
    # counts approximate fractions within 1 unit + rounding of the floor
    assert np.all(np.abs(counts - fr * total) <= k + 1)


@given(
    k=st.integers(1, 4),
    seed=st.integers(0, 50),
)
def test_completion_cdf_monotone_and_bounded(k, seed):
    rng = np.random.default_rng(seed)
    p = UnitParams.of(rng.uniform(5, 50, k), rng.uniform(0.5, 5, k),
                      rng.uniform(0.5, 1, k), rng.uniform(0.5, 1, k))
    fr = jnp.asarray(rng.dirichlet(np.ones(k)), jnp.float32)
    eps = jnp.linspace(0.0, 100.0, 128)
    cdf = np.asarray(completion_cdf(eps, fr, p))
    assert (cdf >= -1e-6).all() and (cdf <= 1 + 1e-6).all()
    assert (np.diff(cdf) >= -1e-5).all()  # monotone non-decreasing


@given(seed=st.integers(0, 200))
def test_pareto_mask_is_exactly_nondominated_set(seed):
    rng = np.random.default_rng(seed)
    mu = jnp.asarray(rng.uniform(0, 10, 32), jnp.float32)
    var = jnp.asarray(rng.uniform(0, 10, 32), jnp.float32)
    mask = np.asarray(pareto_mask(mu, var))
    mu_n, var_n = np.asarray(mu), np.asarray(var)
    for i in range(32):
        dominated = bool(
            np.any(
                (mu_n <= mu_n[i]) & (var_n <= var_n[i])
                & ((mu_n < mu_n[i]) | (var_n < var_n[i]))
            )
        )
        assert mask[i] == (not dominated)


@given(
    b=st.integers(1, 4),
    t=st.integers(1, 8),
    v=st.integers(4, 32),
    seed=st.integers(0, 100),
)
def test_cross_entropy_bounds_and_masking(b, t, v, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(b, t, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, size=(b, t)), jnp.int32)
    xent, z = cross_entropy(logits, labels, v)
    assert float(xent) >= -1e-5
    # fully-masked labels give zero loss
    xent_m, _ = cross_entropy(logits, jnp.full((b, t), -100, jnp.int32), v)
    assert abs(float(xent_m)) < 1e-6
    # uniform logits -> log(v)
    xent_u, _ = cross_entropy(jnp.zeros((b, t, v)), labels, v)
    np.testing.assert_allclose(float(xent_u), np.log(v), rtol=1e-5)
