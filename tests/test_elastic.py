"""Elastic membership on capacity slots: device-resident admit/retire with a
live mask, mesh-path round-trips, EWMA/live consistency, and zero-retrace
jitted cycles under fixed capacity."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched
from repro.core.sharding import ShardingConfig

CFG = sched.SchedulerConfig(n_iters=2, grid_size=32, num_points=64, opt_steps=10)


def _telemetry(key, k, n=16):
    f = jax.random.uniform(key, (k, n), minval=0.1, maxval=0.9)
    t = f**0.9 * jnp.linspace(5.0, 25.0, k)[:, None]
    return sched.Telemetry(fracs=f, times=t)


def test_capacity_init_shapes_and_live_mask():
    state = sched.init(CFG, num_workers=3, key=jax.random.PRNGKey(0), capacity=8)
    assert sched.capacity(state) == 8
    assert sched.num_workers(state) == 3
    np.testing.assert_array_equal(
        np.asarray(state.live), [1, 1, 1, 0, 0, 0, 0, 0]
    )
    assert state.ewma_ll.shape == (8,)


def test_exact_size_init_keeps_legacy_treedef():
    """Without capacity, live is None — the pytree structure (and therefore
    every jit cache and checkpoint layout) is unchanged from the legacy."""
    legacy = sched.init(CFG, num_workers=3, key=jax.random.PRNGKey(0))
    assert legacy.live is None
    cap = sched.init(CFG, num_workers=3, key=jax.random.PRNGKey(0), capacity=8)
    assert len(jax.tree_util.tree_leaves(cap)) == len(
        jax.tree_util.tree_leaves(legacy)
    ) + 1


def test_admit_retire_roundtrip_and_ewma_consistency():
    state = sched.init(CFG, num_workers=5, key=jax.random.PRNGKey(0), capacity=8)
    tel = _telemetry(jax.random.PRNGKey(1), 8)
    state, _ = sched.observe(state, tel, CFG)
    state, _ = sched.anomaly(state, tel, CFG)  # populate EWMA freshness

    state = sched.admit_workers(state, 2, CFG)
    assert sched.num_workers(state) == 7
    np.testing.assert_array_equal(
        np.asarray(state.live), [1, 1, 1, 1, 1, 1, 1, 0]
    )

    dead = np.zeros(8, bool)
    dead[2] = True
    state = sched.retire_workers(state, jnp.asarray(dead))
    assert sched.num_workers(state) == 6
    # retired slot: parked with EWMA freshness zeroed so a later admit
    # re-seeds anomaly statistics from scratch
    assert float(state.live[2]) == 0.0
    assert float(state.ewma_ll[2]) == 0.0
    assert int(state.ewma_count[2]) == 0
    # survivors keep their learned statistics
    assert int(state.ewma_count[0]) > 0

    # the freed slot is the lowest dead slot -> next admit reuses it
    state = sched.admit_workers(state, 1, CFG)
    assert float(state.live[2]) == 1.0
    assert int(state.ewma_count[2]) == 0
    assert sched.num_workers(state) == 7


def test_over_admission_never_clobbers_live_slots():
    state = sched.init(CFG, num_workers=7, key=jax.random.PRNGKey(0), capacity=8)
    tel = _telemetry(jax.random.PRNGKey(1), 8)
    state, _ = sched.observe(state, tel, CFG)
    before = state.gibbs.ng.mu0
    state = sched.admit_workers(state, 3, CFG)  # only 1 slot free
    assert sched.num_workers(state) == 8
    # the 7 originally-live posteriors were not re-initialized
    np.testing.assert_array_equal(
        np.asarray(before[:7]), np.asarray(state.gibbs.ng.mu0[:7])
    )


def test_dead_slots_get_exactly_zero_fraction():
    state = sched.init(CFG, num_workers=6, key=jax.random.PRNGKey(0), capacity=6)
    tel = _telemetry(jax.random.PRNGKey(1), 6)
    state, _ = sched.observe(state, tel, CFG)
    dead = np.zeros(6, bool)
    dead[1] = dead[4] = True
    state = sched.retire_workers(state, jnp.asarray(dead))
    fr, stats = sched.propose(state, CFG)
    fr = np.asarray(fr)
    assert fr[1] == 0.0 and fr[4] == 0.0
    assert abs(fr.sum() - 1.0) < 1e-5
    assert np.all(fr[[0, 2, 3, 5]] > 0.0)
    assert np.isfinite(float(stats.e_t))


def test_anomaly_ignores_dead_slots():
    state = sched.init(CFG, num_workers=4, key=jax.random.PRNGKey(0), capacity=4)
    tel = _telemetry(jax.random.PRNGKey(1), 4)
    state, _ = sched.observe(state, tel, CFG)
    dead = np.zeros(4, bool)
    dead[3] = True
    state = sched.retire_workers(state, jnp.asarray(dead))
    state, scores = sched.anomaly(state, tel, CFG)
    assert int(state.ewma_count[3]) == 0  # dead slot accumulates nothing
    assert float(scores[3]) == 0.0


def test_jitted_admit_observe_propose_cycle_zero_retrace():
    """The elastic cycle under capacity compiles ONCE: leaf shapes are fixed
    at the capacity, membership changes are data, not structure."""
    state = sched.init(CFG, num_workers=2, key=jax.random.PRNGKey(0), capacity=8)
    traces = []

    @functools.partial(jax.jit, static_argnames=("config",))
    def cycle(state, telemetry, config):
        traces.append(1)  # appends only while tracing
        state = sched.admit_workers(state, 1, config)
        state, _ = sched.observe(state, telemetry, config)
        fr, _ = sched.propose(state, config)
        return state, fr

    rng = jax.random.PRNGKey(1)
    for i in range(5):  # 2 live -> 7 live, capacity 8 throughout
        state, fr = cycle(state, _telemetry(jax.random.fold_in(rng, i), 8), CFG)
    jax.block_until_ready(fr)
    assert len(traces) == 1
    assert sched.num_workers(state) == 7
    assert abs(float(jnp.sum(fr)) - 1.0) < 1e-5


def test_grow_capacity_pads_dead_slots():
    state = sched.init(CFG, num_workers=3, key=jax.random.PRNGKey(0), capacity=4)
    grown = sched.grow_capacity(state, 10, CFG)
    assert sched.capacity(grown) == 10
    assert sched.num_workers(grown) == 3
    np.testing.assert_array_equal(np.asarray(grown.live[4:]), np.zeros(6))
    # no-op when already large enough
    assert sched.grow_capacity(grown, 4, CFG) is grown


def test_mesh_path_admit_retire_roundtrip():
    """The same elastic transitions on a mesh-constrained capacity state."""
    cfg = ShardingConfig.auto()
    config = sched.SchedulerConfig(
        n_iters=2, grid_size=32, num_points=64, opt_steps=10, mesh=cfg
    )
    state = sched.init(config, num_workers=4, key=jax.random.PRNGKey(0),
                       capacity=8)
    tel = _telemetry(jax.random.PRNGKey(1), 8)
    state, _ = sched.observe(state, tel, config)
    state = sched.admit_workers(state, 2, config)
    assert sched.num_workers(state) == 6
    dead = np.zeros(8, bool)
    dead[0] = True
    state = sched.retire_workers(state, jnp.asarray(dead))
    assert sched.num_workers(state) == 5
    state, _ = sched.observe(state, tel, config)
    fr, _ = sched.propose(state, config)
    fr = np.asarray(fr)
    assert fr[0] == 0.0 and abs(fr.sum() - 1.0) < 1e-5


def test_host_add_remove_still_work_on_capacity_states():
    """The shape-changing fallback path carries the live leaf through."""
    state = sched.init(CFG, num_workers=3, key=jax.random.PRNGKey(0), capacity=4)
    bigger = sched.add_workers(state, 2, CFG)
    assert sched.capacity(bigger) == 6
    assert sched.num_workers(bigger) == 5  # new rows admitted live
    smaller = sched.remove_workers(bigger, np.asarray([0, 1, 0, 0, 0, 0], bool))
    assert sched.capacity(smaller) == 5
    assert smaller.live is not None


def test_scheduler_shell_elastic_api():
    s = sched.Scheduler(3, config=CFG, seed=0, capacity=4)
    assert s.capacity == 4 and s.num_workers == 3
    s.observe(_telemetry(jax.random.PRNGKey(1), 4))
    s.admit_workers(1)
    assert s.num_workers == 4 and s.capacity == 4
    s.admit_workers(2)  # full -> shell grows capacity host-side
    assert s.num_workers == 6 and s.capacity >= 6
    s.retire_workers(np.asarray([True] + [False] * (s.capacity - 1)))
    assert s.num_workers == 5
    counts = s.propose_microbatches(64)
    assert counts[0] == 0 and counts.sum() == 64
    flags = s.flag_stragglers()
    assert not flags[0]  # dead slots are never flagged
