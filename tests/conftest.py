import jax
import pytest

# Tests run on the single real CPU device (the dry-run subprocess sets its own
# XLA_FLAGS; never set device-count flags here).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
