import contextlib

import jax
import pytest

# Tests run on the single real CPU device (the dry-run subprocess sets its own
# XLA_FLAGS; never set device-count flags here).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _no_host_sync_guard(request):
    """Runtime counterpart of reprolint RL001: tests marked ``no_host_sync``
    run under ``jax.transfer_guard("disallow")``, so any implicit host->device
    transfer on their jitted path fails loudly instead of silently syncing.

    Device->host reads are free on CPU and jitted calls stage their own
    transfers, so in practice the guard enforces "the hot path stays inside
    jit".  Eager setup/teardown that legitimately builds device values
    (PRNG keys, jnp literals) belongs inside the ``host_staging`` fixture's
    context manager, whose inner ``allow`` overrides the outer ``disallow``.
    """
    if request.node.get_closest_marker("no_host_sync") is None:
        yield
        return
    with jax.transfer_guard("disallow"):
        yield


@pytest.fixture
def host_staging():
    """Context manager for the sanctioned host<->device edges of a
    ``no_host_sync`` test: setup that mints device values and assertions that
    read them back.  Everything *outside* the ``with`` stays guarded."""

    @contextlib.contextmanager
    def staging():
        with jax.transfer_guard("allow"):
            yield

    return staging
