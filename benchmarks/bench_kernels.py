"""Kernel-layer microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode (they are
TPU kernels); the meaningful CPU numbers are the XLA-compiled reference
paths, reported alongside interpret-mode verification deltas.  On TPU the
same ops.py entry points dispatch to the Mosaic kernels.

``fleet_main`` is the fleet-scale estimation-engine case (part of the CI
smoke suite): the legacy PR-2 production path — per-worker vmap of two
single-mode direct-form grid oracles, recomputing the pow table per
exponent — against the fused engine, which evaluates every worker and both
exponents from one shared pow table (one Pallas launch on TPU; the
cache-blocked unified oracle on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, time_pair_min
from repro.core.moments import (
    BetaParams,
    exponent_grid,
    log_posterior_alpha_ref,
    log_posterior_grid,
)
from repro.kernels import ref


# --------------------------------------------------------------------------
# Faithful copy of the PR-2 reference path (the "before" of the fused-engine
# refactor): one direct-form (G, N) evaluation PER exponent, each building
# its own exp table.  Kept here so the speedup baseline stays measurable
# after the production code collapsed onto the unified oracle.
# --------------------------------------------------------------------------
def _legacy_alpha(grid, t, f, mu, lam, beta, pa, pb):
    f = jnp.maximum(f, 1e-6)
    logf = jnp.log(f)
    mean = jnp.exp(grid[:, None] * logf[None, :]) * mu
    z = (t[None, :] - mean) * jnp.exp(-beta * logf)[None, :]
    quad = -0.5 * lam * jnp.sum(z * z, axis=-1)
    g = jnp.clip(grid, 1e-6, 1.0 - 1e-6)
    return quad + (pa - 1.0) * jnp.log(g) + (pb - 1.0) * jnp.log1p(-g)


def _legacy_beta(grid, t, f, mu, lam, alpha, pa, pb):
    f = jnp.maximum(f, 1e-6)
    logf = jnp.log(f)
    resid = t - jnp.exp(alpha * logf) * mu
    z = resid[None, :] * jnp.exp(-grid[:, None] * logf[None, :])
    quad = -0.5 * lam * jnp.sum(z * z, axis=-1) - grid * jnp.sum(logf)
    g = jnp.clip(grid, 1e-6, 1.0 - 1e-6)
    return quad + (pa - 1.0) * jnp.log(g) + (pb - 1.0) * jnp.log1p(-g)


def _fleet_problem(k: int, g: int, n: int):
    key = jax.random.PRNGKey(0)
    kf, kt = jax.random.split(key)
    f = jax.random.uniform(kf, (k, n), minval=0.05, maxval=0.95)
    t = f**0.9 * 25.0 + f**0.7 * 2.0 * jax.random.normal(kt, (k, n))
    grid = exponent_grid(g)
    ones = jnp.ones((k,), jnp.float32)
    return (
        grid, t, f,
        25.0 * ones, 0.25 * ones, 0.9 * ones, 0.7 * ones,
        BetaParams(2.0 * ones, 2.0 * ones), BetaParams(2.0 * ones, 2.0 * ones),
    )


def fleet_main() -> None:
    """Fleet-scale grid-posterior throughput: legacy ref path vs fused engine."""
    k, g, n = 16, 512, 4096
    grid, t, f, mu, lam, alpha, beta, ap, bp = _fleet_problem(k, g, n)
    cells = 2 * k * g * n  # both exponents, every (worker, grid, obs) cell

    # Both sides jit with operands passed per call (no constant folding), and
    # the ratio comes from an interleaved min-time A/B so a noisy-neighbor
    # machine degrades both sides equally.
    legacy = jax.jit(
        jax.vmap(
            lambda tt, ff, m, l, a, b: (
                _legacy_alpha(grid, tt, ff, m, l, b, 2.0, 2.0),
                _legacy_beta(grid, tt, ff, m, l, a, 2.0, 2.0),
            )
        )
    )
    fused = jax.jit(
        lambda tt, ff: log_posterior_grid(
            grid, tt, ff, mu, lam, alpha, beta, ap, bp, symmetric_grid=True
        )
    )
    us_ref, us_fused = time_pair_min(
        lambda: legacy(t, f, mu, lam, alpha, beta), lambda: fused(t, f)
    )
    emit(
        f"posterior_grid_fleet_ref_k{k}_g{g}_n{n}", us_ref,
        f"{cells / (us_ref * 1e-6) / 1e9:.2f} Gcell/s legacy two-pass vmap",
    )
    emit(
        f"posterior_grid_fleet_fused_k{k}_g{g}_n{n}", us_fused,
        f"{cells / (us_fused * 1e-6) / 1e9:.2f} Gcell/s "
        f"{us_ref / us_fused:.2f}x vs ref",
    )

    # Sharded A/B: the same fused oracle with the fleet axis partitioned
    # across all local devices (ISSUE 5 mesh path).  On the 1-device CPU
    # container this measures pure shard_map overhead; under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 (CI) or on a real
    # multi-chip slice it is the scale-out path.
    from repro.core.sharding import ShardingConfig, shard_fleet_call

    shard_cfg = ShardingConfig.auto()
    body = lambda tt, ff, m, l, a, b, pa, pb: log_posterior_grid(
        grid, tt, ff, m, l, a, b, pa, pb, symmetric_grid=True
    )
    # shard_fleet_call pads K up to the shard count (a 6-device host does
    # not divide K=16) — the padded rows are honest overhead of the mesh.
    # Both sides take all 8 operands per call so neither gets a
    # constant-folding advantage (same discipline as the legacy/fused pair).
    fused_full = jax.jit(body)
    fused_sh = jax.jit(
        lambda *a: shard_fleet_call(body, shard_cfg, a)
    )
    us_1dev, us_sh = time_pair_min(
        lambda: fused_full(t, f, mu, lam, alpha, beta, ap, bp),
        lambda: fused_sh(t, f, mu, lam, alpha, beta, ap, bp),
    )
    emit(
        f"posterior_grid_fleet_sharded_k{k}_g{g}_n{n}_"
        f"d{shard_cfg.num_shards}", us_sh,
        f"{cells / (us_sh * 1e-6) / 1e9:.2f} Gcell/s "
        f"{us_1dev / us_sh:.2f}x vs single-device fused "
        f"({shard_cfg.num_shards} shards)",
    )

    # Pallas fleet kernel: one launch for all K workers and both exponents.
    # On CPU this is interpret-mode emulation (honest but not the production
    # number — on TPU the same call lowers to one Mosaic kernel).
    from repro.kernels.posterior_grid import posterior_grid_fleet_pallas

    mask = jnp.ones_like(t)
    pallas_fn = lambda tt, ff: posterior_grid_fleet_pallas(
        grid, tt, ff, mask, mu, lam, alpha, beta, ap.a, ap.b, bp.a, bp.b,
        interpret=True,
    )
    us_pal = time_fn(pallas_fn, t, f, warmup=1, iters=3)
    out_pal = pallas_fn(t, f)
    want = fused(t, f)
    err = float(
        jnp.max(jnp.abs(out_pal - want)) / (1.0 + jnp.max(jnp.abs(want)))
    )
    emit(
        f"posterior_grid_fleet_pallas_interp_k{k}_g{g}_n{n}", us_pal,
        f"{cells / (us_pal * 1e-6) / 1e9:.2f} Gcell/s interpret-mode "
        f"max_rel_err={err:.2e}",
    )


def main() -> None:
    key = jax.random.PRNGKey(0)
    kf, kt, kdecode = jax.random.split(key, 3)

    # posterior grid: production telemetry scale (N=16k obs, G=512)
    n, g = 16384, 512
    f = jax.random.uniform(kf, (n,), minval=0.05, maxval=0.95)
    t = f**0.9 * 25.0 + f**0.7 * 2.0 * jax.random.normal(kt, (n,))
    grid = exponent_grid(g)
    prior = BetaParams(jnp.float32(2.0), jnp.float32(2.0))

    fn = jax.jit(
        lambda tt, ff: log_posterior_alpha_ref(
            grid, tt, ff, jnp.float32(25.0), jnp.float32(0.25),
            jnp.float32(0.7), prior,
        )
    )
    us = time_fn(fn, t, f)
    gflops = 2 * g * n * 4 / (us * 1e-6) / 1e9  # ~4 transcendental-ish ops/cell
    emit(f"posterior_grid_ref_g{g}_n{n}", us, f"~{gflops:.1f} GOp/s xla-cpu")

    from repro.kernels.posterior_grid import posterior_grid_pallas

    out_i = posterior_grid_pallas(
        grid, t, f, jnp.ones_like(t), 25.0, 0.25, 0.7, 2.0, 2.0,
        mode="alpha", interpret=True,
    )
    want = fn(t, f)
    emit(
        "posterior_grid_pallas_verify", 0.0,
        f"interpret-mode max_rel_err={float(jnp.max(jnp.abs(out_i - want)) / (1 + jnp.max(jnp.abs(want)))):.2e}",
    )

    fleet_main()

    # decode attention: 32k cache, GQA 32q/4kv heads
    b, h, kvh, d, s = 4, 32, 4, 128, 32768
    kq, kk, kv = jax.random.split(kdecode, 3)
    q = jax.random.normal(kq, (b, h, d), jnp.bfloat16)
    kc = jax.random.normal(kk, (b, s, kvh, d), jnp.bfloat16)
    vc = jax.random.normal(kv, (b, s, kvh, d), jnp.bfloat16)
    length = jnp.full((b,), s, jnp.int32)
    fn2 = jax.jit(lambda qq, kk_, vv: ref.decode_attention_ref(qq, kk_, vv, length))
    us2 = time_fn(fn2, q, kc, vc, iters=5)
    bytes_moved = 2 * b * s * kvh * d * 2
    emit(
        f"decode_attention_ref_b{b}_s{s}", us2,
        f"cache={bytes_moved/2**20:.0f}MiB eff_bw={bytes_moved/(us2*1e-6)/2**30:.1f}GiB/s xla-cpu",
    )


if __name__ == "__main__":
    main()
