"""Kernel-layer microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode (they are
TPU kernels); the meaningful CPU numbers are the XLA-compiled reference
paths, reported alongside interpret-mode verification deltas.  On TPU the
same ops.py entry points dispatch to the Mosaic kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.moments import BetaParams, exponent_grid
from repro.kernels import ref


def main() -> None:
    key = jax.random.PRNGKey(0)
    kf, kt = jax.random.split(key)

    # posterior grid: production telemetry scale (N=16k obs, G=512)
    n, g = 16384, 512
    f = jax.random.uniform(kf, (n,), minval=0.05, maxval=0.95)
    t = f**0.9 * 25.0 + f**0.7 * 2.0 * jax.random.normal(kt, (n,))
    grid = exponent_grid(g)
    prior = BetaParams(jnp.float32(2.0), jnp.float32(2.0))

    fn = jax.jit(
        lambda tt, ff: ref.posterior_grid_ref(
            grid, tt, ff, jnp.float32(25.0), jnp.float32(0.25),
            jnp.float32(0.7), prior.a, prior.b, None, mode="alpha",
        )
    )
    us = time_fn(fn, t, f)
    gflops = 2 * g * n * 4 / (us * 1e-6) / 1e9  # ~4 transcendental-ish ops/cell
    emit(f"posterior_grid_ref_g{g}_n{n}", us, f"~{gflops:.1f} GOp/s xla-cpu")

    from repro.kernels.posterior_grid import posterior_grid_pallas

    out_i = posterior_grid_pallas(
        grid, t, f, jnp.ones_like(t), 25.0, 0.25, 0.7, 2.0, 2.0,
        mode="alpha", interpret=True,
    )
    want = fn(t, f)
    emit(
        "posterior_grid_pallas_verify", 0.0,
        f"interpret-mode max_rel_err={float(jnp.max(jnp.abs(out_i - want)) / (1 + jnp.max(jnp.abs(want)))):.2e}",
    )

    # decode attention: 32k cache, GQA 32q/4kv heads
    b, h, kvh, d, s = 4, 32, 4, 128, 32768
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, d), jnp.bfloat16)
    kc = jax.random.normal(kk, (b, s, kvh, d), jnp.bfloat16)
    vc = jax.random.normal(kv, (b, s, kvh, d), jnp.bfloat16)
    length = jnp.full((b,), s, jnp.int32)
    fn2 = jax.jit(lambda qq, kk_, vv: ref.decode_attention_ref(qq, kk_, vv, length))
    us2 = time_fn(fn2, q, kc, vc, iters=5)
    bytes_moved = 2 * b * s * kvh * d * 2
    emit(
        f"decode_attention_ref_b{b}_s{s}", us2,
        f"cache={bytes_moved/2**20:.0f}MiB eff_bw={bytes_moved/(us2*1e-6)/2**30:.1f}GiB/s xla-cpu",
    )


if __name__ == "__main__":
    main()
