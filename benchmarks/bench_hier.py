"""Hierarchical-pooling benchmark: cold-start transfer + surprise latency.

Two row families for the BENCH artifact (``benchmarks.run --smoke``):

  * **cold-start observations-to-convergence** — the ISSUE's acceptance
    scenario measured, not just asserted: converge a K=16 fleet of
    identical workers, admit one newcomer with and without hierarchical
    pooling, and count the observations the newcomer needs before its
    proposed fraction is within 10% of its oracle share (1/17).  The
    pooled admit must converge in <= half the global-prior admit's
    observations (``hier_cold_start_ratio``).
  * **surprise-scoring latency** — the per-drain cost the serve loop's
    drift gate pays for the fleet-size-invariant statistic, at
    K = 10^2..10^4 (jitted, device-resident, O(K) elementwise math — it
    must stay microseconds even at 10^4).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro import hier, sched
from repro.core import gibbs


def _telemetry(rng, fracs, mu=800.0, n=16):
    fmat = np.tile(np.asarray(fracs, np.float32)[:, None], (1, n))
    tmat = fmat**0.9 * mu * (1.0 + 0.02 * rng.standard_normal(fmat.shape))
    return sched.Telemetry(
        jnp.asarray(fmat, jnp.float32), jnp.asarray(tmat, jnp.float32)
    )


def _explore_telemetry(rng, k, mu=800.0, n=16):
    fmat = rng.uniform(0.05, 0.9, (k, n)).astype(np.float32)
    tmat = fmat**0.9 * mu * (1.0 + 0.02 * rng.standard_normal(fmat.shape))
    return sched.Telemetry(
        jnp.asarray(fmat, jnp.float32), jnp.asarray(tmat, jnp.float32)
    )


def _obs_to_band(scheduler, oracle, rng, n=4, max_cycles=15):
    for cycle in range(max_cycles + 1):
        fr, _, _ = scheduler.propose_fractions()
        if abs(fr[-1] - oracle) <= 0.1 * oracle:
            return cycle * n
        scheduler.observe(_telemetry(rng, fr, n=n))
    return (max_cycles + 1) * n


def cold_start_main() -> None:
    import dataclasses

    cfg = sched.SchedulerConfig(
        n_iters=3, grid_size=32, num_points=64, opt_steps=30, mu_guess=1.0
    )
    rng = np.random.default_rng(0)
    base = sched.Scheduler(16, config=cfg, seed=0)
    for _ in range(8):
        base.observe(_explore_telemetry(rng, 16))

    oracle = 1.0 / 17.0
    obs = {}
    for label, hierarchical in (("pooled", True), ("global", False)):
        s = sched.Scheduler(
            1, config=dataclasses.replace(cfg, hierarchical=hierarchical)
        )
        s.state = base.state  # immutable pytree: share-then-diverge
        s.add_workers(1, seed=7)
        cap = 16 * 4  # (max_cycles + 1) * n: right-censored if never in band
        obs[label] = _obs_to_band(s, oracle, np.random.default_rng(1))
        note = " [censored at budget]" if obs[label] >= cap else ""
        emit(
            f"hier_cold_start_{label}_obs", obs[label],
            "newcomer observations to within 10% of oracle fraction "
            f"(K=16 converged fleet, hierarchical={hierarchical}){note}",
        )
    ratio = obs["pooled"] / max(obs["global"], 1)
    emit(
        "hier_cold_start_ratio", ratio,
        f"pooled/global observations-to-convergence "
        f"({obs['pooled']}/{obs['global']}); acceptance: <= 0.5",
    )


def surprise_main() -> None:
    for k in (100, 1_000, 10_000):
        key = jax.random.PRNGKey(0)
        f = jax.random.uniform(key, (k, 16), minval=0.1, maxval=0.9)
        t = f**0.9 * 4.0
        fleet, _ = gibbs.fit_fleet(key, t, f, n_iters=1, grid_size=32)
        hyper = hier.fit_hyperprior(fleet)
        us = time_fn(lambda: hier.surprise(fleet, hyper))
        emit(f"hier_surprise_k{k}", us, "per-drain drift scoring, (K,) out")
        us = time_fn(lambda: hier.fit_hyperprior(fleet))
        emit(f"hier_refit_k{k}", us, "hyperprior refit from fleet posteriors")


def main() -> None:
    cold_start_main()
    surprise_main()


if __name__ == "__main__":
    main()
