"""DAG-scale estimation A/B: stacked single-launch vs per-stage loop.

The stacked path folds a pipeline's (S, K, N) telemetry into one
(S*K)-fleet ``gibbs_batch`` — a single compiled program (and, with Pallas,
one kernel launch per sweep) for the whole DAG.  The per-stage reference
dispatches S separate fleet programs, one per stage, which is exactly what a
naive "loop over stages" scheduler would do.  Both sides compute identical
chains (stage folding is a reshape, not an approximation), so the ratio is
pure dispatch/fusion win.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, time_pair_min
from repro.core import gibbs


def _dag_problem(s: int, k: int, n: int):
    key = jax.random.PRNGKey(0)
    kf, kt, ks = jax.random.split(key, 3)
    f = jax.random.uniform(kf, (s, k, n), minval=0.05, maxval=0.95)
    mu = jax.random.uniform(ks, (s, k), minval=5.0, maxval=30.0)
    t = f**0.9 * mu[..., None] + f**0.7 * jax.random.normal(kt, (s, k, n))
    t = jnp.maximum(t, 1e-3)
    keys = jax.random.split(jax.random.PRNGKey(1), s * k)
    states = gibbs.unfold_stage_axis(jax.vmap(gibbs.init_state)(keys), s)
    return t, f, states


def _run(s: int, k: int, n: int, iters: int, g: int) -> None:
    t, f, states = _dag_problem(s, k, n)
    cells = 2 * s * k * g * n * iters  # grid-posterior cells per DAG advance

    fold = gibbs.fold_stage_axis
    stacked = jax.jit(
        lambda st, tt, ff: gibbs.gibbs_batch(
            fold(st), fold(tt), fold(ff), n_iters=iters, grid_size=g
        )[0]
    )

    def per_stage(st, tt, ff):
        # The naive scheduler: one (already-jitted) fleet program per stage.
        # Compilation is cached across calls; the cost measured is the S-way
        # dispatch + lost cross-stage fusion, not recompilation.
        outs = []
        for si in range(s):
            sliced = jax.tree_util.tree_map(lambda x: x[si], st)
            outs.append(
                gibbs.gibbs_batch(
                    sliced, tt[si], ff[si], n_iters=iters, grid_size=g
                )[0]
            )
        return outs

    us_loop, us_stacked = time_pair_min(
        lambda: per_stage(states, t, f), lambda: stacked(states, t, f), rounds=5
    )
    emit(
        f"dag_engine_perstage_s{s}_k{k}_g{g}_n{n}_it{iters}", us_loop,
        f"{cells / (us_loop * 1e-6) / 1e9:.2f} Gcell/s S-dispatch loop",
    )
    emit(
        f"dag_engine_stacked_s{s}_k{k}_g{g}_n{n}_it{iters}", us_stacked,
        f"{cells / (us_stacked * 1e-6) / 1e9:.2f} Gcell/s stacked single program "
        f"({us_loop / us_stacked:.2f}x)",
    )


def stochastic_main() -> None:
    """Stochastic-vs-deterministic propose A/B on a branching+rework workload.

    The acceptance diamond: 4 stages, K = 8 heterogeneous workers
    (fast-noisy vs slow-precise), one p = 0.3 conditional stage, one
    geometric-rework stage, end-to-end variance budget.  Both proposals are
    timed, and the derived column prices each against the MC simulator
    oracle at the TRUE parameters — the quality gap is the reason the
    stochastic-aware path exists, so the benchmark records it next to the
    cost of computing it.
    """
    import numpy as np

    from repro import sched, sim
    from repro.core.frontier import UnitParams

    s, k = 4, 8
    dag = sched.WorkflowDAG.from_edges(
        s, ((0, 1), (0, 2), (1, 3), (2, 3)), num_workers=k
    )
    dag_sto = dag.with_stochastic(
        exec_probs=(1.0, 0.3, 1.0, 1.0),
        rework_probs=(0.0, 0.0, 0.4, 0.0),
        max_retries=(1, 1, 4, 1),
    )
    base_mu = np.asarray([5.0] * 4 + [9.0] * 4, np.float32)
    base_sig = np.asarray([6.0] * 4 + [0.3] * 4, np.float32)
    scale = np.asarray([0.4, 1.6, 0.5, 0.4], np.float32)
    true = UnitParams.of(
        scale[:, None] * base_mu[None, :],
        scale[:, None] * base_sig[None, :],
        np.full((s, k), 0.9, np.float32),
        np.full((s, k), 0.55, np.float32),
    )
    cfg = sched.SchedulerConfig(
        objective=sched.Objective.variance_budget(2.0),
        opt_steps=200, num_points=256,
    )
    state = sched.init_dag(cfg, dag, jax.random.PRNGKey(3))

    det = jax.jit(
        lambda st: sched.propose_dag(st, dag, cfg, params=true)[0]
    )
    sto = jax.jit(
        lambda st: sched.propose_dag(st, dag_sto, cfg, params=true)[0]
    )
    us_det, us_sto = time_pair_min(lambda: det(state), lambda: sto(state), rounds=3)

    # Price both against the oracle with common random numbers.
    key = jax.random.PRNGKey(7)
    n_mc = 200_000
    e = {
        name: float(
            jnp.mean(sim.simulate_workflow(key, dag_sto, fr, true, num_samples=n_mc))
        )
        for name, fr in (("det", det(state)), ("sto", sto(state)))
    }
    emit(
        "propose_dag_det_assume_diamond_s4_k8", us_det,
        f"MC E[t]={e['det']:.4f} deterministic-assumption allocation",
    )
    emit(
        "propose_dag_stochastic_diamond_s4_k8", us_sto,
        f"MC E[t]={e['sto']:.4f} effective-moment allocation "
        f"({e['det'] - e['sto']:+.4f} E[t] vs det, {us_sto / us_det:.2f}x cost)",
    )


def smoke_main() -> None:
    """CI smoke: the acceptance-scale 3-stage x 4-worker pipeline, plus the
    stochastic-vs-deterministic propose A/B."""
    _run(s=3, k=4, n=512, iters=2, g=128)
    stochastic_main()


def main() -> None:
    smoke_main()
    _run(s=8, k=16, n=2048, iters=2, g=256)

    # propose_dag end-to-end (estimate -> allocate -> compose) at smoke scale
    from repro import sched

    dag = sched.WorkflowDAG.chain(3, 4)
    cfg = sched.SchedulerConfig(n_iters=4, grid_size=128, opt_steps=100)
    state = sched.init_dag(cfg, dag, jax.random.PRNGKey(2))
    us = time_fn(lambda: jax.block_until_ready(sched.propose_dag(state, dag, cfg)))
    emit("propose_dag_chain_s3_k4", us, "stage-wise solve + composition")


if __name__ == "__main__":
    main()
