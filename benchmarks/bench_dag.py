"""DAG-scale estimation A/B: stacked single-launch vs per-stage loop.

The stacked path folds a pipeline's (S, K, N) telemetry into one
(S*K)-fleet ``gibbs_batch`` — a single compiled program (and, with Pallas,
one kernel launch per sweep) for the whole DAG.  The per-stage reference
dispatches S separate fleet programs, one per stage, which is exactly what a
naive "loop over stages" scheduler would do.  Both sides compute identical
chains (stage folding is a reshape, not an approximation), so the ratio is
pure dispatch/fusion win.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, time_pair_min
from repro.core import gibbs


def _dag_problem(s: int, k: int, n: int):
    key = jax.random.PRNGKey(0)
    kf, kt, ks = jax.random.split(key, 3)
    f = jax.random.uniform(kf, (s, k, n), minval=0.05, maxval=0.95)
    mu = jax.random.uniform(ks, (s, k), minval=5.0, maxval=30.0)
    t = f**0.9 * mu[..., None] + f**0.7 * jax.random.normal(kt, (s, k, n))
    t = jnp.maximum(t, 1e-3)
    keys = jax.random.split(jax.random.PRNGKey(1), s * k)
    states = gibbs.unfold_stage_axis(jax.vmap(gibbs.init_state)(keys), s)
    return t, f, states


def _run(s: int, k: int, n: int, iters: int, g: int) -> None:
    t, f, states = _dag_problem(s, k, n)
    cells = 2 * s * k * g * n * iters  # grid-posterior cells per DAG advance

    fold = gibbs.fold_stage_axis
    stacked = jax.jit(
        lambda st, tt, ff: gibbs.gibbs_batch(
            fold(st), fold(tt), fold(ff), n_iters=iters, grid_size=g
        )[0]
    )

    def per_stage(st, tt, ff):
        # The naive scheduler: one (already-jitted) fleet program per stage.
        # Compilation is cached across calls; the cost measured is the S-way
        # dispatch + lost cross-stage fusion, not recompilation.
        outs = []
        for si in range(s):
            sliced = jax.tree_util.tree_map(lambda x: x[si], st)
            outs.append(
                gibbs.gibbs_batch(
                    sliced, tt[si], ff[si], n_iters=iters, grid_size=g
                )[0]
            )
        return outs

    us_loop, us_stacked = time_pair_min(
        lambda: per_stage(states, t, f), lambda: stacked(states, t, f), rounds=5
    )
    emit(
        f"dag_engine_perstage_s{s}_k{k}_g{g}_n{n}_it{iters}", us_loop,
        f"{cells / (us_loop * 1e-6) / 1e9:.2f} Gcell/s S-dispatch loop",
    )
    emit(
        f"dag_engine_stacked_s{s}_k{k}_g{g}_n{n}_it{iters}", us_stacked,
        f"{cells / (us_stacked * 1e-6) / 1e9:.2f} Gcell/s stacked single program "
        f"({us_loop / us_stacked:.2f}x)",
    )


def smoke_main() -> None:
    """CI smoke: the acceptance-scale 3-stage x 4-worker pipeline."""
    _run(s=3, k=4, n=512, iters=2, g=128)


def main() -> None:
    smoke_main()
    _run(s=8, k=16, n=2048, iters=2, g=256)

    # propose_dag end-to-end (estimate -> allocate -> compose) at smoke scale
    from repro import sched

    dag = sched.WorkflowDAG.chain(3, 4)
    cfg = sched.SchedulerConfig(n_iters=4, grid_size=128, opt_steps=100)
    state = sched.init_dag(cfg, dag, jax.random.PRNGKey(2))
    us = time_fn(lambda: jax.block_until_ready(sched.propose_dag(state, dag, cfg)))
    emit("propose_dag_chain_s3_k4", us, "stage-wise solve + composition")


if __name__ == "__main__":
    main()
