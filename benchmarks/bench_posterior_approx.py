"""Paper Figs 3-4: true grid posterior of alpha/beta vs the Beta
method-of-moments approximation.

Reports the total-variation distance between the normalized grid posterior
and its Beta fit, plus the mean-vs-mode gap the paper highlights (small gap
=> sampling behaves like hill-climbing the likelihood, §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.distributions import normalize_log_density, trapezoid_weights
from repro.core.moments import (
    BetaParams,
    exponent_grid,
    fit_beta_method_of_moments,
    log_posterior_alpha_ref,
    log_posterior_beta_ref,
    moments_from_log_density,
)
from repro.distributed.simulated_cluster import SimulatedCluster, WorkerSpec


def _tv_distance(grid, logp, fit: BetaParams) -> float:
    from repro.core.distributions import beta_logpdf

    p = normalize_log_density(logp, grid)
    q = normalize_log_density(beta_logpdf(grid, fit.a, fit.b), grid)
    w = trapezoid_weights(grid)
    return float(0.5 * jnp.sum(jnp.abs(p - q) * w))


def main() -> None:
    rng = np.random.default_rng(0)
    n = 256
    spec = WorkerSpec(mu=25.0, sigma=2.0, alpha=0.9, beta=0.8)
    f = rng.uniform(0.05, 0.95, n).astype(np.float32)
    t = (f**spec.alpha * spec.mu
         + f**spec.beta * spec.sigma * rng.normal(size=n)).astype(np.float32)
    grid = exponent_grid(1024)
    prior = BetaParams(jnp.float32(2.0), jnp.float32(2.0))
    mu, lam = spec.mu, 1.0 / spec.sigma**2

    for name, fn, other in (
        ("alpha", log_posterior_alpha_ref, spec.beta),
        ("beta", log_posterior_beta_ref, spec.alpha),
    ):
        eval_fn = jax.jit(
            lambda tt, ff: fn(grid, tt, ff, jnp.float32(mu), jnp.float32(lam),
                              jnp.float32(other), prior)
        )
        us = time_fn(eval_fn, jnp.asarray(t), jnp.asarray(f))
        logp = eval_fn(jnp.asarray(t), jnp.asarray(f))
        e, v = moments_from_log_density(grid, logp)
        fit = fit_beta_method_of_moments(e, v)
        tv = _tv_distance(grid, logp, fit)
        mode = float(grid[int(jnp.argmax(logp))])
        emit(
            f"posterior_{name}_grid1024_n256", us,
            f"E={float(e):.4f} mode={mode:.4f} mean_mode_gap={abs(float(e)-mode):.4f} "
            f"beta_fit=({float(fit.a):.1f},{float(fit.b):.1f}) tv_dist={tv:.4f}",
        )


if __name__ == "__main__":
    main()
