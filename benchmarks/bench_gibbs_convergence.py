"""Paper Fig 5: log-likelihood vs number of observations (network file
transfer analogue -> simulated cluster telemetry), plus Gibbs throughput
(single unit and a vmapped 64-worker fleet)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro import sched
from repro.core import gibbs
from repro.core.posterior import log_likelihood


def main() -> None:
    rng = np.random.default_rng(0)
    mu, sigma, alpha, beta = 30.0, 2.0, 0.9, 0.8
    n = 512
    f = rng.uniform(0.05, 0.95, n).astype(np.float32)
    t = (f**alpha * mu + f**beta * sigma * rng.normal(size=n)).astype(np.float32)

    # Fig 5 curve: held-out LL vs observations seen
    f_ho = rng.uniform(0.05, 0.95, 256).astype(np.float32)
    t_ho = (f_ho**alpha * mu
            + f_ho**beta * sigma * rng.normal(size=256)).astype(np.float32)
    state = gibbs.init_state(jax.random.PRNGKey(0), mu_guess=float(t.mean() / f.mean()))
    curve = []
    bs = 32
    for b in range(n // bs):
        sl = slice(b * bs, (b + 1) * bs)
        state, _ = gibbs.gibbs_batch(
            state, jnp.asarray(t[sl]), jnp.asarray(f[sl]), n_iters=15, grid_size=256
        )
        curve.append((
            (b + 1) * bs,
            float(log_likelihood(jnp.asarray(t_ho), jnp.asarray(f_ho),
                                 state.mu, state.lam, state.alpha, state.beta)),
        ))
    np.savetxt("experiments/fig5_convergence.csv", np.asarray(curve),
               header="observations,heldout_loglik", delimiter=",", comments="")
    emit(
        "gibbs_fig5_final_estimates", 0.0,
        f"mu={float(state.mu):.2f}/{mu} sigma={float(state.sigma):.2f}/{sigma} "
        f"alpha={float(state.alpha):.3f}/{alpha} beta={float(state.beta):.3f}/{beta} "
        f"ll_first={curve[0][1]:.1f} ll_last={curve[-1][1]:.1f}",
    )

    # throughput: one batch update, jitted
    st2 = gibbs.init_state(jax.random.PRNGKey(1), mu_guess=10.0)
    fn = lambda tt, ff: gibbs.gibbs_batch(st2, tt, ff, n_iters=15, grid_size=256)[1]
    us = time_fn(fn, jnp.asarray(t[:64]), jnp.asarray(f[:64]))
    emit("gibbs_batch_n64_iters15_grid256", us, "single unit")

    # fleet: 64 workers vmapped (production path)
    k = 64
    tf = jnp.asarray(np.tile(t[:64], (k, 1)))
    ff = jnp.asarray(np.tile(f[:64], (k, 1)))
    fleet_fn = lambda: gibbs.fit_fleet(jax.random.PRNGKey(2), tf, ff,
                                       n_iters=15, grid_size=256)[1]
    us_fleet = time_fn(fleet_fn, iters=3)
    emit("gibbs_fleet_64workers", us_fleet,
         f"per-worker={us_fleet/k:.1f}us ({us/ (us_fleet/k):.1f}x vmap win)")

    # same fleet through the pure scheduler transition (jit observe), i.e. the
    # state-in/state-out path the trainer/server actually run in production
    config = sched.SchedulerConfig(n_iters=15, grid_size=256, mu_guess=10.0)
    state = sched.init(config, k, jax.random.PRNGKey(3))
    telem = sched.Telemetry(fracs=ff, times=tf)
    obs_fn = lambda: sched.observe(state, telem, config)[1]
    us_obs = time_fn(obs_fn, iters=3)
    emit("sched_observe_64workers", us_obs,
         f"per-worker={us_obs/k:.1f}us (jitted SchedulerState transition)")


if __name__ == "__main__":
    main()
