"""Paper Fig 5: log-likelihood vs number of observations (network file
transfer analogue -> simulated cluster telemetry), plus Gibbs throughput
(single unit and a fleet), plus the fleet-scale estimation-engine case
(``fleet_main``, part of the CI smoke suite): the legacy PR-2 engine —
per-worker vmap of a sweep that evaluates each exponent's grid posterior in
its own direct-form pass — against the fused fleet engine, whose sweeps
evaluate every worker and both exponents from one shared pow table."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, time_pair_min
from repro import sched
from repro.core import gibbs
from repro.core.distributions import sample_beta, sample_gamma, sample_normal
from repro.core.moments import (
    exponent_grid,
    fit_beta_method_of_moments,
    moments_from_log_density,
)
from repro.core.posterior import log_likelihood, update_normal_gamma


def _legacy_gibbs_batch(state, t, f, *, n_iters: int, grid_size: int):
    """Faithful PR-2 single-unit Gibbs batch (the fused engine's "before").

    Each sweep runs TWO independent direct-form (G, N) grid evaluations —
    alpha then beta, each building its own exp table — exactly as the legacy
    production path did; fleets were handled by vmapping this whole function
    per worker.
    """
    from benchmarks.bench_kernels import _legacy_alpha, _legacy_beta

    grid = exponent_grid(grid_size)

    def sweep(carry, _):
        st = carry
        key, k_l, k_m, k_a, k_b = jax.random.split(st.key, 5)
        ng_post = update_normal_gamma(st.ng, t, f, st.alpha, st.beta)
        lam = sample_gamma(k_l, ng_post.nu0, ng_post.psi0)
        mu = sample_normal(
            k_m, ng_post.mu0, 1.0 / jnp.sqrt(jnp.maximum(ng_post.kappa0 * lam, 1e-30))
        )
        logp_a = _legacy_alpha(
            grid, t, f, mu, lam, st.beta, st.alpha_prior.a, st.alpha_prior.b
        )
        logp_b = _legacy_beta(
            grid, t, f, mu, lam, st.alpha, st.beta_prior.a, st.beta_prior.b
        )
        ea, va = moments_from_log_density(grid, logp_a)
        eb, vb = moments_from_log_density(grid, logp_b)
        a_post = fit_beta_method_of_moments(ea, va)
        b_post = fit_beta_method_of_moments(eb, vb)
        alpha = sample_beta(k_a, a_post.a, a_post.b)
        beta = sample_beta(k_b, b_post.a, b_post.b)
        new_st = gibbs.GibbsState(
            st.ng, st.alpha_prior, st.beta_prior, mu, lam, alpha, beta, key
        )
        return new_st, None

    state, _ = jax.lax.scan(sweep, state, None, length=n_iters)
    return state


def fleet_main() -> None:
    """Fleet-scale engine throughput: legacy vmapped engine vs fused engine."""
    from benchmarks.bench_kernels import _fleet_problem

    k, g, n, iters = 16, 512, 4096, 2
    _, t, f, *_ = _fleet_problem(k, g, n)  # same problem as the kernel bench
    cells = 2 * k * g * n * iters  # grid-posterior cells per engine call

    keys = jax.random.split(jax.random.PRNGKey(1), k)
    states = jax.vmap(lambda kk: gibbs.init_state(kk, mu_guess=25.0))(keys)

    # Both sides jit with operands passed per call; interleaved min-time A/B
    # (see benchmarks.common.time_pair_min) keeps the ratio honest on noisy
    # shared machines.
    legacy = jax.jit(
        jax.vmap(
            lambda st, tt, ff: _legacy_gibbs_batch(
                st, tt, ff, n_iters=iters, grid_size=g
            )
        )
    )
    fused = jax.jit(
        lambda st, tt, ff: gibbs.gibbs_batch(st, tt, ff, n_iters=iters, grid_size=g)[0]
    )
    us_ref, us_fused = time_pair_min(
        lambda: legacy(states, t, f), lambda: fused(states, t, f), rounds=5
    )
    emit(
        f"gibbs_fleet_engine_ref_k{k}_g{g}_n{n}_it{iters}", us_ref,
        f"{cells / (us_ref * 1e-6) / 1e9:.2f} Gcell/s legacy vmap engine",
    )
    emit(
        f"gibbs_fleet_engine_fused_k{k}_g{g}_n{n}_it{iters}", us_fused,
        f"{cells / (us_fused * 1e-6) / 1e9:.2f} Gcell/s "
        f"{us_ref / us_fused:.2f}x vs ref",
    )

    # Sharded A/B: the SAME fused engine with its fleet axis partitioned
    # across all local devices via shard_map (docs/scaling.md).  Chains
    # advance bitwise-identically; only the device layout changes.
    from repro.core.sharding import ShardingConfig

    shard_cfg = ShardingConfig.auto()
    sharded = jax.jit(
        lambda st, tt, ff: gibbs.gibbs_batch(
            st, tt, ff, n_iters=iters, grid_size=g, sharding=shard_cfg
        )[0]
    )
    us_1dev, us_sh = time_pair_min(
        lambda: fused(states, t, f), lambda: sharded(states, t, f), rounds=5
    )
    emit(
        f"gibbs_fleet_engine_sharded_k{k}_g{g}_n{n}_it{iters}_"
        f"d{shard_cfg.num_shards}", us_sh,
        f"{cells / (us_sh * 1e-6) / 1e9:.2f} Gcell/s "
        f"{us_1dev / us_sh:.2f}x vs single-device fused "
        f"({shard_cfg.num_shards} shards)",
    )


def main() -> None:
    rng = np.random.default_rng(0)
    mu, sigma, alpha, beta = 30.0, 2.0, 0.9, 0.8
    n = 512
    f = rng.uniform(0.05, 0.95, n).astype(np.float32)
    t = (f**alpha * mu + f**beta * sigma * rng.normal(size=n)).astype(np.float32)

    # Fig 5 curve: held-out LL vs observations seen
    f_ho = rng.uniform(0.05, 0.95, 256).astype(np.float32)
    t_ho = (f_ho**alpha * mu
            + f_ho**beta * sigma * rng.normal(size=256)).astype(np.float32)
    state = gibbs.init_state(jax.random.PRNGKey(0), mu_guess=float(t.mean() / f.mean()))
    curve = []
    bs = 32
    for b in range(n // bs):
        sl = slice(b * bs, (b + 1) * bs)
        state, _ = gibbs.gibbs_batch(
            state, jnp.asarray(t[sl]), jnp.asarray(f[sl]), n_iters=15, grid_size=256
        )
        curve.append((
            (b + 1) * bs,
            float(log_likelihood(jnp.asarray(t_ho), jnp.asarray(f_ho),
                                 state.mu, state.lam, state.alpha, state.beta)),
        ))
    np.savetxt("experiments/fig5_convergence.csv", np.asarray(curve),
               header="observations,heldout_loglik", delimiter=",", comments="")
    emit(
        "gibbs_fig5_final_estimates", 0.0,
        f"mu={float(state.mu):.2f}/{mu} sigma={float(state.sigma):.2f}/{sigma} "
        f"alpha={float(state.alpha):.3f}/{alpha} beta={float(state.beta):.3f}/{beta} "
        f"ll_first={curve[0][1]:.1f} ll_last={curve[-1][1]:.1f}",
    )

    # throughput: one batch update, jitted
    st2 = gibbs.init_state(jax.random.PRNGKey(1), mu_guess=10.0)
    fn = lambda tt, ff: gibbs.gibbs_batch(st2, tt, ff, n_iters=15, grid_size=256)[1]
    us = time_fn(fn, jnp.asarray(t[:64]), jnp.asarray(f[:64]))
    emit("gibbs_batch_n64_iters15_grid256", us, "single unit")

    # fleet: 64 workers vmapped (production path)
    k = 64
    tf = jnp.asarray(np.tile(t[:64], (k, 1)))
    ff = jnp.asarray(np.tile(f[:64], (k, 1)))
    fleet_fn = lambda: gibbs.fit_fleet(jax.random.PRNGKey(2), tf, ff,
                                       n_iters=15, grid_size=256)[1]
    us_fleet = time_fn(fleet_fn, iters=3)
    emit("gibbs_fleet_64workers", us_fleet,
         f"per-worker={us_fleet/k:.1f}us ({us/ (us_fleet/k):.1f}x vmap win)")

    # same fleet through the pure scheduler transition (jit observe), i.e. the
    # state-in/state-out path the trainer/server actually run in production
    config = sched.SchedulerConfig(n_iters=15, grid_size=256, mu_guess=10.0)
    state = sched.init(config, k, jax.random.PRNGKey(3))
    telem = sched.Telemetry(fracs=ff, times=tf)
    obs_fn = lambda: sched.observe(state, telem, config)[1]
    us_obs = time_fn(obs_fn, iters=3)
    emit("sched_observe_64workers", us_obs,
         f"per-worker={us_obs/k:.1f}us (jitted SchedulerState transition)")

    fleet_main()


if __name__ == "__main__":
    main()
