"""Benchmark harness: one module per paper table/figure + system benches.

Emits ``name,us_per_call,derived`` CSV rows.  ``python -m benchmarks.run``;
``--smoke`` runs the fast CI subset (frontier sweep + partitioner quality)
so a CPU-only runner finishes in minutes.
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (
    bench_frontier,
    bench_gibbs_convergence,
    bench_kernels,
    bench_partitioner,
    bench_posterior_approx,
    bench_train_step,
)

ALL = [
    ("fig1_2_frontier", bench_frontier.main),
    ("fig3_4_posterior_approx", bench_posterior_approx.main),
    ("fig5_gibbs_convergence", bench_gibbs_convergence.main),
    ("partitioner_vs_naive", bench_partitioner.main),
    ("kernels", bench_kernels.main),
    ("train_step", bench_train_step.main),
]

SMOKE = [
    ("fig1_2_frontier", bench_frontier.main),
    ("partitioner_vs_naive", bench_partitioner.main),
]


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    unknown = [a for a in argv if a != "--smoke"]
    if unknown:
        sys.exit(f"usage: python -m benchmarks.run [--smoke]  (got {unknown})")
    suite = SMOKE if "--smoke" in argv else ALL
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suite:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name},FAILED,{type(e).__name__}: {e}")
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
