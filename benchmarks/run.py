"""Benchmark harness: one module per paper table/figure + system benches.

Emits ``name,us_per_call,derived`` CSV rows.  ``python -m benchmarks.run``;
``--smoke`` runs the fast CI subset (frontier sweep + partitioner quality +
the fleet-scale estimation-engine cases) so a CPU-only runner finishes in
minutes; ``--json PATH`` additionally persists every emitted row (plus the
suite name and failures) as a JSON artifact — CI uploads the smoke run as
``BENCH_<pr>.json`` so the perf trajectory accumulates across PRs.

The artifact schema, the interleaved min-time A/B methodology behind the
``*_ref`` / ``*_fused`` / ``*_sharded`` row families, and the exact
regeneration commands are documented in ``docs/benchmarks.md``.
"""
from __future__ import annotations

import json
import platform
import sys
import traceback

from benchmarks import (
    bench_dag,
    bench_fleet_scale,
    bench_frontier,
    bench_gibbs_convergence,
    bench_hier,
    bench_kernels,
    bench_partitioner,
    bench_posterior_approx,
    bench_serve,
    bench_train_step,
    common,
)

ALL = [
    ("fig1_2_frontier", bench_frontier.main),
    ("fig3_4_posterior_approx", bench_posterior_approx.main),
    ("fig5_gibbs_convergence", bench_gibbs_convergence.main),
    ("partitioner_vs_naive", bench_partitioner.main),
    ("kernels", bench_kernels.main),
    ("dag_engine", bench_dag.main),
    ("train_step", bench_train_step.main),
    ("serve_loop", bench_serve.main),
    ("hier_pooling", bench_hier.main),
    ("fleet_scale", bench_fleet_scale.main),
]

SMOKE = [
    ("fig1_2_frontier", bench_frontier.main),
    ("partitioner_vs_naive", bench_partitioner.main),
    ("kernels_fleet", bench_kernels.fleet_main),
    ("gibbs_fleet_engine", bench_gibbs_convergence.fleet_main),
    ("dag_stacked_engine", bench_dag.smoke_main),
    ("serve_loop", bench_serve.main),
    ("hier_pooling", bench_hier.main),
    ("fleet_scale", bench_fleet_scale.smoke_main),
]


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            sys.exit("usage: python -m benchmarks.run [--smoke] [--json PATH]")
        json_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    unknown = [a for a in argv if a != "--smoke"]
    if unknown:
        sys.exit(f"usage: python -m benchmarks.run [--smoke] [--json PATH]  (got {unknown})")
    suite = SMOKE if "--smoke" in argv else ALL
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suite:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name},FAILED,{type(e).__name__}: {e}")
    if json_path:
        import jax

        payload = {
            "suite": "smoke" if "--smoke" in argv else "all",
            "backend": jax.default_backend(),
            # Cross-PR comparisons must match device_count: forcing N host
            # devices (the CI mesh recipe) partitions the machine, which
            # shifts even the single-device rows (docs/benchmarks.md).
            "device_count": jax.device_count(),
            "platform": platform.platform(),
            "failed": failed,
            "rows": common.ROWS,
        }
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(common.ROWS)} rows to {json_path}")
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
