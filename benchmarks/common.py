"""Shared benchmark utilities: timing + CSV emission + JSON row capture."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

# Every ``emit`` appends here so ``benchmarks.run --json`` can persist the
# full run (the CI perf-trajectory artifact) without re-parsing stdout.
ROWS: List[Dict[str, object]] = []


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (jax results block_until_ready)."""
    import jax

    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def time_pair_min(fn_a: Callable, fn_b: Callable, rounds: int = 8) -> tuple:
    """Interleaved min-time A/B comparison in microseconds.

    For head-to-head throughput ratios on shared machines: alternating the
    two sides inside each round exposes both to the same noisy-neighbor
    conditions, and the per-side minimum keeps the least-interfered sample.
    The thunks must call through an argument-passing jit boundary so neither
    side gets constant-folding advantages.
    """
    import jax

    jax.block_until_ready(fn_a())
    jax.block_until_ready(fn_b())
    best_a = best_b = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a * 1e6, best_b * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1), "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")
