"""End-to-end train-step throughput on CPU (reduced config).

Covers the full production path: microbatched grad accumulation, AdamW,
and (separately) the int8 error-feedback compression variant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import RunConfig, get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.distributed.compression import make_compressor
from repro.models import model_zoo
from repro.models.layers import ApplyCtx
from repro.optim import adamw
from repro.train import train_step as ts


def main() -> None:
    cfg = reduced(get_arch("smollm-135m"), d_model=128, num_layers=4, d_ff=512)
    shape = ShapeConfig("bench", seq_len=128, global_batch=8, kind="train")
    run = RunConfig(model=cfg, shape=shape)
    params = model_zoo.init_model_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    m = 4
    batch = {
        "tokens": jnp.ones((m, shape.global_batch // m, shape.seq_len), jnp.int32),
        "labels": jnp.ones((m, shape.global_batch // m, shape.seq_len), jnp.int32),
    }
    tokens_per_step = shape.global_batch * shape.seq_len

    step = jax.jit(
        ts.make_train_step(cfg, run, ctx=ApplyCtx(mode="train"), num_microbatches=m)
    )
    us = time_fn(step, params, opt, batch, jnp.asarray(0), iters=5)
    emit(
        "train_step_smoke_4L_d128", us,
        f"{tokens_per_step / (us * 1e-6):.0f} tok/s cpu",
    )

    compress, init_ef = make_compressor("int8_ef", None)
    ef = init_ef(params)
    step_c = jax.jit(
        ts.make_train_step(
            cfg, run, ctx=ApplyCtx(mode="train"), num_microbatches=m,
            compression=compress,
        )
    )
    w = jnp.ones((m,), jnp.float32)
    us_c = time_fn(step_c, params, opt, batch, jnp.asarray(0), w, ef, iters=5)
    emit(
        "train_step_int8ef_compression", us_c,
        f"overhead={(us_c - us) / us * 100:.0f}% (grad traffic 4x smaller)",
    )


if __name__ == "__main__":
    main()
