"""Serving-loop benchmark: the always-on estimator service under load.

For fleets of K = 10^2..10^4 workers, drive ``repro.serve.ServiceLoop``
with a steady-state workload (fixed ground-truth worker speeds from the
paper's noise model ``t = f^alpha mu + f^beta sigma eps``, a fixed
near-optimal split) and measure the latencies a serving request would
actually sit behind:

  * **push** — one telemetry row into the device-resident ring (the only
    per-request cost on the observe path; donated, no host sync);
  * **observe tick** — drain + whole-batch Gibbs advance, propose skipped
    (the drift gate held: the posterior did not move);
  * **propose tick** — the same plus a frontier re-solve + publication
    (drift above threshold or the split hit max staleness).

p50/p99 per class, plus the propose-skip rate — the fraction of drains
where the decoupled cadence saved a frontier solve.  Under a steady-state
workload the skip rate must be > 0: that is the point of the cadence.
Rows land in the BENCH artifact via ``benchmarks.run --smoke``.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro import sched, serve


def _pctiles(samples_us):
    s = sorted(samples_us)
    return s[len(s) // 2], s[min(len(s) - 1, int(len(s) * 0.99))]


def _bench_fleet(k: int, *, drains: int = 20, warmup_drains: int = 2) -> None:
    capacity = 8
    rng = np.random.default_rng(0)
    # Ground-truth fleet: 4x speed spread, modest noise — a steady regime
    # where the posterior converges and the drift gate starts holding.
    mu = np.linspace(0.5, 2.0, k)
    sigma = 0.05 * mu
    alpha, beta = 0.9, 0.8
    # Fixed near-optimal split (inverse-speed): the workload the service
    # sees between drains does not move, so neither should the posterior.
    fracs = (1.0 / mu) / (1.0 / mu).sum()

    def step_times():
        return (fracs**alpha * mu
                + fracs**beta * sigma * rng.standard_normal(k)).astype(np.float32)

    # The drift statistic is a max over the fleet, so its steady-state
    # level grows with K (extreme-value) — and at 10^4 workers the
    # worst-worker jitter is also environment-sensitive (reduction-order
    # float shifts steer the chaotic Gibbs chains).  The gate must sit
    # clearly above that level or the bench re-solves on every drain; the
    # staleness backstop supplies the propose-latency samples either way.
    gate = 0.75 if k < 10_000 else 10.0
    config = serve.ServeConfig(
        sched=sched.SchedulerConfig(
            n_iters=2, grid_size=64, num_points=128, opt_steps=40,
            mu_guess=float(mu.mean()),
        ),
        capacity=capacity,
        drift_threshold=gate,
        max_staleness=5,  # staleness backstop keeps propose samples coming
    )
    loop = serve.ServiceLoop(k, config=config, seed=1)
    fr32 = fracs.astype(np.float32)

    push_us, observe_us, propose_us = [], [], []
    drifts = []
    for d in range(warmup_drains + drains):
        warm = d < warmup_drains  # first ticks pay jit compilation
        for _ in range(capacity):
            t0 = time.perf_counter()
            loop.push(fr32, step_times())
            if not warm:
                push_us.append((time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        info = loop.tick()  # blocks on the drained/proposed scalars
        dt = (time.perf_counter() - t0) * 1e6
        if not warm:
            (propose_us if bool(info.proposed) else observe_us).append(dt)
            drifts.append(float(info.drift))

    c = loop.counters()
    n_prop, n_obs = len(propose_us), len(observe_us)
    skip_rate = n_obs / max(n_prop + n_obs, 1)
    p50, p99 = _pctiles(push_us)
    emit(f"serve_push_k{k}", p50, f"p99={p99:.0f}us ring cap={capacity}")
    if observe_us:
        p50, p99 = _pctiles(observe_us)
        emit(f"serve_observe_k{k}", p50,
             f"p99={p99:.0f}us n={n_obs} drain+gibbs, propose skipped")
    if propose_us:
        p50, p99 = _pctiles(propose_us)
        emit(f"serve_propose_k{k}", p50,
             f"p99={p99:.0f}us n={n_prop} drain+gibbs+frontier solve")
    emit(f"serve_skip_rate_k{k}", skip_rate,
         f"skipped {n_obs}/{n_prop + n_obs} drains "
         f"(steady-state drift p50={np.median(drifts):.3f} "
         f"vs gate {config.drift_threshold}); {c['dropped']} rows dropped")


def main() -> None:
    # Fewer rounds at 10^4: a propose tick there is ~10s on a CPU runner,
    # and 12 drains still yield both tick classes (staleness backstop).
    for k, drains in ((100, 20), (1_000, 20), (10_000, 12)):
        _bench_fleet(k, drains=drains)


if __name__ == "__main__":
    main()
