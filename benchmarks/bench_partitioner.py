"""Partitioner quality: expected makespan of the learned split vs naive
equal split vs the oracle (true-parameter) split, on simulated fleets.

This is the deployable claim of the paper: learning (mu, sigma, alpha, beta)
online buys back most of the oracle's advantage over naive splitting.
Exercises the pure-functional ``repro.sched`` API end to end (jitted
observe/propose transitions, batched quantization refinement).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro import sched
from repro.core.frontier import UnitParams
from repro.distributed.simulated_cluster import SimulatedCluster, WorkerSpec


def main() -> None:
    rng = np.random.default_rng(7)
    for k in (4, 16):
        specs = [
            WorkerSpec(mu=float(m), sigma=float(s),
                       alpha=float(a), beta=float(b))
            for m, s, a, b in zip(
                rng.uniform(5, 40, k), rng.uniform(0.5, 3, k),
                rng.uniform(0.7, 1.0, k), rng.uniform(0.6, 1.0, k),
            )
        ]
        cluster = SimulatedCluster(specs, seed=1)
        config = sched.SchedulerConfig(n_iters=12, grid_size=128, mu_guess=15.0)
        state = sched.init(config, k, jax.random.PRNGKey(0))
        # online: observe 8 batches of 16 steps with the CURRENT split
        for _ in range(8):
            fr = np.asarray(sched.propose(state, config)[0])
            fmat = np.tile(fr[:, None], (1, 16))
            tmat = np.stack([cluster.step_times(fr) for _ in range(16)], axis=1)
            state, _ = sched.observe(
                state, sched.Telemetry(jnp.asarray(fmat), jnp.asarray(tmat)),
                config,
            )

        learned = np.asarray(sched.propose(state, config)[0])
        naive = np.full(k, 1.0 / k)
        oracle, _ = sched.solve_fractions(cluster.true_params())

        e_learned = cluster.oracle_makespan(learned)
        e_naive = cluster.oracle_makespan(naive)
        e_oracle = cluster.oracle_makespan(np.asarray(oracle))
        recovered = (e_naive - e_learned) / max(e_naive - e_oracle, 1e-9)
        emit(
            f"partitioner_k{k}", 0.0,
            f"makespan naive={e_naive:.2f} learned={e_learned:.2f} "
            f"oracle={e_oracle:.2f} oracle_gap_recovered={100*recovered:.0f}%",
        )

    # optimizer throughput (called on every refit)
    p = UnitParams.of(list(rng.uniform(5, 40, 64)), list(rng.uniform(0.5, 3, 64)))
    us = time_fn(lambda: sched.solve_fractions(p)[0], iters=5)
    emit("solve_fractions_k64", us,
         "equalizing init + adam refine + candidate select, jitted")

    # batched quantization: K=64 counts refined in one device program
    fr64, _ = sched.solve_fractions(p)
    us_q = time_fn(
        lambda: sched.quantize_fractions(np.asarray(fr64), 512, p), iters=3
    )
    emit("quantize_fractions_k64_mb512", us_q,
         "largest-remainder + batched greedy refinement")


if __name__ == "__main__":
    main()
