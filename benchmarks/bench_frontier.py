"""Paper Figs 1-2: mu(f), sigma^2(f) curves and the efficient frontier.

Reproduces the hypothetical illustration (mu_i=30 s_i=2, mu_j=20 s_j=6):
parabola-like (mu, var) locus, interior minimum-mean point, efficient
frontier as its lower-left Pareto subset.  Also times the sweep (vmapped
quadrature) — the online partitioner calls this every refit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.frontier import UnitParams, pareto_mask, sweep_two_way


def main() -> None:
    p = UnitParams.of([30.0, 20.0], [2.0, 6.0])
    sweep = jax.jit(lambda: sweep_two_way(p, num_f=201))
    us = time_fn(sweep)
    fg, mu_f, var_f = sweep()
    mask = pareto_mask(mu_f, var_f)
    i = int(jnp.argmin(mu_f))
    emit(
        "frontier_sweep_201pts", us,
        f"f*={float(fg[i]):.3f} mu*={float(mu_f[i]):.2f} "
        f"var*={float(var_f[i]):.2f} pareto={int(mask.sum())}",
    )

    # write the curve for inspection (paper Fig 1 data)
    rows = np.stack([np.asarray(fg), np.asarray(mu_f), np.asarray(var_f),
                     np.asarray(mask, np.float32)], axis=1)
    np.savetxt(
        "experiments/fig1_frontier_curve.csv", rows,
        header="f,mu,var,on_frontier", delimiter=",", comments="",
    )

    # endpoint sanity (everything-on-one-unit is dominated)
    emit(
        "frontier_endpoints", 0.0,
        f"mu(f->0)={float(mu_f[0]):.2f} mu(f->1)={float(mu_f[-1]):.2f} "
        f"(both > mu*={float(mu_f[i]):.2f})",
    )


if __name__ == "__main__":
    main()
