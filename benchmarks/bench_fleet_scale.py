"""Fleet-scale serving benchmark: dense grid vs compressed active-set path.

The dense estimation program materializes a (K, 2, G) exponent log-posterior
per Gibbs sweep — the memory/bandwidth wall that caps practical fleets near
K = 10^4 (~400 MB of transient grid at K = 10^5, G = 512).  The compressed
path (``ServeConfig.active_size`` + ``async_propose``) runs the full grid
program only for the top-M active workers (young / surprising / anomalous /
stale — ``core.compress.select_active``), advances the rest through the
moment-matched Beta surrogate, and dispatches the simplex solve OFF the tick
path, publishing on completion.

Per fleet size this module records:

  * **propose-tick p50/p99** for each side — the latency the serving beat
    actually sits behind (every tick proposes: staleness=1, gate held);
  * an interleaved min-time A/B row (``time_pair_min``) with the
    dense/compressed speedup — the acceptance target is >= 5x at K = 10^5;
  * **posterior-state bytes**: the analytic per-sweep grid working set
    (``compress.compression_report``, >= 10x smaller at K = 10^5) plus the
    measured live-array footprint and process peak-RSS high-water mark;
  * a **reader-latency** row: ``fractions()`` p50 while a fleet-sized solve
    is in flight — the published split is a host-buffer read, independent
    of solve time (the non-blocking-tick acceptance check);
  * the O(K log K) water-fill quantization at K = 10^5 (the host rounding
    that was O(K^2 log K) before the vectorized shed/top-up).

``smoke_main`` is the CI entry: reduced grid sizes (G = 64/32 — the guard
that keeps a CPU-only runner in minutes) and few samples; ``main`` widens
the grids and sample counts.  Rows land in ``experiments/BENCH_8.json``.
"""
from __future__ import annotations

import resource
import time

import numpy as np

from benchmarks.common import emit, time_pair_min
from repro import sched, serve
from repro.core import compress

_RING = 8  # telemetry rows buffered per drain


def _pctiles(samples_us):
    s = sorted(samples_us)
    return s[len(s) // 2], s[-1] if len(s) < 100 else s[int(len(s) * 0.99)]


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _live_mb() -> float:
    import jax

    return sum(a.nbytes for a in jax.live_arrays()) / 1e6


def _make_loop(k: int, grid: int, *, active=None, async_p=False):
    mu = np.linspace(0.5, 2.0, k)
    config = serve.ServeConfig(
        sched=sched.SchedulerConfig(
            n_iters=2, grid_size=grid, num_points=128, opt_steps=20,
            mu_guess=float(mu.mean()),
        ),
        capacity=_RING,
        # Every data tick proposes: the gate never fires, staleness always
        # does — clean propose-tick samples on both sides.
        drift_threshold=1e9,
        max_staleness=1,
        active_size=active,
        async_propose=async_p,
    )
    loop = serve.ServiceLoop(k, config=config, seed=1)
    fracs = ((1.0 / mu) / (1.0 / mu).sum()).astype(np.float32)
    rng = np.random.default_rng(0)

    def step_times():
        return (
            fracs**0.9 * mu + fracs**0.8 * 0.05 * mu * rng.standard_normal(k)
        ).astype(np.float32)

    return loop, fracs, step_times


def _drive(loop, fracs, step_times, n_ticks: int, warmup: int = 1):
    """Push one ring of telemetry + tick, ``n_ticks`` timed rounds."""
    samples = []
    info = None
    for d in range(warmup + n_ticks):
        for _ in range(_RING):
            loop.push(fracs, step_times())
        t0 = time.perf_counter()
        info = loop.tick()
        dt = (time.perf_counter() - t0) * 1e6
        if d >= warmup:
            samples.append(dt)
    assert info is not None and bool(info.drained)
    return samples


def _fleet_case(
    k: int, grid: int, active: int, *, dense_ticks: int, comp_ticks: int,
    ab_rounds: int = 0,
) -> None:
    label = f"k{k}_g{grid}"

    # -- compressed first: the dense side then owns the RSS high-water mark
    comp, fracs, step = _make_loop(k, grid, active=active, async_p=True)
    cs = _drive(comp, fracs, step, comp_ticks)
    p50c, p99c = _pctiles(cs)
    emit(
        f"fleet_propose_tick_compressed_{label}", p50c,
        f"p99={p99c:.0f}us n={len(cs)} active M={active} async solve "
        f"off-path; live={_live_mb():.0f}MB rss_peak={_peak_rss_mb():.0f}MB",
    )

    # -- reader latency while a fleet-sized solve is in flight -------------
    # The tick above dispatched a solve; time the published-split read now.
    in_flight = comp._pending is not None
    reads = []
    for _ in range(200):
        t0 = time.perf_counter()
        fr = comp.fractions()
        reads.append((time.perf_counter() - t0) * 1e6)
    assert fr.shape == (k,)
    p50r, p99r = _pctiles(reads)
    emit(
        f"fleet_fractions_read_{label}", p50r,
        f"p99={p99r:.1f}us host buffer read, solve_in_flight={in_flight} "
        "(reader never blocks on the solve)",
    )
    while comp.poll() is False and comp._pending is not None:
        time.sleep(0.01)
    del comp

    rss_before_dense = _peak_rss_mb()
    dense, fracs, step = _make_loop(k, grid)
    ds = _drive(dense, fracs, step, dense_ticks)
    p50d, p99d = _pctiles(ds)
    emit(
        f"fleet_propose_tick_dense_{label}", p50d,
        f"p99={p99d:.0f}us n={len(ds)} full (K,2,G) grid + in-tick solve; "
        f"live={_live_mb():.0f}MB rss_peak={_peak_rss_mb():.0f}MB "
        f"(+{_peak_rss_mb() - rss_before_dense:.0f}MB over compressed)",
    )
    emit(
        f"fleet_propose_speedup_{label}", p50d / max(p50c, 1e-9),
        f"x dense p50 / compressed p50 (target >= 5x at k=100000)",
    )

    # -- interleaved min-time A/B: same noisy-neighbor conditions ----------
    if ab_rounds:
        comp2, fr2, st2 = _make_loop(k, grid, active=active, async_p=True)
        _drive(comp2, fr2, st2, 1)  # compile both sides before interleaving

        def one_cycle(loop, fracs, step):
            for _ in range(_RING):
                loop.push(fracs, step())
            return loop.tick().ll

        a_us, b_us = time_pair_min(
            lambda: one_cycle(dense, fracs, step),
            lambda: one_cycle(comp2, fr2, st2),
            rounds=ab_rounds,
        )
        emit(
            f"fleet_ab_min_dense_{label}", a_us,
            f"vs compressed {b_us:.0f}us -> {a_us / max(b_us, 1e-9):.1f}x "
            f"(interleaved min-time, {ab_rounds} rounds)",
        )
        del comp2
    del dense

    # -- the analytic footprint the grid program materializes per sweep ----
    # Emitted at the bench grid AND at the paper-fidelity G=512: the report
    # is closed-form, so the production sizing does not need the reduced-G
    # guard the *timing* rows run under.
    grids = (grid,) if grid == 512 else (grid, 512)
    for g in grids:
        rep = compress.compression_report(k, g, active)
        emit(
            f"fleet_posterior_bytes_dense_k{k}_g{g}", rep.dense_bytes / 1e6,
            f"MB per-sweep grid working set (K,2,G) f32 + chain scalars",
        )
        emit(
            f"fleet_posterior_bytes_compressed_k{k}_g{g}",
            rep.compressed_bytes / 1e6,
            f"MB active slab M={active} + Beta surrogate scalars -> "
            f"{rep.ratio:.1f}x smaller (target >= 10x at k=100000, G=512)",
        )


def _quantize_row(k: int) -> None:
    rng = np.random.default_rng(0)
    fr = rng.dirichlet(np.full(k, 2.0))
    t0 = time.perf_counter()
    counts = sched.quantize_fractions(fr, 8 * k)
    dt = (time.perf_counter() - t0) * 1e6
    assert counts.sum() == 8 * k
    emit(
        f"quantize_waterfill_k{k}", dt,
        "host rounding, O(K log K) water-fill shed/top-up",
    )


def main() -> None:
    """Full suite: paper-fidelity grids where feasible, all three decades."""
    _fleet_case(1_000, 512, 128, dense_ticks=5, comp_ticks=5, ab_rounds=3)
    _fleet_case(10_000, 128, 512, dense_ticks=3, comp_ticks=5, ab_rounds=2)
    _fleet_case(100_000, 64, 2048, dense_ticks=2, comp_ticks=3)
    _quantize_row(100_000)


def smoke_main() -> None:
    """CI subset: reduced-G guard keeps the CPU runner in minutes."""
    _fleet_case(1_000, 64, 128, dense_ticks=5, comp_ticks=5, ab_rounds=3)
    _fleet_case(10_000, 32, 512, dense_ticks=3, comp_ticks=4, ab_rounds=2)
    _fleet_case(100_000, 32, 2048, dense_ticks=2, comp_ticks=3)
    _quantize_row(100_000)


if __name__ == "__main__":
    main()
