"""Distribution primitives used by the Bayesian workflow-partitioning estimator.

All functions are pure, jittable, and broadcast over leading batch axes so the
Gibbs chain can be vmapped across thousands of workers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy import special as jsp_special
from jax.scipy.stats import norm as jsp_norm

Array = jax.Array

# Numerical floors. We run everything in f32 (TPU-native); these keep the
# grid-integration and moment-matching well-conditioned.
EPS = 1e-6
TINY = 1e-30


def normal_logpdf(x: Array, loc: Array, scale: Array) -> Array:
    scale = jnp.maximum(scale, EPS)
    z = (x - loc) / scale
    return -0.5 * z * z - jnp.log(scale) - 0.5 * jnp.log(2.0 * jnp.pi)


def normal_cdf(x: Array, loc: Array, scale: Array) -> Array:
    scale = jnp.maximum(scale, EPS)
    return jsp_norm.cdf(x, loc=loc, scale=scale)


def gamma_logpdf(x: Array, shape: Array, rate: Array) -> Array:
    x = jnp.maximum(x, TINY)
    return (
        shape * jnp.log(rate)
        - jsp_special.gammaln(shape)
        + (shape - 1.0) * jnp.log(x)
        - rate * x
    )


def beta_logpdf(x: Array, a: Array, b: Array) -> Array:
    x = jnp.clip(x, EPS, 1.0 - EPS)
    return (
        (a - 1.0) * jnp.log(x)
        + (b - 1.0) * jnp.log1p(-x)
        - jsp_special.betaln(a, b)
    )


def sample_gamma(key: Array, shape_param: Array, rate: Array) -> Array:
    """Gamma(shape, rate) sampler (jax.random.gamma is shape/scale=1)."""
    shape_param = jnp.maximum(shape_param, EPS)
    rate = jnp.maximum(rate, TINY)
    return jax.random.gamma(key, shape_param) / rate


def sample_normal(key: Array, loc: Array, scale: Array) -> Array:
    return loc + jnp.maximum(scale, 0.0) * jax.random.normal(key, jnp.shape(loc))


def sample_beta(key: Array, a: Array, b: Array) -> Array:
    a = jnp.maximum(a, EPS)
    b = jnp.maximum(b, EPS)
    return jnp.clip(jax.random.beta(key, a, b), EPS, 1.0 - EPS)


def trapezoid_weights(grid: Array) -> Array:
    """Trapezoid-rule quadrature weights for a (possibly non-uniform) 1-D grid."""
    d = jnp.diff(grid)
    w = jnp.zeros_like(grid)
    w = w.at[:-1].add(0.5 * d)
    w = w.at[1:].add(0.5 * d)
    return w


def normalize_log_density(logp: Array, grid: Array) -> Array:
    """Normalize an unnormalized log-density evaluated on ``grid`` into a pdf.

    Uses log-sum-exp against trapezoid weights for f32 stability.
    Supports leading batch axes on ``logp`` (grid is the trailing axis).
    """
    w = trapezoid_weights(grid)
    m = jnp.max(logp, axis=-1, keepdims=True)
    p = jnp.exp(logp - m)
    z = jnp.sum(p * w, axis=-1, keepdims=True)
    return p / jnp.maximum(z, TINY)
