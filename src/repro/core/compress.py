"""Compressed posterior representation + active-set refresh policy.

The dense (K, 2, G) exponent log-posterior grid is the fleet estimator's
memory and bandwidth ceiling (~400 MB at K=1e5, G=512, re-evaluated every
drain).  This module breaks that wall for *converged* workers:

  * The **surrogate** is the moment-matched Beta fit the sampler already
    maintains — ``GibbsState.alpha_prior`` / ``beta_prior`` are the Eqs 12-18
    method-of-moments compression of the last full grid evaluation.  Once a
    worker has converged, sampling its exponents from the frozen Beta fit is
    within grid-integration error of re-evaluating the grid (validated
    against ``moments.log_posterior_grid`` by :func:`surrogate_gap`); the
    conjugate Normal-Gamma block needs no grid at all.  For positive-scale
    summaries (the completion-time scale ``mu``) the matching compression is
    a log-normal fit, :func:`fit_lognormal_moments`.

  * The **active set** keeps the full grid for the M workers that still need
    it — young (low Normal-Gamma pseudo-counts), high ``hier.surprise``, or
    high-anomaly workers, plus anyone whose surrogate has gone stale.
    :func:`select_active` ranks the fleet by a priority built from exactly
    those existing statistics and takes a fixed-size top-M, so downstream
    shapes stay static and jit never retraces as membership churns.

``gibbs_batch(..., active_idx=...)`` consumes the selection: the gathered
M-worker slab runs the full fused grid path, everyone else runs the
grid-free surrogate sweep, and the results scatter-merge back — bitwise the
dense program when M = K.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .gibbs import GibbsState
from .moments import (
    BetaParams,
    exponent_grid,
    fit_beta_method_of_moments,
    log_posterior_grid,
    moments_from_log_density,
)

Array = jax.Array

# float32 leaves of one worker's compressed GibbsState: ng(mu0, kappa0, nu0,
# psi0) + alpha_prior(a, b) + beta_prior(a, b) + samples(mu, lam, alpha,
# beta).  The uint32 PRNG key pair adds the same 8 bytes to both
# representations and is excluded from the comparison.
COMPRESSED_LEAVES = 12


def beta_moments(p: BetaParams) -> Tuple[Array, Array]:
    """Analytic (E, Var) of Beta(a, b) — the surrogate's closed-form moments."""
    s = p.a + p.b
    mean = p.a / s
    var = p.a * p.b / (s * s * (s + 1.0))
    return mean, var


def fit_lognormal_moments(mean: Array, var: Array) -> Tuple[Array, Array]:
    """Log-normal (m, s2) matching (E, Var) — the positive-scale surrogate.

    Returns the log-space location and variance such that
    ``LogNormal(m, s2)`` has the given mean and variance.  Used to compress
    positive-scale posteriors (completion-time scale) where a Beta fit does
    not apply.
    """
    mean = jnp.maximum(mean, 1e-12)
    s2 = jnp.log1p(jnp.maximum(var, 0.0) / (mean * mean))
    m = jnp.log(mean) - 0.5 * s2
    return m, s2


def surrogate_moments(state: GibbsState) -> Tuple[Array, Array]:
    """(E, Var) of the compressed exponent posteriors, shape (..., 2).

    Index 0 is the alpha posterior, index 1 the beta posterior — matching the
    layout of ``moments.log_posterior_grid``.
    """
    ea, va = beta_moments(state.alpha_prior)
    eb, vb = beta_moments(state.beta_prior)
    return jnp.stack([ea, eb], axis=-1), jnp.stack([va, vb], axis=-1)


def grid_moments(
    state: GibbsState,
    t: Array,
    f: Array,
    mask: Optional[Array] = None,
    *,
    grid_size: int = 512,
) -> Tuple[Array, Array]:
    """(E, Var) of the dense exponent grid posterior, shape (..., 2).

    Evaluates ``moments.log_posterior_grid`` at the state's current
    conditioning samples — exactly the grid the next ``_advance`` sweep
    would moment-fit — and integrates it.  The reference the surrogate is
    validated against.
    """
    grid = exponent_grid(grid_size)
    logp = log_posterior_grid(
        grid, t, f, state.mu, state.lam, state.alpha, state.beta,
        state.alpha_prior, state.beta_prior, mask, symmetric_grid=True,
    )
    return moments_from_log_density(grid, logp)


def fit_surrogate(
    state: GibbsState,
    t: Array,
    f: Array,
    mask: Optional[Array] = None,
    *,
    grid_size: int = 512,
) -> Tuple[BetaParams, BetaParams]:
    """Moment-match fresh Beta surrogates to the dense grid posterior.

    This is what a full active-set refresh chains into ``alpha_prior`` /
    ``beta_prior`` (identical to the fit inside ``gibbs._advance``); exposed
    for validation and for compressing externally-fitted states.
    """
    mean, var = grid_moments(state, t, f, mask, grid_size=grid_size)
    a = fit_beta_method_of_moments(mean[..., 0], var[..., 0])
    b = fit_beta_method_of_moments(mean[..., 1], var[..., 1])
    return a, b


def surrogate_gap(
    state: GibbsState,
    t: Array,
    f: Array,
    mask: Optional[Array] = None,
    *,
    grid_size: int = 512,
) -> Tuple[Array, Array]:
    """|moment error| of the surrogate vs the dense grid, shape (..., 2).

    Returns (|E_grid - E_surrogate|, |Var_grid - Var_surrogate|) per exponent.
    For a converged worker (evidence dominated by the chained prior) the mean
    gap is < 1e-3 — the acceptance bound for trusting the compressed path.
    """
    ge, gv = grid_moments(state, t, f, mask, grid_size=grid_size)
    se, sv = surrogate_moments(state)
    return jnp.abs(ge - se), jnp.abs(gv - sv)


def select_active(
    m: int,
    *,
    age: Array,
    nu: Optional[Array] = None,
    surprise: Optional[Array] = None,
    anomaly: Optional[Array] = None,
    live: Optional[Array] = None,
    youth_weight: float = 32.0,
    surprise_weight: float = 8.0,
    anomaly_weight: float = 4.0,
    youth_scale: float = 16.0,
) -> Tuple[Array, Array]:
    """Pick the fixed-size top-M active set; returns (idx (M,), priority (K,)).

    Priority is a sum of the existing fleet-health statistics — no new
    signals are estimated:

      * ``age``: drains since the worker's last full grid refresh.  Baseline
        term; guarantees every live worker is eventually refreshed
        (round-robin under ties, since ``top_k`` breaks ties by index).
      * ``nu``: Normal-Gamma ``nu0`` pseudo-counts.  Young workers (low
        effective sample size ``2(nu-1)``) score up to ``youth_weight``.
      * ``surprise``: ``hier.surprise`` drift statistic (clipped at 0).
      * ``anomaly``: any higher-is-worse anomaly score, e.g. the EWMA
        log-likelihood deficit from ``sched.anomaly``.
      * ``live``: dead capacity slots drop to -inf and are only selected
        when fewer than M live workers exist.

    M is static so the returned index is a fixed shape — selection feeds
    ``gibbs_batch(active_idx=...)`` without retracing.
    """
    pri = age.astype(jnp.float32)
    if nu is not None:
        ess = jnp.maximum(2.0 * (nu - 1.0), 0.0)  # hier.effective_sample_size
        pri = pri + youth_weight * youth_scale / (youth_scale + ess)
    if surprise is not None:
        pri = pri + surprise_weight * jnp.maximum(surprise, 0.0)
    if anomaly is not None:
        pri = pri + anomaly_weight * jnp.maximum(anomaly, 0.0)
    if live is not None:
        pri = jnp.where(live > 0, pri, -jnp.inf)
    _, idx = jax.lax.top_k(pri, m)
    return idx, pri


class CompressionReport(NamedTuple):
    """Posterior-state footprint of dense vs compressed configurations."""

    dense_bytes: int
    compressed_bytes: int
    ratio: float


def compression_report(
    k: int, grid_size: int, active: int, *, dtype_bytes: int = 4
) -> CompressionReport:
    """Posterior-state bytes: dense (K, 2, G) grid vs active-set compressed.

    Dense keeps the full exponent grid for all K workers; compressed keeps
    the grid only for the M-worker active slab plus the per-worker scalar
    surrogate (COMPRESSED_LEAVES floats) that every configuration carries.
    """
    scalars = k * COMPRESSED_LEAVES * dtype_bytes
    dense = k * 2 * grid_size * dtype_bytes + scalars
    compressed = min(active, k) * 2 * grid_size * dtype_bytes + scalars
    return CompressionReport(dense, compressed, dense / max(compressed, 1))
