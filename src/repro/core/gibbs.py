"""Algorithm 1 — Gibbs sampling of (mu, sigma, alpha, beta).

The sampler follows the paper exactly:

  * per batch of telemetry (T, F): run ``n_iters`` Gibbs sweeps, each sweep
    - recomputing the Normal-Gamma posterior (Eqs 6-9) at the current
      (alpha, beta) and sampling lambda ~ Gamma(nu_N, psi_N),
      mu ~ N(mu_N, (kappa_N lambda)^{-1});
    - refitting the Beta approximations of alpha and beta (Eqs 10-18) at the
      current (mu, lambda) and sampling alpha, beta from them;
  * chaining batches: the posterior hyperparameters become the next batch's
    prior ("the posterior belief ... can become the prior belief for the next
    batch"), which lets the estimator track drifting systems.

Implementation notes (TPU-native):
  * the whole sweep loop is a ``jax.lax.scan`` inside one jitted function;
  * every function broadcasts over leading worker axes, so a fleet of K units
    is estimated with ``jax.vmap`` in a single device program;
  * the O(G*N) grid evaluation can be routed to the Pallas kernel
    (``use_pallas=True``), which is the perf-critical path for production
    telemetry volumes.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .distributions import sample_beta, sample_gamma, sample_normal
from .moments import (
    BetaParams,
    exponent_grid,
    update_alpha_beta_params,
)
from .posterior import NormalGammaParams, log_likelihood, update_normal_gamma

Array = jax.Array


class GibbsState(NamedTuple):
    """Carry of the Gibbs chain: prior hyperparameters + current samples."""

    ng: NormalGammaParams
    alpha_prior: BetaParams
    beta_prior: BetaParams
    mu: Array
    lam: Array
    alpha: Array
    beta: Array
    key: Array

    @property
    def sigma(self) -> Array:
        return jnp.sqrt(1.0 / jnp.maximum(self.lam, 1e-30))


def init_state(
    key: Array,
    ng: Optional[NormalGammaParams] = None,
    alpha_prior: Optional[BetaParams] = None,
    beta_prior: Optional[BetaParams] = None,
    mu_guess: float = 1.0,
) -> GibbsState:
    """Draw the initial (alpha, beta) from their priors, as in Algorithm 1."""
    ng = ng if ng is not None else NormalGammaParams.default(mu_guess)
    alpha_prior = alpha_prior if alpha_prior is not None else BetaParams.default()
    beta_prior = beta_prior if beta_prior is not None else BetaParams.default()
    k_a, k_b, k_l, k_m, key = jax.random.split(key, 5)
    alpha = sample_beta(k_a, alpha_prior.a, alpha_prior.b)
    beta = sample_beta(k_b, beta_prior.a, beta_prior.b)
    lam = sample_gamma(k_l, ng.nu0, ng.psi0)
    mu = sample_normal(k_m, ng.mu0, 1.0 / jnp.sqrt(jnp.maximum(ng.kappa0 * lam, 1e-30)))
    return GibbsState(ng, alpha_prior, beta_prior, mu, lam, alpha, beta, key)


@functools.partial(
    jax.jit, static_argnames=("n_iters", "grid_size", "use_pallas", "chain_priors")
)
def gibbs_batch(
    state: GibbsState,
    t: Array,
    f: Array,
    mask: Optional[Array] = None,
    *,
    n_iters: int = 20,
    grid_size: int = 512,
    use_pallas: bool = False,
    chain_priors: bool = True,
) -> Tuple[GibbsState, Array]:
    """Process one telemetry batch; returns (new_state, log_likelihood).

    Args:
      state: current chain state (prior hyperparameters + samples).
      t, f: observations, shape (N,).
      mask: optional validity mask (N,).
      chain_priors: if True (paper's Algorithm 1), the batch posterior becomes
        the next batch's prior.
    """
    grid = exponent_grid(grid_size)

    def sweep(carry, _):
        st = carry
        key, k_l, k_m, k_a, k_b = jax.random.split(st.key, 5)

        # -- (mu, lambda) block: conjugate update at current (alpha, beta).
        ng_post = update_normal_gamma(st.ng, t, f, st.alpha, st.beta, mask)
        lam = sample_gamma(k_l, ng_post.nu0, ng_post.psi0)
        mu = sample_normal(
            k_m, ng_post.mu0, 1.0 / jnp.sqrt(jnp.maximum(ng_post.kappa0 * lam, 1e-30))
        )

        # -- (alpha, beta) block: grid posterior -> Beta moment fit -> sample.
        a_post, b_post = update_alpha_beta_params(
            grid, t, f, mu, lam, st.alpha, st.beta,
            st.alpha_prior, st.beta_prior, mask, use_pallas=use_pallas,
        )
        alpha = sample_beta(k_a, a_post.a, a_post.b)
        beta = sample_beta(k_b, b_post.a, b_post.b)

        new_st = GibbsState(st.ng, st.alpha_prior, st.beta_prior, mu, lam, alpha, beta, key)
        return new_st, (ng_post, a_post, b_post)

    state, (ng_hist, a_hist, b_hist) = jax.lax.scan(
        sweep, state, None, length=n_iters
    )

    last = lambda tree: jax.tree_util.tree_map(lambda x: x[-1], tree)
    ng_post, a_post, b_post = last(ng_hist), last(a_hist), last(b_hist)

    if chain_priors:
        state = state._replace(ng=ng_post, alpha_prior=a_post, beta_prior=b_post)

    ll = log_likelihood(t, f, state.mu, state.lam, state.alpha, state.beta, mask)
    return state, ll


def discount_state(state: GibbsState, rho: float) -> GibbsState:
    """Power-prior forgetting (beyond-paper extension, DESIGN.md §8).

    Algorithm 1 chains posterior -> prior with full weight, so a long healthy
    history makes the estimator sluggish when the system drifts.  Scaling the
    pseudo-count hyperparameters by rho in (0, 1] keeps every posterior MEAN
    but widens the distributions — equivalent to exponentially down-weighting
    old evidence.  rho=1 recovers the paper exactly.
    """
    if rho >= 1.0:
        return state
    ng = state.ng
    ng = NormalGammaParams(
        mu0=ng.mu0,
        kappa0=ng.kappa0 * rho,
        nu0=jnp.maximum(ng.nu0 * rho, 0.51),  # keep Gamma proper
        psi0=ng.psi0 * rho,
    )
    soften = lambda p: BetaParams(
        a=(p.a - 1.0) * rho + 1.0, b=(p.b - 1.0) * rho + 1.0
    )
    return state._replace(
        ng=ng,
        alpha_prior=soften(state.alpha_prior),
        beta_prior=soften(state.beta_prior),
    )


def fit(
    key: Array,
    t: Array,
    f: Array,
    *,
    batch_size: int = 32,
    n_iters: int = 20,
    grid_size: int = 512,
    mu_guess: Optional[float] = None,
    use_pallas: bool = False,
) -> Tuple[GibbsState, Array]:
    """Fit one unit's parameters from a telemetry stream (N,) in batches.

    Returns the final state and the per-batch log-likelihood trace
    (the paper's Fig 5 curve).
    """
    n = t.shape[-1]
    n_batches = max(n // batch_size, 1)
    n_used = n_batches * batch_size
    t_b = t[:n_used].reshape(n_batches, batch_size)
    f_b = f[:n_used].reshape(n_batches, batch_size)

    guess = float(jnp.mean(t) / jnp.maximum(jnp.mean(f), 1e-6)) if mu_guess is None else mu_guess
    state = init_state(key, mu_guess=guess)

    lls = []
    for b in range(n_batches):
        state, ll = gibbs_batch(
            state, t_b[b], f_b[b],
            n_iters=n_iters, grid_size=grid_size, use_pallas=use_pallas,
        )
        lls.append(ll)
    return state, jnp.stack(lls)


def fit_fleet(
    key: Array,
    t: Array,
    f: Array,
    *,
    n_iters: int = 20,
    grid_size: int = 512,
    mu_guess: Optional[Array] = None,
) -> Tuple[GibbsState, Array]:
    """Vmapped fleet estimation: t, f of shape (K, N) -> per-worker states.

    One device program estimates every worker simultaneously — this is the
    production path for thousands of nodes.
    """
    k = t.shape[0]
    keys = jax.random.split(key, k)
    if mu_guess is None:
        mu_guess = jnp.mean(t, axis=-1) / jnp.maximum(jnp.mean(f, axis=-1), 1e-6)

    def one(key_i, guess_i):
        ng = NormalGammaParams(
            mu0=guess_i.astype(jnp.float32),
            kappa0=jnp.asarray(1e-3, jnp.float32),
            nu0=jnp.asarray(1.0, jnp.float32),
            psi0=jnp.asarray(1.0, jnp.float32),
        )
        return init_state(key_i, ng=ng)

    states = jax.vmap(one)(keys, mu_guess)

    batched = jax.vmap(
        lambda st, ti, fi: gibbs_batch(
            st, ti, fi, n_iters=n_iters, grid_size=grid_size
        )
    )
    states, ll = batched(states, t, f)
    return states, ll
