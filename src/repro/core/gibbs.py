"""Algorithm 1 — Gibbs sampling of (mu, sigma, alpha, beta).

The sampler follows the paper exactly:

  * per batch of telemetry (T, F): run ``n_iters`` Gibbs sweeps, each sweep
    - recomputing the Normal-Gamma posterior (Eqs 6-9) at the current
      (alpha, beta) and sampling lambda ~ Gamma(nu_N, psi_N),
      mu ~ N(mu_N, (kappa_N lambda)^{-1});
    - refitting the Beta approximations of alpha and beta (Eqs 10-18) at the
      current (mu, lambda) and sampling alpha, beta from them;
  * chaining batches: the posterior hyperparameters become the next batch's
    prior ("the posterior belief ... can become the prior belief for the next
    batch"), which lets the estimator track drifting systems.

Implementation notes (TPU-native):
  * the whole sweep loop is a ``jax.lax.scan`` inside one jitted function;
  * ``gibbs_batch`` is fleet-native: hand it a state whose leaves carry a
    leading worker axis K (as built by ``vmap(init_state)``) plus (K, N)
    telemetry and every sub-step runs batched — the O(K*G*N) grid posterior
    is then ONE fused Pallas launch per sweep covering all workers and both
    exponents (``use_pallas=True``), not a vmap of per-worker kernels;
  * single-unit states (scalar leaves) take the same code path with K
    collapsed, so ``vmap(gibbs_batch)`` remains valid for exotic batching;
  * ``fit`` streams telemetry batches through ``lax.scan`` with the final
    partial batch padded + masked, so no observation is ever dropped;
  * the fleet axis (including the folded S*K stage-fleet axis of a workflow
    DAG) optionally shards across a device mesh via ``shard_map`` — pass a
    ``core.sharding.ShardingConfig`` as ``sharding=``; results are bitwise
    identical to the single-device program (see ``docs/scaling.md``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .distributions import sample_beta, sample_gamma, sample_normal
from .moments import (
    BetaParams,
    exponent_grid,
    update_alpha_beta_params,
)
from .posterior import NormalGammaParams, log_likelihood, update_normal_gamma
from .sharding import ShardingConfig, shard_fleet_call

Array = jax.Array


class GibbsState(NamedTuple):
    """Carry of the Gibbs chain: prior hyperparameters + current samples."""

    ng: NormalGammaParams
    alpha_prior: BetaParams
    beta_prior: BetaParams
    mu: Array
    lam: Array
    alpha: Array
    beta: Array
    key: Array

    @property
    def sigma(self) -> Array:
        return jnp.sqrt(1.0 / jnp.maximum(self.lam, 1e-30))


def init_state(
    key: Array,
    ng: Optional[NormalGammaParams] = None,
    alpha_prior: Optional[BetaParams] = None,
    beta_prior: Optional[BetaParams] = None,
    mu_guess: float = 1.0,
) -> GibbsState:
    """Draw the initial (alpha, beta) from their priors, as in Algorithm 1."""
    ng = ng if ng is not None else NormalGammaParams.default(mu_guess)
    alpha_prior = alpha_prior if alpha_prior is not None else BetaParams.default()
    beta_prior = beta_prior if beta_prior is not None else BetaParams.default()
    k_a, k_b, k_l, k_m, key = jax.random.split(key, 5)
    alpha = sample_beta(k_a, alpha_prior.a, alpha_prior.b)
    beta = sample_beta(k_b, beta_prior.a, beta_prior.b)
    lam = sample_gamma(k_l, ng.nu0, ng.psi0)
    mu = sample_normal(k_m, ng.mu0, 1.0 / jnp.sqrt(jnp.maximum(ng.kappa0 * lam, 1e-30)))
    return GibbsState(ng, alpha_prior, beta_prior, mu, lam, alpha, beta, key)


def _split5(key: Array) -> Tuple[Array, Array, Array, Array, Array]:
    """Five-way PRNG split, batched over an optional leading worker axis.

    Per-worker keys are split exactly as a vmap of ``jax.random.split`` would,
    so the fleet-native sweep reproduces the legacy vmapped chains bitwise.
    """
    if key.ndim == 1:
        k = jax.random.split(key, 5)
        return k[0], k[1], k[2], k[3], k[4]
    ks = jax.vmap(lambda kk: jax.random.split(kk, 5))(key)  # (K, 5, 2)
    return ks[:, 0], ks[:, 1], ks[:, 2], ks[:, 3], ks[:, 4]


def _sample(fn, key: Array, *params: Array) -> Array:
    """Apply a distribution sampler per worker when keys are batched."""
    if key.ndim == 1:
        return fn(key, *params)
    return jax.vmap(fn)(key, *params)


def _advance(
    state: GibbsState,
    t: Array,
    f: Array,
    mask: Optional[Array],
    *,
    n_iters: int,
    grid_size: int,
    use_pallas: bool,
    chain_priors: bool,
) -> Tuple[GibbsState, Array]:
    """One telemetry batch of Gibbs sweeps (the ``gibbs_batch`` body).

    Strictly per-worker: no operation mixes rows of the fleet axis, which is
    what makes the whole function safe to ``shard_map`` over K — each shard
    runs this exact program on its slice of the fleet and the results
    concatenate to the single-device answer bitwise.
    """
    grid = exponent_grid(grid_size)

    def sweep(carry, _):
        st = carry
        key, k_l, k_m, k_a, k_b = _split5(st.key)

        # -- (mu, lambda) block: conjugate update at current (alpha, beta).
        ng_post = update_normal_gamma(st.ng, t, f, st.alpha, st.beta, mask)
        lam = _sample(sample_gamma, k_l, ng_post.nu0, ng_post.psi0)
        mu = _sample(
            sample_normal, k_m, ng_post.mu0,
            1.0 / jnp.sqrt(jnp.maximum(ng_post.kappa0 * lam, 1e-30)),
        )

        # -- (alpha, beta) block: grid posterior -> Beta moment fit -> sample.
        a_post, b_post = update_alpha_beta_params(
            grid, t, f, mu, lam, st.alpha, st.beta,
            st.alpha_prior, st.beta_prior, mask, use_pallas=use_pallas,
            symmetric_grid=True,  # exponent_grid is a symmetric linspace
        )
        alpha = _sample(sample_beta, k_a, a_post.a, a_post.b)
        beta = _sample(sample_beta, k_b, b_post.a, b_post.b)

        new_st = GibbsState(st.ng, st.alpha_prior, st.beta_prior, mu, lam, alpha, beta, key)
        return new_st, (ng_post, a_post, b_post)

    state, (ng_hist, a_hist, b_hist) = jax.lax.scan(
        sweep, state, None, length=n_iters
    )

    last = lambda tree: jax.tree_util.tree_map(lambda x: x[-1], tree)
    ng_post, a_post, b_post = last(ng_hist), last(a_hist), last(b_hist)

    if chain_priors:
        state = state._replace(ng=ng_post, alpha_prior=a_post, beta_prior=b_post)

    ll = log_likelihood(t, f, state.mu, state.lam, state.alpha, state.beta, mask)
    return state, ll


def _advance_surrogate(
    state: GibbsState,
    t: Array,
    f: Array,
    mask: Optional[Array],
    *,
    n_iters: int,
    chain_priors: bool,
) -> Tuple[GibbsState, Array]:
    """Grid-free Gibbs sweeps against the compressed exponent posterior.

    The stored Beta hyperparameters ARE the moment-matched surrogate of the
    exponent posterior (``core.compress``): instead of re-evaluating the
    (2, G) log-posterior grid, each sweep samples (alpha, beta) directly from
    the frozen Beta fit and runs only the conjugate Normal-Gamma block.  The
    Beta priors are never re-chained — they stay frozen until the worker next
    enters the active set and earns a full grid refresh.

    PRNG discipline matches ``_advance`` split-for-split, so a worker keeps a
    coherent key stream while it alternates between the two paths.
    """

    def sweep(carry, _):
        st = carry
        key, k_l, k_m, k_a, k_b = _split5(st.key)

        ng_post = update_normal_gamma(st.ng, t, f, st.alpha, st.beta, mask)
        lam = _sample(sample_gamma, k_l, ng_post.nu0, ng_post.psi0)
        mu = _sample(
            sample_normal, k_m, ng_post.mu0,
            1.0 / jnp.sqrt(jnp.maximum(ng_post.kappa0 * lam, 1e-30)),
        )

        alpha = _sample(sample_beta, k_a, st.alpha_prior.a, st.alpha_prior.b)
        beta = _sample(sample_beta, k_b, st.beta_prior.a, st.beta_prior.b)

        new_st = GibbsState(st.ng, st.alpha_prior, st.beta_prior, mu, lam, alpha, beta, key)
        return new_st, ng_post

    state, ng_hist = jax.lax.scan(sweep, state, None, length=n_iters)
    ng_post = jax.tree_util.tree_map(lambda x: x[-1], ng_hist)

    if chain_priors:
        # Only the conjugate block chains; the Beta surrogate stays frozen.
        state = state._replace(ng=ng_post)

    ll = log_likelihood(t, f, state.mu, state.lam, state.alpha, state.beta, mask)
    return state, ll


def _advance_active(
    state: GibbsState,
    t: Array,
    f: Array,
    mask: Optional[Array],
    active_idx: Array,
    *,
    n_iters: int,
    grid_size: int,
    use_pallas: bool,
    chain_priors: bool,
) -> Tuple[GibbsState, Array]:
    """Active-set advance: full grid for the gathered M-worker slab, the
    compressed surrogate for everyone else, scatter-merged back to (K,).

    Because ``_advance`` is strictly per-worker (no op mixes fleet rows), the
    gathered slab computes exactly what the same rows would compute inside a
    dense launch — with ``active_idx = arange(K)`` the result is bitwise the
    dense path.
    """
    m = jnp.ones_like(t) if mask is None else jnp.broadcast_to(mask, t.shape)

    take = lambda x: x[active_idx]
    slab = jax.tree_util.tree_map(take, state)
    slab, ll_slab = _advance(
        slab, take(t), take(f), take(m),
        n_iters=n_iters, grid_size=grid_size, use_pallas=use_pallas,
        chain_priors=chain_priors,
    )

    rest, ll_rest = _advance_surrogate(
        state, t, f, m, n_iters=n_iters, chain_priors=chain_priors
    )

    put = lambda full, part: full.at[active_idx].set(part)
    merged = jax.tree_util.tree_map(put, rest, slab)
    return merged, put(ll_rest, ll_slab)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_iters", "grid_size", "use_pallas", "chain_priors", "sharding"
    ),
)
def gibbs_batch(
    state: GibbsState,
    t: Array,
    f: Array,
    mask: Optional[Array] = None,
    *,
    n_iters: int = 20,
    grid_size: int = 512,
    use_pallas: bool = False,
    chain_priors: bool = True,
    sharding: Optional[ShardingConfig] = None,
    active_idx: Optional[Array] = None,
) -> Tuple[GibbsState, Array]:
    """Process one telemetry batch; returns (new_state, log_likelihood).

    Fleet-native: ``state`` may carry a leading worker axis K on every leaf
    (with t/f/mask shaped (K, N)), in which case all K chains advance inside
    one program and the grid posterior is a single fused evaluation — one
    Pallas launch per sweep when ``use_pallas`` — instead of K separate ones.

    With ``sharding`` (a ``core.sharding.ShardingConfig``) the fleet axis is
    additionally partitioned across the mesh's ``workers`` devices via
    ``shard_map``: each shard advances its K/n_shards chains through the same
    fused per-shard program (kernel launch included), the grid stays
    replicated, and only the small per-worker outputs cross shards.  The
    per-worker math never mixes fleet rows, so the sharded result equals the
    single-device result bitwise (PRNG splits are per-worker).  K not
    divisible by the shard count is padded with masked-out dummy workers and
    sliced back.  Single-unit states ((N,) telemetry) ignore ``sharding``.

    Args:
      state: current chain state (prior hyperparameters + samples).
      t, f: observations, shape (N,) or (K, N).
      mask: optional validity mask, same shape as ``t``.
      chain_priors: if True (paper's Algorithm 1), the batch posterior becomes
        the next batch's prior.
      sharding: optional fleet-axis device sharding; None = single device.
      active_idx: optional (M,) int array of fleet rows to advance through the
        full grid path; the remaining K-M workers advance through the grid-free
        compressed surrogate (``core.compress``).  M is static (fixed-size
        active set), values are traced.  Bitwise-equal to the dense path when
        ``active_idx = arange(K)``.  Single-device only (the slab gather is a
        cross-shard op); combine with ``sharding=None``.
    """
    kw = dict(
        n_iters=n_iters,
        grid_size=grid_size,
        use_pallas=use_pallas,
        chain_priors=chain_priors,
    )
    if active_idx is not None and t.ndim >= 2:
        if sharding is not None:
            raise ValueError(
                "active_idx is a single-device path; pass sharding=None"
            )
        return _advance_active(state, t, f, mask, active_idx, **kw)
    if sharding is None or t.ndim < 2:
        return _advance(state, t, f, mask, **kw)

    # Dummy workers added by the pad (when K % n_shards != 0) carry
    # duplicated finite state rows and fully-masked telemetry: they compute
    # a discarded posterior from zero observations and cannot touch real
    # rows (no cross-worker ops).
    m = jnp.ones_like(t) if mask is None else jnp.broadcast_to(mask, t.shape)
    return shard_fleet_call(
        functools.partial(_advance, **kw),
        sharding,
        (state, t, f, m),
        mask_index=3,
    )


def discount_state(state: GibbsState, rho: float) -> GibbsState:
    """Power-prior forgetting (beyond-paper extension, DESIGN.md §8).

    Algorithm 1 chains posterior -> prior with full weight, so a long healthy
    history makes the estimator sluggish when the system drifts.  Scaling the
    pseudo-count hyperparameters by rho in (0, 1] keeps every posterior MEAN
    but widens the distributions — equivalent to exponentially down-weighting
    old evidence.  rho=1 recovers the paper exactly.
    """
    if rho >= 1.0:
        return state
    ng = state.ng
    ng = NormalGammaParams(
        mu0=ng.mu0,
        kappa0=ng.kappa0 * rho,
        nu0=jnp.maximum(ng.nu0 * rho, 0.51),  # keep Gamma proper
        psi0=ng.psi0 * rho,
    )
    soften = lambda p: BetaParams(
        a=(p.a - 1.0) * rho + 1.0, b=(p.b - 1.0) * rho + 1.0
    )
    return state._replace(
        ng=ng,
        alpha_prior=soften(state.alpha_prior),
        beta_prior=soften(state.beta_prior),
    )


def fit(
    key: Array,
    t: Array,
    f: Array,
    *,
    batch_size: int = 32,
    n_iters: int = 20,
    grid_size: int = 512,
    mu_guess: Optional[float] = None,
    use_pallas: bool = False,
) -> Tuple[GibbsState, Array]:
    """Fit one unit's parameters from a telemetry stream (N,) in batches.

    The stream is driven by one ``lax.scan`` (a single compiled program per
    (batch_size, n_iters, grid_size) signature rather than a Python loop of
    dispatches).  The final partial batch is padded and masked, so all N
    observations influence the posterior — the legacy driver silently
    dropped the tail ``n % batch_size`` observations.

    Returns the final state and the per-batch log-likelihood trace
    (the paper's Fig 5 curve).

    >>> import jax, jax.numpy as jnp
    >>> key = jax.random.PRNGKey(0)
    >>> f = jax.random.uniform(key, (48,), minval=0.1, maxval=0.9)
    >>> t = f**0.8 * 10.0                       # noiseless t = f^alpha mu
    >>> state, lls = fit(key, t, f, batch_size=32, n_iters=4, grid_size=64)
    >>> lls.shape                               # ceil(48 / 32) batches
    (2,)
    >>> bool(abs(state.ng.mu0 - 10.0) < 2.0)    # posterior mean near truth
    True
    """
    n = t.shape[-1]
    n_batches = max(-(-n // batch_size), 1)
    n_padded = n_batches * batch_size
    # Padding observations carry mask=0 and interior dummy values: exact
    # no-ops on every masked reduction.
    t_b = jnp.pad(t, (0, n_padded - n)).reshape(n_batches, batch_size)
    f_b = jnp.pad(f, (0, n_padded - n), constant_values=0.5).reshape(
        n_batches, batch_size
    )
    m_b = (jnp.arange(n_padded) < n).astype(jnp.float32).reshape(
        n_batches, batch_size
    )

    # Keep the guess as a traced array (no float() host sync): ``fit`` must
    # compose under jit/vmap, where forcing concretization raises a
    # TracerConversionError.  Mirrors ``fit_fleet``'s array path.
    guess = jnp.mean(t) / jnp.maximum(jnp.mean(f), 1e-6) if mu_guess is None else mu_guess
    state = init_state(key, mu_guess=guess)

    def step(st, xs):
        tb, fb, mb = xs
        st, ll = gibbs_batch(
            st, tb, fb, mb,
            n_iters=n_iters, grid_size=grid_size, use_pallas=use_pallas,
        )
        return st, ll

    state, lls = jax.lax.scan(step, state, (t_b, f_b, m_b))
    return state, lls


def fit_fleet(
    key: Array,
    t: Array,
    f: Array,
    *,
    n_iters: int = 20,
    grid_size: int = 512,
    mu_guess: Optional[Array] = None,
    use_pallas: bool = False,
    sharding: Optional[ShardingConfig] = None,
) -> Tuple[GibbsState, Array]:
    """Fleet estimation: t, f of shape (K, N) -> per-worker states.

    One device program estimates every worker simultaneously through the
    fleet-native ``gibbs_batch`` — with ``use_pallas`` the grid posterior of
    all K workers and both exponents is one kernel launch per sweep.  This is
    the production path for thousands of nodes.  ``sharding`` additionally
    partitions the K axis across a device mesh (see ``gibbs_batch``); the
    per-worker PRNG splits make the sharded chains match single-device
    chains bitwise.
    """
    k = t.shape[0]
    keys = jax.random.split(key, k)
    if mu_guess is None:
        mu_guess = jnp.mean(t, axis=-1) / jnp.maximum(jnp.mean(f, axis=-1), 1e-6)

    def one(key_i, guess_i):
        ng = NormalGammaParams(
            mu0=guess_i.astype(jnp.float32),
            kappa0=jnp.asarray(1e-3, jnp.float32),
            nu0=jnp.asarray(1.0, jnp.float32),
            psi0=jnp.asarray(1.0, jnp.float32),
        )
        return init_state(key_i, ng=ng)

    states = jax.vmap(one)(keys, mu_guess)
    states, ll = gibbs_batch(
        states, t, f, n_iters=n_iters, grid_size=grid_size,
        use_pallas=use_pallas, sharding=sharding,
    )
    return states, ll


def fold_stage_axis(tree):
    """Fold (S, K, ...) pytree leaves into the fleet axis: (S*K, ...).

    The stacked DAG program estimates every stage's fleet in ONE fleet-native
    ``gibbs_batch`` by presenting the stage-stacked fleet as S*K workers —
    stage-major, so stage s worker k lands at flat row s*K + k.
    """
    return jax.tree_util.tree_map(
        lambda x: jnp.reshape(x, (x.shape[0] * x.shape[1],) + x.shape[2:]), tree
    )


def unfold_stage_axis(tree, num_stages: int):
    """Inverse of :func:`fold_stage_axis`: (S*K, ...) leaves -> (S, K, ...)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.reshape(
            x, (num_stages, x.shape[0] // num_stages) + x.shape[1:]
        ),
        tree,
    )


def fit_dag(
    key: Array,
    t: Array,
    f: Array,
    *,
    n_iters: int = 20,
    grid_size: int = 512,
    mu_guess: Optional[Array] = None,
    use_pallas: bool = False,
    sharding: Optional[ShardingConfig] = None,
) -> Tuple[GibbsState, Array]:
    """Stacked stage-fleet estimation: t, f of shape (S, K, N).

    A workflow pipeline of S stages, each partitioned across K workers, is
    estimated as ONE (S, K, N) program: the stage axis is folded into the
    fleet axis so the whole DAG — every stage, every worker, both exponent
    posteriors — advances through a single fleet-native ``gibbs_batch``
    (one fused Pallas launch per sweep with ``use_pallas``), never a Python
    loop over stages.  PRNG keys are split stage-major (stage s worker k
    gets split index s*K + k), so the result bitwise-matches S independent
    ``fit_fleet`` calls handed the corresponding key slices.

    ``sharding`` partitions the FOLDED S*K stage-fleet axis across a device
    mesh — the sharded program pads S*K (not K) up to the shard count, so
    even awkward (S, K) combinations run on any mesh size.

    Returns per-stage-per-worker states with (S, K) leaves and the (S, K)
    log-likelihood.

    >>> import jax, jax.numpy as jnp
    >>> key = jax.random.PRNGKey(0)
    >>> f = jax.random.uniform(key, (2, 3, 32), minval=0.1, maxval=0.9)
    >>> t = f**0.8 * 10.0                       # 2 stages x 3 workers
    >>> states, ll = fit_dag(key, t, f, n_iters=3, grid_size=64)
    >>> ll.shape, states.mu.shape               # (S, K) leaves throughout
    ((2, 3), (2, 3))
    """
    s, k, n = t.shape
    states, ll = fit_fleet(
        key,
        t.reshape(s * k, n),
        f.reshape(s * k, n),
        n_iters=n_iters,
        grid_size=grid_size,
        mu_guess=None if mu_guess is None else jnp.reshape(mu_guess, (s * k,)),
        use_pallas=use_pallas,
        sharding=sharding,
    )
    return unfold_stage_axis(states, s), ll.reshape(s, k)
