"""Grid-based posteriors for the scaling exponents alpha, beta — Eqs 10-18.

The posteriors of alpha (Eq 10) and beta (Eq 11) are non-conjugate.  Following
the paper we (i) evaluate the unnormalized log-posterior on a grid over (0, 1),
(ii) compute E and Var by numerical integration (Eqs 16-18), and (iii) fit a
Beta distribution by the method of moments (Eqs 12-15).

``log_posterior_alpha_ref`` / ``log_posterior_beta_ref`` are the pure-jnp
oracles; ``repro.kernels.posterior_grid`` provides the Pallas TPU kernel for
the same computation (the O(G*N) hot loop).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .distributions import EPS, normalize_log_density, trapezoid_weights

Array = jax.Array

DEFAULT_GRID_SIZE = 512
GRID_LO = 1e-4
GRID_HI = 1.0 - 1e-4


class BetaParams(NamedTuple):
    """Beta prior/posterior hyperparameters for one exponent."""

    a: Array  # theta (for alpha) / delta (for beta)
    b: Array  # phi   (for alpha) / eta   (for beta)

    @staticmethod
    def default() -> "BetaParams":
        # Weakly informative, mildly favouring the interior of (0, 1).
        return BetaParams(jnp.asarray(2.0, jnp.float32), jnp.asarray(2.0, jnp.float32))


def exponent_grid(size: int = DEFAULT_GRID_SIZE) -> Array:
    return jnp.linspace(GRID_LO, GRID_HI, size, dtype=jnp.float32)


def log_posterior_alpha_ref(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    beta: Array,
    prior: BetaParams,
    mask: Optional[Array] = None,
) -> Array:
    """Unnormalized log p(alpha | T, F, mu, lambda, beta) on ``grid`` (Eq 10).

    Shapes: grid (G,), t/f (N,) -> (G,).  Leading batch axes are handled by the
    callers via vmap.
    """
    f = jnp.maximum(f, 1e-6)
    logf = jnp.log(f)  # (N,)
    # mean[g, n] = f_n^{alpha_g} * mu
    mean = jnp.exp(grid[:, None] * logf[None, :]) * mu
    z = (t[None, :] - mean) * jnp.exp(-beta * logf)[None, :]
    sq = z * z
    if mask is not None:
        sq = sq * mask.astype(sq.dtype)[None, :]
    quad = -0.5 * lam * jnp.sum(sq, axis=-1)
    g = jnp.clip(grid, EPS, 1.0 - EPS)
    return quad + (prior.a - 1.0) * jnp.log(g) + (prior.b - 1.0) * jnp.log1p(-g)


def log_posterior_beta_ref(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    alpha: Array,
    prior: BetaParams,
    mask: Optional[Array] = None,
) -> Array:
    """Unnormalized log p(beta | T, F, mu, lambda, alpha) on ``grid`` (Eq 11).

    Includes the -beta * sum(log f) Jacobian term from Eq 4.
    """
    f = jnp.maximum(f, 1e-6)
    logf = jnp.log(f)  # (N,)
    resid = t - jnp.exp(alpha * logf) * mu  # (N,)
    # z[g, n] = resid_n * f_n^{-beta_g}
    z = resid[None, :] * jnp.exp(-grid[:, None] * logf[None, :])
    sq = z * z
    if mask is not None:
        m = mask.astype(sq.dtype)
        sq = sq * m[None, :]
        sum_logf = jnp.sum(logf * m)
    else:
        sum_logf = jnp.sum(logf)
    quad = -0.5 * lam * jnp.sum(sq, axis=-1) - grid * sum_logf
    g = jnp.clip(grid, EPS, 1.0 - EPS)
    return quad + (prior.a - 1.0) * jnp.log(g) + (prior.b - 1.0) * jnp.log1p(-g)


def moments_from_log_density(grid: Array, logp: Array) -> Tuple[Array, Array]:
    """E and Var by numerical integration of a grid log-density (Eqs 16-18)."""
    pdf = normalize_log_density(logp, grid)
    w = trapezoid_weights(grid)
    e1 = jnp.sum(pdf * w * grid, axis=-1)
    e2 = jnp.sum(pdf * w * grid * grid, axis=-1)
    var = jnp.maximum(e2 - e1 * e1, 1e-12)
    return e1, var


def fit_beta_method_of_moments(mean: Array, var: Array) -> BetaParams:
    """Beta(a, b) from (E, Var) — Eqs 12-15.

    Validity requires Var < E(1-E); we clamp into that region (the grid
    integration can land outside it only through numerical error).
    """
    mean = jnp.clip(mean, 1e-4, 1.0 - 1e-4)
    cap = mean * (1.0 - mean)
    var = jnp.clip(var, 1e-10, 0.999 * cap)
    common = cap / var - 1.0
    a = mean * common
    b = (1.0 - mean) * common
    return BetaParams(jnp.maximum(a, 1e-3), jnp.maximum(b, 1e-3))


def update_alpha_beta_params(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    alpha: Array,
    beta: Array,
    alpha_prior: BetaParams,
    beta_prior: BetaParams,
    mask: Optional[Array] = None,
    *,
    use_pallas: bool = False,
) -> Tuple[BetaParams, BetaParams]:
    """Posterior Beta approximations for alpha and beta (one Gibbs sub-step)."""
    if use_pallas:
        from repro.kernels import ops as _kops

        logp_a = _kops.posterior_grid_alpha(grid, t, f, mu, lam, beta, alpha_prior, mask)
        logp_b = _kops.posterior_grid_beta(grid, t, f, mu, lam, alpha, beta_prior, mask)
    else:
        logp_a = log_posterior_alpha_ref(grid, t, f, mu, lam, beta, alpha_prior, mask)
        logp_b = log_posterior_beta_ref(grid, t, f, mu, lam, alpha, beta_prior, mask)
    ea, va = moments_from_log_density(grid, logp_a)
    eb, vb = moments_from_log_density(grid, logp_b)
    return fit_beta_method_of_moments(ea, va), fit_beta_method_of_moments(eb, vb)
