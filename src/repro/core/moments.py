"""Grid-based posteriors for the scaling exponents alpha, beta — Eqs 10-18.

The posteriors of alpha (Eq 10) and beta (Eq 11) are non-conjugate.  Following
the paper we (i) evaluate the unnormalized log-posterior on a grid over (0, 1),
(ii) compute E and Var by numerical integration (Eqs 16-18), and (iii) fit a
Beta distribution by the method of moments (Eqs 12-15).

``log_posterior_grid`` is the single source of truth for the grid evaluation:
a fused pure-jnp oracle that emits BOTH exponent posteriors from one shared
pow table, batched over an optional leading fleet axis.  It is exactly the
formulation the Pallas TPU kernel (``repro.kernels.posterior_grid``)
implements, so kernel/oracle parity is tight.  The historical single-mode
entry points (``log_posterior_alpha_ref`` / ``log_posterior_beta_ref`` here,
``repro.kernels.ref.posterior_grid_ref``) are thin slices of it.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .distributions import EPS, normalize_log_density, trapezoid_weights

Array = jax.Array

DEFAULT_GRID_SIZE = 512
GRID_LO = 1e-4
GRID_HI = 1.0 - 1e-4


@jax.custom_batching.custom_vmap
def _pin(x: Array) -> Array:
    """Optimization barrier that survives vmap.

    ``lax.optimization_barrier`` has no batching rule; the custom-vmap rule
    recurses, peeling one batch level per transform until the plain barrier
    applies to the fully-batched value.
    """
    return jax.lax.optimization_barrier(x)


@_pin.def_vmap
def _pin_vmap(axis_size, in_batched, x):
    del axis_size
    return _pin(x), in_batched[0]


class BetaParams(NamedTuple):
    """Beta prior/posterior hyperparameters for one exponent."""

    a: Array  # theta (for alpha) / delta (for beta)
    b: Array  # phi   (for alpha) / eta   (for beta)

    @staticmethod
    def default() -> "BetaParams":
        # Weakly informative, mildly favouring the interior of (0, 1).
        return BetaParams(jnp.asarray(2.0, jnp.float32), jnp.asarray(2.0, jnp.float32))


def exponent_grid(size: int = DEFAULT_GRID_SIZE) -> Array:
    return jnp.linspace(GRID_LO, GRID_HI, size, dtype=jnp.float32)


def log_posterior_grid(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    alpha: Array,
    beta: Array,
    alpha_prior: BetaParams,
    beta_prior: BetaParams,
    mask: Optional[Array] = None,
    *,
    chunk_g: int = 16,
    symmetric_grid: bool = False,
) -> Array:
    """Fused evaluation of both exponent log-posteriors (Eqs 10 + 11).

    The unified oracle: one pow table pg = f^g serves both modes — the alpha
    posterior (which consumes the held beta) uses pg and pg^2, the beta
    posterior (which consumes the held alpha, plus the -beta*sum(log f)
    Jacobian term of Eq 4) uses 1/pg^2.  The quadratic forms are expanded
    into masked inner products so each mode is three multiply-accumulate
    passes over the pow table; the Pallas fleet kernel implements the
    same formulation block-wise.

    The grid axis is processed in ``chunk_g``-point blocks (``lax.map`` with
    the pow table pinned behind an optimization barrier): the (..., chunk_g,
    N) table stays cache-resident and is computed exactly once — without the
    barrier XLA rematerializes the exp per consumer, which is the legacy
    path's 2x transcendental cost all over again.

    ``symmetric_grid=True`` asserts grid[i] + grid[G-1-i] is constant (true
    for ``exponent_grid``: a linspace is symmetric about its midpoint) and
    exploits f^{-2 g_i} = f^{-2(g_0 + g_{G-1})} * f^{2 g_{G-1-i}}: the beta
    mode then reads the alpha mode's pg^2 table at the mirrored index
    instead of paying a reciprocal pass per cell.  Algebraically identical
    (fp difference ~1e-7 relative); NEVER set it for a non-symmetric grid.

    Shapes: grid (G,); t/f/mask (..., N); mu/lam/alpha/beta and the prior
    leaves (...) -> (..., 2, G) with [..., 0, :] the alpha posterior and
    [..., 1, :] the beta posterior.  The leading axes are the fleet axes.
    """
    f = jnp.maximum(f, 1e-6)
    logf = jnp.log(f)  # (..., N)
    m = jnp.ones_like(logf) if mask is None else mask.astype(logf.dtype)
    mu_b = jnp.asarray(mu, logf.dtype)[..., None]
    lam_b = jnp.asarray(lam, logf.dtype)[..., None]
    alpha_b = jnp.asarray(alpha, logf.dtype)[..., None]
    beta_b = jnp.asarray(beta, logf.dtype)[..., None]

    # O(N) precomputations shared by every grid chunk.
    wb2 = m * jnp.exp(-2.0 * beta_b * logf)  # (..., N) m * f^{-2 beta}
    u = wb2 * t
    a0 = jnp.sum(u * t, axis=-1)  # (...)
    r = t - jnp.exp(alpha_b * logf) * mu_b  # (..., N)
    w = m * r * r
    if symmetric_grid:
        # S_b[i] = <f^{-2 g_i}, w> = <pg^2, w * f^{-2s}>[G-1-i], s = g_0+g_{G-1}
        w = w * jnp.exp(-2.0 * (grid[0] + grid[-1]) * logf)
    sum_logf = jnp.sum(logf * m, axis=-1)  # (...)

    g_n = grid.shape[0]
    cg = min(chunk_g, g_n)
    g_pad = (-g_n) % cg
    # Interior padding values produce finite logs and are sliced off below.
    grid_p = jnp.pad(grid, (0, g_pad), constant_values=0.5)

    def chunk(gc):
        # alpha mode: S_a = A0 - 2 mu <pg, m wb^2 t> + mu^2 <pg^2, m wb^2>
        # beta  mode: S_b = <1/pg^2, m r^2>  (mirrored <pg^2, w> if symmetric)
        pg = _pin(jnp.exp(gc[:, None] * logf[..., None, :]))  # (..., cg, N) = f^g
        pg2 = pg * pg
        s1 = jnp.einsum("...gn,...n->...g", pg, u)
        s2 = jnp.einsum("...gn,...n->...g", pg2, wb2)
        s3 = jnp.einsum("...gn,...n->...g", pg2 if symmetric_grid else 1.0 / pg2, w)
        qa = -0.5 * lam_b * (a0[..., None] - 2.0 * mu_b * s1 + mu_b * mu_b * s2)
        qb = -0.5 * lam_b * s3
        return qa, qb

    qa_c, qb_c = jax.lax.map(chunk, grid_p.reshape(-1, cg))  # (C, ..., cg)
    join = lambda x: jnp.moveaxis(x, 0, -2).reshape(*x.shape[1:-1], -1)[..., :g_n]
    quad_a = join(qa_c)
    quad_b = join(qb_c)
    if symmetric_grid:
        # Mirrored positions are all within the unpadded [0, G) range, so the
        # flip happens after the padding slice.
        quad_b = jnp.flip(quad_b, axis=-1)

    g = jnp.clip(grid, EPS, 1.0 - EPS)
    lg = jnp.log(g)
    l1mg = jnp.log1p(-g)
    pleaf = lambda x: jnp.asarray(x, logf.dtype)[..., None]
    logp_a = quad_a + (pleaf(alpha_prior.a) - 1.0) * lg + (
        pleaf(alpha_prior.b) - 1.0
    ) * l1mg
    logp_b = (
        quad_b
        - grid * sum_logf[..., None]
        + (pleaf(beta_prior.a) - 1.0) * lg
        + (pleaf(beta_prior.b) - 1.0) * l1mg
    )
    return jnp.stack([logp_a, logp_b], axis=-2)


def log_posterior_alpha_ref(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    beta: Array,
    prior: BetaParams,
    mask: Optional[Array] = None,
) -> Array:
    """Unnormalized log p(alpha | T, F, mu, lambda, beta) on ``grid`` (Eq 10).

    Thin slice of the unified oracle ``log_posterior_grid``.
    Shapes: grid (G,), t/f (N,) -> (G,).  Leading batch axes broadcast.
    """
    return log_posterior_grid(
        grid, t, f, mu, lam, jnp.asarray(0.5, jnp.float32), beta,
        prior, BetaParams.default(), mask,
    )[..., 0, :]


def log_posterior_beta_ref(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    alpha: Array,
    prior: BetaParams,
    mask: Optional[Array] = None,
) -> Array:
    """Unnormalized log p(beta | T, F, mu, lambda, alpha) on ``grid`` (Eq 11).

    Includes the -beta * sum(log f) Jacobian term from Eq 4.  Thin slice of
    the unified oracle ``log_posterior_grid``.
    """
    return log_posterior_grid(
        grid, t, f, mu, lam, alpha, jnp.asarray(0.5, jnp.float32),
        BetaParams.default(), prior, mask,
    )[..., 1, :]


def moments_from_log_density(grid: Array, logp: Array) -> Tuple[Array, Array]:
    """E and Var by numerical integration of a grid log-density (Eqs 16-18)."""
    pdf = normalize_log_density(logp, grid)
    w = trapezoid_weights(grid)
    e1 = jnp.sum(pdf * w * grid, axis=-1)
    e2 = jnp.sum(pdf * w * grid * grid, axis=-1)
    var = jnp.maximum(e2 - e1 * e1, 1e-12)
    return e1, var


def fit_beta_method_of_moments(mean: Array, var: Array) -> BetaParams:
    """Beta(a, b) from (E, Var) — Eqs 12-15.

    Validity requires Var < E(1-E); we clamp into that region (the grid
    integration can land outside it only through numerical error).
    """
    mean = jnp.clip(mean, 1e-4, 1.0 - 1e-4)
    cap = mean * (1.0 - mean)
    var = jnp.clip(var, 1e-10, 0.999 * cap)
    common = cap / var - 1.0
    a = mean * common
    b = (1.0 - mean) * common
    return BetaParams(jnp.maximum(a, 1e-3), jnp.maximum(b, 1e-3))


def update_alpha_beta_params(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    alpha: Array,
    beta: Array,
    alpha_prior: BetaParams,
    beta_prior: BetaParams,
    mask: Optional[Array] = None,
    *,
    use_pallas: bool = False,
    symmetric_grid: bool = False,
) -> Tuple[BetaParams, BetaParams]:
    """Posterior Beta approximations for alpha and beta (one Gibbs sub-step).

    Batched: ``t``/``f``/``mask`` may carry a leading fleet axis K (with
    mu/lam/alpha/beta and the prior leaves shaped (K,)), in which case the
    whole fleet is evaluated fused — with ``use_pallas`` that is ONE kernel
    launch covering every worker and both exponents.  ``symmetric_grid``
    may be set when ``grid`` is midpoint-symmetric (``exponent_grid`` is);
    see ``log_posterior_grid``.
    """
    if use_pallas:
        from repro.kernels import ops as _kops

        batched = t.ndim > 1
        if batched:
            logp = _kops.posterior_grid_fleet(
                grid, t, f, mu, lam, alpha, beta, alpha_prior, beta_prior, mask
            )
        else:
            one = lambda x: jnp.reshape(jnp.asarray(x, jnp.float32), (1,))
            logp = _kops.posterior_grid_fleet(
                grid,
                t[None, :],
                f[None, :],
                one(mu),
                one(lam),
                one(alpha),
                one(beta),
                BetaParams(one(alpha_prior.a), one(alpha_prior.b)),
                BetaParams(one(beta_prior.a), one(beta_prior.b)),
                None if mask is None else mask[None, :],
            )[0]
    else:
        logp = log_posterior_grid(
            grid, t, f, mu, lam, alpha, beta, alpha_prior, beta_prior, mask,
            symmetric_grid=symmetric_grid,
        )
    ea, va = moments_from_log_density(grid, logp[..., 0, :])
    eb, vb = moments_from_log_density(grid, logp[..., 1, :])
    return fit_beta_method_of_moments(ea, va), fit_beta_method_of_moments(eb, vb)
