"""The paper's contribution: Bayesian estimation of processing-unit models and
frontier-optimal workflow partitioning (Chua & Huberman 2015)."""
from .distributions import (
    beta_logpdf,
    gamma_logpdf,
    normal_cdf,
    normal_logpdf,
    sample_beta,
    sample_gamma,
    sample_normal,
)
from .frontier import (
    UnitParams,
    completion_cdf,
    dag_completion_moments,
    mean_var_completion,
    optimal_two_way_fraction,
    parallel_max_moments,
    pareto_mask,
    serial_moments,
    sweep_two_way,
)
from .compress import (
    CompressionReport,
    beta_moments,
    compression_report,
    fit_lognormal_moments,
    fit_surrogate,
    grid_moments,
    select_active,
    surrogate_gap,
    surrogate_moments,
)
from .gibbs import GibbsState, fit, fit_dag, fit_fleet, gibbs_batch, init_state
from .moments import (
    BetaParams,
    exponent_grid,
    fit_beta_method_of_moments,
    log_posterior_alpha_ref,
    log_posterior_beta_ref,
    log_posterior_grid,
    moments_from_log_density,
    update_alpha_beta_params,
)
from .posterior import (
    NormalGammaParams,
    log_likelihood,
    posterior_predictive_logpdf,
    update_normal_gamma,
)
from .sharding import ShardingConfig, constrain_fleet, shard_fleet_map

__all__ = [
    "BetaParams",
    "CompressionReport",
    "GibbsState",
    "HeterogeneityAwarePartitioner",
    "NormalGammaParams",
    "ShardingConfig",
    "UnitParams",
    "WorkerTelemetry",
    "beta_logpdf",
    "beta_moments",
    "completion_cdf",
    "compression_report",
    "constrain_fleet",
    "dag_completion_moments",
    "exponent_grid",
    "fit",
    "fit_beta_method_of_moments",
    "fit_dag",
    "fit_fleet",
    "fit_lognormal_moments",
    "fit_surrogate",
    "gamma_logpdf",
    "grid_moments",
    "gibbs_batch",
    "init_state",
    "log_likelihood",
    "log_posterior_alpha_ref",
    "log_posterior_beta_ref",
    "log_posterior_grid",
    "mean_var_completion",
    "moments_from_log_density",
    "normal_cdf",
    "normal_logpdf",
    "optimal_two_way_fraction",
    "parallel_max_moments",
    "optimize_fractions",
    "pareto_mask",
    "serial_moments",
    "shard_fleet_map",
    "posterior_predictive_logpdf",
    "quantize_fractions",
    "sample_beta",
    "sample_gamma",
    "sample_normal",
    "select_active",
    "surrogate_gap",
    "surrogate_moments",
    "sweep_two_way",
    "update_alpha_beta_params",
    "update_normal_gamma",
]

# The legacy partitioner layer now delegates to the pure-functional
# ``repro.sched`` package, which itself builds on this one — so its names are
# resolved lazily (PEP 562) to keep the import graph acyclic.
_PARTITIONER_NAMES = (
    "HeterogeneityAwarePartitioner",
    "WorkerTelemetry",
    "optimize_fractions",
    "quantize_fractions",
)


def __getattr__(name):
    if name in _PARTITIONER_NAMES:
        from . import partitioner

        return getattr(partitioner, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
