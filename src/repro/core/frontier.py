"""Completion-time statistics of a partitioned workflow and the QoS frontier.

Implements Section 1 of the paper, generalized from 2 units to K units:

  P(t <= eps | f, Theta) = prod_k P(t_k <= eps | f_k, Theta_k)
  E(t)   = int_0^inf [1 - P(t <= eps)] d eps
  Var(t) = 2 int_0^inf eps [1 - P(t <= eps)] d eps - E(t)^2

with per-unit times t_k ~ N(f_k^alpha_k mu_k, (f_k^beta_k sigma_k)^2).
The (mu(f), sigma^2(f)) locus over the fraction simplex is parabola-like; its
Pareto-minimal subset is the efficient frontier used to pick the operating
point for a QoS target.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .distributions import normal_cdf

Array = jax.Array

DEFAULT_QUAD_POINTS = 1024


class UnitParams(NamedTuple):
    """Per-unit completion-time model parameters; leaves have shape (K,)."""

    mu: Array
    sigma: Array
    alpha: Array
    beta: Array

    @staticmethod
    def of(mu, sigma, alpha=None, beta=None) -> "UnitParams":
        mu = jnp.asarray(mu, jnp.float32)
        sigma = jnp.asarray(sigma, jnp.float32)
        one = jnp.ones_like(mu)
        return UnitParams(
            mu,
            sigma,
            one if alpha is None else jnp.asarray(alpha, jnp.float32),
            one if beta is None else jnp.asarray(beta, jnp.float32),
        )


def component_mean_std(fracs: Array, params: UnitParams) -> Tuple[Array, Array]:
    """Per-unit mean f^alpha mu and std f^beta sigma for fractions (..., K)."""
    f = jnp.maximum(fracs, 1e-9)
    mean = f**params.alpha * params.mu
    std = f**params.beta * params.sigma
    return mean, jnp.maximum(std, 1e-9)


def completion_cdf(eps: Array, fracs: Array, params: UnitParams) -> Array:
    """P(t <= eps | f, Theta): product of per-unit Normal CDFs.

    eps: (..., Q); fracs: (K,).  Returns (..., Q).
    """
    mean, std = component_mean_std(fracs, params)  # (K,)
    cdfs = normal_cdf(eps[..., None], mean, std)  # (..., Q, K)
    return jnp.prod(cdfs, axis=-1)


def _quad_grid(means: Array, stds: Array, num_points: int, dtype) -> Array:
    """Quadrature abscissae on [0, max(mean + 8 std)] — the survival
    integrand is exponentially small beyond."""
    upper = jnp.maximum(jnp.max(means + 8.0 * stds), 1e-6)
    return jnp.linspace(0.0, 1.0, num_points, dtype=dtype) * upper


def _moments_from_survival(eps: Array, surv: Array) -> Tuple[Array, Array]:
    """(E, Var) of a nonnegative variable from its survival function values."""
    e_t = jnp.trapezoid(surv, eps)
    e_t2 = 2.0 * jnp.trapezoid(eps * surv, eps)
    return e_t, jnp.maximum(e_t2 - e_t * e_t, 0.0)


def mean_var_completion(
    fracs: Array,
    params: UnitParams,
    num_points: int = DEFAULT_QUAD_POINTS,
) -> Tuple[Array, Array]:
    """E(t) and Var(t) of the max-completion time by trapezoid quadrature.

    Differentiable in ``fracs`` so the partitioner can use gradients.
    """
    mean, std = component_mean_std(fracs, params)
    eps = _quad_grid(mean, std, num_points, fracs.dtype)
    surv = 1.0 - completion_cdf(eps, fracs, params)  # (Q,)
    return _moments_from_survival(eps, surv)


# --------------------------------------------------------------------------
# stochastic stage transforms (conditional branches + rework loops)
# --------------------------------------------------------------------------
def mixture_moments(p: Array, mean: Array, var: Array) -> Tuple[Array, Array]:
    """Moments of ``Z = B * X`` with ``B ~ Bernoulli(p)`` independent of X.

    A conditionally-executed workflow stage contributes its makespan only
    when its path indicator fires; the law of total mean/variance over the
    Bernoulli activation gives

      E[Z]   = p E[X]
      Var[Z] = p Var[X] + p (1 - p) E[X]^2

    (condition on B: the mean-of-variances is ``p Var[X]``, the
    variance-of-means is that of a two-point {0, E[X]} distribution).
    Broadcasts elementwise; exact — no distributional approximation — so
    the MC oracle (``repro.sim``) pins it to sampling noise.  ``p = 1`` is
    an exact identity (``1*x == x``, ``v + 0.0 == v`` bitwise).

    >>> import jax.numpy as jnp
    >>> e, v = mixture_moments(jnp.float32(0.25), jnp.float32(8.0),
    ...                        jnp.float32(4.0))
    >>> float(e), float(v)                # 0.25*8, 0.25*4 + 0.25*0.75*64
    (2.0, 13.0)
    """
    e = p * mean
    v = p * var + p * (1.0 - p) * (mean * mean)
    return e, v


def truncated_geometric_moments(
    success_prob: Array,
    max_attempts,
    *,
    max_support: Optional[int] = None,
) -> Tuple[Array, Array]:
    """(E[N], Var[N]) of ``N = min(Geometric(q), R)`` attempt counts.

    A rework loop retries a stage until it succeeds (per-attempt success
    probability ``q``) or hits the retry cap ``R = max_attempts``; the pmf is
    ``P(N=n) = (1-q)^(n-1) q`` for ``n < R`` and the whole surviving tail
    ``(1-q)^(R-1)`` collapses onto ``n = R``.  Moments are computed exactly
    from the pmf over the static support ``1..max_support`` (``max_attempts``
    may be a traced per-stage array bounded by the static ``max_support``),
    so this jits and differentiates through ``q``.

    The untruncated limits are recovered as R grows: ``E[N] -> 1/q``,
    ``Var[N] -> (1-q)/q^2``.  ``q = 1`` (or ``R = 1``) puts all mass on
    ``N = 1`` exactly: E[N] == 1.0 and Var[N] == 0.0 bitwise, which is what
    keeps zero-rework topologies on the deterministic code path's numbers.

    >>> import jax.numpy as jnp
    >>> e_n, v_n = truncated_geometric_moments(jnp.float32(0.5), 30)
    >>> round(float(e_n), 4), round(float(v_n), 4)   # ~1/q, ~(1-q)/q^2
    (2.0, 2.0)
    >>> e_1, v_1 = truncated_geometric_moments(jnp.float32(0.5), 1)
    >>> float(e_1), float(v_1)                       # cap 1 = no rework
    (1.0, 0.0)
    """
    q = jnp.asarray(success_prob, jnp.float32)
    if max_support is None:
        if isinstance(max_attempts, int):
            max_support = max_attempts
        elif isinstance(max_attempts, (tuple, list)):
            max_support = int(max(max_attempts))
        else:
            raise ValueError(
                "max_support is required when max_attempts is a traced array"
            )
    caps = jnp.asarray(max_attempts, jnp.float32)[..., None]
    n = jnp.arange(1, max_support + 1, dtype=jnp.float32)  # static support
    fail = 1.0 - q[..., None]
    geometric = fail ** (n - 1.0) * q[..., None]
    tail = fail ** (caps - 1.0)  # all surviving mass collapses onto n == cap
    pmf = jnp.where(n < caps, geometric, jnp.where(n == caps, tail, 0.0))
    e_n = jnp.sum(n * pmf, axis=-1)
    e_n2 = jnp.sum(n * n * pmf, axis=-1)
    return e_n, jnp.maximum(e_n2 - e_n * e_n, 0.0)


def compound_sum_moments(
    n_mean: Array, n_var: Array, mean: Array, var: Array
) -> Tuple[Array, Array]:
    """Moments of ``T = sum_{i=1}^N X_i`` (i.i.d. X independent of N).

    The compound-sum (Wald) identities:

      E[T]   = E[N] E[X]
      Var[T] = E[N] Var[X] + Var[N] E[X]^2

    Exact for any attempt-count distribution — pair with
    :func:`truncated_geometric_moments` for geometric rework loops.
    ``(E[N], Var[N]) = (1, 0)`` is a bitwise identity.

    >>> import jax.numpy as jnp
    >>> e, v = compound_sum_moments(jnp.float32(2.0), jnp.float32(2.0),
    ...                             jnp.float32(3.0), jnp.float32(0.5))
    >>> float(e), float(v)                 # 2*3, 2*0.5 + 2*9
    (6.0, 19.0)
    """
    return n_mean * mean, n_mean * var + n_var * (mean * mean)


def stochastic_stage_moments(
    stage_means: Array,
    stage_vars: Array,
    *,
    exec_probs: Optional[Array] = None,
    success_probs: Optional[Array] = None,
    max_retries=None,
    max_support: Optional[int] = None,
) -> Tuple[Array, Array]:
    """Effective stage-duration moments under rework loops + branch activation.

    Transforms per-execution ("one attempt, stage taken") makespan moments
    into the moments of what the stage actually contributes to the workflow:
    geometric rework first (the loop repeats the attempt — ``success_probs``
    is the per-attempt success probability, i.e. 1 - rework probability),
    Bernoulli path activation second (a skipped stage skips ALL its retries).
    Both transforms are exact in the moments, so chain compositions of the
    result stay exact; only fork/join max-composition introduces the usual
    moment-matching approximation.

    >>> import jax.numpy as jnp
    >>> e, v = stochastic_stage_moments(
    ...     jnp.asarray([3.0, 5.0]), jnp.asarray([0.5, 1.0]),
    ...     exec_probs=jnp.asarray([1.0, 0.5]),
    ...     success_probs=jnp.asarray([0.5, 1.0]), max_retries=(30, 1))
    >>> [round(float(x), 3) for x in e]      # stage 0: ~2 attempts of 3
    [6.0, 2.5]
    """
    e, v = stage_means, stage_vars
    if success_probs is not None:
        if max_retries is None:
            raise ValueError("success_probs requires max_retries")
        n_mean, n_var = truncated_geometric_moments(
            success_probs, max_retries, max_support=max_support
        )
        e, v = compound_sum_moments(n_mean, n_var, e, v)
    if exec_probs is not None:
        e, v = mixture_moments(exec_probs, e, v)
    return e, v


# --------------------------------------------------------------------------
# stage composition (multi-stage workflow DAGs)
# --------------------------------------------------------------------------
def serial_moments(stage_means: Array, stage_vars: Array) -> Tuple[Array, Array]:
    """Serial (chain) composition of stage completion moments.

    A pipeline's end-to-end time is the SUM of its stage makespans (stage
    s+1 starts when stage s finishes), so with independent stage times the
    mean and variance both add — the companion paper's sequential-channel
    composition.  ``stage_means``/``stage_vars`` are (S,) (or (S, ...) for
    batched composition over a trailing axis).

    >>> import jax.numpy as jnp
    >>> e, v = serial_moments(jnp.asarray([3.0, 2.0]), jnp.asarray([0.4, 0.1]))
    >>> float(e), float(v)
    (5.0, 0.5)
    """
    return jnp.sum(stage_means, axis=0), jnp.sum(stage_vars, axis=0)


def parallel_max_moments(
    branch_means: Array,
    branch_vars: Array,
    num_points: int = DEFAULT_QUAD_POINTS,
) -> Tuple[Array, Array]:
    """Moments of the max over parallel branches by survival quadrature.

    Each branch's completion time is moment-matched to a Normal; the max of
    independent branches then has CDF ``prod_b Phi((eps - m_b)/s_b)``, and
    E/Var follow from the same survival-function integration used for the
    within-stage worker max (:func:`mean_var_completion`).  Branches that
    share ancestors are treated as independent (the classic PERT
    approximation) — the induced positive correlation means the true E[max]
    is slightly LOWER than reported, so the composition errs conservative.

    >>> import jax.numpy as jnp
    >>> e, v = parallel_max_moments(
    ...     jnp.asarray([3.0, 3.0]), jnp.asarray([0.25, 0.25]))
    >>> bool(e > 3.0)   # E[max of two noisy branches] exceeds either mean
    True
    >>> e0, _ = parallel_max_moments(jnp.asarray([5.0]), jnp.asarray([1e-9]))
    >>> bool(abs(e0 - 5.0) < 0.01)  # single near-deterministic branch
    True
    """
    std = jnp.sqrt(jnp.maximum(branch_vars, 1e-18))
    eps = _quad_grid(branch_means, std, num_points, jnp.float32)
    cdfs = normal_cdf(eps[:, None], branch_means, std)  # (Q, B)
    surv = 1.0 - jnp.prod(cdfs, axis=-1)
    return _moments_from_survival(eps, surv)


def dag_completion_moments(
    preds: Tuple[Tuple[int, ...], ...],
    stage_means: Array,
    stage_vars: Array,
    *,
    num_points: int = DEFAULT_QUAD_POINTS,
) -> Tuple[Array, Array]:
    """End-to-end (E, Var) of a stage DAG by topological reduction.

    ``preds`` is the static topology: ``preds[i]`` lists the stages that must
    finish before stage i starts, with every predecessor index < i (stages
    topologically numbered — ``repro.sched.WorkflowDAG`` guarantees this).
    Each stage's finish time is tracked as a moment-matched Normal: a stage's
    start is the max over its predecessors' finishes
    (:func:`parallel_max_moments`), its finish adds its own makespan moments
    (:func:`serial_moments` pairwise), and the DAG completes at the max over
    sink stages.  A serial chain reduces exactly to summed moments; parallel
    branches compose by quadrature over the per-branch survival functions.

    >>> import jax.numpy as jnp
    >>> chain = ((), (0,), (1,))                   # 0 -> 1 -> 2
    >>> e, v = dag_completion_moments(
    ...     chain, jnp.asarray([1.0, 2.0, 3.0]), jnp.asarray([0.1, 0.1, 0.1]))
    >>> round(float(e), 4), round(float(v), 4)     # chain == summed moments
    (6.0, 0.3)
    >>> diamond = ((), (0,), (0,), (1, 2))         # 0 -> {1, 2} -> 3
    >>> e_d, _ = dag_completion_moments(
    ...     diamond, jnp.asarray([1.0, 2.0, 2.0, 1.0]),
    ...     jnp.asarray([0.1, 0.2, 0.2, 0.1]))
    >>> bool(e_d > 4.0)   # E[max] of the noisy parallel arms adds a premium
    True
    """
    s = len(preds)
    fin_e: list = [None] * s
    fin_v: list = [None] * s
    for i in range(s):
        ps = preds[i]
        if not ps:
            start_e = jnp.asarray(0.0, jnp.float32)
            start_v = jnp.asarray(0.0, jnp.float32)
        elif len(ps) == 1:
            start_e, start_v = fin_e[ps[0]], fin_v[ps[0]]
        else:
            start_e, start_v = parallel_max_moments(
                jnp.stack([fin_e[p] for p in ps]),
                jnp.stack([fin_v[p] for p in ps]),
                num_points,
            )
        fin_e[i] = start_e + stage_means[i]
        fin_v[i] = start_v + stage_vars[i]
    has_succ = {p for pp in preds for p in pp}
    sinks = [i for i in range(s) if i not in has_succ]
    if len(sinks) == 1:
        return fin_e[sinks[0]], fin_v[sinks[0]]
    return parallel_max_moments(
        jnp.stack([fin_e[i] for i in sinks]),
        jnp.stack([fin_v[i] for i in sinks]),
        num_points,
    )


def sweep_two_way(
    params: UnitParams,
    num_f: int = 201,
    num_points: int = DEFAULT_QUAD_POINTS,
) -> Tuple[Array, Array, Array]:
    """The paper's Fig 1/2 curves: (f_grid, mu(f), sigma^2(f)) for K=2."""
    f_grid = jnp.linspace(1e-3, 1.0 - 1e-3, num_f, dtype=jnp.float32)

    def one(f):
        fracs = jnp.stack([f, 1.0 - f])
        return mean_var_completion(fracs, params, num_points)

    mu_f, var_f = jax.vmap(one)(f_grid)
    return f_grid, mu_f, var_f


def pareto_mask(mu_f: Array, var_f: Array) -> Array:
    """Efficient frontier: points not dominated in (mu, var) (both minimized)."""
    dominated = jnp.any(
        (mu_f[None, :] <= mu_f[:, None])
        & (var_f[None, :] <= var_f[:, None])
        & ((mu_f[None, :] < mu_f[:, None]) | (var_f[None, :] < var_f[:, None])),
        axis=1,
    )
    return ~dominated


def optimal_two_way_fraction(
    params: UnitParams,
    *,
    num_f: int = 201,
    num_points: int = DEFAULT_QUAD_POINTS,
    objective="mean",
    risk_aversion: float = 0.0,
    var_budget: float = float("inf"),
) -> Tuple[Array, Array, Array]:
    """Pick f on the frontier for K=2.

    ``objective`` is a ``repro.sched.Objective`` — the same pluggable value
    used by ``sched.propose`` and quantization — or one of the legacy strings
    ("mean" | "mean_var" | "constrained") combined with the ``risk_aversion``
    / ``var_budget`` floats.  Only the objective *kind* is jit-static: the
    parameter floats stay traced, so sweeping risk_aversion or var_budget
    reuses one compilation.  Returns (f*, mu(f*), sigma^2(f*)).
    """
    from repro.sched.objectives import Objective

    if isinstance(objective, Objective):
        risk_aversion = objective.risk_aversion
        var_budget = objective.var_budget
        deadline = objective.deadline
        kind = objective.kind
    else:
        kind = {"constrained": "var_budget"}.get(objective, objective)
        deadline = 0.0
    return _optimal_two_way(
        params,
        jnp.asarray(risk_aversion, jnp.float32),
        jnp.asarray(var_budget, jnp.float32),
        jnp.asarray(deadline, jnp.float32),
        kind=kind,
        num_f=num_f,
        num_points=num_points,
    )


@functools.partial(jax.jit, static_argnames=("kind", "num_f", "num_points"))
def _optimal_two_way(
    params: UnitParams,
    risk_aversion: Array,
    var_budget: Array,
    deadline: Array,
    *,
    kind: str,
    num_f: int,
    num_points: int,
) -> Tuple[Array, Array, Array]:
    from repro.sched.objectives import score_moments_dynamic

    f_grid, mu_f, var_f = sweep_two_way(params, num_f, num_points)
    if kind == "deadline":
        score = jax.vmap(
            lambda f: -completion_cdf(deadline, jnp.stack([f, 1.0 - f]), params)
        )(f_grid)
    else:
        score = score_moments_dynamic(kind, mu_f, var_f, risk_aversion, var_budget)
    idx = jnp.argmin(score)
    return f_grid[idx], mu_f[idx], var_f[idx]
