"""K-way workflow partitioner built on the learned completion-time models.

Two layers:

  * ``optimize_fractions`` — continuous frontier search on the K-simplex via
    projected gradient (Adam on softmax logits); the quadrature in
    ``frontier.mean_var_completion`` is differentiable.
  * ``quantize_fractions`` — SPMD reality: fractions are realized as integer
    microbatch counts (static shapes, no recompilation).  Largest-remainder
    rounding followed by greedy 1-microbatch moves that directly minimize the
    expected-makespan objective on the lattice.

``HeterogeneityAwarePartitioner`` is the online driver used by the trainer and
the server: feed it (fractions, measured times) telemetry; it Gibbs-updates the
per-worker posteriors (chained priors, Algorithm 1) and emits new splits plus
straggler anomaly scores.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import gibbs
from .frontier import UnitParams, mean_var_completion
from .posterior import posterior_predictive_logpdf

Array = jax.Array


def _objective(fracs: Array, params: UnitParams, risk_aversion: float) -> Array:
    e_t, var = mean_var_completion(fracs, params)
    return e_t + risk_aversion * var


@functools.partial(jax.jit, static_argnames=("steps",))
def optimize_fractions(
    params: UnitParams,
    *,
    risk_aversion: float = 0.0,
    steps: int = 300,
    lr: float = 0.05,
) -> Tuple[Array, Array, Array]:
    """Frontier point on the K-simplex: min E[max_k t_k] + ra * Var.

    Adam on logits; fractions = softmax(logits).  Initialized at the
    closed-form heuristic f_k ∝ (1/mu_k) (equalize linear-scaling means).
    Returns (fractions, expected_makespan, variance).
    """
    k = params.mu.shape[0]
    inv = 1.0 / jnp.maximum(params.mu, 1e-9)
    logits0 = jnp.log(inv / jnp.sum(inv))

    def loss(logits):
        fracs = jax.nn.softmax(logits)
        return _objective(fracs, params, risk_aversion)

    grad = jax.grad(loss)

    def step(carry, _):
        logits, m, v, t = carry
        g = grad(logits)
        t = t + 1.0
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1.0 - 0.9**t)
        vh = v / (1.0 - 0.999**t)
        logits = logits - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return (logits, m, v, t), None

    init = (logits0, jnp.zeros((k,)), jnp.zeros((k,)), jnp.asarray(0.0))
    (logits, _, _, _), _ = jax.lax.scan(step, init, None, length=steps)
    fracs = jax.nn.softmax(logits)
    e_t, var = mean_var_completion(fracs, params)
    return fracs, e_t, var


def quantize_fractions(
    fracs: np.ndarray,
    total_microbatches: int,
    params: Optional[UnitParams] = None,
    risk_aversion: float = 0.0,
    min_per_worker: int = 1,
    refine_passes: int = 4,
) -> np.ndarray:
    """Round simplex fractions to integer microbatch counts summing to total.

    Largest-remainder rounding, then greedy donor->receiver single-microbatch
    moves accepted only if they reduce the true (quantized) objective.
    """
    k = len(fracs)
    if total_microbatches < k * min_per_worker:
        raise ValueError(
            f"{total_microbatches} microbatches cannot give {k} workers "
            f">= {min_per_worker} each"
        )
    raw = np.asarray(fracs, np.float64) * total_microbatches
    counts = np.maximum(np.floor(raw).astype(np.int64), min_per_worker)
    while counts.sum() > total_microbatches:
        # Shed from the largest over-allocated worker (keep the floor).
        order = np.argsort(-(counts - raw))
        for idx in order:
            if counts[idx] > min_per_worker:
                counts[idx] -= 1
                break
    rema = raw - counts
    while counts.sum() < total_microbatches:
        idx = int(np.argmax(rema))
        counts[idx] += 1
        rema[idx] -= 1.0

    if params is None:
        return counts

    def obj(c: np.ndarray) -> float:
        fr = jnp.asarray(c / total_microbatches, jnp.float32)
        e_t, var = mean_var_completion(fr, params)
        return float(e_t + risk_aversion * var)

    best = obj(counts)
    for _ in range(refine_passes):
        improved = False
        for donor in range(k):
            if counts[donor] <= min_per_worker:
                continue
            for recv in range(k):
                if recv == donor:
                    continue
                trial = counts.copy()
                trial[donor] -= 1
                trial[recv] += 1
                val = obj(trial)
                if val < best - 1e-9:
                    counts, best, improved = trial, val, True
        if not improved:
            break
    return counts


class WorkerTelemetry(NamedTuple):
    """One batch of per-worker observations: fractions worked and times taken."""

    fracs: Array  # (K, N) workload fraction each worker processed
    times: Array  # (K, N) measured completion times


class HeterogeneityAwarePartitioner:
    """Online Bayesian partitioner over K processing units (pods/workers).

    The paper's estimator wrapped as the scheduler the trainer/server call:

      observe(telemetry)  -> Gibbs-update every worker's posterior (vmapped)
      propose(total_mb)   -> microbatch counts on the efficient frontier
      anomaly_scores(...) -> posterior-predictive log-likelihoods (stragglers)
    """

    def __init__(
        self,
        num_workers: int,
        *,
        seed: int = 0,
        risk_aversion: float = 0.0,
        n_iters: int = 20,
        grid_size: int = 256,
        mu_guess: float = 1.0,
        discount: float = 0.9,
    ):
        self.num_workers = num_workers
        self.risk_aversion = risk_aversion
        self.n_iters = n_iters
        self.grid_size = grid_size
        self.discount = discount
        key = jax.random.PRNGKey(seed)
        keys = jax.random.split(key, num_workers)
        self.states: gibbs.GibbsState = jax.vmap(
            lambda k: gibbs.init_state(k, mu_guess=mu_guess)
        )(keys)
        self._ewma_ll = np.zeros(num_workers, np.float64)
        self._ewma_initialized = False
        self.history_ll: list = []

    # ---- estimation ------------------------------------------------------
    def observe(self, telemetry: WorkerTelemetry) -> Array:
        """Gibbs-update every worker's posterior from one telemetry batch.

        A power-prior forgetting factor is applied before each batch so the
        estimator tracks drifting systems (see gibbs.discount_state)."""
        self.states = jax.vmap(
            lambda st: gibbs.discount_state(st, self.discount)
        )(self.states)
        step = jax.vmap(
            lambda st, t, f: gibbs.gibbs_batch(
                st, t, f, n_iters=self.n_iters, grid_size=self.grid_size
            )
        )
        self.states, ll = step(self.states, telemetry.times, telemetry.fracs)
        self.history_ll.append(np.asarray(ll))
        return ll

    def unit_params(self) -> UnitParams:
        st = self.states
        return UnitParams(mu=st.mu, sigma=st.sigma, alpha=st.alpha, beta=st.beta)

    # ---- partitioning ----------------------------------------------------
    def propose_fractions(self) -> Tuple[np.ndarray, float, float]:
        fracs, e_t, var = optimize_fractions(
            self.unit_params(), risk_aversion=self.risk_aversion
        )
        return np.asarray(fracs), float(e_t), float(var)

    def propose_microbatches(
        self, total_microbatches: int, min_per_worker: int = 1
    ) -> np.ndarray:
        fracs, _, _ = self.propose_fractions()
        return quantize_fractions(
            fracs,
            total_microbatches,
            self.unit_params(),
            self.risk_aversion,
            min_per_worker,
        )

    # ---- anomaly / straggler detection -----------------------------------
    def anomaly_scores(
        self, fracs: Array, times: Array, ewma: float = 0.8
    ) -> np.ndarray:
        """Negative posterior-predictive log-likelihood per worker (EWMA'd).

        High score == recent behaviour inconsistent with the learned model.
        """
        st = self.states
        ll = jax.vmap(posterior_predictive_logpdf)(
            jnp.asarray(times), jnp.asarray(fracs), st.mu, st.lam, st.alpha, st.beta
        )
        score = -np.asarray(jnp.atleast_1d(ll), np.float64)
        if not self._ewma_initialized:
            self._ewma_ll = score
            self._ewma_initialized = True
        else:
            self._ewma_ll = ewma * self._ewma_ll + (1.0 - ewma) * score
        return self._ewma_ll

    def flag_stragglers(self, threshold_sigma: float = 3.0) -> np.ndarray:
        """Workers whose anomaly score is an outlier vs the fleet."""
        s = self._ewma_ll
        med = np.median(s)
        mad = np.median(np.abs(s - med)) + 1e-9
        return s > med + threshold_sigma * 1.4826 * mad

    # ---- elastic membership ----------------------------------------------
    def remove_workers(self, dead: np.ndarray) -> None:
        """Drop failed workers from the fleet (elastic down-scale)."""
        keep = ~np.asarray(dead, bool)
        take = lambda x: x[keep] if hasattr(x, "shape") and x.shape[:1] == (self.num_workers,) else x
        self.states = jax.tree_util.tree_map(take, self.states)
        self._ewma_ll = self._ewma_ll[keep]
        self.num_workers = int(keep.sum())

    def add_workers(self, count: int, seed: int = 1234, mu_guess: float = 1.0) -> None:
        """Admit new workers with fresh priors (elastic up-scale)."""
        keys = jax.random.split(jax.random.PRNGKey(seed), count)
        fresh = jax.vmap(lambda k: gibbs.init_state(k, mu_guess=mu_guess))(keys)
        cat = lambda a, b: jnp.concatenate([a, b], axis=0)
        self.states = jax.tree_util.tree_map(cat, self.states, fresh)
        self._ewma_ll = np.concatenate([self._ewma_ll, np.zeros(count)])
        self.num_workers += count
