"""Deprecated import path for the legacy partitioner API.

The implementation moved to :mod:`repro.sched.compat` — it wraps the
``repro.sched`` scheduler, and ``core`` sits *below* ``sched`` in the layer
map (``tools/reprolint/layers.toml``), so keeping the wrapper here would be
an upward import (reprolint RL005).  This module survives only so that
``from repro.core.partitioner import HeterogeneityAwarePartitioner`` keeps
working; the names resolve lazily (PEP 562) through a deferred import, the
sanctioned acyclic escape hatch.

New code should import from ``repro.sched`` directly.
"""
from __future__ import annotations

__all__ = [
    "HeterogeneityAwarePartitioner",
    "WorkerTelemetry",
    "optimize_fractions",
    "quantize_fractions",
]


def __getattr__(name):
    if name in __all__ or name in ("Array", "_legacy_objective"):
        from repro.sched import compat

        return getattr(compat, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
