"""Fleet-axis sharding of the estimation engine (multi-host / multi-device).

The paper's Gibbs estimator treats each processing unit's (alpha, beta)
posterior independently, so the fleet axis K of the fused estimation engine
is embarrassingly parallel: sharding K across a 1-D ``workers`` device mesh
with ``shard_map`` splits every per-worker quantity — telemetry (K, N),
chain states (K, ...), the O(K*G*N) grid-posterior evaluation — while the
tiny exponent grid (G,) stays replicated.  Each shard runs the SAME fused
program (one Pallas launch on TPU, the cache-blocked XLA oracle elsewhere)
on its K/n_shards workers; only the small per-worker outputs (the (K, 2, G)
log-posteriors, the chain states, the log-likelihoods) ever cross shard
boundaries, and only when a consumer (moment integration outside the kernel
wrapper, ``sched.propose``'s fleet-wide solve, the anomaly median) actually
gathers them.

``ShardingConfig`` is the one value threaded through the stack:

    core.gibbs.gibbs_batch / fit_fleet / fit_dag      sharding=...
    kernels.ops.posterior_grid_fleet                  sharding=...
    sched.SchedulerConfig(mesh=...) -> observe / observe_dag

``None`` everywhere means the single-device behavior is bit-for-bit
unchanged.  A fleet whose K does not divide the shard count is padded with
masked-out dummy workers (mask rows of zeros; duplicated state rows) and
sliced back after the mapped region — real workers' chains are unaffected.

Frozen and hashable (``jax.sharding.Mesh`` hashes structurally), so it rides
through ``jax.jit`` as a static argument, including inside the equally-static
``sched.SchedulerConfig``.

>>> import jax
>>> cfg = ShardingConfig.auto()            # 1-D mesh over all local devices
>>> cfg.num_shards == jax.device_count()
True
>>> cfg.pad(10) == (-10) % jax.device_count()
True
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

FLEET_AXIS = "workers"


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """How to partition the estimation fleet axis across devices.

    ``mesh`` must contain ``axis``; the fleet axis K (or the folded S*K
    stage-fleet axis of a workflow DAG) is partitioned across it, everything
    else — the exponent grid, per-shard scalars — is replicated.  Hashable:
    valid as a jit-static argument.
    """

    mesh: Mesh
    axis: str = FLEET_AXIS

    def __post_init__(self):
        if self.axis not in self.mesh.axis_names:
            raise ValueError(
                f"mesh {self.mesh.axis_names} has no {self.axis!r} axis"
            )

    @staticmethod
    def auto(
        num_devices: Optional[int] = None, axis: str = FLEET_AXIS
    ) -> "ShardingConfig":
        """1-D mesh over the first ``num_devices`` local devices (default all).

        The zero-config entry point: on a CPU host started with
        ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` this gives an
        8-way fleet mesh; on a TPU slice, one shard per chip.
        """
        devs = jax.devices()
        if num_devices is not None:
            devs = devs[:num_devices]
        return ShardingConfig(mesh=Mesh(np.array(devs), (axis,)), axis=axis)

    @property
    def num_shards(self) -> int:
        return self.mesh.shape[self.axis]

    def pad(self, k: int) -> int:
        """Dummy workers needed to make a K-fleet divide the shard count."""
        return (-k) % self.num_shards

    def spec(self, ndim: int = 1) -> P:
        """PartitionSpec sharding the leading (fleet) axis, rest replicated."""
        return P(self.axis, *([None] * (ndim - 1)))

    def fleet_sharding(self, ndim: int = 1) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(ndim))


def pad_fleet_axis(tree, pad: int):
    """Append ``pad`` dummy rows to every leaf's leading (fleet) axis.

    Dummy rows duplicate the last real row — always finite, always the right
    dtype — so the padded program computes harmless garbage that callers
    slice off with :func:`unpad_fleet_axis`.  Telemetry padding should
    instead carry ``mask=0`` rows so the dummies can never influence even
    their own (discarded) posterior row.
    """
    if pad == 0:
        return tree
    grow = lambda x: jnp.concatenate(
        [x, jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])], axis=0
    )
    return jax.tree_util.tree_map(grow, tree)


def unpad_fleet_axis(tree, k: int):
    """Inverse of :func:`pad_fleet_axis`: keep the first ``k`` fleet rows."""
    return jax.tree_util.tree_map(lambda x: x[:k], tree)


def shard_fleet_map(fn, sharding: ShardingConfig, *, out_specs=None):
    """``shard_map`` a fleet-batched function over the workers axis.

    Every argument and result must carry the fleet axis leading; replicated
    extras (the grid) should be closed over.  ``check_rep`` is off because
    the per-worker math is embarrassingly parallel by construction — there
    is nothing cross-shard to verify.
    """
    spec_of = lambda tree: jax.tree_util.tree_map(
        lambda _: P(sharding.axis), tree
    )

    def wrapped(*args):
        return shard_map(
            fn,
            mesh=sharding.mesh,
            in_specs=tuple(spec_of(a) for a in args),
            out_specs=(
                spec_of(jax.eval_shape(fn, *args))
                if out_specs is None
                else out_specs
            ),
            check_rep=False,
        )(*args)

    return wrapped


def shard_fleet_call(fn, sharding: ShardingConfig, args, *, mask_index=None):
    """Pad -> shard_map -> unpad in one place (the fleet-call pattern).

    Every positional arg (pytree leaves included) must carry the fleet axis
    leading.  If K does not divide the shard count, all args are padded with
    duplicated edge rows; ``mask_index`` names the arg holding the validity
    mask, whose padded rows are zeroed so dummy workers contribute nothing
    even to their own (discarded) output rows.  Outputs are sliced back to
    K.  Both ``gibbs.gibbs_batch`` and ``kernels.ops.posterior_grid_fleet``
    route their sharded paths through here so padding semantics cannot
    diverge between the engine and the kernel wrapper.
    """
    k = jax.tree_util.tree_leaves(args[0])[0].shape[0]
    pad = sharding.pad(k)
    if pad:
        args = pad_fleet_axis(tuple(args), pad)
        if mask_index is not None:
            m = args[mask_index].at[k:].set(0)
            args = args[:mask_index] + (m,) + args[mask_index + 1:]
    out = shard_fleet_map(fn, sharding)(*args)
    return unpad_fleet_axis(out, k) if pad else out


def constrain_fleet(tree, sharding: Optional[ShardingConfig], *, axis: int = 0):
    """Attach fleet-axis sharding constraints to a pytree's leaves.

    Usable inside jit (``lax.with_sharding_constraint``) and a no-op when
    ``sharding`` is None, so state constructors can call it unconditionally.
    Leaves whose fleet-axis extent does not divide the shard count are left
    unconstrained (the mapped compute path pads for itself; placement of the
    stored state is only a locality hint).  ``axis`` selects which leaf axis
    is the fleet axis — 1 for (S, K, ...) workflow-DAG leaves.
    """
    if sharding is None:
        return tree
    n = sharding.num_shards

    def one(x):
        if x.ndim <= axis or x.shape[axis] % n != 0:
            return x
        spec = P(*([None] * axis), sharding.axis)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(sharding.mesh, spec)
        )

    return jax.tree_util.tree_map(one, tree)
