"""Conjugate Normal-Gamma updates for (mu, lambda) — Eqs 6-9 of the paper.

The completion-time model for one processing unit is

    t_n | f_n ~ N( f_n^alpha * mu,  f_n^{2 beta} / lambda )        (Eq 1)

With the Normal-Gamma prior

    mu | lambda ~ N(mu_0, (kappa_0 lambda)^{-1}),   lambda ~ Gamma(nu_0, rate=psi_0)

the posterior after observing T = {t_n}, F = {f_n} (alpha, beta held fixed) is
Normal-Gamma with parameters given by Eqs 6-9.  All updates support an optional
boolean ``mask`` so fixed-shape telemetry buffers with variable fill work under
jit, and broadcast over leading worker axes for vmap.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

_PSI_FLOOR = 1e-8


class NormalGammaParams(NamedTuple):
    """Hyperparameters of the Normal-Gamma distribution over (mu, lambda)."""

    mu0: Array
    kappa0: Array
    nu0: Array
    psi0: Array

    @staticmethod
    def default(mu_guess: float = 1.0) -> "NormalGammaParams":
        """A weak prior centred at ``mu_guess`` (paper: subjective constants)."""
        return NormalGammaParams(
            mu0=jnp.asarray(mu_guess, jnp.float32),
            kappa0=jnp.asarray(1e-3, jnp.float32),
            nu0=jnp.asarray(1.0, jnp.float32),
            psi0=jnp.asarray(1.0, jnp.float32),
        )


def update_normal_gamma(
    prior: NormalGammaParams,
    t: Array,
    f: Array,
    alpha: Array,
    beta: Array,
    mask: Optional[Array] = None,
) -> NormalGammaParams:
    """Posterior Normal-Gamma hyperparameters — Eqs 6-9.

    Args:
      prior: current hyperparameters (scalars or batched with leading axes).
      t: observed completion times, shape (..., N).
      f: workload fractions in (0, 1], shape (..., N).
      alpha, beta: current scaling-exponent samples (scalar or leading axes).
      mask: optional (..., N) validity mask.
    """
    f = jnp.maximum(f, 1e-6)
    logf = jnp.log(f)
    alpha = jnp.asarray(alpha)[..., None]
    beta = jnp.asarray(beta)[..., None]

    # Weights reused across the four sufficient statistics.
    w_cross = jnp.exp((alpha - 2.0 * beta) * logf)  # f^{alpha-2beta}
    w_self = jnp.exp(2.0 * (alpha - beta) * logf)  # f^{2alpha-2beta}
    t_scaled = t * jnp.exp(-beta * logf)  # t / f^beta

    if mask is not None:
        m = mask.astype(t.dtype)
        n_eff = jnp.sum(m, axis=-1)
        s_cross = jnp.sum(m * w_cross * t, axis=-1)
        s_self = jnp.sum(m * w_self, axis=-1)
        s_sq = jnp.sum(m * t_scaled * t_scaled, axis=-1)
    else:
        n_eff = jnp.asarray(t.shape[-1], t.dtype)
        s_cross = jnp.sum(w_cross * t, axis=-1)
        s_self = jnp.sum(w_self, axis=-1)
        s_sq = jnp.sum(t_scaled * t_scaled, axis=-1)

    kappa_n = prior.kappa0 + s_self  # Eq 7
    mu_n = (prior.mu0 * prior.kappa0 + s_cross) / kappa_n  # Eq 6
    nu_n = prior.nu0 + 0.5 * n_eff  # Eq 8
    psi_n = prior.psi0 + 0.5 * (
        -mu_n * mu_n * kappa_n + prior.mu0 * prior.mu0 * prior.kappa0 + s_sq
    )  # Eq 9
    # psi_n > 0 mathematically; clamp guards f32 cancellation for huge N.
    psi_n = jnp.maximum(psi_n, _PSI_FLOOR)
    return NormalGammaParams(mu_n, kappa_n, nu_n, psi_n)


def log_likelihood(
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    alpha: Array,
    beta: Array,
    mask: Optional[Array] = None,
) -> Array:
    """Data log-likelihood (Eq 4 incl. the 1/f^beta Jacobian), summed over N.

    This is the quantity plotted in the paper's Fig 5.
    """
    f = jnp.maximum(f, 1e-6)
    logf = jnp.log(f)
    alpha = jnp.asarray(alpha)[..., None]
    beta = jnp.asarray(beta)[..., None]
    lam_b = jnp.asarray(lam)[..., None]
    mu_b = jnp.asarray(mu)[..., None]

    mean = jnp.exp(alpha * logf) * mu_b
    z = (t - mean) * jnp.exp(-beta * logf)
    ll = (
        0.5 * jnp.log(jnp.maximum(lam_b, 1e-30))
        - beta * logf
        - 0.5 * lam_b * z * z
        - 0.5 * jnp.log(2.0 * jnp.pi)
    )
    if mask is not None:
        ll = ll * mask.astype(ll.dtype)
    return jnp.sum(ll, axis=-1)


def posterior_predictive_logpdf(
    t: Array, f: Array, mu: Array, lam: Array, alpha: Array, beta: Array
) -> Array:
    """Plug-in predictive log-density of a single observation.

    Used by the straggler detector: persistently low values mean the unit no
    longer behaves like its learned model.
    """
    f = jnp.maximum(f, 1e-6)
    mean = f**alpha * mu
    sigma = f**beta / jnp.sqrt(jnp.maximum(lam, 1e-30))
    z = (t - mean) / jnp.maximum(sigma, 1e-6)
    return -0.5 * z * z - jnp.log(jnp.maximum(sigma, 1e-6)) - 0.5 * jnp.log(2.0 * jnp.pi)
