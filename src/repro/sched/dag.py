"""Stage-structured workflow DAGs: stacked estimation + composed frontier.

The paper partitions ONE workflow stage across K uncertain units; real
workflows are pipelines.  This module lifts the whole scheduler stack from a
simplex to a *graph*:

  * ``WorkflowDAG`` — S stages (each a K-worker fleet with its own exponent
    posteriors) plus a static precedence topology.  Serial chains are the
    common case; general DAGs compose via topological reduction
    (``frontier.dag_completion_moments``).  Stochastic annotations make the
    topology itself uncertain: per-stage execution probabilities
    (``exec_probs`` — conditional branches), geometric rework loops
    (``rework_probs`` + ``max_retries``), and heterogeneous per-stage fleet
    widths (``stage_workers`` — pad to max K, dead columns masked to exactly
    zero fraction).
  * ``DagState`` — one ``GibbsState`` whose leaves carry (S, K) leading axes.
    Estimation NEVER loops over stages: ``observe_dag`` / ``core.gibbs.fit_dag``
    fold the stage axis into the fleet axis and advance the entire (S, K, N)
    telemetry block through one fleet-native ``gibbs_batch`` — a single fused
    Pallas launch per sweep sees S*K workers.  Stochastic annotations change
    NOTHING here: the estimator learns per-attempt worker behaviour, and all
    branch/rework structure lives in the composition layer.
  * ``propose_dag`` — partitions stage by stage against the shared
    ``Objective`` (or a per-stage ``objectives`` tuple).  The moment-separable
    kinds decompose exactly for chains (E and Var of a sum both add);
    budgeted kinds (``var_budget``, ``deadline``) allocate the end-to-end
    budget across stages, and the critical-path-aware variant spends the risk
    budget where variance hurts end-to-end latency most.  On a *stochastic*
    DAG the allocation runs over EFFECTIVE stage moments (what each stage
    contributes after rework amplification and branch thinning —
    ``effective_stage_moments``), and a joint end-to-end refinement pass
    descends on all S*K logits at once against the composed objective,
    keeping whichever of {per-stage, joint} actually scores better: the
    per-stage decomposition cannot see that variance bought at a noisy
    fork/join costs E[max] downstream, the joint pass can.

All propose-side transitions are pure and jit-compatible: the topology is a
frozen, hashable dataclass (jit-static), stage moments stay traced.
Degenerate annotations (p = 1 branches, zero rework, full-width stages) are
detected statically (``is_stochastic``) and take the deterministic code path
bitwise — ``tests/test_stochastic.py`` pins this leaf-for-leaf.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import gibbs
from repro.core.frontier import (
    UnitParams,
    dag_completion_moments,
    mean_var_completion,
    stochastic_stage_moments,
    truncated_geometric_moments,
)
from repro.core.sharding import constrain_fleet

from .objectives import Objective, as_stage_objectives, score_moments_dynamic
from .scheduler import (
    SchedulerConfig,
    Telemetry,
    advance_fleet,
    solve_fractions,
    unit_params_from_gibbs,
)

Array = jax.Array


# --------------------------------------------------------------------------
# topology
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkflowDAG:
    """Static topology of a stage-structured workflow.

    ``preds[i]`` lists the stages that must finish before stage i starts;
    stages must be numbered topologically (every predecessor index < i), so
    the structure is acyclic by construction and composition can run one
    forward pass.  ``num_workers`` is the per-stage fleet width K — the
    (S, K, N) telemetry block stacks into one fused estimation program;
    ``stage_workers`` optionally narrows individual stages (K_s <= K):
    columns beyond a stage's width are dead — masked out of estimation and
    pinned to exactly 0.0 fraction by the proposal.

    Stochastic annotations (all optional, all per-stage tuples so the
    dataclass stays hashable and jit-static):

      exec_probs[i]    probability stage i executes at all (conditional
                       branch on upstream data); a skipped stage contributes
                       zero time but still forwards its predecessors' finish.
      rework_probs[i]  probability an attempt of stage i must be REDONE
                       (per-attempt failure), so attempt counts are
                       Geometric(1 - rework_probs[i]) ...
      max_retries[i]   ... truncated at this cap (defaults to 8 whenever
                       ``rework_probs`` is given).

    Hashable and immutable: rides through ``jax.jit`` as a static argument.
    """

    preds: Tuple[Tuple[int, ...], ...]
    num_workers: int
    names: Optional[Tuple[str, ...]] = None
    exec_probs: Optional[Tuple[float, ...]] = None
    rework_probs: Optional[Tuple[float, ...]] = None
    max_retries: Optional[Tuple[int, ...]] = None
    stage_workers: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        for i, ps in enumerate(self.preds):
            for p in ps:
                if not 0 <= p < i:
                    raise ValueError(
                        f"stage {i} depends on stage {p}: stages must be "
                        "numbered topologically (predecessor < successor); "
                        "cycles are unrepresentable"
                    )
        s = len(self.preds)
        if self.names is not None and len(self.names) != s:
            raise ValueError("names must match num_stages")
        # Normalize annotations to plain tuples (hashability under jit).
        for field in ("exec_probs", "rework_probs"):
            val = getattr(self, field)
            if val is None:
                continue
            val = tuple(float(x) for x in val)
            object.__setattr__(self, field, val)
            if len(val) != s:
                raise ValueError(f"{field} must have one entry per stage")
            if not all(0.0 <= x <= 1.0 for x in val):
                raise ValueError(f"{field} entries must lie in [0, 1]")
        if self.rework_probs is not None and any(
            x >= 1.0 for x in self.rework_probs
        ):
            raise ValueError(
                "rework_probs must be < 1 (an always-failing stage never "
                "completes)"
            )
        if self.max_retries is not None and self.rework_probs is None:
            raise ValueError("max_retries without rework_probs is meaningless")
        if self.rework_probs is not None:
            caps = self.max_retries
            caps = (8,) * s if caps is None else tuple(int(r) for r in caps)
            object.__setattr__(self, "max_retries", caps)
            if len(caps) != s:
                raise ValueError("max_retries must have one entry per stage")
            if not all(r >= 1 for r in caps):
                raise ValueError("max_retries entries must be >= 1")
        if self.stage_workers is not None:
            widths = tuple(int(k) for k in self.stage_workers)
            object.__setattr__(self, "stage_workers", widths)
            if len(widths) != s:
                raise ValueError("stage_workers must have one entry per stage")
            if not all(1 <= k <= self.num_workers for k in widths):
                raise ValueError(
                    "stage_workers entries must lie in [1, num_workers]"
                )

    # -- constructors ------------------------------------------------------
    @staticmethod
    def chain(num_stages: int, num_workers: int) -> "WorkflowDAG":
        """A serial pipeline: stage i feeds stage i+1."""
        preds = tuple(() if i == 0 else (i - 1,) for i in range(num_stages))
        return WorkflowDAG(preds=preds, num_workers=num_workers)

    @staticmethod
    def from_edges(
        num_stages: int, edges: Tuple[Tuple[int, int], ...], num_workers: int
    ) -> "WorkflowDAG":
        """Build from (upstream, downstream) pairs (topologically numbered)."""
        preds = [[] for _ in range(num_stages)]
        for u, v in edges:
            if not 0 <= v < num_stages:
                raise ValueError(f"edge ({u}, {v}) out of range")
            preds[v].append(u)
        return WorkflowDAG(
            preds=tuple(tuple(sorted(set(p))) for p in preds),
            num_workers=num_workers,
        )

    # -- annotated copies --------------------------------------------------
    def with_stochastic(
        self,
        *,
        exec_probs: Optional[Sequence[float]] = None,
        rework_probs: Optional[Sequence[float]] = None,
        max_retries: Optional[Sequence[int]] = None,
    ) -> "WorkflowDAG":
        """Copy with branch/rework annotations (validated, tuple-normalized)."""
        return dataclasses.replace(
            self,
            exec_probs=None if exec_probs is None else tuple(exec_probs),
            rework_probs=None if rework_probs is None else tuple(rework_probs),
            max_retries=None if max_retries is None else tuple(max_retries),
        )

    def with_stage_workers(self, widths: Sequence[int]) -> "WorkflowDAG":
        """Copy with heterogeneous per-stage fleet widths (K_s <= K)."""
        return dataclasses.replace(self, stage_workers=tuple(widths))

    # -- structure ---------------------------------------------------------
    @property
    def num_stages(self) -> int:
        return len(self.preds)

    @property
    def sinks(self) -> Tuple[int, ...]:
        has_succ = {p for pp in self.preds for p in pp}
        return tuple(i for i in range(self.num_stages) if i not in has_succ)

    @property
    def is_chain(self) -> bool:
        return all(
            ps == (() if i == 0 else (i - 1,)) for i, ps in enumerate(self.preds)
        )

    def succs(self, i: int) -> Tuple[int, ...]:
        return tuple(j for j in range(self.num_stages) if i in self.preds[j])

    @property
    def is_stochastic(self) -> bool:
        """True only for NON-degenerate randomness.

        p = 1.0 branches and zero-probability (or cap-1) rework change no
        number, so they are routed through the deterministic code path —
        that is what makes the bitwise-regression guarantee structural
        rather than numerical luck.
        """
        if self.exec_probs is not None and any(p < 1.0 for p in self.exec_probs):
            return True
        if self.rework_probs is not None:
            return any(
                r > 0.0 and cap > 1
                for r, cap in zip(self.rework_probs, self.max_retries)
            )
        return False

    def stage_live(self) -> Optional[Array]:
        """(S, K) {0, 1} per-stage worker mask, or None when homogeneous."""
        if self.stage_workers is None:
            return None
        col = jnp.arange(self.num_workers)[None, :]
        widths = jnp.asarray(self.stage_workers, jnp.int32)[:, None]
        return (col < widths).astype(jnp.float32)


def path_lengths(dag: WorkflowDAG, stage_means: Array) -> Tuple[Array, Array]:
    """Longest expected path THROUGH each stage, and the critical-path length.

    ``through[i] = fwd[i] + bwd[i] - mean[i]`` where fwd/bwd are the longest
    expected path ending at / starting from stage i.  The topology is static
    (Python loop over stage indices) while the means stay traced, so this
    jits.  ``through[i] / max(through)`` is the criticality weight used by
    the budget allocator: 1 on the critical path, < 1 for stages whose
    longest path has slack against it.  On a stochastic DAG pass EFFECTIVE
    means (``effective_stage_moments``) so criticality reflects what stages
    actually contribute.
    """
    s = dag.num_stages
    fwd: list = [None] * s
    for i in range(s):
        up = [fwd[p] for p in dag.preds[i]]
        start = functools.reduce(jnp.maximum, up) if up else jnp.asarray(0.0, jnp.float32)
        fwd[i] = start + stage_means[i]
    bwd: list = [None] * s
    for i in reversed(range(s)):
        down = [bwd[j] for j in dag.succs(i)]
        tail = functools.reduce(jnp.maximum, down) if down else jnp.asarray(0.0, jnp.float32)
        bwd[i] = tail + stage_means[i]
    through = jnp.stack([fwd[i] + bwd[i] - stage_means[i] for i in range(s)])
    return through, jnp.max(through)


# --------------------------------------------------------------------------
# stochastic composition helpers
# --------------------------------------------------------------------------
def _stochastic_factors(dag: WorkflowDAG) -> Tuple[Array, Array, Array]:
    """(p, E[N], Var[N]) per stage from the static annotations."""
    s = dag.num_stages
    p = jnp.asarray(
        dag.exec_probs if dag.exec_probs is not None else (1.0,) * s,
        jnp.float32,
    )
    if dag.rework_probs is not None:
        n_mean, n_var = truncated_geometric_moments(
            1.0 - jnp.asarray(dag.rework_probs, jnp.float32), dag.max_retries
        )
    else:
        n_mean = jnp.ones((s,), jnp.float32)
        n_var = jnp.zeros((s,), jnp.float32)
    return p, n_mean, n_var


def effective_stage_moments(
    dag: WorkflowDAG, stage_means: Array, stage_vars: Array
) -> Tuple[Array, Array]:
    """Per-attempt stage moments -> what each stage contributes end-to-end.

    Applies the geometric-rework compound-sum transform then the Bernoulli
    branch mixture (``frontier.stochastic_stage_moments``).  A DAG without
    non-degenerate annotations passes through UNTOUCHED — same arrays, same
    bits — which is what keeps the deterministic path regression-exact.
    """
    if not dag.is_stochastic:
        return stage_means, stage_vars
    return stochastic_stage_moments(
        stage_means,
        stage_vars,
        exec_probs=(
            None
            if dag.exec_probs is None
            else jnp.asarray(dag.exec_probs, jnp.float32)
        ),
        success_probs=(
            None
            if dag.rework_probs is None
            else 1.0 - jnp.asarray(dag.rework_probs, jnp.float32)
        ),
        max_retries=dag.max_retries,
    )


# --------------------------------------------------------------------------
# state + estimation (stacked — never a Python loop over stages)
# --------------------------------------------------------------------------
class DagState(NamedTuple):
    """Everything the DAG scheduler has learned; a registered pytree.

    ``gibbs`` leaves carry (S, K, ...) leading axes — stage-major, matching
    ``gibbs.fold_stage_axis`` — so checkpointing, vmap-over-tenants, and the
    fused estimation path all treat the DAG as one S*K fleet.
    """

    gibbs: gibbs.GibbsState  # per-stage-per-worker posteriors, leaves (S, K, ...)
    step: Array  # scalar, observe_dag() calls so far
    key: Array  # DAG-scheduler PRNG key


class DagProposeStats(NamedTuple):
    """Per-stage and end-to-end statistics of a proposed stage-wise split.

    On a stochastic DAG ``stage_e`` / ``stage_var`` are the EFFECTIVE
    contributions (rework-amplified, branch-thinned) and ``e_t`` / ``var``
    compose them; on a deterministic DAG they are the raw per-attempt
    makespan moments, unchanged from PR 4.
    """

    stage_e: Array  # (S,) expected makespan of each stage at its split
    stage_var: Array  # (S,) completion-time variance of each stage
    e_t: Array  # end-to-end expected completion (topological composition)
    var: Array  # end-to-end completion variance
    score: Array  # DAG-level objective score (lower is better)


@functools.partial(jax.jit, static_argnames=("config", "dag"))
def init_dag(config: SchedulerConfig, dag: WorkflowDAG, key: Array) -> DagState:
    """Fresh beliefs for every stage's fleet."""
    s, k = dag.num_stages, dag.num_workers
    key, sub = jax.random.split(key)
    keys = jax.random.split(sub, s * k)
    fleet = jax.vmap(lambda kk: gibbs.init_state(kk, mu_guess=config.mu_guess))(keys)
    return DagState(
        # With config.mesh the per-stage fleets are sharded over the worker
        # axis (leaf axis 1) from birth; observe_dag's folded S*K program
        # re-lays them out stage-major per shard as needed.
        gibbs=constrain_fleet(
            gibbs.unfold_stage_axis(fleet, s), config.mesh, axis=1
        ),
        step=jnp.zeros((), jnp.int32),
        key=key,
    )


@functools.partial(jax.jit, static_argnames=("config", "dag"))
def observe_dag(
    state: DagState,
    telemetry: Telemetry,
    config: SchedulerConfig = SchedulerConfig(),
    mask: Optional[Array] = None,
    dag: Optional[WorkflowDAG] = None,
) -> Tuple[DagState, Array]:
    """Advance every stage's posteriors from one (S, K, N) telemetry block.

    The stage axis folds into the fleet axis, so the whole DAG advances as
    ONE stacked fleet-native ``gibbs_batch`` program — with the Pallas path
    each sweep's grid posterior is a single kernel launch covering S*K
    workers and both exponents.  With ``config.mesh`` that folded S*K axis
    is partitioned across the device mesh (``shard_map``), so a wide or
    deep DAG scales out without changing this call.

    ``mask`` optionally invalidates telemetry elements (broadcastable to the
    (S, K, N) times).  Passing a ``dag`` with heterogeneous ``stage_workers``
    additionally masks every dead column automatically — whatever garbage a
    padded row carries is an exact no-op on its parked posterior.  Returns
    per-stage-per-worker (S, K) log-likelihood.
    """
    s = telemetry.times.shape[0]
    if dag is not None and dag.stage_workers is not None:
        lv = dag.stage_live()[:, :, None]  # (S, K, 1)
        mask = (
            lv
            if mask is None
            else jnp.broadcast_to(mask, telemetry.times.shape) * lv
        )
    fold = gibbs.fold_stage_axis
    fleet, ll = advance_fleet(
        fold(state.gibbs),
        fold(telemetry.times),
        fold(telemetry.fracs),
        config,
        mask=None if mask is None else fold(jnp.broadcast_to(mask, telemetry.times.shape)),
    )
    return (
        state._replace(gibbs=gibbs.unfold_stage_axis(fleet, s), step=state.step + 1),
        ll.reshape(telemetry.times.shape[:2]),
    )


def stage_params(state: DagState, *, use_samples: bool = False) -> UnitParams:
    """Current point estimates as frontier parameters, leaves (S, K)."""
    return unit_params_from_gibbs(state.gibbs, use_samples=use_samples)


# --------------------------------------------------------------------------
# partitioning
# --------------------------------------------------------------------------
def uniform_fractions(dag: WorkflowDAG) -> Array:
    """The naive baseline: every stage split 1/K_s across its live workers."""
    live = dag.stage_live()
    if live is None:
        return jnp.full(
            (dag.num_stages, dag.num_workers), 1.0 / dag.num_workers, jnp.float32
        )
    return live / jnp.sum(live, axis=-1, keepdims=True)


def dag_stats(
    dag: WorkflowDAG,
    fracs: Array,
    params: UnitParams,
    objective: Objective = Objective(),
    *,
    num_points: int = 512,
) -> DagProposeStats:
    """Compose per-stage makespan moments into end-to-end DAG statistics.

    Stochastic annotations are folded in between the per-stage quadrature and
    the topological reduction: each stage's per-attempt moments become its
    effective contribution (``effective_stage_moments``) before composition.
    """
    stage_e, stage_var = jax.vmap(
        lambda fr, p: mean_var_completion(fr, p, num_points)
    )(fracs, params)
    stage_e, stage_var = effective_stage_moments(dag, stage_e, stage_var)
    e_t, var = dag_completion_moments(
        dag.preds, stage_e, stage_var, num_points=num_points
    )
    if objective.needs_cdf():
        # Normal-matched end-to-end tail: P(T <= d) under the composed moments.
        from repro.core.distributions import normal_cdf

        score = -normal_cdf(
            jnp.asarray(objective.deadline, jnp.float32),
            e_t,
            jnp.sqrt(jnp.maximum(var, 1e-18)),
        )
    else:
        score = objective.score_moments(e_t, var)
    return DagProposeStats(
        stage_e=stage_e, stage_var=stage_var, e_t=e_t, var=var, score=score
    )


def _dag_objective_score(
    dag: WorkflowDAG,
    fracs: Array,
    params: UnitParams,
    objective: Objective,
    num_points: int,
    *,
    smooth: bool = False,
) -> Array:
    """Composed end-to-end objective score of an (S, K) split (differentiable)."""
    stage_e, stage_var = jax.vmap(
        lambda fr, p: mean_var_completion(fr, p, num_points)
    )(fracs, params)
    stage_e, stage_var = effective_stage_moments(dag, stage_e, stage_var)
    e_t, var = dag_completion_moments(
        dag.preds, stage_e, stage_var, num_points=num_points
    )
    if objective.needs_cdf():
        from repro.core.distributions import normal_cdf

        p_meet = normal_cdf(
            jnp.asarray(objective.deadline, jnp.float32),
            e_t,
            jnp.sqrt(jnp.maximum(var, 1e-18)),
        )
        if smooth:
            return -jnp.log(jnp.maximum(p_meet, 1e-12))
        return -p_meet
    return score_moments_dynamic(
        objective.kind,
        e_t,
        var,
        objective.risk_aversion,
        objective.var_budget,
        smooth=smooth,
    )


def _joint_refine(
    dag: WorkflowDAG,
    fracs: Array,
    params: UnitParams,
    objective: Objective,
    config: SchedulerConfig,
    live: Optional[Array],
) -> Array:
    """End-to-end Adam refinement of ALL stage splits at once.

    The per-stage decomposition is blind to cross-stage coupling that only
    the composed objective sees — on a stochastic DAG, trading a little
    per-stage expected time for less variance at a noisy fork/join lowers the
    end-to-end E[max].  This pass descends on the full (S, K) logit tensor
    against the composed (effective-moment) objective.  The caller keeps the
    result only if it beats the per-stage solution under the non-smooth
    composed score, so refinement can never lose ground.
    """
    num_points = config.num_points

    def smooth_loss(logits: Array) -> Array:
        if live is not None:
            logits = jnp.where(live > 0, logits, -1e9)
        f = jax.nn.softmax(logits, axis=-1)
        return _dag_objective_score(
            dag, f, params, objective, num_points, smooth=True
        )

    grad = jax.grad(smooth_loss)
    logits0 = jnp.log(jnp.maximum(fracs, 1e-9))

    def adam_step(carry, _):
        logits, m, v, t = carry
        g = grad(logits)
        t = t + 1.0
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1.0 - 0.9**t)
        vh = v / (1.0 - 0.999**t)
        logits = logits - config.opt_lr * mh / (jnp.sqrt(vh) + 1e-8)
        return (logits, m, v, t), None

    zeros = jnp.zeros_like(logits0)
    (logits, _, _, _), _ = jax.lax.scan(
        adam_step, (logits0, zeros, zeros, jnp.asarray(0.0)), None,
        length=config.opt_steps,
    )
    if live is not None:
        logits = jnp.where(live > 0, logits, -1e9)
    f = jax.nn.softmax(logits, axis=-1)
    # Same per-worker floor discipline as solve_fractions, rows renormalized.
    if live is None:
        f = jnp.maximum(f, config.min_fraction)
    else:
        f = jnp.where(live > 0, jnp.maximum(f, config.min_fraction), 0.0)
    return f / jnp.sum(f, axis=-1, keepdims=True)


@functools.partial(
    jax.jit,
    static_argnames=("dag", "config", "critical_path_aware", "objectives"),
)
def propose_dag(
    state: DagState,
    dag: WorkflowDAG,
    config: SchedulerConfig = SchedulerConfig(),
    *,
    critical_path_aware: bool = True,
    objectives: Optional[Tuple[Objective, ...]] = None,
    params: Optional[UnitParams] = None,
) -> Tuple[Array, DagProposeStats]:
    """Objective-optimal stage-wise splits under the current beliefs.

    Returns fractions (S, K) — each row on the (live-masked) K-simplex —
    plus composed end-to-end statistics.  Decomposition by objective kind:

      mean       Stage-separable for chains: E[sum] = sum E -> each stage
                 independently minimizes its expected makespan.
      mean_var   Separable too (Var of a sum of independent stage times
                 adds); the critical-path-aware variant scales each stage's
                 risk aversion by its criticality — variance on a slack
                 branch cannot move end-to-end latency, so it is not worth
                 paying expected time to remove.
      var_budget The end-to-end variance budget is allocated across stages
                 proportional to their unconstrained variance share (times
                 criticality when critical-path-aware), then each stage
                 solves its own budgeted problem; one reallocation round
                 returns slack from stages that beat their slice to the
                 stages that clipped against theirs.
      deadline   The end-to-end deadline splits along paths: stage s gets
                 d_s = d * E_s / L_s with L_s the longest expected path
                 through s.  Along ANY path the allocated deadlines sum to
                 <= d, so the product of per-stage P(t_s <= d_s) lower-bounds
                 P(T <= d) — each stage then maximizes its own term.

    On a stochastic DAG (non-degenerate ``exec_probs`` / ``rework_probs``)
    every cross-stage quantity above — criticality, variance shares, budget
    and deadline slices — is computed from EFFECTIVE stage moments, and the
    end-to-end budgets are converted to the per-attempt level each stage
    solve actually controls (a stage retried E[N] times on a p-probability
    branch turns one unit of per-attempt variance into p*E[N] units of
    effective variance).  A joint refinement pass then descends on all S*K
    logits against the composed objective and is kept only if it wins
    (``_joint_refine``).  Degenerate annotations take the deterministic path
    bitwise.

    ``objectives`` (a per-stage tuple, jit-static) switches each stage to
    its OWN objective — budgets and deadlines are then per-stage constraints,
    not end-to-end allocations; stages sharing an objective value still solve
    in one vmapped program, and the returned stats score the composition
    under ``config.objective``.  ``params`` overrides the posterior point
    estimates (e.g. the TRUE worker parameters when evaluating against the
    MC oracle).

    All stage solves are vmapped ``solve_fractions`` programs (the objective
    kind is static; per-stage budget/deadline slices ride through as traced
    overrides), not a Python loop of per-stage compilations.
    """
    if params is None:
        params = stage_params(state)
    live = dag.stage_live()
    stochastic = dag.is_stochastic
    solve_kw = dict(
        steps=config.opt_steps,
        lr=config.opt_lr,
        num_points=config.num_points,
        min_fraction=config.min_fraction,
    )

    def vsolve(p, objective, live_rows=None, **overrides):
        """One vmapped solve across a leading stage axis."""
        names = tuple(k for k, v in overrides.items() if v is not None)
        vals = tuple(overrides[k] for k in names)
        if live_rows is None:
            return jax.vmap(
                lambda pp, *ov: solve_fractions(
                    pp, objective=objective, **solve_kw, **dict(zip(names, ov))
                )
            )(p, *vals)
        return jax.vmap(
            lambda pp, lv, *ov: solve_fractions(
                pp, objective=objective, live=lv, **solve_kw,
                **dict(zip(names, ov)),
            )
        )(p, live_rows, *vals)

    # Unconstrained (risk-neutral) pre-solve: the allocation baseline.
    mean_obj = Objective.mean()
    f0, st0 = vsolve(params, mean_obj, live_rows=live)
    e0, v0 = st0.e_t, st0.var  # (S,) per-attempt moments at the mean split

    # Cross-stage bookkeeping runs on effective contributions; per-stage
    # solves stay at the per-attempt level they control.
    if stochastic:
        p_exec, n_mean, n_var = _stochastic_factors(dag)
        eff_e0, eff_v0 = effective_stage_moments(dag, e0, v0)
    else:
        eff_e0, eff_v0 = e0, v0

    through, crit_len = path_lengths(dag, eff_e0)
    crit = (
        through / jnp.maximum(crit_len, 1e-9)
        if critical_path_aware
        else jnp.ones_like(e0)
    )

    if objectives is not None:
        obj_tuple = as_stage_objectives(objectives, dag.num_stages)
        fracs = f0
        groups: dict = {}
        for i, o in enumerate(obj_tuple):
            groups.setdefault(o, []).append(i)
        for o, idx_list in groups.items():
            if o.kind == "mean":
                continue  # the presolve rows already minimize E[t]
            idx = jnp.asarray(tuple(idx_list))
            take = lambda x: x[idx]
            p_g = jax.tree_util.tree_map(take, params)
            lv_g = None if live is None else live[idx]
            if o.kind == "mean_var":
                ra = o.risk_aversion * crit[idx]
                if stochastic:
                    ra = ra * (p_exec * n_mean)[idx]
                f_g, _ = vsolve(p_g, o, live_rows=lv_g, risk_aversion=ra)
            elif o.kind == "var_budget":
                # Per-stage budgets constrain the stage's EFFECTIVE variance;
                # convert to the per-attempt budget the solve controls.
                b = jnp.full((len(idx_list),), o.var_budget, jnp.float32)
                if stochastic:
                    b = _attempt_var_budget(
                        b, e0[idx], p_exec[idx], n_mean[idx], n_var[idx]
                    )
                f_g, _ = vsolve(p_g, o, live_rows=lv_g, var_budget=b)
            else:  # deadline: the stage's own latency target
                d_g = jnp.full((len(idx_list),), o.deadline, jnp.float32)
                if stochastic:
                    d_g = d_g / n_mean[idx]  # each attempt gets its share
                f_g, _ = vsolve(p_g, o, live_rows=lv_g, deadline=d_g)
            fracs = fracs.at[idx].set(f_g)
        stats_obj = config.objective
    else:
        obj = config.objective
        stats_obj = obj
        if obj.kind == "mean":
            fracs = f0
        elif obj.kind == "mean_var":
            ra = obj.risk_aversion * crit  # (S,)
            if stochastic:
                ra = ra * p_exec * n_mean
            fracs, _ = vsolve(params, obj, live_rows=live, risk_aversion=ra)
        elif obj.kind == "var_budget":
            w = eff_v0 * crit + 1e-12
            budget = jnp.asarray(obj.var_budget, jnp.float32)
            b_s = budget * w / jnp.sum(w)  # effective-variance slices
            if stochastic:
                b_s = _attempt_var_budget(b_s, e0, p_exec, n_mean, n_var)
            solve_b = lambda b: vsolve(params, obj, live_rows=live, var_budget=b)
            fracs, st1 = solve_b(b_s)
            # Reallocation round: non-binding stages (v clearly below their
            # slice) donate their surplus to stages that clipped against
            # theirs — spend the risk budget where it actually buys expected
            # time.  A stage is donor OR receiver, never both, so the
            # re-solve slices still sum to <= the end-to-end budget.
            binding = st1.var >= 0.95 * b_s
            surplus = jnp.sum(
                jnp.where(binding, 0.0, jnp.maximum(b_s - st1.var, 0.0))
            )
            recv = binding.astype(jnp.float32) * w
            extra = surplus * recv / jnp.maximum(jnp.sum(recv), 1e-12)
            fracs, _ = solve_b(b_s + extra)
        else:  # deadline
            d = jnp.asarray(obj.deadline, jnp.float32)
            d_s = d * eff_e0 / jnp.maximum(through, 1e-9)  # path-wise slices
            if stochastic:
                d_s = d_s / n_mean  # per-attempt share of the stage's slice
            fracs, _ = vsolve(params, obj, live_rows=live, deadline=d_s)

        if stochastic:
            # Joint end-to-end refinement: keep it only if the composed
            # objective actually improves.
            refined = _joint_refine(dag, fracs, params, obj, config, live)
            sc_base = _dag_objective_score(
                dag, fracs, params, obj, config.num_points
            )
            sc_ref = _dag_objective_score(
                dag, refined, params, obj, config.num_points
            )
            fracs = jnp.where(sc_ref < sc_base, refined, fracs)

    stats = dag_stats(dag, fracs, params, stats_obj, num_points=config.num_points)
    return fracs, stats


def _attempt_var_budget(
    b_eff: Array, e0: Array, p_exec: Array, n_mean: Array, n_var: Array
) -> Array:
    """Invert the effective-variance transform at the allocation point.

    v_eff = p (E[N] v + Var[N] e^2) + p (1 - p) (E[N] e)^2, solved for the
    per-attempt variance v a stage's solve controls, holding the per-attempt
    mean at the presolve value ``e0``.  Floored at a tiny positive budget:
    an allocation smaller than the structural variance (rework/branch terms
    that no split can remove) still yields the stage's minimum-variance
    split rather than NaN.
    """
    v = (
        b_eff / jnp.maximum(p_exec, 1e-9)
        - n_var * e0 * e0
        - (1.0 - p_exec) * (n_mean * e0) ** 2
    ) / jnp.maximum(n_mean, 1e-9)
    return jnp.maximum(v, 1e-9)
