"""Stage-structured workflow DAGs: stacked estimation + composed frontier.

The paper partitions ONE workflow stage across K uncertain units; real
workflows are pipelines.  This module lifts the whole scheduler stack from a
simplex to a *graph*:

  * ``WorkflowDAG`` — S stages (each a K-worker fleet with its own exponent
    posteriors) plus a static precedence topology.  Serial chains are the
    common case; general DAGs compose via topological reduction
    (``frontier.dag_completion_moments``).
  * ``DagState`` — one ``GibbsState`` whose leaves carry (S, K) leading axes.
    Estimation NEVER loops over stages: ``observe_dag`` / ``core.gibbs.fit_dag``
    fold the stage axis into the fleet axis and advance the entire (S, K, N)
    telemetry block through one fleet-native ``gibbs_batch`` — a single fused
    Pallas launch per sweep sees S*K workers.
  * ``propose_dag`` — partitions stage by stage against the shared
    ``Objective``.  The moment-separable kinds decompose exactly for chains
    (E and Var of a sum both add); budgeted kinds (``var_budget``,
    ``deadline``) allocate the end-to-end budget across stages, and the
    critical-path-aware variant spends the risk budget where variance hurts
    end-to-end latency most (stages on short parallel branches absorb slack
    instead of budget).

All propose-side transitions are pure and jit-compatible: the topology is a
frozen, hashable dataclass (jit-static), stage moments stay traced.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gibbs
from repro.core.frontier import (
    UnitParams,
    dag_completion_moments,
    mean_var_completion,
)
from repro.core.sharding import constrain_fleet

from .objectives import Objective
from .scheduler import (
    SchedulerConfig,
    Telemetry,
    advance_fleet,
    solve_fractions,
    unit_params_from_gibbs,
)

Array = jax.Array


# --------------------------------------------------------------------------
# topology
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkflowDAG:
    """Static topology of a stage-structured workflow.

    ``preds[i]`` lists the stages that must finish before stage i starts;
    stages must be numbered topologically (every predecessor index < i), so
    the structure is acyclic by construction and composition can run one
    forward pass.  ``num_workers`` is the per-stage fleet width K — uniform
    across stages so the (S, K, N) telemetry block stacks into one fused
    estimation program (heterogeneous fleets pad to max K with masks).

    Hashable and immutable: rides through ``jax.jit`` as a static argument.
    """

    preds: Tuple[Tuple[int, ...], ...]
    num_workers: int
    names: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        for i, ps in enumerate(self.preds):
            for p in ps:
                if not 0 <= p < i:
                    raise ValueError(
                        f"stage {i} depends on stage {p}: stages must be "
                        "numbered topologically (predecessor < successor); "
                        "cycles are unrepresentable"
                    )
        if self.names is not None and len(self.names) != len(self.preds):
            raise ValueError("names must match num_stages")

    # -- constructors ------------------------------------------------------
    @staticmethod
    def chain(num_stages: int, num_workers: int) -> "WorkflowDAG":
        """A serial pipeline: stage i feeds stage i+1."""
        preds = tuple(() if i == 0 else (i - 1,) for i in range(num_stages))
        return WorkflowDAG(preds=preds, num_workers=num_workers)

    @staticmethod
    def from_edges(
        num_stages: int, edges: Tuple[Tuple[int, int], ...], num_workers: int
    ) -> "WorkflowDAG":
        """Build from (upstream, downstream) pairs (topologically numbered)."""
        preds = [[] for _ in range(num_stages)]
        for u, v in edges:
            if not 0 <= v < num_stages:
                raise ValueError(f"edge ({u}, {v}) out of range")
            preds[v].append(u)
        return WorkflowDAG(
            preds=tuple(tuple(sorted(set(p))) for p in preds),
            num_workers=num_workers,
        )

    # -- structure ---------------------------------------------------------
    @property
    def num_stages(self) -> int:
        return len(self.preds)

    @property
    def sinks(self) -> Tuple[int, ...]:
        has_succ = {p for pp in self.preds for p in pp}
        return tuple(i for i in range(self.num_stages) if i not in has_succ)

    @property
    def is_chain(self) -> bool:
        return all(
            ps == (() if i == 0 else (i - 1,)) for i, ps in enumerate(self.preds)
        )

    def succs(self, i: int) -> Tuple[int, ...]:
        return tuple(j for j in range(self.num_stages) if i in self.preds[j])


def path_lengths(dag: WorkflowDAG, stage_means: Array) -> Tuple[Array, Array]:
    """Longest expected path THROUGH each stage, and the critical-path length.

    ``through[i] = fwd[i] + bwd[i] - mean[i]`` where fwd/bwd are the longest
    expected path ending at / starting from stage i.  The topology is static
    (Python loop over stage indices) while the means stay traced, so this
    jits.  ``through[i] / max(through)`` is the criticality weight used by
    the budget allocator: 1 on the critical path, < 1 for stages whose
    longest path has slack against it.
    """
    s = dag.num_stages
    fwd: list = [None] * s
    for i in range(s):
        up = [fwd[p] for p in dag.preds[i]]
        start = functools.reduce(jnp.maximum, up) if up else jnp.asarray(0.0, jnp.float32)
        fwd[i] = start + stage_means[i]
    bwd: list = [None] * s
    for i in reversed(range(s)):
        down = [bwd[j] for j in dag.succs(i)]
        tail = functools.reduce(jnp.maximum, down) if down else jnp.asarray(0.0, jnp.float32)
        bwd[i] = tail + stage_means[i]
    through = jnp.stack([fwd[i] + bwd[i] - stage_means[i] for i in range(s)])
    return through, jnp.max(through)


# --------------------------------------------------------------------------
# state + estimation (stacked — never a Python loop over stages)
# --------------------------------------------------------------------------
class DagState(NamedTuple):
    """Everything the DAG scheduler has learned; a registered pytree.

    ``gibbs`` leaves carry (S, K, ...) leading axes — stage-major, matching
    ``gibbs.fold_stage_axis`` — so checkpointing, vmap-over-tenants, and the
    fused estimation path all treat the DAG as one S*K fleet.
    """

    gibbs: gibbs.GibbsState  # per-stage-per-worker posteriors, leaves (S, K, ...)
    step: Array  # scalar, observe_dag() calls so far
    key: Array  # DAG-scheduler PRNG key


class DagProposeStats(NamedTuple):
    """Per-stage and end-to-end statistics of a proposed stage-wise split."""

    stage_e: Array  # (S,) expected makespan of each stage at its split
    stage_var: Array  # (S,) completion-time variance of each stage
    e_t: Array  # end-to-end expected completion (topological composition)
    var: Array  # end-to-end completion variance
    score: Array  # DAG-level objective score (lower is better)


@functools.partial(jax.jit, static_argnames=("config", "dag"))
def init_dag(config: SchedulerConfig, dag: WorkflowDAG, key: Array) -> DagState:
    """Fresh beliefs for every stage's fleet."""
    s, k = dag.num_stages, dag.num_workers
    key, sub = jax.random.split(key)
    keys = jax.random.split(sub, s * k)
    fleet = jax.vmap(lambda kk: gibbs.init_state(kk, mu_guess=config.mu_guess))(keys)
    return DagState(
        # With config.mesh the per-stage fleets are sharded over the worker
        # axis (leaf axis 1) from birth; observe_dag's folded S*K program
        # re-lays them out stage-major per shard as needed.
        gibbs=constrain_fleet(
            gibbs.unfold_stage_axis(fleet, s), config.mesh, axis=1
        ),
        step=jnp.zeros((), jnp.int32),
        key=key,
    )


@functools.partial(jax.jit, static_argnames=("config",))
def observe_dag(
    state: DagState,
    telemetry: Telemetry,
    config: SchedulerConfig = SchedulerConfig(),
) -> Tuple[DagState, Array]:
    """Advance every stage's posteriors from one (S, K, N) telemetry block.

    The stage axis folds into the fleet axis, so the whole DAG advances as
    ONE stacked fleet-native ``gibbs_batch`` program — with the Pallas path
    each sweep's grid posterior is a single kernel launch covering S*K
    workers and both exponents.  With ``config.mesh`` that folded S*K axis
    is partitioned across the device mesh (``shard_map``), so a wide or
    deep DAG scales out without changing this call.  Returns
    per-stage-per-worker (S, K) log-likelihood.
    """
    s = telemetry.times.shape[0]
    fold = gibbs.fold_stage_axis
    fleet, ll = advance_fleet(
        fold(state.gibbs), fold(telemetry.times), fold(telemetry.fracs), config
    )
    return (
        state._replace(gibbs=gibbs.unfold_stage_axis(fleet, s), step=state.step + 1),
        ll.reshape(telemetry.times.shape[:2]),
    )


def stage_params(state: DagState, *, use_samples: bool = False) -> UnitParams:
    """Current point estimates as frontier parameters, leaves (S, K)."""
    return unit_params_from_gibbs(state.gibbs, use_samples=use_samples)


# --------------------------------------------------------------------------
# partitioning
# --------------------------------------------------------------------------
def uniform_fractions(dag: WorkflowDAG) -> Array:
    """The naive baseline: every stage split 1/K."""
    return jnp.full(
        (dag.num_stages, dag.num_workers), 1.0 / dag.num_workers, jnp.float32
    )


def dag_stats(
    dag: WorkflowDAG,
    fracs: Array,
    params: UnitParams,
    objective: Objective = Objective(),
    *,
    num_points: int = 512,
) -> DagProposeStats:
    """Compose per-stage makespan moments into end-to-end DAG statistics."""
    stage_e, stage_var = jax.vmap(
        lambda fr, p: mean_var_completion(fr, p, num_points)
    )(fracs, params)
    e_t, var = dag_completion_moments(
        dag.preds, stage_e, stage_var, num_points=num_points
    )
    if objective.needs_cdf():
        # Normal-matched end-to-end tail: P(T <= d) under the composed moments.
        from repro.core.distributions import normal_cdf

        score = -normal_cdf(
            jnp.asarray(objective.deadline, jnp.float32),
            e_t,
            jnp.sqrt(jnp.maximum(var, 1e-18)),
        )
    else:
        score = objective.score_moments(e_t, var)
    return DagProposeStats(
        stage_e=stage_e, stage_var=stage_var, e_t=e_t, var=var, score=score
    )


@functools.partial(
    jax.jit, static_argnames=("dag", "config", "critical_path_aware")
)
def propose_dag(
    state: DagState,
    dag: WorkflowDAG,
    config: SchedulerConfig = SchedulerConfig(),
    *,
    critical_path_aware: bool = True,
) -> Tuple[Array, DagProposeStats]:
    """Objective-optimal stage-wise splits under the current beliefs.

    Returns fractions (S, K) — each row on the K-simplex — plus composed
    end-to-end statistics.  Decomposition by objective kind:

      mean       Stage-separable for chains: E[sum] = sum E -> each stage
                 independently minimizes its expected makespan.
      mean_var   Separable too (Var of a sum of independent stage times
                 adds); the critical-path-aware variant scales each stage's
                 risk aversion by its criticality — variance on a slack
                 branch cannot move end-to-end latency, so it is not worth
                 paying expected time to remove.
      var_budget The end-to-end variance budget is allocated across stages
                 proportional to their unconstrained variance share (times
                 criticality when critical-path-aware), then each stage
                 solves its own budgeted problem; one reallocation round
                 returns slack from stages that beat their slice to the
                 stages that clipped against theirs.
      deadline   The end-to-end deadline splits along paths: stage s gets
                 d_s = d * E_s / L_s with L_s the longest expected path
                 through s.  Along ANY path the allocated deadlines sum to
                 <= d, so the product of per-stage P(t_s <= d_s) lower-bounds
                 P(T <= d) — each stage then maximizes its own term.

    All stage solves are ONE vmapped ``solve_fractions`` program (the
    objective kind is static; per-stage budget/deadline slices ride through
    as traced overrides), not a Python loop of per-stage compilations.
    """
    params = stage_params(state)
    obj = config.objective
    solve_kw = dict(
        steps=config.opt_steps,
        lr=config.opt_lr,
        num_points=config.num_points,
        min_fraction=config.min_fraction,
    )

    # Unconstrained (risk-neutral) pre-solve: the allocation baseline.
    mean_obj = Objective.mean()
    f0, st0 = jax.vmap(
        lambda p: solve_fractions(p, objective=mean_obj, **solve_kw)
    )(params)
    e0, v0 = st0.e_t, st0.var  # (S,)

    through, crit_len = path_lengths(dag, e0)
    crit = (
        through / jnp.maximum(crit_len, 1e-9)
        if critical_path_aware
        else jnp.ones_like(e0)
    )

    if obj.kind == "mean":
        fracs = f0
    elif obj.kind == "mean_var":
        ra = obj.risk_aversion * crit  # (S,)
        fracs, _ = jax.vmap(
            lambda p, r: solve_fractions(
                p, objective=obj, risk_aversion=r, **solve_kw
            )
        )(params, ra)
    elif obj.kind == "var_budget":
        w = v0 * crit + 1e-12
        budget = jnp.asarray(obj.var_budget, jnp.float32)
        b_s = budget * w / jnp.sum(w)
        solve_b = jax.vmap(
            lambda p, b: solve_fractions(p, objective=obj, var_budget=b, **solve_kw)
        )
        fracs, st1 = solve_b(params, b_s)
        # Reallocation round: non-binding stages (v clearly below their
        # slice) donate their surplus to stages that clipped against theirs
        # — spend the risk budget where it actually buys expected time.  A
        # stage is donor OR receiver, never both, so the re-solve slices
        # still sum to <= the end-to-end budget.
        binding = st1.var >= 0.95 * b_s
        surplus = jnp.sum(
            jnp.where(binding, 0.0, jnp.maximum(b_s - st1.var, 0.0))
        )
        recv = binding.astype(jnp.float32) * w
        extra = surplus * recv / jnp.maximum(jnp.sum(recv), 1e-12)
        fracs, _ = solve_b(params, b_s + extra)
    else:  # deadline
        d = jnp.asarray(obj.deadline, jnp.float32)
        d_s = d * e0 / jnp.maximum(through, 1e-9)  # sums to <= d on every path
        fracs, _ = jax.vmap(
            lambda p, ds: solve_fractions(p, objective=obj, deadline=ds, **solve_kw)
        )(params, d_s)

    stats = dag_stats(dag, fracs, params, obj, num_points=config.num_points)
    return fracs, stats
