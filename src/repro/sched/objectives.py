"""Pluggable partitioning objectives over the completion-time frontier.

The companion paper ("Partitioning Uncertain Workflows") frames the split
choice as an objective over the (mean, variance) frontier.  One ``Objective``
value now encodes that choice everywhere — the K-simplex optimizer
(``sched.solve_fractions``), the two-way frontier sweep
(``frontier.optimal_two_way_fraction``), microbatch quantization, and the
serve path — replacing the three divergent encodings (``objective=`` strings,
``risk_aversion=`` floats, hard-coded ``E + ra*Var``) that used to live in
``frontier.py`` and ``partitioner.py``.

An ``Objective`` is a frozen, hashable dataclass, so it rides through
``jax.jit`` as a static argument; scores are pure jnp and differentiable
(``smooth=True`` swaps hard constraints/indicators for their soft relaxations
so the simplex optimizer can follow gradients).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

Array = jax.Array

# Hard-constraint violations are scored BIG + violation instead of inf so that
# argmin still orders infeasible points (and never returns NaN from inf-inf).
_BIG = 1e9


@dataclasses.dataclass(frozen=True)
class Objective:
    """What "best split" means.  Lower score is better.

    kind:
      "mean"        — E[t]                          (fastest expected)
      "mean_var"    — E[t] + risk_aversion * Var[t] (risk-sensitive)
      "var_budget"  — min E[t]  s.t.  Var[t] <= var_budget
      "deadline"    — max P(t <= deadline)          (QoS quantile target)

    One value encodes the choice for every consumer — the simplex solver,
    the two-way frontier sweep, quantization, and the serve path:

    >>> obj = Objective.mean_var(0.5)
    >>> float(obj.score_moments(10.0, 4.0))        # E + 0.5 * Var
    12.0
    >>> feasible = Objective.variance_budget(5.0)
    >>> bool(feasible.score_moments(10.0, 4.0) < feasible.score_moments(9.0, 6.0))
    True
    >>> Objective.deadline_quantile(30.0).needs_cdf()  # moment form not enough
    True
    """

    kind: str = "mean"
    risk_aversion: float = 0.0
    var_budget: float = math.inf
    deadline: float = 0.0

    def __post_init__(self):
        if self.kind not in ("mean", "mean_var", "var_budget", "deadline"):
            raise ValueError(f"unknown objective kind {self.kind!r}")

    # -- constructors --------------------------------------------------------
    @staticmethod
    def mean() -> "Objective":
        return Objective(kind="mean")

    @staticmethod
    def mean_var(risk_aversion: float) -> "Objective":
        return Objective(kind="mean_var", risk_aversion=float(risk_aversion))

    @staticmethod
    def variance_budget(var_budget: float) -> "Objective":
        return Objective(kind="var_budget", var_budget=float(var_budget))

    @staticmethod
    def deadline_quantile(deadline: float) -> "Objective":
        return Objective(kind="deadline", deadline=float(deadline))

    @staticmethod
    def from_legacy(
        objective: str,
        risk_aversion: float = 0.0,
        var_budget: float = math.inf,
        deadline: float = 0.0,
    ) -> "Objective":
        """Map the old ``frontier.optimal_two_way_fraction`` string API."""
        kind = {"constrained": "var_budget"}.get(objective, objective)
        return Objective(
            kind=kind,
            risk_aversion=float(risk_aversion),
            var_budget=float(var_budget),
            deadline=float(deadline),
        )

    # -- scoring -------------------------------------------------------------
    def score_moments(self, e_t: Array, var: Array, *, smooth: bool = False) -> Array:
        """Score from completion-time moments alone (broadcasts elementwise).

        Only valid for the moment-based kinds; "deadline" needs the full CDF —
        use :func:`evaluate` (or :meth:`needs_cdf` to dispatch).
        """
        return score_moments_dynamic(
            self.kind, e_t, var, self.risk_aversion, self.var_budget,
            smooth=smooth,
        )

    def needs_cdf(self) -> bool:
        return self.kind == "deadline"


def as_stage_objectives(objectives, num_stages: int) -> tuple:
    """Normalize a per-stage objective spec to a validated tuple.

    Accepts a single ``Objective`` (broadcast to every stage) or a sequence
    with exactly one entry per stage.  The result is a plain tuple of frozen
    ``Objective`` values, so it is hashable and rides through ``jax.jit`` as
    a static argument (``sched.propose_dag(objectives=...)``,
    ``sched.quantize_dag_fractions(objectives=...)``).

    >>> objs = as_stage_objectives(Objective.mean(), 2)
    >>> len(objs), objs[0].kind
    (2, 'mean')
    >>> len(as_stage_objectives((Objective.mean(), Objective.mean_var(0.5)), 2))
    2
    """
    if isinstance(objectives, Objective):
        return (objectives,) * num_stages
    objectives = tuple(objectives)
    if len(objectives) != num_stages:
        raise ValueError(
            f"need one objective per stage: got {len(objectives)} "
            f"for {num_stages} stages"
        )
    for o in objectives:
        if not isinstance(o, Objective):
            raise TypeError(f"expected Objective, got {type(o).__name__}")
    return objectives


def score_moments_dynamic(
    kind: str,
    e_t: Array,
    var: Array,
    risk_aversion,
    var_budget,
    *,
    smooth: bool = False,
) -> Array:
    """Moment-based scoring with the floats as (possibly traced) values.

    ``Objective.score_moments`` bakes its floats in as jit-static constants —
    right for the scheduler, whose objective rarely changes.  Callers that
    sweep the risk/budget parameter (e.g. tracing a tradeoff curve through
    ``frontier.optimal_two_way_fraction``) use this form so only ``kind``
    is static and every parameter value reuses one compilation.
    """
    if kind == "mean":
        return e_t
    if kind == "mean_var":
        return e_t + risk_aversion * var
    if kind == "var_budget":
        excess = var - var_budget
        if smooth:
            # softplus barrier keeps the score differentiable; the sharp
            # scale makes the feasible region's boundary steep.
            return e_t + jax.nn.softplus(20.0 * excess)
        return jnp.where(excess <= 0, e_t, _BIG + excess)
    raise ValueError(f"objective {kind!r} is not moment-based")


def evaluate(
    objective: Objective,
    fracs: Array,
    params,
    *,
    num_points: int = 512,
    smooth: bool = False,
    risk_aversion=None,
    var_budget=None,
    deadline=None,
) -> Array:
    """Score one fraction vector (K,) on the simplex.  Lower is better.

    Pure and differentiable in ``fracs``; ``objective`` must be static under
    jit.  ``params`` is a ``frontier.UnitParams``.

    ``risk_aversion`` / ``var_budget`` / ``deadline``, when given, override
    the objective's static floats with (possibly traced) values — only the
    KIND stays jit-static.  This is how the DAG partitioner hands each stage
    its own slice of a shared risk budget or end-to-end deadline without one
    compilation per stage (``sched.dag.propose_dag`` vmaps over stages).
    """
    from repro.core.frontier import completion_cdf, mean_var_completion

    if objective.needs_cdf():
        d = objective.deadline if deadline is None else deadline
        p_meet = completion_cdf(jnp.asarray(d, fracs.dtype), fracs, params)
        if smooth:
            return -jnp.log(jnp.maximum(p_meet, 1e-12))
        return -p_meet
    e_t, var = mean_var_completion(fracs, params, num_points)
    return score_moments_dynamic(
        objective.kind,
        e_t,
        var,
        objective.risk_aversion if risk_aversion is None else risk_aversion,
        objective.var_budget if var_budget is None else var_budget,
        smooth=smooth,
    )
