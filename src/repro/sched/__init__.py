"""Pure-functional online scheduler API (state-in/state-out).

The paper's Bayesian partitioner re-cast as explicit pytree state plus pure
transitions — every entry point is jit-compatible, vmappable across tenant
fleets, and checkpointable through ``repro.checkpoint.CheckpointManager``:

    state = sched.init(config, num_workers, key)
    state, ll     = sched.observe(state, telemetry, config)
    fracs, stats  = sched.propose(state, config)
    state, scores = sched.anomaly(state, telemetry, config)

``Scheduler`` is the thin imperative shell (config + current state) used by
the trainer/server loops; ``repro.core.HeterogeneityAwarePartitioner`` is the
deprecated legacy wrapper delegating here.
"""
from .objectives import Objective
from .quantize import quantize_fractions
from .scheduler import (
    ProposeStats,
    Scheduler,
    SchedulerConfig,
    SchedulerState,
    Telemetry,
    add_workers,
    anomaly,
    flag_stragglers,
    init,
    num_workers,
    observe,
    propose,
    remove_workers,
    solve_fractions,
    unit_params,
)

__all__ = [
    "Objective",
    "ProposeStats",
    "Scheduler",
    "SchedulerConfig",
    "SchedulerState",
    "Telemetry",
    "add_workers",
    "anomaly",
    "flag_stragglers",
    "init",
    "num_workers",
    "observe",
    "propose",
    "quantize_fractions",
    "remove_workers",
    "solve_fractions",
    "unit_params",
]
