"""Pure-functional online scheduler API (state-in/state-out).

The paper's Bayesian partitioner re-cast as explicit pytree state plus pure
transitions — every entry point is jit-compatible, vmappable across tenant
fleets, and checkpointable through ``repro.checkpoint.CheckpointManager``.
The full cycle — learn from telemetry, propose a split, score anomalies:

>>> import jax, jax.numpy as jnp
>>> from repro import sched
>>> config = sched.SchedulerConfig(n_iters=2, grid_size=32, num_points=64,
...                                opt_steps=10)
>>> state = sched.init(config, num_workers=3, key=jax.random.PRNGKey(0))
>>> f = jax.random.uniform(jax.random.PRNGKey(1), (3, 16), minval=0.1,
...                        maxval=0.9)
>>> t = f**0.9 * jnp.asarray([[5.0], [10.0], [20.0]])   # hidden unit speeds
>>> telemetry = sched.Telemetry(fracs=f, times=t)
>>> state, ll = sched.observe(state, telemetry, config)
>>> fracs, stats = sched.propose(state, config)
>>> fracs.shape, bool(abs(float(jnp.sum(fracs)) - 1.0) < 1e-5)
((3,), True)
>>> state, scores = sched.anomaly(state, telemetry, config)
>>> scores.shape
(3,)

``Scheduler`` is the thin imperative shell (config + current state) used by
the trainer/server loops; ``repro.core.HeterogeneityAwarePartitioner`` is the
deprecated legacy wrapper delegating here.

Multi-stage pipelines lift the same API to workflow DAGs (``repro.sched.dag``):

    state = sched.init_dag(config, dag, key)          # dag: WorkflowDAG
    state, ll     = sched.observe_dag(state, telemetry, config)  # (S, K, N)
    fracs, stats  = sched.propose_dag(state, dag, config)        # (S, K)

Estimation of the whole DAG is ONE stacked (S, K, N) program — the stage
axis folds into the fleet axis, never a Python loop over stages.

Fleet-axis scale-out (multi-device / multi-host; see ``docs/scaling.md``):
``SchedulerConfig.mesh`` takes a ``ShardingConfig`` and the SAME transitions
partition the worker axis across a device mesh with ``shard_map`` — results
match the single-device program bitwise, so it composes with everything
above (checkpointing, vmap-over-tenants, DAGs):

>>> mesh = sched.ShardingConfig.auto()       # 1-D mesh over local devices
>>> sconfig = sched.SchedulerConfig(n_iters=2, grid_size=32, mesh=mesh)
>>> sstate = sched.init(sconfig, num_workers=3, key=jax.random.PRNGKey(0))
>>> sstate, sll = sched.observe(sstate, telemetry, sconfig)
>>> bool(jnp.all(sstate.gibbs.key == state.gibbs.key))  # PRNG: bitwise
True
>>> bool(jnp.max(jnp.abs(sll - ll))                     # posteriors: fp-close
...      <= 1e-3 * (1.0 + jnp.max(jnp.abs(ll))))
True
"""
from .dag import (
    DagProposeStats,
    DagState,
    WorkflowDAG,
    dag_stats,
    effective_stage_moments,
    init_dag,
    observe_dag,
    path_lengths,
    propose_dag,
    stage_params,
    uniform_fractions,
)
from repro.core.sharding import ShardingConfig

from .objectives import Objective, as_stage_objectives
from .quantize import quantize_dag_fractions, quantize_fractions
from .scheduler import (
    ProposeStats,
    Scheduler,
    SchedulerConfig,
    SchedulerState,
    Telemetry,
    add_workers,
    admit_workers,
    advance_fleet,
    anomaly,
    capacity,
    flag_stragglers,
    grow_capacity,
    init,
    num_workers,
    observe,
    propose,
    remove_workers,
    retire_workers,
    solve_fractions,
    unit_params,
    unit_params_from_gibbs,
)

__all__ = [
    "DagProposeStats",
    "DagState",
    "Objective",
    "ProposeStats",
    "Scheduler",
    "SchedulerConfig",
    "SchedulerState",
    "ShardingConfig",
    "Telemetry",
    "WorkflowDAG",
    "add_workers",
    "admit_workers",
    "advance_fleet",
    "anomaly",
    "as_stage_objectives",
    "capacity",
    "dag_stats",
    "effective_stage_moments",
    "flag_stragglers",
    "grow_capacity",
    "init",
    "init_dag",
    "num_workers",
    "observe",
    "observe_dag",
    "path_lengths",
    "propose",
    "propose_dag",
    "quantize_dag_fractions",
    "quantize_fractions",
    "remove_workers",
    "retire_workers",
    "solve_fractions",
    "stage_params",
    "uniform_fractions",
    "unit_params",
    "unit_params_from_gibbs",
]
