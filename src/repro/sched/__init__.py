"""Pure-functional online scheduler API (state-in/state-out).

The paper's Bayesian partitioner re-cast as explicit pytree state plus pure
transitions — every entry point is jit-compatible, vmappable across tenant
fleets, and checkpointable through ``repro.checkpoint.CheckpointManager``:

    state = sched.init(config, num_workers, key)
    state, ll     = sched.observe(state, telemetry, config)
    fracs, stats  = sched.propose(state, config)
    state, scores = sched.anomaly(state, telemetry, config)

``Scheduler`` is the thin imperative shell (config + current state) used by
the trainer/server loops; ``repro.core.HeterogeneityAwarePartitioner`` is the
deprecated legacy wrapper delegating here.

Multi-stage pipelines lift the same API to workflow DAGs (``repro.sched.dag``):

    state = sched.init_dag(config, dag, key)          # dag: WorkflowDAG
    state, ll     = sched.observe_dag(state, telemetry, config)  # (S, K, N)
    fracs, stats  = sched.propose_dag(state, dag, config)        # (S, K)

Estimation of the whole DAG is ONE stacked (S, K, N) program — the stage
axis folds into the fleet axis, never a Python loop over stages.
"""
from .dag import (
    DagProposeStats,
    DagState,
    WorkflowDAG,
    dag_stats,
    init_dag,
    observe_dag,
    path_lengths,
    propose_dag,
    stage_params,
    uniform_fractions,
)
from .objectives import Objective
from .quantize import quantize_fractions
from .scheduler import (
    ProposeStats,
    Scheduler,
    SchedulerConfig,
    SchedulerState,
    Telemetry,
    add_workers,
    anomaly,
    flag_stragglers,
    init,
    num_workers,
    observe,
    propose,
    remove_workers,
    solve_fractions,
    unit_params,
    unit_params_from_gibbs,
)

__all__ = [
    "DagProposeStats",
    "DagState",
    "Objective",
    "ProposeStats",
    "Scheduler",
    "SchedulerConfig",
    "SchedulerState",
    "Telemetry",
    "WorkflowDAG",
    "add_workers",
    "anomaly",
    "dag_stats",
    "flag_stragglers",
    "init",
    "init_dag",
    "num_workers",
    "observe",
    "observe_dag",
    "path_lengths",
    "propose",
    "propose_dag",
    "quantize_fractions",
    "remove_workers",
    "solve_fractions",
    "stage_params",
    "uniform_fractions",
    "unit_params",
    "unit_params_from_gibbs",
]
