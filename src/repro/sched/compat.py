"""Legacy partitioner API, kept for compatibility with pre-sched callers.

The online partitioning API lives in ``repro.sched`` as a pure-functional
state-in/state-out design (pytree ``SchedulerState``, pluggable ``Objective``,
jit/vmap/checkpoint-friendly transitions).  This module keeps the original
``repro.core.partitioner`` entry points working:

  * ``optimize_fractions`` / ``quantize_fractions`` — thin delegates with the
    legacy ``risk_aversion`` float mapped onto ``Objective.mean_var``;
  * ``WorkerTelemetry`` — alias of ``sched.Telemetry``;
  * ``HeterogeneityAwarePartitioner`` — deprecated wrapper around
    ``sched.Scheduler`` (emits ``DeprecationWarning`` on construction).

It lives in ``sched`` (not ``core``) because it *wraps* the scheduler: the
implementation imports upward from nowhere — ``repro.core.frontier`` is a
layer below, the rest is same-layer — so the layer map in
``tools/reprolint/layers.toml`` holds.  ``repro.core.partitioner`` re-exports
these names lazily for the old import path.

New code should import from ``repro.sched`` directly.
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple

import jax
import numpy as np

from repro.core.frontier import UnitParams

from .objectives import Objective
from .quantize import quantize_fractions as _quantize
from .scheduler import Scheduler, SchedulerConfig, Telemetry, solve_fractions

Array = jax.Array

# Legacy name: telemetry batches are plain (fracs, times) pairs.
WorkerTelemetry = Telemetry


def _legacy_objective(risk_aversion: float) -> Objective:
    return Objective.mean_var(risk_aversion) if risk_aversion else Objective.mean()


def optimize_fractions(
    params: UnitParams,
    *,
    risk_aversion: float = 0.0,
    steps: int = 300,
    lr: float = 0.05,
) -> Tuple[Array, Array, Array]:
    """Frontier point on the K-simplex: min E[max_k t_k] + ra * Var.

    Legacy signature; delegates to ``sched.solve_fractions``.
    Returns (fractions, expected_makespan, variance).
    """
    fracs, stats = solve_fractions(
        params, objective=_legacy_objective(risk_aversion), steps=steps, lr=lr
    )
    return fracs, stats.e_t, stats.var


def quantize_fractions(
    fracs: np.ndarray,
    total_microbatches: int,
    params: Optional[UnitParams] = None,
    risk_aversion: float = 0.0,
    min_per_worker: int = 1,
    refine_passes: int = 4,
) -> np.ndarray:
    """Round simplex fractions to integer microbatch counts summing to total.

    Legacy signature; delegates to ``sched.quantize_fractions`` (batched
    on-device refinement).
    """
    return _quantize(
        fracs,
        total_microbatches,
        params,
        objective=_legacy_objective(risk_aversion),
        min_per_worker=min_per_worker,
        refine_passes=refine_passes,
    )


class HeterogeneityAwarePartitioner(Scheduler):
    """Deprecated: use ``repro.sched.Scheduler`` (or the pure functions).

    Preserves the original constructor and the mutable ``risk_aversion``
    attribute; everything else is inherited from the functional shell.
    """

    def __init__(
        self,
        num_workers: int,
        *,
        seed: int = 0,
        risk_aversion: float = 0.0,
        n_iters: int = 20,
        grid_size: int = 256,
        mu_guess: float = 1.0,
        discount: float = 0.9,
    ):
        warnings.warn(
            "HeterogeneityAwarePartitioner is deprecated; use "
            "repro.sched.Scheduler or the pure repro.sched API",
            DeprecationWarning,
            stacklevel=2,
        )
        super().__init__(
            num_workers,
            config=SchedulerConfig(
                objective=_legacy_objective(risk_aversion),
                n_iters=n_iters,
                grid_size=grid_size,
                mu_guess=mu_guess,
                discount=discount,
            ),
            seed=seed,
        )

    @property
    def risk_aversion(self) -> float:
        return self.config.objective.risk_aversion

    @risk_aversion.setter
    def risk_aversion(self, value: float) -> None:
        self.objective = _legacy_objective(value)
