"""Pure-functional online Bayesian scheduler (state-in/state-out).

``SchedulerState`` is a pytree (NamedTuple of arrays): the vmapped per-worker
``GibbsState`` fleet, the EWMA anomaly scores, a step counter, and a PRNG key.
All transitions are pure —

    init(config, num_workers, key)            -> state
    observe(state, telemetry, config)         -> (state, ll)
    propose(state, config)                    -> (fractions, stats)
    anomaly(state, telemetry, config)         -> (state, scores)

— so they jit, vmap across tenants, and checkpoint through the existing
``CheckpointManager`` pytree path with no special cases.  Elastic membership
(``add_workers`` / ``remove_workers``) changes leaf shapes and therefore
lives outside jit, but is still pure state-in/state-out.

The fraction solver fixes the legacy ``optimize_fractions`` failure mode:
softmax-logits descent initialized at ``f ∝ 1/mu`` could slide onto a
degenerate simplex vertex under freshly-chained posteriors (sub-linear
sampled alphas flatten the objective, and vertices are softmax attractors).
``solve_fractions`` instead (i) starts from the makespan-equalizing split
solved by bisection *with the current alpha estimates*, (ii) refines by Adam
on logits, and (iii) keeps whichever of {equalizing, uniform, refined}
candidates actually scores best — descent can only improve the proposal,
never destroy it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gibbs
from repro.core.frontier import UnitParams, mean_var_completion
from repro.core.posterior import posterior_predictive_logpdf
from repro.core.sharding import ShardingConfig, constrain_fleet

from .objectives import Objective, evaluate

Array = jax.Array


class Telemetry(NamedTuple):
    """One batch of per-worker observations: fractions worked, times taken."""

    fracs: Array  # (K, N) workload fraction each worker processed
    times: Array  # (K, N) measured completion times


class SchedulerState(NamedTuple):
    """Everything the scheduler has learned; a registered pytree.

    Leaves carry a leading worker axis K where per-worker (``gibbs``,
    ``ewma_ll``) and are scalars otherwise, so a multi-tenant fleet is just
    one more leading axis added by ``jax.vmap``.
    """

    gibbs: gibbs.GibbsState  # per-worker posteriors, leaves (K, ...)
    ewma_ll: Array  # (K,) EWMA of negative predictive log-likelihood
    ewma_count: Array  # (K,) anomaly updates folded into each worker's EWMA
    step: Array  # scalar, observe() calls so far
    key: Array  # scheduler-level PRNG key
    live: Optional[Array] = None  # (K,) float {0, 1} capacity-slot mask; None
    # = every slot live (bitwise-legacy).  Allocated by ``init(capacity=...)``
    # and flipped in place by the jit-native ``admit_workers`` /
    # ``retire_workers`` slot transitions — fleet membership then changes
    # with no K-sized host hop and no leaf reshapes (so jit never retraces
    # until capacity itself grows via ``grow_capacity``).


class ProposeStats(NamedTuple):
    """Frontier statistics of a proposed split."""

    e_t: Array  # expected makespan at the proposal
    var: Array  # completion-time variance at the proposal
    score: Array  # objective score (lower is better)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Static hyperparameters; hashable, passed through jit as static."""

    objective: Objective = Objective()
    n_iters: int = 20  # Gibbs sweeps per telemetry batch
    grid_size: int = 256  # exponent-posterior grid resolution
    use_pallas: Optional[bool] = None  # route estimation through the fused
    # fleet kernel; None = auto by backend (TPU: Mosaic kernels, else oracle)
    mesh: Optional[ShardingConfig] = None  # shard the fleet axis across a
    # device mesh (observe/observe_dag run one shard_map'd program; state
    # leaves carry fleet shardings); None = single-device, bitwise-legacy.
    # A bare jax.sharding.Mesh is accepted and wrapped (axis "workers").
    discount: float = 0.9  # power-prior forgetting factor
    mu_guess: float = 1.0  # prior center for per-unit mean time
    ewma: float = 0.8  # anomaly-score smoothing
    opt_steps: int = 200  # Adam steps of the simplex refinement
    opt_lr: float = 0.05
    num_points: int = 512  # quadrature points for objective evaluation
    min_fraction: float = 5e-3  # proposal floor per worker (see solve_fractions)
    hierarchical: bool = False  # pool strength across the fleet (repro.hier):
    # add_workers admits newcomers from the empirical-Bayes fleet hyperprior
    # instead of the global prior, and the serve loop's drift gate scores
    # per-worker surprise against it.  False = bitwise-legacy everywhere.
    hyper_strength: float = 8.0  # fleet-prior pseudo-observations: a worker
    # needs ~this many of its own observations to outvote the pool (shrink)
    hyper_refit_every: int = 4  # drains between hyperprior refits (serve/train)

    def __post_init__(self):
        if self.mesh is not None and not isinstance(self.mesh, ShardingConfig):
            object.__setattr__(self, "mesh", ShardingConfig(mesh=self.mesh))


# --------------------------------------------------------------------------
# transitions
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("config", "num_workers", "capacity"))
def init(
    config: SchedulerConfig,
    num_workers: int,
    key: Array,
    capacity: Optional[int] = None,
) -> SchedulerState:
    """Fresh beliefs for a K-worker fleet.

    ``capacity`` allocates that many worker slots up front (must be >=
    ``num_workers``): leaves are sized (capacity, ...), the first
    ``num_workers`` slots are live, and membership changes run through the
    jit-native ``admit_workers`` / ``retire_workers`` transitions without
    reshaping a single leaf.  ``capacity=None`` is the legacy exact-size
    state with no live mask.
    """
    if capacity is None:
        slots, live = num_workers, None
    else:
        if capacity < num_workers:
            raise ValueError(f"{capacity=} < {num_workers=}")
        slots = capacity
        live = (jnp.arange(capacity) < num_workers).astype(jnp.float32)
    key, sub = jax.random.split(key)
    keys = jax.random.split(sub, slots)
    fleet = jax.vmap(
        lambda k: gibbs.init_state(k, mu_guess=config.mu_guess)
    )(keys)
    return SchedulerState(
        # With config.mesh the fleet leaves carry NamedShardings from birth,
        # so the telemetry->estimate->propose cycle never reshuffles them and
        # checkpointing (np.asarray gathers) works unchanged.
        gibbs=constrain_fleet(fleet, config.mesh),
        ewma_ll=constrain_fleet(
            jnp.zeros((slots,), jnp.float32), config.mesh
        ),
        ewma_count=constrain_fleet(
            jnp.zeros((slots,), jnp.int32), config.mesh
        ),
        step=jnp.zeros((), jnp.int32),
        key=key,
        live=constrain_fleet(live, config.mesh) if live is not None else None,
    )


def advance_fleet(
    fleet: gibbs.GibbsState,
    times: Array,
    fracs: Array,
    config: SchedulerConfig,
    mask: Optional[Array] = None,
    active_idx: Optional[Array] = None,
) -> Tuple[gibbs.GibbsState, Array]:
    """The one fleet-advance path: discount -> fleet-native ``gibbs_batch``.

    Shared by ``observe`` (flat K-worker fleet), ``dag.observe_dag``
    (stage-folded S*K fleet) and the push-mode serving loop
    (``repro.serve``, whole-ring drains with a masked tail) so the
    estimation semantics cannot diverge.  Resolves ``config.use_pallas=None``
    to the backend default; threads ``config.mesh`` so a sharded scheduler
    advances each worker's chain on the device that owns it
    (``gibbs_batch``'s ``shard_map`` path).

    ``active_idx`` routes the advance through the compressed active-set path
    (``core.compress``): the (M,) selected rows get the full grid program,
    everyone else the grid-free surrogate sweep.  Power-prior forgetting of
    the exponent Beta priors pairs with the grid re-fit that re-tightens
    them, so surrogate workers skip BOTH — their frozen Beta fit neither
    widens nor re-learns until they re-enter the active set.  The conjugate
    Normal-Gamma block discounts for every worker as usual.
    """
    use_pallas = config.use_pallas
    if use_pallas is None:
        from repro.kernels.ops import use_pallas_default

        use_pallas = use_pallas_default()
    discounted = gibbs.discount_state(fleet, config.discount)
    if active_idx is not None and times.ndim >= 2:
        onehot = (
            jnp.zeros(times.shape[:1], jnp.float32).at[active_idx].set(1.0)
        )
        freeze = lambda orig, disc: jnp.where(onehot > 0, disc, orig)
        pick = lambda o, d: type(o)(freeze(o.a, d.a), freeze(o.b, d.b))
        discounted = discounted._replace(
            alpha_prior=pick(fleet.alpha_prior, discounted.alpha_prior),
            beta_prior=pick(fleet.beta_prior, discounted.beta_prior),
        )
    return gibbs.gibbs_batch(
        discounted,
        times,
        fracs,
        mask,
        n_iters=config.n_iters,
        grid_size=config.grid_size,
        use_pallas=use_pallas,
        sharding=None if active_idx is not None else config.mesh,
        active_idx=active_idx,
    )


@functools.partial(jax.jit, static_argnames=("config",))
def observe(
    state: SchedulerState,
    telemetry: Telemetry,
    config: SchedulerConfig = SchedulerConfig(),
    mask: Optional[Array] = None,
) -> Tuple[SchedulerState, Array]:
    """Gibbs-update every worker's posterior from one telemetry batch.

    The power-prior forgetting factor is applied before the batch so the
    estimator tracks drifting systems.  Returns per-worker log-likelihood.

    The whole fleet advances through the fleet-native ``gibbs_batch`` — no
    per-worker vmap — so with the Pallas path enabled (``config.use_pallas``,
    auto-on for TPU backends) each sweep's grid posterior is ONE kernel
    launch covering every worker and both exponents.

    ``mask`` optionally invalidates telemetry elements (same shape as
    ``telemetry.times``): masked slots — a ring drain's padded tail, a
    failed worker's garbage times — are exact no-ops on every posterior.

    On a capacity-slot state (``state.live`` is not None) dead slots are
    masked out automatically: whatever telemetry their rows carry is an
    exact no-op on their parked posteriors.
    """
    if state.live is not None:
        lv = state.live[:, None]
        mask = lv if mask is None else jnp.broadcast_to(mask, telemetry.times.shape) * lv
    fleet, ll = advance_fleet(
        state.gibbs, telemetry.times, telemetry.fracs, config, mask=mask
    )
    return state._replace(gibbs=fleet, step=state.step + 1), ll


def unit_params_from_gibbs(
    st: gibbs.GibbsState, *, use_samples: bool = False
) -> UnitParams:
    """Point estimates from a (possibly batched) ``GibbsState``.

    Leaves of any leading shape pass through unchanged — (K,) for a fleet,
    (S, K) for a stage-stacked workflow DAG.
    """
    if use_samples:
        return UnitParams(mu=st.mu, sigma=st.sigma, alpha=st.alpha, beta=st.beta)
    ng = st.ng
    lam_mean = ng.nu0 / jnp.maximum(ng.psi0, 1e-30)
    return UnitParams(
        mu=ng.mu0,
        sigma=1.0 / jnp.sqrt(jnp.maximum(lam_mean, 1e-30)),
        alpha=st.alpha_prior.a / (st.alpha_prior.a + st.alpha_prior.b),
        beta=st.beta_prior.a / (st.beta_prior.a + st.beta_prior.b),
    )


def unit_params(state: SchedulerState, *, use_samples: bool = False) -> UnitParams:
    """Current point estimates as frontier parameters.

    By default uses the chained posterior MEANS (Normal-Gamma for (mu, sigma),
    Beta for the exponents) — the Bayes decision point — rather than the last
    Gibbs samples.  Samples are the right thing inside the chain, but as
    partitioning inputs their noise is destructive: one vague-prior draw
    (mu ~ N(mu0, (1e-3 lam)^-1) before any data) can swing a worker's
    apparent speed by orders of magnitude and lock the fleet into a
    pathological split before the estimator ever sees real telemetry.
    """
    return unit_params_from_gibbs(state.gibbs, use_samples=use_samples)


def _equalizing_fractions(
    params: UnitParams, live: Optional[Array] = None
) -> Array:
    """Makespan-equalizing split: find tau with sum_k (tau/mu_k)^(1/alpha_k) = 1.

    Solved by bisection in log-space (the sum is monotone in tau); exact for
    zero variance, and a robust interior starting point otherwise.  Unlike the
    legacy ``f ∝ 1/mu`` heuristic this respects the scaling exponents, so
    sub-linear alpha estimates no longer mislead the optimizer.

    ``live`` (a (K,) {0, 1} mask) excludes dead capacity slots: they get
    exactly zero and never enter the bisection sum.
    """
    mu = jnp.maximum(params.mu, 1e-6)
    alpha = jnp.clip(params.alpha, 0.05, 1.0)
    log_mu = jnp.log(mu)
    lv = jnp.ones_like(mu) if live is None else live.astype(mu.dtype)

    def frac_sum(log_tau):
        log_f = jnp.clip((log_tau - log_mu) / alpha, -60.0, 0.0)
        return jnp.sum(lv * jnp.exp(log_f))

    # At tau = max over live mu: f_k >= 1 for the slowest live unit -> sum >= 1.
    hi0 = jnp.max(jnp.where(lv > 0, log_mu, -jnp.inf))
    lo0 = hi0 - 60.0

    def bisect(carry, _):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        too_big = frac_sum(mid) > 1.0
        return (jnp.where(too_big, lo, mid), jnp.where(too_big, mid, hi)), None

    (lo, hi), _ = jax.lax.scan(bisect, (lo0, hi0), None, length=50)
    log_tau = 0.5 * (lo + hi)
    f = lv * jnp.exp(jnp.clip((log_tau - log_mu) / alpha, -60.0, 0.0))
    return f / jnp.maximum(jnp.sum(f), 1e-30)


@functools.partial(
    jax.jit, static_argnames=("objective", "steps", "num_points", "min_fraction")
)
def solve_fractions(
    params: UnitParams,
    *,
    objective: Objective = Objective(),
    steps: int = 200,
    lr: float = 0.05,
    num_points: int = 512,
    min_fraction: float = 5e-3,
    risk_aversion=None,
    var_budget=None,
    deadline=None,
    live: Optional[Array] = None,
) -> Tuple[Array, ProposeStats]:
    """Objective-optimal fractions on the K-simplex (see module docstring).

    ``live`` (a (K,) {0, 1} capacity-slot mask) restricts the solve to live
    workers: dead slots get exactly zero fraction (their logits are pinned at
    -inf through the softmax and the ``min_fraction`` floor skips them), and
    neither the equalizing init nor the objective ever consults their parked
    posteriors.

    Proposals are floored at ``min_fraction`` per worker: SPMD quantization
    gives every live worker at least one microbatch anyway, and telemetry at
    f -> 0 carries unbounded weight f^(alpha-2beta) in the Normal-Gamma
    update — one near-zero assignment could poison a worker's posterior
    (kappa -> 1e9 at a garbage mu) beyond recovery.

    ``risk_aversion`` / ``var_budget`` / ``deadline`` optionally override the
    objective's static parameter floats with traced values (see
    ``objectives.evaluate``) — the DAG partitioner uses this to vmap one
    compiled solve across stages that each own a different budget slice.

    Returns (fractions, ProposeStats).  Jit-compatible; ``objective`` static.
    """
    overrides = dict(
        risk_aversion=risk_aversion, var_budget=var_budget, deadline=deadline
    )
    if live is not None:
        # Park dead slots on benign interior parameters so their (ignored)
        # rows cannot poison the quadrature with extreme magnitudes.
        lv = live > 0
        params = UnitParams(
            mu=jnp.where(lv, params.mu, 1.0),
            sigma=jnp.where(lv, params.sigma, 1e-3),
            alpha=jnp.where(lv, params.alpha, 0.5),
            beta=jnp.where(lv, params.beta, 0.5),
        )
    f_eq = _equalizing_fractions(params, live)
    k = f_eq.shape[0]
    if live is None:
        f_uni = jnp.full((k,), 1.0 / k, f_eq.dtype)
    else:
        n_live = jnp.maximum(jnp.sum(live), 1.0)
        f_uni = live.astype(f_eq.dtype) / n_live

    def smooth_loss(logits):
        if live is not None:
            logits = jnp.where(live > 0, logits, -1e9)
        fracs = jax.nn.softmax(logits)
        return evaluate(
            objective, fracs, params, num_points=num_points, smooth=True,
            **overrides,
        )

    grad = jax.grad(smooth_loss)
    logits0 = jnp.log(jnp.maximum(f_eq, 1e-9))

    def adam_step(carry, _):
        logits, m, v, t = carry
        g = grad(logits)
        t = t + 1.0
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1.0 - 0.9**t)
        vh = v / (1.0 - 0.999**t)
        logits = logits - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return (logits, m, v, t), None

    init_carry = (logits0, jnp.zeros((k,)), jnp.zeros((k,)), jnp.asarray(0.0))
    (logits, _, _, _), _ = jax.lax.scan(adam_step, init_carry, None, length=steps)
    if live is not None:
        logits = jnp.where(live > 0, logits, -1e9)
    f_ref = jax.nn.softmax(logits)

    # Safeguard: descent may only improve on the analytic candidates.
    cands = jnp.stack([f_ref, f_eq, f_uni])  # (3, K)
    if live is None:
        cands = jnp.maximum(cands, min_fraction)
    else:
        cands = jnp.where(live > 0, jnp.maximum(cands, min_fraction), 0.0)
    cands = cands / jnp.sum(cands, axis=-1, keepdims=True)
    scores = jax.vmap(
        lambda f: evaluate(
            objective, f, params, num_points=num_points, **overrides
        )
    )(cands)
    best = cands[jnp.argmin(scores)]

    e_t, var = mean_var_completion(best, params, num_points)
    return best, ProposeStats(e_t=e_t, var=var, score=jnp.min(scores))


@functools.partial(jax.jit, static_argnames=("config",))
def propose(
    state: SchedulerState, config: SchedulerConfig = SchedulerConfig()
) -> Tuple[Array, ProposeStats]:
    """Objective-optimal fractions under the current beliefs.

    On a capacity-slot state, dead slots receive exactly zero fraction."""
    return solve_fractions(
        unit_params(state),
        objective=config.objective,
        steps=config.opt_steps,
        lr=config.opt_lr,
        num_points=config.num_points,
        min_fraction=config.min_fraction,
        live=state.live,
    )


@functools.partial(jax.jit, static_argnames=("config",))
def anomaly(
    state: SchedulerState,
    telemetry: Telemetry,
    config: SchedulerConfig = SchedulerConfig(),
    valid: Optional[Array] = None,
) -> Tuple[SchedulerState, Array]:
    """EWMA'd negative posterior-predictive log-likelihood per worker.

    High score == recent behaviour inconsistent with the learned model.
    Accepts (K,) single observations or (K, N) batches (averaged over N).

    Freshness is tracked PER WORKER (``ewma_count`` is (K,)): a worker
    admitted after the fleet's first update still gets its EWMA initialized
    at its own first score instead of blended with the zero placeholder —
    the fleet-global scalar used to bias new workers "healthy" and delay
    straggler detection by several EWMA half-lives.

    ``valid`` optionally masks observations (per worker (K,) or per element,
    same shape as ``times``): invalid telemetry — e.g. the non-finite times
    of a hard-failed worker — never touches any EWMA or freshness counter.
    """
    p = unit_params(state)
    lam_mean = 1.0 / jnp.maximum(p.sigma * p.sigma, 1e-30)
    t = jnp.asarray(telemetry.times)
    f = jnp.asarray(telemetry.fracs)
    if valid is None:
        v = jnp.ones(t.shape, jnp.float32)
    else:
        v = jnp.asarray(valid, jnp.float32)
        if v.ndim < t.ndim:  # per-worker (K,) mask over a (K, N) batch
            v = v[..., None]
        v = jnp.broadcast_to(v, t.shape)
    if state.live is not None:
        # Dead capacity slots never touch an EWMA or freshness counter.
        lv = state.live
        v = v * (lv if v.ndim == 1 else lv[:, None])
    # Invalid slots get interior dummy values so inf/nan never reaches the
    # logpdf (0 * inf = nan would leak through the mask otherwise).
    t = jnp.where(v > 0, t, 1.0)
    f = jnp.where(v > 0, f, 0.5)
    ll = jax.vmap(posterior_predictive_logpdf)(
        t, f, p.mu, lam_mean, p.alpha, p.beta
    )
    if ll.ndim > 1:
        n_valid = jnp.sum(v, axis=-1)
        ll = jnp.sum(ll * v, axis=-1) / jnp.maximum(n_valid, 1.0)
        worker_valid = n_valid > 0
    else:
        worker_valid = v > 0
    score = -ll
    fresh = state.ewma_count == 0
    blended = jnp.where(
        fresh, score, config.ewma * state.ewma_ll + (1.0 - config.ewma) * score
    )
    new_ewma = jnp.where(worker_valid, blended, state.ewma_ll)
    state = state._replace(
        ewma_ll=new_ewma,
        ewma_count=state.ewma_count + worker_valid.astype(state.ewma_count.dtype),
    )
    return state, new_ewma


@jax.jit
def flag_stragglers(
    scores: Array, threshold_sigma: float = 3.0, valid: Optional[Array] = None
) -> Array:
    """Workers whose anomaly score is a robust outlier vs the fleet.

    ``valid`` optionally excludes workers (hard failures, just-admitted
    members) from the median/MAD baseline — a dead worker's stale or
    corrupted score must not skew the statistics the LIVE fleet is judged
    against — and excluded workers are never flagged.
    """
    if valid is None:
        med = jnp.median(scores)
        mad = jnp.median(jnp.abs(scores - med)) + 1e-9
        return scores > med + threshold_sigma * 1.4826 * mad
    v = jnp.asarray(valid, bool)
    masked = jnp.where(v, scores, jnp.nan)
    med = jnp.nanmedian(masked)
    mad = jnp.nanmedian(jnp.where(v, jnp.abs(scores - med), jnp.nan)) + 1e-9
    return v & (scores > med + threshold_sigma * 1.4826 * mad)


# --------------------------------------------------------------------------
# elastic membership
# --------------------------------------------------------------------------
def num_workers(state: SchedulerState) -> int:
    """Live fleet size: slot count, or the live-mask sum on a capacity state.

    The capacity path syncs one scalar to the host — O(1), never a K-sized
    transfer.
    """
    if state.live is None:
        return int(state.ewma_ll.shape[0])
    return int(jnp.sum(state.live))


def capacity(state: SchedulerState) -> int:
    """Allocated worker slots (== num_workers when there is no live mask)."""
    return int(state.ewma_ll.shape[0])


# -- capacity-slot transitions (jit-native: no host hop, no leaf reshape) ---
@functools.partial(jax.jit, static_argnames=("count", "config"))
def admit_workers(
    state: SchedulerState,
    count: int,
    config: SchedulerConfig = SchedulerConfig(),
) -> SchedulerState:
    """Admit ``count`` workers into dead capacity slots, entirely on device.

    The lowest-priority (dead) slots are located with one argsort of the
    live mask, re-initialized from fresh priors via scatter, and flipped
    live — leaf shapes never change, so a jitted
    admit -> observe -> propose cycle runs without a single retrace until
    capacity is exhausted (then ``grow_capacity`` is the shape-changing
    fallback).  Slots beyond the available dead count are left untouched
    (the scatter is guarded), so over-admitting clobbers nothing.

    Requires a capacity state (``init(..., capacity=)``); ``count`` is
    static.  Admission draws come from the scheduler's PRNG stream.
    """
    if state.live is None:
        raise ValueError(
            "admit_workers needs a capacity state (init(..., capacity=)); "
            "use add_workers for exact-size fleets"
        )
    key, sub = jax.random.split(state.key)
    # Stable ascending sort puts dead slots (0.0) first, lowest index first.
    idx = jnp.argsort(state.live, stable=True)[:count]
    ok = state.live[idx] == 0.0  # guard: never clobber a live slot

    keys = jax.random.split(sub, count)
    if config.hierarchical:
        from repro import hier

        # Dead slots' parked posteriors are masked out of the pool.
        lv = jnp.broadcast_to(state.live, state.ewma_ll.shape)
        hyper = (
            hier.fit_hyperprior_sharded(state.gibbs, config.mesh, lv)
            if config.mesh is not None
            else hier.fit_hyperprior(state.gibbs, lv)
        )
        fresh = hier.init_from_hyperprior(sub, count, hyper)
    else:
        fresh = jax.vmap(
            lambda k: gibbs.init_state(k, mu_guess=config.mu_guess)
        )(keys)

    put = lambda full, new: full.at[idx].set(
        jnp.where(
            jnp.reshape(ok, ok.shape + (1,) * (new.ndim - 1)), new, full[idx]
        )
    )
    return state._replace(
        gibbs=jax.tree_util.tree_map(put, state.gibbs, fresh),
        ewma_ll=put(state.ewma_ll, jnp.zeros((count,), jnp.float32)),
        ewma_count=put(state.ewma_count, jnp.zeros((count,), jnp.int32)),
        live=put(state.live, jnp.ones((count,), state.live.dtype)),
        key=key,
    )


@jax.jit
def retire_workers(state: SchedulerState, dead: Array) -> SchedulerState:
    """Mark workers dead in place (elastic down-scale, entirely on device).

    ``dead`` is a (capacity,) boolean/0-1 mask.  The slots' posteriors are
    parked (ignored by observe/propose/anomaly via the live mask) and their
    EWMA leaves are zeroed, so a later ``admit_workers`` reusing the slot
    seeds anomaly freshness from scratch (``ewma_count == 0``).
    """
    if state.live is None:
        raise ValueError(
            "retire_workers needs a capacity state (init(..., capacity=)); "
            "use remove_workers for exact-size fleets"
        )
    gone = jnp.asarray(dead).astype(state.live.dtype) > 0
    return state._replace(
        live=jnp.where(gone, 0.0, state.live),
        ewma_ll=jnp.where(gone, 0.0, state.ewma_ll),
        ewma_count=jnp.where(gone, 0, state.ewma_count),
    )


def grow_capacity(
    state: SchedulerState,
    new_capacity: int,
    config: SchedulerConfig = SchedulerConfig(),
) -> SchedulerState:
    """Reallocate a capacity state with more slots (host-side fallback).

    The shape-changing escape hatch for when admissions exhaust capacity:
    leaves are padded with fresh dead slots (prior-initialized posteriors,
    live=0).  Doubling amortizes retraces — jit signatures change only when
    this runs.
    """
    cap = state.ewma_ll.shape[0]
    if state.live is None:
        raise ValueError("grow_capacity needs a capacity state")
    if new_capacity <= cap:
        return state
    extra = new_capacity - cap
    key, sub = jax.random.split(state.key)
    keys = jax.random.split(sub, extra)
    fresh = jax.vmap(
        lambda k: gibbs.init_state(k, mu_guess=config.mu_guess)
    )(keys)
    cat = lambda a, b: jnp.concatenate([jnp.asarray(a), b], axis=0)
    return state._replace(
        gibbs=jax.tree_util.tree_map(cat, state.gibbs, fresh),
        ewma_ll=cat(state.ewma_ll, jnp.zeros((extra,), jnp.float32)),
        ewma_count=cat(state.ewma_count, jnp.zeros((extra,), jnp.int32)),
        live=cat(state.live, jnp.zeros((extra,), state.live.dtype)),
        key=key,
    )


# -- shape-changing path (pure but not jittable) ----------------------------
def remove_workers(state: SchedulerState, dead: np.ndarray) -> SchedulerState:
    """Drop failed workers from the fleet (elastic down-scale)."""
    keep = np.flatnonzero(~np.asarray(dead, bool))
    take = lambda x: jnp.take(x, keep, axis=0)
    return state._replace(
        gibbs=jax.tree_util.tree_map(take, state.gibbs),
        ewma_ll=take(state.ewma_ll),
        ewma_count=take(state.ewma_count),
        live=None if state.live is None else take(state.live),
    )


def add_workers(
    state: SchedulerState,
    count: int,
    config: SchedulerConfig = SchedulerConfig(),
    *,
    key: Optional[Array] = None,
    mu_guess: Optional[float] = None,
    hyper=None,
) -> SchedulerState:
    """Admit new workers with fresh priors (elastic up-scale).

    The new workers' prior draws come from the scheduler's own PRNG stream
    unless an explicit ``key`` is supplied; ``mu_guess`` overrides the
    config's prior center (e.g. seeding admits at the fleet's known speed).

    With ``config.hierarchical`` the newcomers are instead born from the
    empirical-Bayes fleet hyperprior (``repro.hier``): their Normal-Gamma
    and exponent priors are pooled from the incumbents' posteriors
    (refit here unless a pre-fit ``hyper`` is passed), so their first
    ``propose`` already reflects what the fleet knows — the cold-start
    transfer path.  ``hierarchical=False`` is the bitwise-legacy global
    prior.
    """
    if key is None:
        key, sub = jax.random.split(state.key)
    else:
        key, sub = state.key, key
    if config.hierarchical:
        from repro import hier

        if hyper is None:
            hyper = (
                hier.fit_hyperprior_sharded(state.gibbs, config.mesh)
                if config.mesh is not None
                else hier.fit_hyperprior(state.gibbs)
            )
        fresh = hier.init_from_hyperprior(sub, count, hyper)
    else:
        keys = jax.random.split(sub, count)
        guess = config.mu_guess if mu_guess is None else mu_guess
        fresh = jax.vmap(lambda k: gibbs.init_state(k, mu_guess=guess))(keys)
    cat = lambda a, b: jnp.concatenate([jnp.asarray(a), b], axis=0)
    return state._replace(
        gibbs=jax.tree_util.tree_map(cat, state.gibbs, fresh),
        ewma_ll=jnp.concatenate([jnp.asarray(state.ewma_ll), jnp.zeros(count)]),
        # Fresh admits carry ewma_count=0, so their first anomaly score seeds
        # the EWMA directly (per-worker freshness — see ``anomaly``).
        ewma_count=jnp.concatenate(
            [jnp.asarray(state.ewma_count), jnp.zeros(count, jnp.int32)]
        ),
        live=(
            None
            if state.live is None
            else cat(state.live, jnp.ones((count,), state.live.dtype))
        ),
        key=key,
    )


# --------------------------------------------------------------------------
# imperative shell
# --------------------------------------------------------------------------
class Scheduler:
    """Thin imperative shell: config + current ``SchedulerState``.

    All logic lives in the pure functions above; this class only threads the
    state for callers structured as loops (trainer, server, monitor).  The
    ``state`` attribute is the checkpointable pytree — hand it to
    ``CheckpointManager.save`` and assign it back after ``restore``.
    """

    def __init__(
        self,
        num_workers: int,
        *,
        config: Optional[SchedulerConfig] = None,
        seed: int = 0,
        capacity: Optional[int] = None,
        **overrides,
    ):
        config = config or SchedulerConfig()
        if overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self.state = init(
            config, num_workers, jax.random.PRNGKey(seed), capacity
        )

    @property
    def num_workers(self) -> int:
        return num_workers(self.state)

    @property
    def objective(self) -> Objective:
        return self.config.objective

    @objective.setter
    def objective(self, obj: Objective) -> None:
        self.config = dataclasses.replace(self.config, objective=obj)

    # -- estimation --------------------------------------------------------
    def observe(self, telemetry: Telemetry, mask=None) -> Array:
        self.state, ll = observe(
            self.state, telemetry, self.config,
            None if mask is None else jnp.asarray(mask),
        )
        return ll

    def unit_params(self) -> UnitParams:
        return unit_params(self.state)

    # -- partitioning ------------------------------------------------------
    def propose_fractions(self) -> Tuple[np.ndarray, float, float]:
        fracs, stats = propose(self.state, self.config)
        return np.asarray(fracs), float(stats.e_t), float(stats.var)

    def propose_microbatches(
        self, total_microbatches: int, min_per_worker: int = 1
    ) -> np.ndarray:
        from .quantize import quantize_fractions

        fracs, _ = propose(self.state, self.config)
        return quantize_fractions(
            np.asarray(fracs),
            total_microbatches,
            self.unit_params(),
            objective=self.config.objective,
            min_per_worker=min_per_worker,
            live=(
                None
                if self.state.live is None
                else np.asarray(self.state.live) > 0
            ),
        )

    # -- anomaly / straggler detection -------------------------------------
    def anomaly_scores(self, fracs, times, valid=None) -> np.ndarray:
        self.state, scores = anomaly(
            self.state,
            Telemetry(fracs=jnp.asarray(fracs), times=jnp.asarray(times)),
            self.config,
            None if valid is None else jnp.asarray(valid),
        )
        return np.asarray(scores, np.float64)

    def flag_stragglers(self, threshold_sigma: float = 3.0, valid=None) -> np.ndarray:
        if valid is None and self.state.live is not None:
            valid = self.state.live > 0  # dead slots never skew or get flagged
        return np.asarray(
            flag_stragglers(
                self.state.ewma_ll,
                threshold_sigma,
                None if valid is None else jnp.asarray(valid),
            )
        )

    # -- hierarchical pooling (repro.hier) ---------------------------------
    def fit_hyperprior(self):
        """Pool the current per-worker posteriors into a fleet hyperprior."""
        from repro import hier

        if self.config.mesh is not None:
            return hier.fit_hyperprior_sharded(self.state.gibbs, self.config.mesh)
        return hier.fit_hyperprior(self.state.gibbs)

    def shrink(self, hyper=None) -> None:
        """Blend cold workers toward the fleet prior (ESS-weighted)."""
        from repro import hier

        hyper = hyper if hyper is not None else self.fit_hyperprior()
        self.state = self.state._replace(
            gibbs=hier.shrink(
                self.state.gibbs,
                hyper,
                strength=self.config.hyper_strength,
                sharding=self.config.mesh,
            )
        )

    def surprise(self, hyper=None) -> np.ndarray:
        """Per-worker drift scores against the pooled prior."""
        from repro import hier

        hyper = hyper if hyper is not None else self.fit_hyperprior()
        return np.asarray(
            hier.surprise(self.state.gibbs, hyper, sharding=self.config.mesh)
        )

    # -- elastic membership ------------------------------------------------
    @property
    def capacity(self) -> int:
        return capacity(self.state)

    def admit_workers(self, count: int) -> None:
        """Slot-based admission; doubles capacity (host-side) only when full."""
        cap = capacity(self.state)
        free = cap - num_workers(self.state)
        if count > free:
            self.state = grow_capacity(
                self.state, max(2 * cap, cap + count - free), self.config
            )
        self.state = admit_workers(self.state, count, self.config)

    def retire_workers(self, dead: np.ndarray) -> None:
        """Slot-based removal: parks the slots, leaf shapes unchanged."""
        self.state = retire_workers(self.state, jnp.asarray(dead))

    def remove_workers(self, dead: np.ndarray) -> None:
        self.state = remove_workers(self.state, dead)

    def add_workers(
        self,
        count: int,
        seed: Optional[int] = None,
        mu_guess: Optional[float] = None,
    ) -> None:
        key = None if seed is None else jax.random.PRNGKey(seed)
        self.state = add_workers(
            self.state, count, self.config, key=key, mu_guess=mu_guess
        )
