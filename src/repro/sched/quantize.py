"""Integer microbatch quantization with batched on-device refinement.

SPMD reality: simplex fractions are realized as integer microbatch counts
(static shapes, no recompilation).  Largest-remainder rounding runs on the
host — vectorized water-fill shed/top-up, O(K log K) at K=10^5 where the
legacy one-unit-per-argsort loop was O(K^2 log K) — then the greedy
donor->receiver refinement evaluates candidate moves in one batched
objective sweep inside a single jitted ``lax.while_loop``.  Beyond
``_REFINE_SLAB`` workers the sweep restricts donors and receivers to the
top-M slab ranked by the smooth objective gradient, so each move costs
O(M^2) evaluations instead of the O(K^2) that made refinement the K=10^4+
bottleneck; fleets at or under the slab keep the exact exhaustive sweep.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.frontier import UnitParams

from .objectives import Objective, as_stage_objectives, evaluate

Array = jax.Array

# Coarser quadrature than the continuous solver: the lattice steps are
# O(1/total) so fine integration noise is irrelevant, and the refinement
# evaluates many candidates per move.
_REFINE_QUAD_POINTS = 192

# Fleets larger than this use gradient-ranked donor/receiver slabs; at or
# under it the move sweep stays exhaustive (and bitwise-legacy).
_REFINE_SLAB = 32


def _water_fill(priority: np.ndarray, cap: np.ndarray, need: int) -> np.ndarray:
    """Integer units per worker reproducing descending-priority greedy taking.

    The legacy shed/top-up loops take one unit at a time from the current
    argmax of ``priority_i - taken_i`` (bounded by ``cap_i``) until ``need``
    units are taken — O(K log K) *per unit*.  The closed form is a water
    level tau with ``taken_i = clip(ceil(priority_i - tau), 0, cap_i)``;
    bisecting tau costs O(K) per iteration for a fixed ~80 iterations, then
    boundary ties (units exactly at the water line, at most one per worker)
    are trimmed lowest-priority-first with a single stable argsort.
    """
    cap = np.asarray(cap, np.int64)
    taken = np.zeros_like(cap)
    if need <= 0:
        return taken
    priority = np.asarray(priority, np.float64)
    lo = float(priority.min() - cap.max() - 2.0)  # taken = cap everywhere
    hi = float(priority.max() + 1.0)  # taken = 0 everywhere
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if np.clip(np.ceil(priority - mid), 0, cap).sum() >= need:
            lo = mid
        else:
            hi = mid
    taken = np.clip(np.ceil(priority - lo), 0, cap).astype(np.int64)
    surplus = int(taken.sum()) - need
    if surplus > 0:
        last_unit = np.where(taken > 0, priority - taken + 1.0, np.inf)
        order = np.argsort(last_unit, kind="stable")
        taken[order[:surplus]] -= 1
    return taken


@functools.partial(
    jax.jit,
    static_argnames=("objective", "min_per_worker", "max_moves", "slab"),
)
def _refine_counts(
    counts: Array,
    params: UnitParams,
    total: Array,
    *,
    objective: Objective,
    min_per_worker: int,
    max_moves: int,
    slab: int = _REFINE_SLAB,
) -> Array:
    """Greedy best-move descent on the count lattice, fully on device.

    Each iteration scores single-microbatch donor->receiver moves and applies
    the best strictly-improving one; stops when none improves.  At K <= slab
    all K*K moves are scored (donors swept by ``lax.map`` to bound memory,
    receivers vmapped) — the exact legacy sweep.  Larger fleets rank workers
    by the smooth objective gradient wrt fractions (high gradient = the move
    away helps most -> donor; low = receiver) and score only the slab x slab
    block; acceptance still uses the true quantized objective, so a move is
    never applied on gradient evidence alone.
    """
    k = counts.shape[0]
    inv_total = 1.0 / total.astype(jnp.float32)
    ids = jnp.arange(k)

    def score(c):
        return evaluate(
            objective,
            c.astype(jnp.float32) * inv_total,
            params,
            num_points=_REFINE_QUAD_POINTS,
        )

    if k <= slab:
        eye = jnp.eye(k, dtype=counts.dtype)

        def best_move(c):
            def donor_row(d):
                cand = c[None, :] - eye[d][None, :] + eye  # (K, K) moves
                s = jax.vmap(score)(cand)
                valid = (c[d] > min_per_worker) & (ids != d)
                return jnp.where(valid, s, jnp.inf)

            all_scores = jax.lax.map(donor_row, ids)  # (K donors, K receivers)
            flat = jnp.argmin(all_scores)
            return flat // k, flat % k, all_scores.reshape(-1)[flat]

        def apply_move(c, d, r):
            return c - eye[d] + eye[r]

    else:
        grad_smooth = jax.grad(
            lambda fr: evaluate(
                objective, fr, params,
                num_points=_REFINE_QUAD_POINTS, smooth=True,
            )
        )
        hot = lambda i: (ids == i).astype(counts.dtype)

        def best_move(c):
            g = grad_smooth(c.astype(jnp.float32) * inv_total)
            _, d_idx = jax.lax.top_k(
                jnp.where(c > min_per_worker, g, -jnp.inf), slab
            )
            _, r_idx = jax.lax.top_k(-g, slab)
            recv = jax.vmap(hot)(r_idx)  # (slab, K)

            def donor_row(d):
                cand = c[None, :] - hot(d)[None, :] + recv
                s = jax.vmap(score)(cand)
                valid = (c[d] > min_per_worker) & (r_idx != d)
                return jnp.where(valid, s, jnp.inf)

            all_scores = jax.lax.map(donor_row, d_idx)  # (slab, slab)
            flat = jnp.argmin(all_scores)
            return (
                d_idx[flat // slab],
                r_idx[flat % slab],
                all_scores.reshape(-1)[flat],
            )

        def apply_move(c, d, r):
            return c - hot(d) + hot(r)

    def cond(carry):
        _, _, moves, done = carry
        return (~done) & (moves < max_moves)

    def body(carry):
        c, best, moves, _ = carry
        d, r, val = best_move(c)
        improved = val < best - 1e-9
        c = jnp.where(improved, apply_move(c, d, r), c)
        return c, jnp.minimum(val, best), moves + 1, ~improved

    carry = (counts, score(counts), jnp.zeros((), jnp.int32), jnp.asarray(False))
    counts, _, _, _ = jax.lax.while_loop(cond, body, carry)
    return counts


def quantize_fractions(
    fracs: np.ndarray,
    total_microbatches: int,
    params: Optional[UnitParams] = None,
    *,
    objective: Objective = Objective(),
    min_per_worker: int = 1,
    refine_passes: int = 4,
    live: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Round simplex fractions to integer microbatch counts summing to total.

    Largest-remainder rounding (vectorized water-fill shed/top-up — see
    ``_water_fill``); when ``params`` is given, batched greedy
    single-microbatch moves accepted only if they reduce the true (quantized)
    objective.  Invariants: counts.sum() == total_microbatches and every
    count >= min_per_worker, for any fraction vector.

    ``live`` (a (K,) boolean mask from a capacity-slot ``SchedulerState``)
    restricts quantization to live workers: dead slots get exactly zero
    microbatches, are exempt from the ``min_per_worker`` floor, and never
    enter the refinement sweep.
    """
    if live is not None:
        live = np.asarray(live, bool)
        alive = np.flatnonzero(live)
        sub = np.asarray(fracs, np.float64)[alive]
        sub_params = params
        if params is not None:
            gather = lambda x: jnp.asarray(np.asarray(x)[alive])
            sub_params = jax.tree_util.tree_map(gather, params)
        counts = np.zeros(len(live), np.int64)
        counts[alive] = quantize_fractions(
            sub / max(sub.sum(), 1e-30),
            total_microbatches,
            sub_params,
            objective=objective,
            min_per_worker=min_per_worker,
            refine_passes=refine_passes,
        )
        return counts

    k = len(fracs)
    if total_microbatches < k * min_per_worker:
        raise ValueError(
            f"{total_microbatches} microbatches cannot give {k} workers "
            f">= {min_per_worker} each"
        )
    raw = np.asarray(fracs, np.float64) * total_microbatches
    counts = np.maximum(np.floor(raw).astype(np.int64), min_per_worker)
    # Shed from the most over-allocated workers that can still give
    # (sum > total >= k*min implies headroom exists).
    counts -= _water_fill(
        counts - raw,
        counts - min_per_worker,
        int(counts.sum()) - total_microbatches,
    )
    # Top up by largest remainder (each extra unit lowers the remainder by 1,
    # which is exactly the water-fill greedy).
    need = total_microbatches - int(counts.sum())
    counts += _water_fill(raw - counts, np.full(k, max(need, 0)), need)

    if params is None:
        return counts

    refined = _refine_counts(
        jnp.asarray(counts),
        params,
        jnp.asarray(total_microbatches),
        objective=objective,
        min_per_worker=min_per_worker,
        max_moves=refine_passes * min(k, 4 * _REFINE_SLAB),
    )
    return np.asarray(refined, np.int64)


def quantize_dag_fractions(
    fracs: np.ndarray,
    total_microbatches,
    params: Optional[UnitParams] = None,
    *,
    objective: Objective = Objective(),
    objectives=None,
    min_per_worker: int = 1,
    refine_passes: int = 4,
    live: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Round (S, K) stage-wise fractions to per-stage integer counts.

    Each stage's row quantizes independently (the lattice couples workers
    within a stage, never across stages), so this is a host-side loop of
    ``quantize_fractions`` calls.  ``total_microbatches`` is an int shared by
    every stage or a per-stage sequence; ``objectives`` optionally gives each
    stage its own rounding objective (a single ``Objective`` or one per
    stage — same spec ``propose_dag`` takes); ``live`` is an (S, K) mask
    (e.g. ``WorkflowDAG.stage_live()``) pinning dead pad columns of a
    heterogeneous-width stage to exactly zero microbatches.
    """
    fracs = np.asarray(fracs, np.float64)
    if fracs.ndim != 2:
        raise ValueError(f"expected (S, K) fractions, got shape {fracs.shape}")
    s = fracs.shape[0]
    objs = as_stage_objectives(
        objective if objectives is None else objectives, s
    )
    if np.ndim(total_microbatches) == 0:
        totals = [int(total_microbatches)] * s
    else:
        totals = [int(t) for t in total_microbatches]
        if len(totals) != s:
            raise ValueError("need one microbatch total per stage")
    live = None if live is None else np.asarray(live, bool)
    counts = np.zeros(fracs.shape, np.int64)
    for i in range(s):
        p_i = params
        if params is not None:
            p_i = jax.tree_util.tree_map(
                lambda x: jnp.asarray(np.asarray(x)[i]), params
            )
        counts[i] = quantize_fractions(
            fracs[i],
            totals[i],
            p_i,
            objective=objs[i],
            min_per_worker=min_per_worker,
            refine_passes=refine_passes,
            live=None if live is None else live[i],
        )
    return counts
