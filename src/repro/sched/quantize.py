"""Integer microbatch quantization with batched on-device refinement.

SPMD reality: simplex fractions are realized as integer microbatch counts
(static shapes, no recompilation).  Largest-remainder rounding runs on the
host (O(K) integers), then the greedy donor->receiver refinement — formerly a
Python double loop issuing one device program per candidate move — evaluates
every (donor, receiver) move of a step in one batched objective sweep inside
a single jitted ``lax.while_loop``, so a fleet of hundreds of workers
quantizes in one device program.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.frontier import UnitParams

from .objectives import Objective, evaluate

Array = jax.Array

# Coarser quadrature than the continuous solver: the lattice steps are
# O(1/total) so fine integration noise is irrelevant, and the refinement
# evaluates K^2 candidates per move.
_REFINE_QUAD_POINTS = 192


@functools.partial(
    jax.jit, static_argnames=("objective", "min_per_worker", "max_moves")
)
def _refine_counts(
    counts: Array,
    params: UnitParams,
    total: Array,
    *,
    objective: Objective,
    min_per_worker: int,
    max_moves: int,
) -> Array:
    """Greedy best-move descent on the count lattice, fully on device.

    Each iteration scores all K*K single-microbatch donor->receiver moves
    (donors swept by ``lax.map`` to bound memory, receivers vmapped) and
    applies the best strictly-improving one; stops when none improves.
    """
    k = counts.shape[0]
    eye = jnp.eye(k, dtype=counts.dtype)
    inv_total = 1.0 / total.astype(jnp.float32)
    ids = jnp.arange(k)

    def score(c):
        return evaluate(
            objective,
            c.astype(jnp.float32) * inv_total,
            params,
            num_points=_REFINE_QUAD_POINTS,
        )

    def best_move(c):
        def donor_row(d):
            cand = c[None, :] - eye[d][None, :] + eye  # (K, K) receiver moves
            s = jax.vmap(score)(cand)
            valid = (c[d] > min_per_worker) & (ids != d)
            return jnp.where(valid, s, jnp.inf)

        all_scores = jax.lax.map(donor_row, ids)  # (K donors, K receivers)
        flat = jnp.argmin(all_scores)
        return flat // k, flat % k, all_scores.reshape(-1)[flat]

    def cond(carry):
        _, _, moves, done = carry
        return (~done) & (moves < max_moves)

    def body(carry):
        c, best, moves, _ = carry
        d, r, val = best_move(c)
        improved = val < best - 1e-9
        c = jnp.where(improved, c - eye[d] + eye[r], c)
        return c, jnp.minimum(val, best), moves + 1, ~improved

    carry = (counts, score(counts), jnp.zeros((), jnp.int32), jnp.asarray(False))
    counts, _, _, _ = jax.lax.while_loop(cond, body, carry)
    return counts


def quantize_fractions(
    fracs: np.ndarray,
    total_microbatches: int,
    params: Optional[UnitParams] = None,
    *,
    objective: Objective = Objective(),
    min_per_worker: int = 1,
    refine_passes: int = 4,
) -> np.ndarray:
    """Round simplex fractions to integer microbatch counts summing to total.

    Largest-remainder rounding; when ``params`` is given, batched greedy
    single-microbatch moves accepted only if they reduce the true (quantized)
    objective.  Invariants: counts.sum() == total_microbatches and every
    count >= min_per_worker, for any fraction vector.
    """
    k = len(fracs)
    if total_microbatches < k * min_per_worker:
        raise ValueError(
            f"{total_microbatches} microbatches cannot give {k} workers "
            f">= {min_per_worker} each"
        )
    raw = np.asarray(fracs, np.float64) * total_microbatches
    counts = np.maximum(np.floor(raw).astype(np.int64), min_per_worker)
    while counts.sum() > total_microbatches:
        # Shed from the most over-allocated worker that can still give
        # (sum > total >= k*min implies one exists, so this terminates).
        order = np.argsort(-(counts - raw))
        for idx in order:
            if counts[idx] > min_per_worker:
                counts[idx] -= 1
                break
    rema = raw - counts
    while counts.sum() < total_microbatches:
        idx = int(np.argmax(rema))
        counts[idx] += 1
        rema[idx] -= 1.0

    if params is None:
        return counts

    refined = _refine_counts(
        jnp.asarray(counts),
        params,
        jnp.asarray(total_microbatches),
        objective=objective,
        min_per_worker=min_per_worker,
        max_moves=refine_passes * k,
    )
    return np.asarray(refined, np.int64)
