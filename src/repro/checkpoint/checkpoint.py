"""Fault-tolerant checkpointing: atomic, async, retention-managed.

Layout (one directory per step):

  <dir>/step_000123.tmp/...   (written)
  <dir>/step_000123/          (atomic rename on completion)
      manifest.json           step, data-iterator state, rng, tree structure
      arr_00000.npy ...       flattened param/opt leaves

Atomicity: a checkpoint is valid iff the final directory exists (rename is
atomic on POSIX); partially written .tmp dirs are ignored and purged.  The
async writer moves serialization off the training thread (device->host copy
happens synchronously to get a consistent snapshot; file IO is overlapped).
On multi-host deployments each host writes only its local shards (the
manifest records the process index); restore reassembles per host.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree: Any) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _flatten_with_paths(tree: Any):
    """Flatten keeping key-paths; order matches ``tree_flatten`` exactly."""
    kl, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(kp) for kp, _ in kl]
    return paths, [leaf for _, leaf in kl], treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._purge_tmp()

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        """Snapshot (sync device->host) then write (async unless disabled).

        The manifest records each leaf's key-path (``jax.tree_util.keystr``)
        so ``restore_by_name`` can later match leaves by NAME: a checkpoint
        whose scheduler/ring leaves drifted in shape still gives back its
        perfectly valid model params instead of forcing a fresh start.
        """
        keypaths, raw_leaves, treedef = _flatten_with_paths(tree)
        leaves = [np.asarray(l) for l in raw_leaves]  # consistent snapshot
        extra = dict(extra or {})
        self.wait()  # one outstanding write at a time

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, arr in enumerate(leaves):
                np.save(tmp / f"arr_{i:05d}.npy", arr)
            manifest = {
                "step": step,
                "num_arrays": len(leaves),
                "keypaths": keypaths,
                "process_index": jax.process_index(),
                "extra": extra,
            }
            (tmp / MANIFEST).write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)  # atomic commit
            self._retain()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- read ----------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                if (p / MANIFEST).exists():
                    out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, tree_like: Any, step: Optional[int] = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``tree_like``; returns (tree, extra)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / MANIFEST).read_text())
        leaves, treedef = jax.tree_util.tree_flatten(tree_like)
        arrs = [np.load(path / f"arr_{i:05d}.npy") for i in range(manifest["num_arrays"])]
        if len(arrs) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(arrs)} leaves, structure needs {len(leaves)}"
            )
        # Shape drift must fail HERE (callers keep a legacy fallback), not
        # surface later as a runtime crash: leaf count alone let e.g. an old
        # scalar ewma_count restore into today's per-worker (K,) slot.
        for i, (arr, leaf) in enumerate(zip(arrs, leaves)):
            if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"checkpoint leaf {i} has shape {tuple(arr.shape)}, "
                    f"structure needs {tuple(leaf.shape)}"
                )
        restored = jax.tree_util.tree_unflatten(treedef, arrs)
        return restored, manifest["extra"]

    def restore_by_name(
        self, tree_like: Any, step: Optional[int] = None
    ) -> Tuple[Any, Dict, Dict[str, List[str]]]:
        """Subset restore: match checkpoint leaves to ``tree_like`` by NAME.

        Each leaf of ``tree_like`` whose key-path exists in the checkpoint
        with the same shape and dtype gets the saved array; every other leaf
        keeps its template value.  This is the structure-drift recovery
        path: a shape-drifted scheduler or telemetry-ring leaf no longer
        drags perfectly valid model params down with it — only the drifted
        subtree resets.

        Returns ``(tree, extra, report)`` where ``report`` lists the
        ``restored`` and ``skipped`` key-paths so callers can decide whether
        the subset is good enough (e.g. the trainer requires every
        params/opt_state leaf).  Raises ``ValueError`` for pre-keypath
        checkpoints (restore those positionally via ``restore``).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / MANIFEST).read_text())
        if "keypaths" not in manifest:
            raise ValueError(
                "checkpoint predates key-path manifests; use restore()"
            )
        index = {kp: i for i, kp in enumerate(manifest["keypaths"])}
        paths, leaves, treedef = _flatten_with_paths(tree_like)
        out, restored, skipped = [], [], []
        for kp, leaf in zip(paths, leaves):
            i = index.get(kp)
            arr = np.load(path / f"arr_{i:05d}.npy") if i is not None else None
            want_shape = tuple(getattr(leaf, "shape", ()))
            want_dtype = getattr(leaf, "dtype", None)
            if (
                arr is not None
                and tuple(arr.shape) == want_shape
                and (want_dtype is None or arr.dtype == np.dtype(want_dtype))
            ):
                out.append(arr)
                restored.append(kp)
            else:
                out.append(leaf)
                skipped.append(kp)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, manifest["extra"], {"restored": restored, "skipped": skipped}

    # -- hygiene ---------------------------------------------------------------
    def _retain(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def _purge_tmp(self) -> None:
        for p in self.dir.glob("step_*.tmp"):
            shutil.rmtree(p, ignore_errors=True)
