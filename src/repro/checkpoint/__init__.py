"""checkpoint subpackage."""
