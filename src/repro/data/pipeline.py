"""Deterministic, checkpointable, sharded synthetic-token data pipeline.

Production shape without external deps:
  * a ``TokenSource`` produces documents deterministically from (seed, index)
    — a stand-in for a tokenized corpus shard; swap in a memory-mapped
    array source for real data (same interface).
  * ``PackedLMDataset`` packs documents into fixed (seq_len+1) windows with
    next-token labels, document-boundary loss masking, and padding.
  * ``DataIterator`` is stateful and *checkpointable* (its cursor rides in
    every checkpoint, so restarts resume mid-epoch exactly).
  * sharding: each data-parallel worker reads only its slice (index-strided),
    matching the (M, B/M, ...) microbatched global layout the trainer feeds
    to SPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

PAD = -1  # label id for masked positions


class TokenSource:
    """Deterministic document stream: doc i is reproducible from (seed, i)."""

    def __init__(self, vocab_size: int, seed: int = 0,
                 min_len: int = 32, max_len: int = 512):
        self.vocab_size = vocab_size
        self.seed = seed
        self.min_len = min_len
        self.max_len = max_len

    def doc(self, index: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, index))
        n = int(rng.integers(self.min_len, self.max_len + 1))
        # zipf-ish marginal over the vocab (realistic token frequencies)
        z = rng.zipf(1.3, size=n)
        return np.minimum(z, self.vocab_size - 1).astype(np.int32)


@dataclasses.dataclass
class IteratorState:
    doc_cursor: int
    buffer: np.ndarray  # leftover tokens from the last packed document

    def to_dict(self) -> Dict:
        return {"doc_cursor": int(self.doc_cursor), "buffer": self.buffer.tolist()}

    @staticmethod
    def from_dict(d: Dict) -> "IteratorState":
        return IteratorState(int(d["doc_cursor"]), np.asarray(d["buffer"], np.int32))


class PackedLMDataset:
    """Packs the document stream into (tokens, labels) training windows."""

    def __init__(self, source: TokenSource, seq_len: int):
        self.source = source
        self.seq_len = seq_len

    def fill(self, state: IteratorState, n_windows: int) -> Tuple[np.ndarray, np.ndarray, IteratorState]:
        need = n_windows * (self.seq_len + 1)
        buf = state.buffer
        cursor = state.doc_cursor
        parts = [buf]
        total = len(buf)
        while total < need:
            d = self.source.doc(cursor)
            cursor += 1
            parts.append(d)
            total += len(d)
        stream = np.concatenate(parts)
        used, rest = stream[:need], stream[need:]
        w = used.reshape(n_windows, self.seq_len + 1)
        tokens = w[:, :-1].copy()
        labels = w[:, 1:].copy()
        return tokens, labels, IteratorState(cursor, rest.astype(np.int32))


class DataIterator:
    """Sharded, stateful iterator emitting the trainer's global batch layout.

    Emits {tokens, labels} with shape (M, B/M, seq_len) — already microbatched
    (see train_step.accumulate_grads).  With ``shard_index/shard_count`` set,
    only the host's slice of the batch is materialized (multi-host input
    pipeline); on a single host the full global batch is produced.
    """

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        num_microbatches: int,
        seed: int = 0,
        shard_index: int = 0,
        shard_count: int = 1,
    ):
        assert global_batch % num_microbatches == 0
        self.dataset = PackedLMDataset(
            TokenSource(vocab_size, seed=seed * 1000 + shard_index), seq_len
        )
        self.global_batch = global_batch
        self.m = num_microbatches
        self.shard_count = shard_count
        self.state = IteratorState(0, np.zeros((0,), np.int32))

    def __iter__(self) -> "DataIterator":
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        n = self.global_batch // self.shard_count
        tokens, labels, self.state = self.dataset.fill(self.state, n)
        mb = self.global_batch // self.m
        mb_local = mb // self.shard_count
        tokens = tokens.reshape(self.m, mb_local, -1)
        labels = labels.reshape(self.m, mb_local, -1)
        return {"tokens": tokens, "labels": labels}

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict:
        return self.state.to_dict()

    def load_state_dict(self, d: Dict) -> None:
        self.state = IteratorState.from_dict(d)
