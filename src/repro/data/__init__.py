"""data subpackage."""
