"""Training step: loss, microbatched gradient accumulation, optimizer fusion.

The step is built in composable units so the dry-run can cost them
separately (XLA's HLO cost analysis counts while-loop bodies once):

  microbatch fwd+bwd  --scan over M microbatches-->  grads
  grads  --[optional compression hook]-->  AdamW update (donated, in-place)

Heterogeneous work assignment (the paper's partitioner) is realized as
*weighted* gradient accumulation: each worker runs its own number of
microbatches and gradients are combined with token-count weights — shapes
stay static (no recompilation when the split changes).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model_zoo
from repro.models.layers import ApplyCtx
from repro.optim import adamw

Array = jax.Array

Z_LOSS_WEIGHT = 1e-4


def cross_entropy(logits: Array, labels: Array, vocab: int) -> Tuple[Array, Array]:
    """Mean token cross-entropy + z-loss.  labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    xent = jnp.sum(nll) / denom
    z = jnp.sum(jnp.square(logz) * mask) / denom
    return xent, z


def loss_fn(
    cfg: ModelConfig, params, batch: Dict[str, Array], ctx: ApplyCtx
) -> Tuple[Array, Dict[str, Array]]:
    logits, aux = model_zoo.forward_train(cfg, params, batch, ctx=ctx)
    labels = batch["labels"]
    if cfg.vision_patches and logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:]  # loss on text positions only
    xent, z = cross_entropy(logits, labels, cfg.vocab_size)
    loss = xent + Z_LOSS_WEIGHT * z + cfg.router_aux_weight * aux
    return loss, {"xent": xent, "aux": aux, "z": z}


def microbatch_value_and_grad(cfg: ModelConfig, ctx: ApplyCtx) -> Callable:
    """(params, microbatch) -> ((loss, metrics), grads) — the dry-run's
    per-microbatch cost unit."""

    def f(params, mb):
        return loss_fn(cfg, params, mb, ctx)

    return jax.value_and_grad(f, has_aux=True)


def split_microbatches(batch: Dict[str, Array], m: int) -> Dict[str, Array]:
    """Host-side microbatch split: (B, ...) -> (M, B/M, ...).

    IMPORTANT for SPMD: the global batch must be laid out so each (B/M, ...)
    slice spans all data-parallel shards (the data pipeline emits it this
    way).  ``accumulate_grads`` expects batches already in (M, B/M, ...) form
    with the *second* dim sharded over the data axes — scanning over a
    sharded leading dim would force a re-distribution every microbatch.
    """

    def r(x):
        b = x.shape[0]
        assert b % m == 0, f"batch {b} not divisible into {m} microbatches"
        return x.reshape(m, b // m, *x.shape[1:])

    return jax.tree_util.tree_map(r, batch)


def accumulate_grads(
    cfg: ModelConfig,
    params,
    batch: Dict[str, Array],
    *,
    ctx: ApplyCtx,
    num_microbatches: int,
    weights: Optional[Array] = None,
    grad_dtype=jnp.float32,
) -> Tuple[Any, Dict[str, Array]]:
    """Scan-accumulated gradients over microbatches.

    weights: optional (M,) per-microbatch weights (the Bayesian partitioner's
    heterogeneous split — weight 0 skips a microbatch's contribution, which is
    how per-worker work counts differ without shape changes).

    ``batch`` leaves must already be microbatched: (M, B/M, ...) with dim 1
    sharded over the data axes (see ``split_microbatches``).
    """
    vg = microbatch_value_and_grad(cfg, ctx)
    mbs = batch
    if weights is None:
        weights = jnp.ones((num_microbatches,), jnp.float32)
    wsum = jnp.maximum(jnp.sum(weights), 1e-9)

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, grad_dtype), params
    )

    def body(carry, xs):
        grads_acc, loss_acc = carry
        mb, w = xs
        (loss, metrics), grads = vg(params, mb)
        grads_acc = jax.tree_util.tree_map(
            lambda a, g: a + w.astype(grad_dtype) * g.astype(grad_dtype),
            grads_acc, grads,
        )
        return (grads_acc, loss_acc + w * loss), metrics

    (grads, loss_sum), metrics = jax.lax.scan(
        body, (zeros, jnp.zeros(())), (mbs, weights)
    )
    grads = jax.tree_util.tree_map(lambda g: g / wsum, grads)
    metrics = jax.tree_util.tree_map(lambda x: jnp.mean(x), metrics)
    metrics["loss"] = loss_sum / wsum
    return grads, metrics


def make_train_step(
    cfg: ModelConfig,
    run: RunConfig,
    *,
    ctx: ApplyCtx,
    num_microbatches: int,
    compression: Optional[Callable] = None,
) -> Callable:
    """Full train step: accum -> (compress w/ error feedback) -> clip -> AdamW.

    Signature without compression:
        (params, opt_state, batch, step[, mb_weights]) ->
        (params, opt_state, metrics)
    With compression (fn: (grads, ef) -> (grads, ef)), an ``ef`` pytree rides
    through the step:
        (params, opt_state, batch, step, mb_weights, ef) ->
        (params, opt_state, metrics, ef)
    """
    schedule = adamw.cosine_schedule(run.learning_rate, run.warmup_steps, run.total_steps)
    grad_dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[run.grad_dtype]

    def _finish(params, opt_state, grads, step, metrics):
        lr = schedule(step)
        params, opt_state, gnorm = adamw.apply(
            params, grads, opt_state, lr,
            weight_decay=run.weight_decay, grad_clip=run.grad_clip,
        )
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, metrics

    if compression is None:
        def step_fn(params, opt_state, batch, step, mb_weights=None):
            grads, metrics = accumulate_grads(
                cfg, params, batch, ctx=ctx,
                num_microbatches=num_microbatches, weights=mb_weights,
                grad_dtype=grad_dt,
            )
            return _finish(params, opt_state, grads, step, metrics)

        return step_fn

    def step_fn_c(params, opt_state, batch, step, mb_weights, ef):
        grads, metrics = accumulate_grads(
            cfg, params, batch, ctx=ctx,
            num_microbatches=num_microbatches, weights=mb_weights,
            grad_dtype=grad_dt,
        )
        grads, ef = compression(grads, ef)
        params, opt_state, metrics = _finish(params, opt_state, grads, step, metrics)
        return params, opt_state, metrics, ef

    return step_fn_c


def make_optimizer_unit(cfg: ModelConfig, run: RunConfig) -> Callable:
    """Optimizer-only unit for dry-run cost accounting."""

    def opt_fn(params, opt_state, grads):
        params, opt_state, gnorm = adamw.apply(
            params, grads, opt_state, jnp.asarray(run.learning_rate, jnp.float32),
            weight_decay=run.weight_decay, grad_clip=run.grad_clip,
        )
        return params, opt_state, gnorm

    return opt_fn
