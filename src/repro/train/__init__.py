"""train subpackage."""
