"""Production training loop with the paper's Bayesian partitioner in charge
of heterogeneous work assignment, plus checkpoint/restart and fault handling.

Flow per step:
  1. data iterator -> (M, B/M, seq) microbatched global batch
  2. jitted train_step with per-microbatch weights (the current split)
  3. telemetry: per-worker step times (measured; simulated on CPU via
     ``SimulatedCluster``) -> FaultToleranceMonitor
  4. every ``partitioner_refit_every`` steps: Gibbs-update posteriors, emit a
     new microbatch split (quantized efficient-frontier fractions)
  5. failures -> evict worker, re-split, continue (elastic); checkpoints are
     atomic and restart-resumable (params, optimizer, data cursor, RNG)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, RunConfig
from repro.sched import Objective, Scheduler, SchedulerConfig, Telemetry
from repro.hier.hyperprior import hyper_init
from repro.serve import ring as serve_ring
from repro.serve.gate import GateState, gate_init, gate_update
from repro.serve.service import posterior_drift
from repro.data.pipeline import DataIterator
from repro.distributed.compression import make_compressor
from repro.distributed.fault_tolerance import FaultToleranceMonitor
from repro.distributed.simulated_cluster import SimulatedCluster
from repro.models import model_zoo
from repro.models.layers import ApplyCtx, MeshInfo
from repro.optim import adamw
from repro.train import train_step as ts


@dataclasses.dataclass
class TrainerReport:
    steps: int
    losses: List[float]
    splits: List[np.ndarray]
    makespans: List[float]
    events: List[Dict]


class Trainer:
    def __init__(
        self,
        run: RunConfig,
        *,
        cluster: Optional[SimulatedCluster] = None,
        num_microbatches: Optional[int] = None,
        mesh_info: Optional[MeshInfo] = None,
        scheduler_config: Optional[SchedulerConfig] = None,
    ):
        """``scheduler_config`` overrides the default partitioner config —
        production deployments pass ``SchedulerConfig(mesh=ShardingConfig)``
        here to shard the estimator's fleet axis across the cluster's devices
        (``docs/scaling.md``); the checkpoint path is unchanged because
        ``CheckpointManager`` gathers sharded leaves on save.  A config whose
        objective is the default (mean) still honors the run's
        ``partitioner_risk_aversion`` — opting into sharding must not
        silently drop risk-sensitive partitioning.  Any non-default
        objective wins as-is; note ``Objective.mean()`` IS the default, so
        to force a plain mean objective against a run that sets
        ``partitioner_risk_aversion``, set the run's risk aversion to 0."""
        self.run = run
        self.cfg = run.model
        self.cluster = cluster
        self.mesh_info = mesh_info
        self.m = num_microbatches or max(run.shape.global_batch // 8, 1)

        key = jax.random.PRNGKey(run.seed)
        self.params = model_zoo.init_model_params(key, self.cfg)
        self.opt_state = adamw.init(self.params)
        self.step = 0

        self.ctx = ApplyCtx(mode="train", mesh_info=mesh_info, remat=run.remat)
        compression = None
        self._ef = None
        if run.grad_compression != "none":
            compression, init_ef = make_compressor(run.grad_compression, None)
            self._ef = init_ef(self.params)

        self._step_fn = jax.jit(
            ts.make_train_step(
                self.cfg, run, ctx=self.ctx,
                num_microbatches=self.m, compression=compression,
            )
        )

        self.data = DataIterator(
            vocab_size=self.cfg.vocab_size,
            seq_len=run.shape.seq_len,
            global_batch=run.shape.global_batch,
            num_microbatches=self.m,
            seed=run.seed,
        )
        self.ckpt = CheckpointManager(run.checkpoint_dir, keep=run.keep_checkpoints)

        # --- the paper's scheduler -----------------------------------------
        self.partitioner = None
        self.monitor = None
        self._mb_weights = np.ones(self.m, np.float32)
        self._worker_of_mb = None
        if run.partitioner_enabled and cluster is not None:
            ra = run.partitioner_risk_aversion
            sched_cfg = scheduler_config or SchedulerConfig(mu_guess=1.0)
            if sched_cfg.objective == Objective():
                sched_cfg = dataclasses.replace(
                    sched_cfg,
                    objective=Objective.mean_var(ra) if ra else Objective.mean(),
                )
            self.partitioner = Scheduler(
                cluster.num_workers,
                config=sched_cfg,
                seed=run.seed,
            )
            self.monitor = FaultToleranceMonitor(
                self.partitioner,
                straggler_sigma=run.straggler_threshold_sigma,
                heartbeat_timeout=1e9,  # simulated clock; evict on inf times
            )
            self._assign_microbatches(equal=True)
            self._init_serve_state()

    # ---------------------------------------------------------------- serve
    def _init_serve_state(self) -> None:
        """Fresh push-mode telemetry state (repro.serve): a device-resident
        ring buffering per-step telemetry between drains, plus the propose
        cadence — the posterior snapshot at the last split and a staleness
        counter.  Rebuilt whenever the fleet changes shape (telemetry and
        beliefs for the old fleet are stale)."""
        k = self.partitioner.num_workers
        # 2x headroom so a late drain degrades to dropped-oldest telemetry
        # (counted in ring.dropped), never a crash or a silent mis-mask.
        self._ring = serve_ring.ring_init(
            2 * self.run.partitioner_refit_every, k
        )
        self._ref_params = self.partitioner.unit_params()
        # Saturated staleness: the first drain always proposes.
        self._staleness = self.run.partitioner_max_staleness
        # Self-calibrating gate baseline (used when the run leaves
        # partitioner_drift_threshold unset) and the pooled fleet prior
        # (refit every hyper_refit_every drains when hierarchical).  The
        # age starts saturated so the first drain refits immediately.
        self._gate = gate_init()
        self._hyper = jax.tree_util.tree_map(
            lambda x: jnp.asarray(x, jnp.float32),
            hyper_init(self.partitioner.config.mu_guess),
        )
        self._hyper_age = self.partitioner.config.hyper_refit_every

    # ------------------------------------------------------------------ utils
    def _assign_microbatches(self, equal: bool = False) -> np.ndarray:
        """Map microbatches to workers per the current frontier split."""
        k = self.partitioner.num_workers
        if equal:
            counts = np.full(k, self.m // k, np.int64)
            counts[: self.m % k] += 1
        else:
            counts = self.partitioner.propose_microbatches(self.m)
        owner = np.repeat(np.arange(k), counts)[: self.m]
        self._worker_of_mb = owner
        return counts

    def current_fracs(self) -> np.ndarray:
        k = self.partitioner.num_workers
        counts = np.bincount(self._worker_of_mb, minlength=k)
        return counts / counts.sum()

    # ------------------------------------------------------------------ resume
    def _ckpt_tree(self) -> Any:
        """Everything checkpointed as one pytree; the scheduler's beliefs AND
        the push-mode telemetry state (ring buffer + propose cadence) are part
        of it, so a restart neither forgets what the estimator learned nor
        drops buffered telemetry / re-solves a split that was still fresh."""
        tree = {"params": self.params, "opt_state": self.opt_state}
        if self.partitioner is not None:
            tree["sched"] = self.partitioner.state
            tree["serve"] = {
                "ring": self._ring,
                "ref": self._ref_params,
                "staleness": jnp.asarray(self._staleness, jnp.int32),
                "gate": self._gate,
                "hyper": self._hyper,
                "hyper_age": jnp.asarray(self._hyper_age, jnp.int32),
            }
        return tree

    def try_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        template = self._ckpt_tree()
        try:
            restored, extra = self.ckpt.restore(template)
        except ValueError:
            # Checkpoint structure drifted (partitioner toggled, legacy
            # scheduler state layout, pre-ring telemetry, ...).  The
            # name-keyed subset restore salvages every leaf whose key-path,
            # shape and dtype still match — a drifted scheduler/ring leaf
            # resets only its own subtree instead of forcing a fresh start —
            # but the MODEL must restore completely: partial params or
            # optimizer moments are silent corruption, not a degraded mode.
            try:
                restored, extra, report = self.ckpt.restore_by_name(template)
                if any(
                    kp.startswith(("['params']", "['opt_state']"))
                    for kp in report["skipped"]
                ):
                    return False
            except ValueError:
                # Pre-keypath checkpoint: the legacy positional model-only
                # layout is the last resort; if even that fails, start fresh
                # rather than crash.
                try:
                    restored, extra = self.ckpt.restore(
                        {"params": self.params, "opt_state": self.opt_state}
                    )
                except ValueError:
                    return False
        self.params = restored["params"]
        self.opt_state = restored["opt_state"]
        sched_state = restored.get("sched")
        if self.partitioner is not None and sched_state is not None:
            # Adopt saved beliefs only if the fleet shape still matches
            # (an eviction between save and restart invalidates them).
            if len(sched_state.ewma_ll) == self.partitioner.num_workers:
                self.partitioner.state = sched_state
                serve_tree = restored.get("serve")
                if serve_tree is not None:
                    self._ring = jax.tree_util.tree_map(
                        jnp.asarray, serve_tree["ring"]
                    )
                    self._ref_params = jax.tree_util.tree_map(
                        jnp.asarray, serve_tree["ref"]
                    )
                    self._staleness = int(serve_tree["staleness"])
                    if "gate" in serve_tree:  # absent in pre-hier checkpoints
                        self._gate = GateState(
                            *jax.tree_util.tree_map(
                                jnp.asarray, tuple(serve_tree["gate"])
                            )
                        )
                    if "hyper" in serve_tree:
                        self._hyper = jax.tree_util.tree_map(
                            jnp.asarray, serve_tree["hyper"]
                        )
                    if "hyper_age" in serve_tree:
                        self._hyper_age = int(serve_tree["hyper_age"])
                self._assign_microbatches(equal=False)
        self.step = int(extra["step"])
        self.data.load_state_dict(extra["data_state"])
        return True

    def save(self) -> None:
        self.ckpt.save(
            self.step,
            self._ckpt_tree(),
            {"step": self.step, "data_state": self.data.state_dict()},
        )

    # ------------------------------------------------------------------ loop
    def train(self, steps: int, log_every: int = 10) -> TrainerReport:
        losses, splits, makespans = [], [], []
        run = self.run
        for _ in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(self.data).items()}
            weights = jnp.asarray(self._mb_weights)
            if self._ef is not None:
                self.params, self.opt_state, metrics, self._ef = self._step_fn(
                    self.params, self.opt_state, batch,
                    jnp.asarray(self.step), weights, self._ef,
                )
            else:
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch, jnp.asarray(self.step), weights
                )
            loss = float(metrics["loss"])
            losses.append(loss)
            self.step += 1

            # ---- telemetry + the paper's scheduler -------------------------
            if self.partitioner is not None:
                fracs = self.current_fracs()
                times = self.cluster.step_times(fracs)
                flags = self.monitor.observe_step(fracs, times)
                makespans.append(
                    float(np.max(times[np.isfinite(times)]))
                    if np.isfinite(times).any() else float("inf")
                )
                # push-mode telemetry: one device-resident ring push per
                # step (non-finite times ride in masked-out, never as the
                # old 1e6 sentinel), drained in whole batches below.
                self._ring = serve_ring.push(
                    self._ring,
                    jnp.asarray(fracs, jnp.float32),
                    jnp.asarray(
                        np.where(np.isfinite(times), times, 1.0), jnp.float32
                    ),
                    jnp.asarray(np.isfinite(times), jnp.float32),
                )

                if flags["failures"].any():
                    # elastic: evict, re-split, checkpoint the new world
                    alive = ~flags["failures"]
                    self.cluster.specs = [
                        s for s, a in zip(self.cluster.specs, alive) if a
                    ]
                    self.monitor.evict(flags["failures"])
                    self._assign_microbatches(equal=False)
                    # telemetry + cadence state for the old fleet shape is
                    # stale: rebuild the ring, re-anchor the drift reference
                    self._init_serve_state()
                    self.save()

                if (
                    self.step % run.partitioner_refit_every == 0
                    and int(self._ring.count) > 0
                ):
                    # observe on every drained batch ...
                    batch, self._ring = serve_ring.drain(self._ring)
                    self.partitioner.observe(
                        Telemetry(fracs=batch.fracs, times=batch.times),
                        mask=batch.mask,
                    )
                    # ... but re-solve the split only when the posterior
                    # actually moved (or the split got too stale) — the
                    # repro.serve cadence policy (docs/serving.md).  With
                    # hierarchical pooling the statistic is the max
                    # per-worker surprise against the fleet hyperprior
                    # (refit every hyper_refit_every drains); a run that
                    # leaves partitioner_drift_threshold unset gets the
                    # self-calibrating EWMA gate (docs/hierarchy.md).
                    cur = self.partitioner.unit_params()
                    if self.partitioner.config.hierarchical:
                        self._hyper_age += 1
                        if (
                            self._hyper_age
                            >= self.partitioner.config.hyper_refit_every
                        ):
                            self._hyper = self.partitioner.fit_hyperprior()
                            self._hyper_age = 0
                        drift = float(
                            np.max(self.partitioner.surprise(self._hyper))
                        )
                    else:
                        drift = float(posterior_drift(self._ref_params, cur))
                    self._staleness += 1
                    thr = run.partitioner_drift_threshold
                    if thr is None:
                        fired, self._gate = gate_update(self._gate, drift)
                        moved = bool(fired)
                    else:
                        moved = drift > thr
                    if (
                        moved
                        or self._staleness >= run.partitioner_max_staleness
                    ):
                        counts = self._assign_microbatches(equal=False)
                        splits.append(counts.copy())
                        self._ref_params = cur
                        self._staleness = 0

            if self.step % run.checkpoint_every == 0:
                self.save()
        self.ckpt.wait()
        events = self.monitor.events if self.monitor else []
        return TrainerReport(self.step, losses, splits, makespans, events)
