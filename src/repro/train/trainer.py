"""Production training loop with the paper's Bayesian partitioner in charge
of heterogeneous work assignment, plus checkpoint/restart and fault handling.

Flow per step:
  1. data iterator -> (M, B/M, seq) microbatched global batch
  2. jitted train_step with per-microbatch weights (the current split)
  3. telemetry: per-worker step times (measured; simulated on CPU via
     ``SimulatedCluster``) -> FaultToleranceMonitor
  4. every ``partitioner_refit_every`` steps: Gibbs-update posteriors, emit a
     new microbatch split (quantized efficient-frontier fractions)
  5. failures -> evict worker, re-split, continue (elastic); checkpoints are
     atomic and restart-resumable (params, optimizer, data cursor, RNG)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, RunConfig
from repro.sched import Objective, Scheduler, SchedulerConfig, Telemetry
from repro.data.pipeline import DataIterator
from repro.distributed.compression import make_compressor
from repro.distributed.fault_tolerance import FaultToleranceMonitor
from repro.distributed.simulated_cluster import SimulatedCluster
from repro.models import model_zoo
from repro.models.layers import ApplyCtx, MeshInfo
from repro.optim import adamw
from repro.train import train_step as ts


@dataclasses.dataclass
class TrainerReport:
    steps: int
    losses: List[float]
    splits: List[np.ndarray]
    makespans: List[float]
    events: List[Dict]


class Trainer:
    def __init__(
        self,
        run: RunConfig,
        *,
        cluster: Optional[SimulatedCluster] = None,
        num_microbatches: Optional[int] = None,
        mesh_info: Optional[MeshInfo] = None,
        scheduler_config: Optional[SchedulerConfig] = None,
    ):
        """``scheduler_config`` overrides the default partitioner config —
        production deployments pass ``SchedulerConfig(mesh=ShardingConfig)``
        here to shard the estimator's fleet axis across the cluster's devices
        (``docs/scaling.md``); the checkpoint path is unchanged because
        ``CheckpointManager`` gathers sharded leaves on save.  A config whose
        objective is the default (mean) still honors the run's
        ``partitioner_risk_aversion`` — opting into sharding must not
        silently drop risk-sensitive partitioning.  Any non-default
        objective wins as-is; note ``Objective.mean()`` IS the default, so
        to force a plain mean objective against a run that sets
        ``partitioner_risk_aversion``, set the run's risk aversion to 0."""
        self.run = run
        self.cfg = run.model
        self.cluster = cluster
        self.mesh_info = mesh_info
        self.m = num_microbatches or max(run.shape.global_batch // 8, 1)

        key = jax.random.PRNGKey(run.seed)
        self.params = model_zoo.init_model_params(key, self.cfg)
        self.opt_state = adamw.init(self.params)
        self.step = 0

        self.ctx = ApplyCtx(mode="train", mesh_info=mesh_info, remat=run.remat)
        compression = None
        self._ef = None
        if run.grad_compression != "none":
            compression, init_ef = make_compressor(run.grad_compression, None)
            self._ef = init_ef(self.params)

        self._step_fn = jax.jit(
            ts.make_train_step(
                self.cfg, run, ctx=self.ctx,
                num_microbatches=self.m, compression=compression,
            )
        )

        self.data = DataIterator(
            vocab_size=self.cfg.vocab_size,
            seq_len=run.shape.seq_len,
            global_batch=run.shape.global_batch,
            num_microbatches=self.m,
            seed=run.seed,
        )
        self.ckpt = CheckpointManager(run.checkpoint_dir, keep=run.keep_checkpoints)

        # --- the paper's scheduler -----------------------------------------
        self.partitioner = None
        self.monitor = None
        self._mb_weights = np.ones(self.m, np.float32)
        self._worker_of_mb = None
        if run.partitioner_enabled and cluster is not None:
            ra = run.partitioner_risk_aversion
            sched_cfg = scheduler_config or SchedulerConfig(mu_guess=1.0)
            if sched_cfg.objective == Objective():
                sched_cfg = dataclasses.replace(
                    sched_cfg,
                    objective=Objective.mean_var(ra) if ra else Objective.mean(),
                )
            self.partitioner = Scheduler(
                cluster.num_workers,
                config=sched_cfg,
                seed=run.seed,
            )
            self.monitor = FaultToleranceMonitor(
                self.partitioner,
                straggler_sigma=run.straggler_threshold_sigma,
                heartbeat_timeout=1e9,  # simulated clock; evict on inf times
            )
            self._assign_microbatches(equal=True)
        self._telemetry_f: List[np.ndarray] = []
        self._telemetry_t: List[np.ndarray] = []

    # ------------------------------------------------------------------ utils
    def _assign_microbatches(self, equal: bool = False) -> np.ndarray:
        """Map microbatches to workers per the current frontier split."""
        k = self.partitioner.num_workers
        if equal:
            counts = np.full(k, self.m // k, np.int64)
            counts[: self.m % k] += 1
        else:
            counts = self.partitioner.propose_microbatches(self.m)
        owner = np.repeat(np.arange(k), counts)[: self.m]
        self._worker_of_mb = owner
        return counts

    def current_fracs(self) -> np.ndarray:
        k = self.partitioner.num_workers
        counts = np.bincount(self._worker_of_mb, minlength=k)
        return counts / counts.sum()

    # ------------------------------------------------------------------ resume
    def _ckpt_tree(self) -> Any:
        """Everything checkpointed as one pytree; the scheduler's beliefs are
        part of it, so a restart no longer forgets what the estimator learned."""
        tree = {"params": self.params, "opt_state": self.opt_state}
        if self.partitioner is not None:
            tree["sched"] = self.partitioner.state
        return tree

    def try_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        try:
            restored, extra = self.ckpt.restore(self._ckpt_tree())
        except ValueError:
            # Checkpoint structure drifted (partitioner toggled, legacy
            # scheduler state layout, ...): the model-only restore still
            # works when the checkpoint was written without scheduler
            # leaves.  If the array layout cannot satisfy even that (e.g.
            # the checkpoint HAS scheduler leaves of an old shape), the
            # checkpoint is unusable — start fresh rather than crash.
            try:
                restored, extra = self.ckpt.restore(
                    {"params": self.params, "opt_state": self.opt_state}
                )
            except ValueError:
                return False
        self.params = restored["params"]
        self.opt_state = restored["opt_state"]
        sched_state = restored.get("sched")
        if self.partitioner is not None and sched_state is not None:
            # Adopt saved beliefs only if the fleet shape still matches
            # (an eviction between save and restart invalidates them).
            if len(sched_state.ewma_ll) == self.partitioner.num_workers:
                self.partitioner.state = sched_state
                self._assign_microbatches(equal=False)
        self.step = int(extra["step"])
        self.data.load_state_dict(extra["data_state"])
        return True

    def save(self) -> None:
        self.ckpt.save(
            self.step,
            self._ckpt_tree(),
            {"step": self.step, "data_state": self.data.state_dict()},
        )

    # ------------------------------------------------------------------ loop
    def train(self, steps: int, log_every: int = 10) -> TrainerReport:
        losses, splits, makespans = [], [], []
        run = self.run
        for _ in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(self.data).items()}
            weights = jnp.asarray(self._mb_weights)
            if self._ef is not None:
                self.params, self.opt_state, metrics, self._ef = self._step_fn(
                    self.params, self.opt_state, batch,
                    jnp.asarray(self.step), weights, self._ef,
                )
            else:
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch, jnp.asarray(self.step), weights
                )
            loss = float(metrics["loss"])
            losses.append(loss)
            self.step += 1

            # ---- telemetry + the paper's scheduler -------------------------
            if self.partitioner is not None:
                fracs = self.current_fracs()
                times = self.cluster.step_times(fracs)
                flags = self.monitor.observe_step(fracs, times)
                makespans.append(
                    float(np.max(times[np.isfinite(times)]))
                    if np.isfinite(times).any() else float("inf")
                )
                self._telemetry_f.append(fracs)
                self._telemetry_t.append(np.where(np.isfinite(times), times, 1e6))

                if flags["failures"].any():
                    # elastic: evict, re-split, checkpoint the new world
                    alive = ~flags["failures"]
                    self.cluster.specs = [
                        s for s, a in zip(self.cluster.specs, alive) if a
                    ]
                    self.monitor.evict(flags["failures"])
                    self._assign_microbatches(equal=False)
                    # telemetry collected for the old fleet shape is stale
                    self._telemetry_f.clear()
                    self._telemetry_t.clear()
                    self.save()

                if self.step % run.partitioner_refit_every == 0 and self._telemetry_f:
                    f = np.stack(self._telemetry_f, axis=1)  # (K, N)
                    t = np.stack(self._telemetry_t, axis=1)
                    self.partitioner.observe(
                        Telemetry(jnp.asarray(f), jnp.asarray(t))
                    )
                    counts = self._assign_microbatches(equal=False)
                    splits.append(counts.copy())
                    self._telemetry_f.clear()
                    self._telemetry_t.clear()

            if self.step % run.checkpoint_every == 0:
                self.save()
        self.ckpt.wait()
        events = self.monitor.events if self.monitor else []
        return TrainerReport(self.step, losses, splits, makespans, events)
