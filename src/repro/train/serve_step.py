"""Serving steps: prefill (build the cache) and decode (one token vs cache).

These are the functions the decode_32k / long_500k / prefill_32k cells lower.
Sampling is greedy/temperature from the last-position logits; the server
driver (examples/serve_partitioned.py) batches requests and uses the paper's
partitioner to split them across heterogeneous replicas.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model_zoo
from repro.models.layers import ApplyCtx

Array = jax.Array


def make_prefill_step(cfg: ModelConfig, *, ctx: ApplyCtx) -> Callable:
    def prefill_fn(params, batch: Dict[str, Array], cache):
        logits, cache = model_zoo.prefill(cfg, params, batch, cache, ctx=ctx)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return token, cache

    return prefill_fn


def make_decode_step(cfg: ModelConfig, *, ctx: ApplyCtx) -> Callable:
    def decode_fn(params, token: Array, cache):
        logits, cache = model_zoo.decode_step(cfg, params, token, cache, ctx=ctx)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, cache

    return decode_fn


def generate(
    cfg: ModelConfig,
    params,
    batch: Dict[str, Array],
    max_len: int,
    steps: int,
    *,
    ctx_prefill: ApplyCtx,
    ctx_decode: ApplyCtx,
) -> Array:
    """Greedy generation loop (CPU examples; the cells lower single steps)."""
    b = batch["tokens"].shape[0]
    cache = model_zoo.init_cache(cfg, b, max_len, jnp.float32)
    token, cache = make_prefill_step(cfg, ctx=ctx_prefill)(params, batch, cache)
    outs = [token]

    decode_fn = jax.jit(make_decode_step(cfg, ctx=ctx_decode))
    for _ in range(steps - 1):
        token, cache = decode_fn(params, token, cache)
        outs.append(token)
    return jnp.concatenate(outs, axis=1)
