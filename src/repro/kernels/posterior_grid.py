"""Pallas TPU kernel for the paper's numerical-integration hot spot.

Evaluates the unnormalized log-posteriors of BOTH scaling exponents (alpha,
Eq 10, and beta, Eq 11) on a G-point grid against N telemetry observations,
for a whole fleet of K workers, in ONE kernel launch:

    logp_a[k, g] = -lam_k/2 * sum_n m_kn * ((t_kn - f_kn^g mu_k) f_kn^-beta_k)^2 + prior(g)
    logp_b[k, g] = -lam_k/2 * sum_n m_kn * ((t_kn - f_kn^alpha_k mu_k) f_kn^-g)^2
                   - g * sum_n m_kn log f_kn + prior(g)

Cost is O(K*G*N) transcendental-heavy VPU work — the dominant compute of
every Gibbs sweep once telemetry is production-sized.  Both modes share the
single expensive pow table pg = exp(g * log f): the alpha mode consumes pg
and pg^2, the beta mode 1/pg^2, so one launch over one pass of t/f/log f
replaces the legacy two-launch (alpha then beta) schedule and halves memory
traffic.  The quadratic form is expanded into three masked inner products

    S_a(g) = A0 - 2 mu <pg, m wb^2 t> + mu^2 <pg^2, m wb^2>,   wb = f^-beta
    S_b(g) = <1/pg^2, m r^2>,                                  r = t - f^alpha mu

so the per-cell op count collapses to one exp + one reciprocal + three
multiply-accumulate passes (the pure-jnp oracle
``repro.core.moments.log_posterior_grid`` uses the identical formulation, so
interpret-mode parity is tight).

TPU mapping:
  * fleet axis      -> leading pallas grid dimension (one program row per worker)
  * grid axis       -> lanes (BG = 128-aligned blocks)
  * observation axis -> streamed VMEM blocks (BN), reduced sequentially via
    the revisiting-output accumulation pattern: pallas grid = (K, G/BG, N/BN);
    both output blocks for a given (k, g-tile) stay resident in VMEM while
    the inner n-loop accumulates into them.
  * per-worker scalars (mu, lam, alpha, beta, priors, sum_logf) ride in a
    packed (1, 16) parameter row mapped to every block of worker k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BLOCK_G = 128
DEFAULT_BLOCK_N = 512

_PARAM_WIDTH = 16  # lane-padded per-worker scalar row


def _fleet_kernel(params_ref, grid_ref, t_ref, f_ref, mask_ref, out_a_ref, out_b_ref):
    ni = pl.program_id(2)

    mu = params_ref[0, 0]
    lam = params_ref[0, 1]
    alpha = params_ref[0, 2]
    beta = params_ref[0, 3]
    a_a = params_ref[0, 4]
    a_b = params_ref[0, 5]
    b_a = params_ref[0, 6]
    b_b = params_ref[0, 7]
    sum_logf = params_ref[0, 8]

    g = grid_ref[0, :]  # (BG,)
    f = jnp.maximum(f_ref[0, :], 1e-6)  # (BN,)
    logf = jnp.log(f)
    t = t_ref[0, :]
    m = mask_ref[0, :]

    # One pow table serves both exponents: pg = f^g per (grid, obs) cell.
    pg = jnp.exp(g[:, None] * logf[None, :])  # (BG, BN)
    pg2 = pg * pg
    ipg2 = 1.0 / pg2

    # alpha mode, expanded: S_a = A0 - 2 mu <pg, u> + mu^2 <pg^2, v>
    wb2 = m * jnp.exp(-2.0 * beta * logf)  # m * f^{-2 beta}  (BN,)
    u = wb2 * t
    a0 = jnp.sum(u * t)
    quad_a = -0.5 * lam * (
        a0
        - 2.0 * mu * jnp.sum(pg * u[None, :], axis=1)
        + mu * mu * jnp.sum(pg2 * wb2[None, :], axis=1)
    )  # (BG,)

    # beta mode: S_b = <1/pg^2, m r^2>
    r = t - jnp.exp(alpha * logf) * mu  # (BN,)
    quad_b = -0.5 * lam * jnp.sum(ipg2 * (m * r * r)[None, :], axis=1)  # (BG,)

    @pl.when(ni == 0)
    def _init():
        gc = jnp.clip(g, 1e-6, 1.0 - 1e-6)
        lg = jnp.log(gc)
        l1mg = jnp.log1p(-gc)
        out_a_ref[0, :] = (a_a - 1.0) * lg + (a_b - 1.0) * l1mg + quad_a
        out_b_ref[0, :] = (b_a - 1.0) * lg + (b_b - 1.0) * l1mg - g * sum_logf + quad_b

    @pl.when(ni != 0)
    def _acc():
        out_a_ref[0, :] = out_a_ref[0, :] + quad_a
        out_b_ref[0, :] = out_b_ref[0, :] + quad_b


@functools.partial(
    jax.jit,
    static_argnames=("block_g", "block_n", "interpret"),
)
def posterior_grid_fleet_pallas(
    grid: Array,
    t: Array,
    f: Array,
    mask: Array,
    mu: Array,
    lam: Array,
    alpha: Array,
    beta: Array,
    alpha_prior_a: Array,
    alpha_prior_b: Array,
    beta_prior_a: Array,
    beta_prior_b: Array,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> Array:
    """Fused fleet evaluation of both exponent log-posteriors.

    Shapes: grid (G,); t/f/mask (K, N); mu/lam/alpha/beta and the four prior
    leaves (K,).  Returns (K, 2, G) f32 — [:, 0] is the alpha posterior
    (which consumes beta), [:, 1] the beta posterior (which consumes alpha).

    Inputs are padded to block multiples here; padding observations carry
    mask=0 (exact no-op on the reduction), padding grid points are sliced off.
    One ``pallas_call`` covers every worker and both exponents.
    """
    k, n = t.shape
    g_n = grid.shape[0]
    bg = min(block_g, max(8, g_n))
    bn = min(block_n, max(128, n))

    g_pad = (-g_n) % bg
    n_pad = (-n) % bn
    # Pad grid with interior values (0.5): finite logs, sliced off below.
    grid_p = jnp.pad(grid.astype(jnp.float32), (0, g_pad), constant_values=0.5)
    t_p = jnp.pad(t.astype(jnp.float32), ((0, 0), (0, n_pad)))
    f_p = jnp.pad(f.astype(jnp.float32), ((0, 0), (0, n_pad)), constant_values=0.5)
    mask_p = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, n_pad)))

    f_safe = jnp.maximum(f.astype(jnp.float32), 1e-6)
    sum_logf = jnp.sum(jnp.log(f_safe) * mask.astype(jnp.float32), axis=-1)  # (K,)

    as_k = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (k,))
    params = jnp.stack(
        [
            as_k(mu),
            as_k(lam),
            as_k(alpha),
            as_k(beta),
            as_k(alpha_prior_a),
            as_k(alpha_prior_b),
            as_k(beta_prior_a),
            as_k(beta_prior_b),
            sum_logf,
        ],
        axis=1,
    )  # (K, 9)
    params = jnp.pad(params, ((0, 0), (0, _PARAM_WIDTH - params.shape[1])))

    n_gb = grid_p.shape[0] // bg
    n_nb = t_p.shape[1] // bn

    out_a, out_b = pl.pallas_call(
        _fleet_kernel,
        grid=(k, n_gb, n_nb),
        in_specs=[
            pl.BlockSpec((1, _PARAM_WIDTH), lambda ki, gi, ni: (ki, 0)),  # params
            pl.BlockSpec((1, bg), lambda ki, gi, ni: (0, gi)),  # grid
            pl.BlockSpec((1, bn), lambda ki, gi, ni: (ki, ni)),  # t
            pl.BlockSpec((1, bn), lambda ki, gi, ni: (ki, ni)),  # f
            pl.BlockSpec((1, bn), lambda ki, gi, ni: (ki, ni)),  # mask
        ],
        out_specs=[
            pl.BlockSpec((1, bg), lambda ki, gi, ni: (ki, gi)),
            pl.BlockSpec((1, bg), lambda ki, gi, ni: (ki, gi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, grid_p.shape[0]), jnp.float32),
            jax.ShapeDtypeStruct((k, grid_p.shape[0]), jnp.float32),
        ],
        interpret=interpret,
    )(
        params,
        grid_p[None, :],
        t_p,
        f_p,
        mask_p,
    )
    return jnp.stack([out_a[:, :g_n], out_b[:, :g_n]], axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "block_g", "block_n", "interpret"),
)
def posterior_grid_pallas(
    grid: Array,
    t: Array,
    f: Array,
    mask: Array,
    mu: Array,
    lam: Array,
    other_exp: Array,
    prior_a: Array,
    prior_b: Array,
    *,
    mode: str = "alpha",
    block_g: int = DEFAULT_BLOCK_G,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> Array:
    """Single-unit, single-mode evaluation.  Returns (G,) f32.

    Kept as a K=1 slice of the fused fleet kernel: ``other_exp`` is the held
    exponent the requested mode consumes, the unused mode's inputs are
    interior dummies and its output row is discarded.  Note the kernel body
    is opaque to XLA, so the discarded mode IS computed — callers that need
    both exponents should call ``posterior_grid_fleet_pallas`` once instead
    of this entry twice (that is the whole point of the fusion); this slice
    exists for validation and back-compat.
    """
    if mode not in ("alpha", "beta"):
        raise ValueError(mode)
    dummy = jnp.float32(0.5)
    if mode == "alpha":
        alpha, beta = dummy, other_exp
        a_prior = (prior_a, prior_b)
        b_prior = (jnp.float32(2.0), jnp.float32(2.0))
    else:
        alpha, beta = other_exp, dummy
        a_prior = (jnp.float32(2.0), jnp.float32(2.0))
        b_prior = (prior_a, prior_b)
    out = posterior_grid_fleet_pallas(
        grid,
        t[None, :],
        f[None, :],
        mask[None, :],
        mu,
        lam,
        alpha,
        beta,
        a_prior[0],
        a_prior[1],
        b_prior[0],
        b_prior[1],
        block_g=block_g,
        block_n=block_n,
        interpret=interpret,
    )
    return out[0, 0 if mode == "alpha" else 1]
