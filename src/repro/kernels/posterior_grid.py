"""Pallas TPU kernel for the paper's numerical-integration hot spot.

Evaluates the unnormalized log-posterior of a scaling exponent (alpha, Eq 10,
or beta, Eq 11) on a G-point grid against N telemetry observations:

    logp[g] = -lam/2 * sum_n mask_n * z(g, n)^2  (+ grid-only prior terms)

    alpha mode: z = (t_n - f_n^g * mu) * f_n^{-beta}
    beta  mode: z = (t_n - f_n^alpha * mu) * f_n^{-g}

Cost is O(G*N) transcendental-heavy VPU work — the dominant compute of every
Gibbs sweep once telemetry is production-sized (fleet-days of step times).

TPU mapping:
  * grid axis  -> lanes   (BG = 128-aligned blocks)
  * observation axis -> streamed VMEM blocks (BN), reduced sequentially via
    the revisiting-output accumulation pattern: pallas grid = (G/BG, N/BN),
    the output block for a given g-tile stays resident in VMEM while the
    inner n-loop accumulates into it.
  * scalars (mu, lam, other exponent, prior a/b, sum_logf) ride in a packed
    (1, 8) parameter row mapped to every block.

The pure-jnp oracle is ``repro.kernels.ref.posterior_grid_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BLOCK_G = 128
DEFAULT_BLOCK_N = 512


def _kernel(params_ref, grid_ref, t_ref, f_ref, mask_ref, out_ref, *, mode: str):
    ni = pl.program_id(1)

    mu = params_ref[0, 0]
    lam = params_ref[0, 1]
    other = params_ref[0, 2]
    prior_a = params_ref[0, 3]
    prior_b = params_ref[0, 4]
    sum_logf = params_ref[0, 5]

    g = grid_ref[0, :]  # (BG,)
    gcol = g[:, None]  # (BG, 1)
    f = jnp.maximum(f_ref[0, :], 1e-6)
    logf = jnp.log(f)[None, :]  # (1, BN)
    t = t_ref[0, :][None, :]  # (1, BN)
    m = mask_ref[0, :][None, :]  # (1, BN)

    if mode == "alpha":
        # z = (t - f^g mu) * f^{-beta}
        mean = jnp.exp(gcol * logf) * mu  # (BG, BN)
        z = (t - mean) * jnp.exp(-other * logf)
    else:
        # z = (t - f^alpha mu) * f^{-g}
        resid = t - jnp.exp(other * logf) * mu  # (1, BN)
        z = resid * jnp.exp(-gcol * logf)

    sq = z * z * m
    partial = -0.5 * lam * jnp.sum(sq, axis=1)  # (BG,)

    @pl.when(ni == 0)
    def _init():
        gc = jnp.clip(g, 1e-6, 1.0 - 1e-6)
        init = (prior_a - 1.0) * jnp.log(gc) + (prior_b - 1.0) * jnp.log1p(-gc)
        if mode == "beta":
            init = init - g * sum_logf
        out_ref[0, :] = init + partial

    @pl.when(ni != 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] + partial


@functools.partial(
    jax.jit,
    static_argnames=("mode", "block_g", "block_n", "interpret"),
)
def posterior_grid_pallas(
    grid: Array,
    t: Array,
    f: Array,
    mask: Array,
    mu: Array,
    lam: Array,
    other_exp: Array,
    prior_a: Array,
    prior_b: Array,
    *,
    mode: str = "alpha",
    block_g: int = DEFAULT_BLOCK_G,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> Array:
    """Tiled evaluation of the exponent log-posterior.  Returns (G,) f32.

    Inputs are padded to block multiples here; padding observations carry
    mask=0 (exact no-op on the reduction), padding grid points are sliced off.
    """
    if mode not in ("alpha", "beta"):
        raise ValueError(mode)
    g_n = grid.shape[0]
    n = t.shape[0]
    bg = min(block_g, max(8, g_n))
    bn = min(block_n, max(128, n))

    g_pad = (-g_n) % bg
    n_pad = (-n) % bn
    # Pad grid with interior values (0.5): they produce finite logs and are
    # discarded below.
    grid_p = jnp.pad(grid.astype(jnp.float32), (0, g_pad), constant_values=0.5)
    t_p = jnp.pad(t.astype(jnp.float32), (0, n_pad))
    f_p = jnp.pad(f.astype(jnp.float32), (0, n_pad), constant_values=0.5)
    mask_p = jnp.pad(mask.astype(jnp.float32), (0, n_pad))

    f_safe = jnp.maximum(f.astype(jnp.float32), 1e-6)
    sum_logf = jnp.sum(jnp.log(f_safe) * mask.astype(jnp.float32))
    params = jnp.stack(
        [
            jnp.asarray(mu, jnp.float32),
            jnp.asarray(lam, jnp.float32),
            jnp.asarray(other_exp, jnp.float32),
            jnp.asarray(prior_a, jnp.float32),
            jnp.asarray(prior_b, jnp.float32),
            sum_logf,
            jnp.float32(0.0),
            jnp.float32(0.0),
        ]
    )[None, :]

    n_gb = grid_p.shape[0] // bg
    n_nb = t_p.shape[0] // bn

    out = pl.pallas_call(
        functools.partial(_kernel, mode=mode),
        grid=(n_gb, n_nb),
        in_specs=[
            pl.BlockSpec((1, 8), lambda gi, ni: (0, 0)),  # params
            pl.BlockSpec((1, bg), lambda gi, ni: (0, gi)),  # grid
            pl.BlockSpec((1, bn), lambda gi, ni: (0, ni)),  # t
            pl.BlockSpec((1, bn), lambda gi, ni: (0, ni)),  # f
            pl.BlockSpec((1, bn), lambda gi, ni: (0, ni)),  # mask
        ],
        out_specs=pl.BlockSpec((1, bg), lambda gi, ni: (0, gi)),
        out_shape=jax.ShapeDtypeStruct((1, grid_p.shape[0]), jnp.float32),
        interpret=interpret,
    )(
        params,
        grid_p[None, :],
        t_p[None, :],
        f_p[None, :],
        mask_p[None, :],
    )
    return out[0, :g_n]
