"""Pallas TPU kernels for the framework's compute hot spots.

  * posterior_grid — the paper's O(K*G*N) exponent-posterior numerical
    integration (Eqs 10/11/16-18), the Gibbs sweep's dominant cost at
    production telemetry volumes; one fused launch evaluates every worker in
    the fleet and both exponents (alpha and beta) from a single pass over
    the telemetry.
  * decode_attention — flash-decode GQA attention over deep KV caches
    (the decode_32k serving cells).
  * lru_scan — blocked linear-recurrence scan (RG-LRU / SSM core; keeps the
    running state VMEM-resident so HBM sees each element exactly once).

``ops`` holds the jit'd public wrappers (interpret=True on CPU), ``ref`` the
pure-jnp oracles the kernels are validated against.
"""
from . import ops, ref
from .decode_attention import decode_attention_pallas
from .lru_scan import lru_scan_pallas
from .posterior_grid import posterior_grid_fleet_pallas, posterior_grid_pallas

__all__ = [
    "ops",
    "ref",
    "decode_attention_pallas",
    "lru_scan_pallas",
    "posterior_grid_fleet_pallas",
    "posterior_grid_pallas",
]
