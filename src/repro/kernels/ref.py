"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret=True
on CPU, real lowering on TPU).  They are deliberately straightforward.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def posterior_grid_ref(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    other_exp: Array,
    prior_a: Array,
    prior_b: Array,
    mask: Optional[Array] = None,
    *,
    mode: str = "alpha",
) -> Array:
    """Deprecated: unnormalized log-posterior of one scaling exponent.

    mode="alpha": Eq 10 — grid is alpha, other_exp is the current beta.
    mode="beta" : Eq 11 — grid is beta,  other_exp is the current alpha,
                  including the -beta * sum(log f) Jacobian term.

    Shapes: grid (G,), t/f/mask (N,) -> (G,).

    The unified oracle lives in ``repro.core.moments.log_posterior_grid``
    (fused both-modes, fleet-batched); this shim slices the requested mode
    out of it for callers of the historical per-mode signature.
    """
    import warnings

    warnings.warn(
        "repro.kernels.ref.posterior_grid_ref is deprecated; use "
        "repro.core.moments.log_posterior_grid (the fused both-modes fleet "
        "oracle) or its per-mode slices "
        "repro.core.moments.log_posterior_{alpha,beta}_ref.",
        DeprecationWarning,
        stacklevel=2,
    )
    if mode not in ("alpha", "beta"):
        raise ValueError(mode)
    from repro.core.moments import BetaParams, log_posterior_grid

    prior = BetaParams(jnp.asarray(prior_a, jnp.float32), jnp.asarray(prior_b, jnp.float32))
    dummy_prior = BetaParams.default()
    dummy = jnp.asarray(0.5, jnp.float32)
    if mode == "alpha":
        both = log_posterior_grid(
            grid, t, f, mu, lam, dummy, other_exp, prior, dummy_prior, mask
        )
        return both[..., 0, :]
    both = log_posterior_grid(
        grid, t, f, mu, lam, other_exp, dummy, dummy_prior, prior, mask
    )
    return both[..., 1, :]


def decode_attention_ref(
    q: Array,  # (B, H, D)
    k: Array,  # (B, S, KVH, D)
    v: Array,  # (B, S, KVH, D)
    length: Optional[Array] = None,  # (B,) valid cache lengths
    scale: Optional[float] = None,
) -> Array:
    """Single-token GQA attention against a KV cache.  Returns (B, H, D)."""
    b, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    groups = h // kvh
    scale = (d**-0.5) if scale is None else scale

    qg = q.reshape(b, kvh, groups, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    if length is not None:
        pos = jnp.arange(s)
        valid = pos[None, :] < length[:, None]  # (B, S)
        logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def lru_scan_ref(a: Array, b: Array, h0: Array) -> Array:
    """h_t = a_t * h_{t-1} + b_t via associative scan (log-depth oracle)."""
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    # fold h0 into the first step
    b32 = b32.at[:, 0].add(a32[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    _, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    return h.astype(a.dtype)
