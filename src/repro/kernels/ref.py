"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret=True
on CPU, real lowering on TPU).  They are deliberately straightforward.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def posterior_grid_ref(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    other_exp: Array,
    prior_a: Array,
    prior_b: Array,
    mask: Optional[Array] = None,
    *,
    mode: str = "alpha",
) -> Array:
    """Unnormalized log-posterior of a scaling exponent on a grid.

    mode="alpha": Eq 10 — grid is alpha, other_exp is the current beta.
    mode="beta" : Eq 11 — grid is beta,  other_exp is the current alpha,
                  including the -beta * sum(log f) Jacobian term.

    Shapes: grid (G,), t/f/mask (N,) -> (G,).
    """
    f = jnp.maximum(f, 1e-6)
    logf = jnp.log(f)
    m = None if mask is None else mask.astype(t.dtype)

    if mode == "alpha":
        mean = jnp.exp(grid[:, None] * logf[None, :]) * mu  # (G, N)
        z = (t[None, :] - mean) * jnp.exp(-other_exp * logf)[None, :]
        sq = z * z
        if m is not None:
            sq = sq * m[None, :]
        quad = -0.5 * lam * jnp.sum(sq, axis=-1)
        extra = jnp.zeros_like(quad)
    elif mode == "beta":
        resid = t - jnp.exp(other_exp * logf) * mu  # (N,)
        z = resid[None, :] * jnp.exp(-grid[:, None] * logf[None, :])
        sq = z * z
        if m is not None:
            sq = sq * m[None, :]
            sum_logf = jnp.sum(logf * m)
        else:
            sum_logf = jnp.sum(logf)
        quad = -0.5 * lam * jnp.sum(sq, axis=-1)
        extra = -grid * sum_logf
    else:
        raise ValueError(mode)

    g = jnp.clip(grid, 1e-6, 1.0 - 1e-6)
    return quad + extra + (prior_a - 1.0) * jnp.log(g) + (prior_b - 1.0) * jnp.log1p(-g)


def decode_attention_ref(
    q: Array,  # (B, H, D)
    k: Array,  # (B, S, KVH, D)
    v: Array,  # (B, S, KVH, D)
    length: Optional[Array] = None,  # (B,) valid cache lengths
    scale: Optional[float] = None,
) -> Array:
    """Single-token GQA attention against a KV cache.  Returns (B, H, D)."""
    b, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    groups = h // kvh
    scale = (d**-0.5) if scale is None else scale

    qg = q.reshape(b, kvh, groups, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    if length is not None:
        pos = jnp.arange(s)
        valid = pos[None, :] < length[:, None]  # (B, S)
        logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def lru_scan_ref(a: Array, b: Array, h0: Array) -> Array:
    """h_t = a_t * h_{t-1} + b_t via associative scan (log-depth oracle)."""
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    # fold h0 into the first step
    b32 = b32.at[:, 0].add(a32[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    _, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    return h.astype(a.dtype)
