"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes eagerly against the oracle semantics; on TPU they lower
to real Mosaic kernels.  The switch is automatic via ``jax.default_backend``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_pallas
from .lru_scan import lru_scan_pallas
from .posterior_grid import posterior_grid_fleet_pallas, posterior_grid_pallas

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def use_pallas_default() -> bool:
    """Auto policy for routing the estimation stack through the kernels.

    On TPU the Mosaic lowering is the production path; elsewhere the XLA
    oracle is faster than interpret-mode emulation, so callers that pass
    ``use_pallas=None`` get the kernel exactly where it wins.
    """
    return jax.default_backend() == "tpu"


def posterior_grid_fleet(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    alpha: Array,
    beta: Array,
    alpha_prior,
    beta_prior,
    mask: Optional[Array] = None,
    *,
    sharding=None,
    active_idx: Optional[Array] = None,
    out_prev: Optional[Array] = None,
) -> Array:
    """Both exponent posteriors for a whole fleet in one kernel launch.

    Signature mirrors ``repro.core.moments.log_posterior_grid``: t/f/mask
    (K, N), per-worker scalars (K,) -> (K, 2, G).

    ``active_idx`` (an (M,) int array, M static) launches the kernel over the
    gathered M-worker slab only: inputs are gathered, the fused kernel runs
    on (M, N) rows, and the (M, 2, G) result is scattered back into
    ``out_prev`` (a persistent (K, 2, G) grid cache; zeros when omitted) via
    ``lax.scatter``.  With ``active_idx = arange(K)`` the output rows are
    bitwise the dense launch — per-worker math never mixes fleet rows.
    Single-device only (the gather is a cross-shard op); combine with
    ``sharding=None``.

    Stacked leading axes are folded into the fleet axis before the launch:
    a workflow DAG's (S, K, N) telemetry block (per-stage scalars (S, K))
    is presented to the kernel as one S*K-worker fleet and the (S*K, 2, G)
    output is unfolded back — the kernel itself never changes, and the whole
    DAG still costs ONE launch.

    ``sharding`` (a ``repro.core.sharding.ShardingConfig``, duck-typed so
    this bottom layer stays import-free of ``core``) partitions the
    (possibly folded) fleet axis across the mesh's workers axis with
    ``shard_map``: each device runs the same fused kernel on its K/n_shards
    rows against the replicated grid, telemetry never leaves its shard, and
    only the tiny (K, 2, G) log-posterior output crosses devices — lazily,
    when a consumer (moment integration, proposal solving) gathers it.
    K % n_shards != 0 pads with masked-out rows, sliced off on return.
    """
    if mask is None:
        mask = jnp.ones_like(t)
    if active_idx is not None and t.ndim == 2:
        if sharding is not None:
            raise ValueError(
                "active_idx is a single-device path; pass sharding=None"
            )
        take_kn = lambda x: x[active_idx]
        take_k = lambda x: jnp.broadcast_to(
            jnp.asarray(x, jnp.float32), t.shape[:1]
        )[active_idx]
        slab = posterior_grid_fleet(
            grid, take_kn(t), take_kn(f),
            take_k(mu), take_k(lam), take_k(alpha), take_k(beta),
            type(alpha_prior)(take_k(alpha_prior.a), take_k(alpha_prior.b)),
            type(beta_prior)(take_k(beta_prior.a), take_k(beta_prior.b)),
            take_kn(mask),
        )
        base = (
            jnp.zeros((t.shape[0],) + slab.shape[1:], slab.dtype)
            if out_prev is None else out_prev
        )
        return base.at[active_idx].set(slab)
    lead = t.shape[:-1]
    if t.ndim > 2:
        n = t.shape[-1]
        flat_kn = lambda x: jnp.reshape(x, (-1, n))
        flat_k = lambda x: jnp.reshape(
            jnp.broadcast_to(jnp.asarray(x, jnp.float32), lead), (-1,)
        )
        out = posterior_grid_fleet(
            grid, flat_kn(t), flat_kn(f),
            flat_k(mu), flat_k(lam), flat_k(alpha), flat_k(beta),
            type(alpha_prior)(flat_k(alpha_prior.a), flat_k(alpha_prior.b)),
            type(beta_prior)(flat_k(beta_prior.a), flat_k(beta_prior.b)),
            flat_kn(mask),
            sharding=sharding,
        )
        return jnp.reshape(out, lead + out.shape[1:])

    per_k = lambda x: jnp.broadcast_to(
        jnp.asarray(x, jnp.float32), t.shape[:1]
    )
    args = (
        t, f, mask,
        per_k(mu), per_k(lam), per_k(alpha), per_k(beta),
        per_k(alpha_prior.a), per_k(alpha_prior.b),
        per_k(beta_prior.a), per_k(beta_prior.b),
    )
    launch = lambda *a: posterior_grid_fleet_pallas(
        grid, *a, interpret=_interpret()
    )
    if sharding is None:
        return launch(*args)

    from repro.core.sharding import (  # deferred: keeps the layer acyclic
        shard_fleet_call,
    )

    # Rows added by the pad (K % n_shards != 0) are fully masked: they
    # yield a prior-only posterior row that is sliced off and never
    # consulted.
    return shard_fleet_call(launch, sharding, args, mask_index=2)


def posterior_grid_alpha(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    beta: Array,
    prior,
    mask: Optional[Array] = None,
) -> Array:
    """Eq 10 on a grid via the Pallas kernel.  Signature mirrors
    ``repro.core.moments.log_posterior_alpha_ref``.

    Back-compat single-mode entry: it slices one row out of the fused K=1
    kernel, which still computes both exponents — production code wanting
    both should call ``posterior_grid_fleet`` once."""
    if mask is None:
        mask = jnp.ones_like(t)
    return posterior_grid_pallas(
        grid, t, f, mask, mu, lam, beta, prior.a, prior.b,
        mode="alpha", interpret=_interpret(),
    )


def posterior_grid_beta(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    alpha: Array,
    prior,
    mask: Optional[Array] = None,
) -> Array:
    """Eq 11 on a grid via the Pallas kernel (back-compat single-mode slice
    of the fused kernel — see ``posterior_grid_alpha``)."""
    if mask is None:
        mask = jnp.ones_like(t)
    return posterior_grid_pallas(
        grid, t, f, mask, mu, lam, alpha, prior.a, prior.b,
        mode="beta", interpret=_interpret(),
    )


def decode_attention(
    q: Array,
    k: Array,
    v: Array,
    length: Optional[Array] = None,
    *,
    block_s: int = 512,
) -> Array:
    """Flash-decode GQA attention (B,H,D) x (B,S,KVH,D) -> (B,H,D)."""
    if length is None:
        length = jnp.full((q.shape[0],), k.shape[1], jnp.int32)
    return decode_attention_pallas(
        q, k, v, length, block_s=block_s, interpret=_interpret()
    )


def lru_scan(a: Array, b: Array, h0: Optional[Array] = None, *, block_t: int = 128) -> Array:
    """Linear-recurrence scan h_t = a_t h_{t-1} + b_t (RG-LRU / SSM core)."""
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), a.dtype)
    return lru_scan_pallas(a, b, h0, block_t=block_t, interpret=_interpret())
