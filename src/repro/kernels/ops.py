"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes eagerly against the oracle semantics; on TPU they lower
to real Mosaic kernels.  The switch is automatic via ``jax.default_backend``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_pallas
from .lru_scan import lru_scan_pallas
from .posterior_grid import posterior_grid_pallas

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def posterior_grid_alpha(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    beta: Array,
    prior,
    mask: Optional[Array] = None,
) -> Array:
    """Eq 10 on a grid via the Pallas kernel.  Signature mirrors
    ``repro.core.moments.log_posterior_alpha_ref``."""
    if mask is None:
        mask = jnp.ones_like(t)
    return posterior_grid_pallas(
        grid, t, f, mask, mu, lam, beta, prior.a, prior.b,
        mode="alpha", interpret=_interpret(),
    )


def posterior_grid_beta(
    grid: Array,
    t: Array,
    f: Array,
    mu: Array,
    lam: Array,
    alpha: Array,
    prior,
    mask: Optional[Array] = None,
) -> Array:
    """Eq 11 on a grid via the Pallas kernel."""
    if mask is None:
        mask = jnp.ones_like(t)
    return posterior_grid_pallas(
        grid, t, f, mask, mu, lam, alpha, prior.a, prior.b,
        mode="beta", interpret=_interpret(),
    )


def decode_attention(
    q: Array,
    k: Array,
    v: Array,
    length: Optional[Array] = None,
    *,
    block_s: int = 512,
) -> Array:
    """Flash-decode GQA attention (B,H,D) x (B,S,KVH,D) -> (B,H,D)."""
    if length is None:
        length = jnp.full((q.shape[0],), k.shape[1], jnp.int32)
    return decode_attention_pallas(
        q, k, v, length, block_s=block_s, interpret=_interpret()
    )


def lru_scan(a: Array, b: Array, h0: Optional[Array] = None, *, block_t: int = 128) -> Array:
    """Linear-recurrence scan h_t = a_t h_{t-1} + b_t (RG-LRU / SSM core)."""
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), a.dtype)
    return lru_scan_pallas(a, b, h0, block_t=block_t, interpret=_interpret())
