"""Pallas TPU flash-decode kernel: single-token GQA attention over a KV cache.

The serving hot spot for the ``decode_32k`` cells: one new query token per
sequence attends to a seq_len-deep KV cache.  Arithmetic intensity is ~O(1)
FLOP/byte (every cache byte is read once per step), so the kernel's job is to
stream the cache through VMEM at full HBM bandwidth with an online softmax —
no (B, H, S) logits ever materialize in HBM.

TPU mapping:
  * pallas grid = (B, KVH, S/BS); the S axis is innermost so the output block
    and the (m, l, acc) running statistics stay VMEM-resident per (b, kv-head).
  * GQA: the H = KVH * G query heads are reshaped to (KVH, G) and the G group
    dim rides the sublane axis, giving (G, D) x (D, BS) MXU matmuls — the TPU
    analogue of the GPU broadcast-q-across-warps trick.
  * online softmax in f32 scratch (m, l running max/denominator), cache
    blocks may be bf16.
  * variable cache fill handled by a per-sequence ``length`` scalar; blocks
    fully beyond length are skipped via @pl.when (no HBM traffic for the
    unfilled tail).

Oracle: ``repro.kernels.ref.decode_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, block_s, scale):
    si = pl.program_id(2)
    n_s = pl.num_programs(2)
    length = len_ref[0, 0]

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Skip blocks entirely beyond the valid cache fill.
    @pl.when(si * block_s < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (BS, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (BS, D)

        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, BS)

        pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(pos < length, logits, NEG_INF)

        m_prev = m_ref[...]  # (G, 1)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)  # (G, BS)
        corr = jnp.exp(m_prev - m_new)  # (G, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(si == n_s - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(
    q: Array,  # (B, H, D)
    k: Array,  # (B, S, KVH, D)
    v: Array,  # (B, S, KVH, D)
    length: Array,  # (B,) int32 valid cache lengths
    *,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> Array:
    """Flash-decode GQA attention.  Returns (B, H, D) in q.dtype."""
    b, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    if h % kvh != 0:
        raise ValueError(f"H={h} not divisible by KVH={kvh}")
    g = h // kvh
    scale = d**-0.5

    bs = min(block_s, s)
    s_pad = (-s) % bs
    if s_pad:
        k = jnp.pad(k, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    s_p = s + s_pad
    n_sb = s_p // bs

    qg = q.reshape(b, kvh, g, d)
    kt = k.transpose(0, 2, 1, 3)  # (B, KVH, S, D)
    vt = v.transpose(0, 2, 1, 3)
    len2 = length.astype(jnp.int32).reshape(b, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, block_s=bs, scale=scale),
        grid=(b, kvh, n_sb),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, ki, si: (bi, 0)),  # length
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),  # q
            pl.BlockSpec((1, 1, bs, d), lambda bi, ki, si: (bi, ki, si, 0)),  # k
            pl.BlockSpec((1, 1, bs, d), lambda bi, ki, si: (bi, ki, si, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),  # running max m
            pltpu.VMEM((g, 1), jnp.float32),  # running denom l
            pltpu.VMEM((g, d), jnp.float32),  # weighted-value accumulator
        ],
        interpret=interpret,
    )(len2, qg, kt, vt)
    return out.reshape(b, h, d)
