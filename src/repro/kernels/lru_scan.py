"""Pallas TPU kernel: blocked linear-recurrence scan (RG-LRU / SSM core).

Computes h_t = a_t * h_{t-1} + b_t along time for (B, T, R) gate/input
streams — the sequential core of RecurrentGemma's RG-LRU and the state
update of linear-attention SSMs.  This is the op that makes the long_500k
cells O(T) instead of O(T^2).

TPU mapping:
  * R (channel) axis -> lanes (128-aligned blocks), B -> sublane-tiled rows;
  * time is blocked: pallas grid = (B_blocks, R_blocks, T/BT) with the
    running state h carried in a VMEM scratch across sequential T steps —
    HBM traffic is exactly one read of (a, b) and one write of h (the
    associative-scan alternative does log T passes over HBM);
  * within a block the recurrence unrolls BT elementwise FMAs on the VPU.

Oracle: ``repro.kernels.ref.lru_scan_ref`` (associative-scan based).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_R = 256


def _kernel(a_ref, b_ref, h0_ref, out_ref, h_ref, *, block_t):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[:, 0, :].astype(jnp.float32)

    h = h_ref[...]  # (BB, BR) f32 running state
    for t in range(block_t):
        a_t = a_ref[:, t, :].astype(jnp.float32)
        b_t = b_ref[:, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        out_ref[:, t, :] = h.astype(out_ref.dtype)
    h_ref[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_r", "interpret")
)
def lru_scan_pallas(
    a: Array,  # (B, T, R) decay gates in (0, 1]
    b: Array,  # (B, T, R) inputs
    h0: Array,  # (B, R) initial state
    *,
    block_t: int = DEFAULT_BLOCK_T,
    block_r: int = DEFAULT_BLOCK_R,
    interpret: bool = False,
) -> Array:
    """Returns h (B, T, R) with h_t = a_t * h_{t-1} + b_t, h_0 folded in."""
    bsz, t, r = a.shape
    bt = min(block_t, t)
    br = min(block_r, r)
    t_pad = (-t) % bt
    r_pad = (-r) % br
    if t_pad or r_pad:
        pad3 = ((0, 0), (0, t_pad), (0, r_pad))
        a = jnp.pad(a, pad3)  # a=0 in padding keeps h finite
        b = jnp.pad(b, pad3)
        h0 = jnp.pad(h0, ((0, 0), (0, r_pad)))
    t_p, r_p = t + t_pad, r + r_pad

    out = pl.pallas_call(
        functools.partial(_kernel, block_t=bt),
        grid=(bsz, r_p // br, t_p // bt),
        in_specs=[
            pl.BlockSpec((1, bt, br), lambda bi, ri, ti: (bi, ti, ri)),  # a
            pl.BlockSpec((1, bt, br), lambda bi, ri, ti: (bi, ti, ri)),  # b
            pl.BlockSpec((1, 1, br), lambda bi, ri, ti: (bi, 0, ri)),  # h0
        ],
        out_specs=pl.BlockSpec((1, bt, br), lambda bi, ri, ti: (bi, ti, ri)),
        out_shape=jax.ShapeDtypeStruct((bsz, t_p, r_p), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, br), jnp.float32)],
        interpret=interpret,
    )(a, b, h0[:, None, :])
    return out[:, :t, :r]
