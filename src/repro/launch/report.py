"""Render the dry-run JSON cells into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List


def load_cells(d: pathlib.Path) -> List[Dict]:
    return [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table(cells: List[Dict]) -> str:
    rows = [
        "| cell | chips | compile s | peak GiB/dev | args GiB | temps GiB | microbatches |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if "skipped" in c:
            rows.append(f"| {c['cell']} | - | - | SKIP: {c['skipped']} | | | |")
            continue
        m = c["full"]["memory"]
        rows.append(
            f"| {c['cell']} | {c['chips']} | {c['full'].get('compile_seconds','-')} "
            f"| {fmt_bytes(m['peak_bytes_est'])} | {fmt_bytes(m['argument_bytes'])} "
            f"| {fmt_bytes(m['temp_bytes'])} | {c['full'].get('num_microbatches','-')} |"
        )
    return "\n".join(rows)


def roofline_table(cells: List[Dict]) -> str:
    rows = [
        "| cell | compute s | memory s | collective s | dominant | bound ms "
        "| MODEL_FLOPS | HLO_FLOPS | model/hlo |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        r = c.get("roofline")
        if not r:
            continue
        t = r["terms_seconds"]
        rows.append(
            f"| {c['cell']} | {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | **{r['dominant'].replace('_s','')}** "
            f"| {1e3 * r['roofline_bound_s']:.1f} "
            f"| {r['model_flops_global']:.2e} | {r['hlo_flops_global']:.2e} "
            f"| {r['model_over_hlo']:.3f} |"
        )
    return "\n".join(rows)


def collective_table(cells: List[Dict]) -> str:
    rows = [
        "| cell | all-reduce GiB | all-gather GiB | reduce-scatter GiB "
        "| all-to-all GiB | permute GiB |",
        "|---|---|---|---|---|---|",
    ]
    for c in cells:
        r = c.get("roofline")
        if not r:
            continue
        b = r["per_device"]["collective_breakdown"]
        rows.append(
            f"| {c['cell']} | {fmt_bytes(b['all-reduce'])} | {fmt_bytes(b['all-gather'])} "
            f"| {fmt_bytes(b['reduce-scatter'])} | {fmt_bytes(b['all-to-all'])} "
            f"| {fmt_bytes(b['collective-permute'])} |"
        )
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    cells = load_cells(pathlib.Path(args.dir))
    print("## Dry-run (full-step compiles)\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod unit decomposition)\n")
    print(roofline_table(cells))
    print("\n## Collective breakdown (per device per step)\n")
    print(collective_table(cells))


if __name__ == "__main__":
    main()
