import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first initialization).  Do not move them.

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Callable, Dict, List, Optional, Tuple  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as PS  # noqa: E402

from repro.configs import (  # noqa: E402
    ARCHS,
    SHAPES,
    RunConfig,
    applicable,
    get_arch,
    get_shape,
)
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    batch_axes as mesh_batch_axes,
    make_production_mesh,
    model_axis as mesh_model_axis,
)
from repro.models import model_zoo, transformer  # noqa: E402
from repro.models.layers import ApplyCtx, MeshInfo  # noqa: E402
from repro.models.params import abstract_params, axes_tree, stack_spec  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.train import serve_step as ss  # noqa: E402
from repro.train import train_step as ts  # noqa: E402

# Perf options toggled from the CLI (EXPERIMENTS.md §Perf A/B runs).
OPTS = {"seq_shard_attention": False, "q_chunk": 2048, "remat": "full", "fsdp": True, "seq_parallel": False, "fuse_projections": False}

# ---------------------------------------------------------------------------
# TPU v5e hardware model (per chip)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
# `= f32[8,16]{1,0} all-reduce(` or `= (f32[2]{0}, f32[4]{0}) all-gather(`
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute-start|collective-permute)\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device collective payloads from post-SPMD HLO.

    Traffic model: all-reduce counts 2x its result bytes (reduce-scatter +
    all-gather phases of a ring); other collectives count 1x result bytes.
    """
    out = {k: 0 for k in _COLL_KINDS}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        b = _shape_bytes(type_str)
        out[op] += 2 * b if op == "all-reduce" else b
    return out


def cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def mem_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "peak_bytes_est": float(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes + ma.temp_size_in_bytes
        ),
    }


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------


def _bdims_for(mesh, dim_size):
    """Data axes the batch dim divides; degrade gracefully (long_500k has
    global_batch=1 -> replicate)."""
    bdims = mesh_batch_axes(mesh)
    while bdims:
        n = 1
        for a in bdims:
            n *= mesh.shape[a]
        if dim_size % n == 0:
            return bdims
        bdims = bdims[1:]  # drop 'pod' first, then give up
    return None


def batch_shardings(batch_abs, mesh, *, microbatched: bool = False):
    """Serving batches shard dim0; train batches are (M, B/M, ...) -> dim1."""

    def one(a):
        d = 1 if microbatched else 0
        bdims = _bdims_for(mesh, a.shape[d])
        if bdims is None:
            return NamedSharding(mesh, PS())
        lead = (None, bdims) if microbatched else (bdims,)
        return NamedSharding(mesh, PS(*lead, *([None] * (a.ndim - len(lead)))))

    return jax.tree_util.tree_map(one, batch_abs)


def replicated(mesh):
    return NamedSharding(mesh, PS())


def activation_sharding(mesh, ndim=3, batch_size=None):
    bdims = (
        mesh_batch_axes(mesh) if batch_size is None else _bdims_for(mesh, batch_size)
    )
    if bdims is None:
        return NamedSharding(mesh, PS())
    return NamedSharding(mesh, PS(bdims, *([None] * (ndim - 1))))


# ---------------------------------------------------------------------------
# unit compiles (single-pod cost decomposition)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class UnitResult:
    name: str
    trips: int
    flops: float
    bytes: float
    coll: Dict[str, int]

    def scaled(self) -> Dict[str, float]:
        return {
            "flops": self.flops * self.trips,
            "bytes": self.bytes * self.trips,
            "coll": {k: v * self.trips for k, v in self.coll.items()},
        }


def compile_unit(name, trips, fn, args_abs, in_sh, mesh, donate=()) -> UnitResult:
    lowered = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate).lower(*args_abs)
    compiled = lowered.compile()
    c = cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return UnitResult(name, trips, c["flops"], c["bytes"], coll)


def _cycle_param_tools(cfg, mesh, *, fsdp=True):
    """Abstract params + shardings for ONE cycle (list over pattern).

    fsdp=False (serving): params replicated over the data axes, TP on model —
    decode steps must not all-gather FSDP shards every token.
    """
    spec = [transformer.block_spec(cfg, k) for k in cfg.pattern]
    dt = model_zoo.model_dtype(cfg)
    rules = shd.default_rules(mesh, fsdp=fsdp)
    p_abs = [abstract_params(s, dt) for s in spec]
    axes = [axes_tree(s) for s in spec]
    sh = [shd.tree_shardings(pa, ax, mesh, rules) for pa, ax in zip(p_abs, axes)]
    return p_abs, sh


def _cycle_cache_tools(cfg, mesh, batch, max_len):
    dt = model_zoo.model_dtype(cfg)
    caches = [
        jax.eval_shape(
            lambda k=k: transformer.init_block_cache(cfg, k, batch, max_len, dt)
        )
        for k in cfg.pattern
    ]
    axes = [transformer._block_cache_axes(cfg, k) for k in cfg.pattern]
    sh = [shd.cache_shardings(c, a, mesh) for c, a in zip(caches, axes)]
    return caches, sh


def train_units(cfg, run, shape, mesh, M) -> List[UnitResult]:
    fsdp = OPTS.get("fsdp", True)
    mi = MeshInfo(mesh, mesh_batch_axes(mesh), mesh_model_axis(mesh))
    ctx = ApplyCtx(mode="train", mesh_info=mi, unroll_chunks=True,
                   remat=run.remat, q_chunk=OPTS["q_chunk"],
                   seq_shard_attention=OPTS["seq_shard_attention"],
                   seq_parallel=OPTS["seq_parallel"],
                   fuse_projections=OPTS["fuse_projections"])
    dt = model_zoo.model_dtype(cfg)
    b_mb = shape.global_batch // M
    t = shape.seq_len
    if cfg.vision_patches:
        t_text = t - cfg.vision_patches
    else:
        t_text = t
    d = cfg.d_model
    n_cycles, rest = transformer._cycles_and_rest(cfg)
    units: List[UnitResult] = []

    x_abs = jax.ShapeDtypeStruct((b_mb, t, d), dt)
    x_sh = activation_sharding(mesh)
    positions = jnp.arange(t)

    # -- per-layer-cycle fwd+bwd
    p_abs, p_sh = _cycle_param_tools(cfg, mesh, fsdp=fsdp)

    enc_out_abs = None
    if cfg.family == "encdec":
        enc_out_abs = jax.ShapeDtypeStruct((b_mb, cfg.encoder_seq, d), dt)

    def cycle_loss(cyc_params, x, enc_out=None):
        def inner(cp, xx):
            y, _, aux = transformer.apply_cycle(
                cfg, cp, xx, ctx=ctx, positions=positions, enc_out=enc_out
            )
            return y, aux

        if ctx.remat == "full":
            inner = jax.checkpoint(inner, prevent_cse=False)
        elif ctx.remat == "dots":
            inner = jax.checkpoint(
                inner, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        elif ctx.remat == "outs":
            inner = jax.checkpoint(
                inner, prevent_cse=False,
                policy=jax.checkpoint_policies.save_only_these_names(
                    "attn_out", "mlp_out", "moe_recv", "moe_back"
                ),
            )
        y, aux = inner(cyc_params, x)
        return jnp.sum(y.astype(jnp.float32)) * 1e-6 + aux

    if enc_out_abs is None:
        vg = jax.value_and_grad(cycle_loss, argnums=(0, 1))
        units.append(
            compile_unit("cycle_vg", n_cycles * M, vg, (p_abs, x_abs), (p_sh, x_sh), mesh)
        )
    else:
        vg = jax.value_and_grad(cycle_loss, argnums=(0, 1, 2))
        units.append(
            compile_unit(
                "cycle_vg", n_cycles * M, vg,
                (p_abs, x_abs, enc_out_abs), (p_sh, x_sh, x_sh), mesh,
            )
        )

    # -- encoder cycles (whisper)
    if cfg.family == "encdec":
        from repro.models.encdec import encoder_cfg

        ecfg = encoder_cfg(cfg)
        ep_abs, ep_sh = _cycle_param_tools(ecfg, mesh, fsdp=fsdp)
        ex_abs = jax.ShapeDtypeStruct((b_mb, cfg.encoder_seq, d), dt)
        epos = jnp.arange(cfg.encoder_seq)

        def enc_loss(cyc_params, x):
            y, _, _ = transformer.apply_cycle(
                ecfg, cyc_params, x, ctx=ctx, positions=epos
            )
            return jnp.sum(y.astype(jnp.float32)) * 1e-6

        evg = jax.value_and_grad(enc_loss, argnums=(0, 1))
        units.append(
            compile_unit(
                "enc_cycle_vg", ecfg.num_layers * M, evg,
                (ep_abs, ex_abs), (ep_sh, x_sh), mesh,
            )
        )

    # -- embed + head + loss fwd+bwd
    hp_spec = {
        "embed": transformer.lm_spec(cfg)["embed"],
        "final_norm": transformer.rmsnorm_spec(d),
    }
    full_spec = transformer.lm_spec(cfg)
    if "head" in full_spec:
        hp_spec["head"] = full_spec["head"]
    hp_abs = abstract_params(hp_spec, dt)
    hp_sh = shd.tree_shardings(
        hp_abs, axes_tree(hp_spec), mesh, shd.default_rules(mesh, fsdp=fsdp)
    )
    tok_abs = jax.ShapeDtypeStruct((b_mb, t_text), jnp.int32)
    lab_abs = jax.ShapeDtypeStruct((b_mb, t_text), jnp.int32)
    xt_abs = jax.ShapeDtypeStruct((b_mb, t_text, d), dt)
    tok_sh = activation_sharding(mesh, 2)

    def eh_loss(hp, tokens, labels, x):
        e = transformer._embed(cfg, hp, tokens, None, ctx)
        h = transformer.rmsnorm(hp["final_norm"], x + e, cfg.norm_eps)
        logits = transformer._head(cfg, hp, h, ctx)
        xent, _ = ts.cross_entropy(logits, labels, cfg.vocab_size)
        return xent

    ehvg = jax.value_and_grad(eh_loss, argnums=(0, 3))
    units.append(
        compile_unit(
            "embed_head_vg", M, ehvg,
            (hp_abs, tok_abs, lab_abs, xt_abs),
            (hp_sh, tok_sh, tok_sh, x_sh), mesh,
        )
    )

    # -- optimizer update (once per step)
    params_abs = model_zoo.abstract_model_params(cfg)
    p_axes = model_zoo.model_axes(cfg)
    params_sh = shd.tree_shardings(
        params_abs, p_axes, mesh, shd.default_rules(mesh, fsdp=fsdp)
    )
    opt_dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[run.optimizer_dtype]
    opt_abs = adamw.abstract_state(params_abs, opt_dt)
    opt_sh = adamw.AdamWState(m=params_sh, v=params_sh, count=replicated(mesh))
    grad_dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[run.grad_dtype]
    grads_abs = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, grad_dt), params_abs
    )
    opt_fn = ts.make_optimizer_unit(cfg, run)
    units.append(
        compile_unit(
            "optimizer", 1, opt_fn,
            (params_abs, opt_abs, grads_abs),
            (params_sh, opt_sh, params_sh), mesh, donate=(0, 1),
        )
    )
    return units


def serve_units(cfg, shape, mesh, kind) -> List[UnitResult]:
    mi = MeshInfo(mesh, mesh_batch_axes(mesh), mesh_model_axis(mesh))
    mode = "prefill" if kind == "prefill" else "decode"
    ctx = ApplyCtx(mode=mode, mesh_info=mi, unroll_chunks=True,
                   q_chunk=OPTS["q_chunk"],
                   seq_shard_attention=OPTS["seq_shard_attention"])
    dt = model_zoo.model_dtype(cfg)
    b = shape.global_batch
    t = shape.seq_len if kind == "prefill" else 1
    d = cfg.d_model
    n_cycles, rest = transformer._cycles_and_rest(cfg)
    units: List[UnitResult] = []

    x_abs = jax.ShapeDtypeStruct((b, t, d), dt)
    x_sh = activation_sharding(mesh, batch_size=b)
    p_abs, p_sh = _cycle_param_tools(cfg, mesh, fsdp=False)
    c_abs, c_sh = _cycle_cache_tools(cfg, mesh, b, shape.seq_len)

    if kind == "prefill":
        positions = jnp.arange(t)
        length = None
    else:
        positions = jnp.full((1,), shape.seq_len - 1, jnp.int32)
        length = jnp.asarray(shape.seq_len - 1, jnp.int32)

    enc_out_abs = None
    if cfg.family == "encdec" and kind == "prefill":
        enc_out_abs = jax.ShapeDtypeStruct((b, cfg.encoder_seq, d), dt)

    def cycle_fwd(cyc_params, x, caches, enc_out=None):
        y, new_caches, _ = transformer.apply_cycle(
            cfg, cyc_params, x, ctx=ctx, positions=positions,
            length=length, caches=caches, enc_out=enc_out,
        )
        return y, new_caches

    if enc_out_abs is None:
        units.append(
            compile_unit(
                f"cycle_{mode}", n_cycles, cycle_fwd,
                (p_abs, x_abs, c_abs), (p_sh, x_sh, c_sh), mesh, donate=(2,),
            )
        )
    else:
        units.append(
            compile_unit(
                f"cycle_{mode}", n_cycles, cycle_fwd,
                (p_abs, x_abs, c_abs, enc_out_abs),
                (p_sh, x_sh, c_sh, activation_sharding(mesh, batch_size=b)),
                mesh, donate=(2,),
            )
        )

    if cfg.family == "encdec" and kind == "prefill":
        from repro.models.encdec import encoder_cfg

        ecfg = encoder_cfg(cfg)
        ep_abs, ep_sh = _cycle_param_tools(ecfg, mesh, fsdp=False)
        ex_abs = jax.ShapeDtypeStruct((b, cfg.encoder_seq, d), dt)
        epos = jnp.arange(cfg.encoder_seq)
        ectx = dataclasses.replace(ctx, mode="train")

        def enc_fwd(cyc_params, x):
            y, _, _ = transformer.apply_cycle(ecfg, cyc_params, x, ctx=ectx, positions=epos)
            return y

        units.append(
            compile_unit("enc_cycle_fwd", ecfg.num_layers, enc_fwd,
                         (ep_abs, ex_abs), (ep_sh, x_sh), mesh)
        )

    # -- embed + head fwd
    dt_ = dt
    hp_spec = {
        "embed": transformer.lm_spec(cfg)["embed"],
        "final_norm": transformer.rmsnorm_spec(d),
    }
    full_spec = transformer.lm_spec(cfg)
    if "head" in full_spec:
        hp_spec["head"] = full_spec["head"]
    hp_abs = abstract_params(hp_spec, dt_)
    hp_sh = shd.tree_shardings(
        hp_abs, axes_tree(hp_spec), mesh, shd.default_rules(mesh, fsdp=False)
    )
    tok_abs = jax.ShapeDtypeStruct((b, t), jnp.int32)
    tok_sh = activation_sharding(mesh, 2, batch_size=b)
    x_last = jax.ShapeDtypeStruct((b, 1, d), dt_)
    xl_sh = activation_sharding(mesh, batch_size=b)

    def eh_fwd(hp, tokens, x):
        e = transformer._embed(cfg, hp, tokens, None, ctx)
        h = transformer.rmsnorm(hp["final_norm"], x + e[:, -1:], cfg.norm_eps)
        logits = transformer._head(cfg, hp, h, ctx)
        return jnp.argmax(logits, -1)

    units.append(
        compile_unit(
            f"embed_head_{mode}", 1, eh_fwd,
            (hp_abs, tok_abs, x_last), (hp_sh, tok_sh, xl_sh), mesh,
        )
    )
    return units


# ---------------------------------------------------------------------------
# full-step compiles (sharding proof + memory analysis)
# ---------------------------------------------------------------------------


def full_compile(cfg, run, shape, mesh) -> Tuple[Dict[str, Any], Any]:
    mi = MeshInfo(mesh, mesh_batch_axes(mesh), mesh_model_axis(mesh))
    dp = 1
    for a in mesh_batch_axes(mesh):
        dp *= mesh.shape[a]

    params_abs = model_zoo.abstract_model_params(cfg)
    params_sh = shd.tree_shardings(
        params_abs, model_zoo.model_axes(cfg), mesh,
        shd.default_rules(
            mesh, fsdp=(shape.kind == "train" and OPTS.get("fsdp", True))
        ),
    )

    if shape.kind == "train":
        ctx = ApplyCtx(mode="train", mesh_info=mi, remat=run.remat,
                       q_chunk=OPTS["q_chunk"],
                       seq_shard_attention=OPTS["seq_shard_attention"],
                       seq_parallel=OPTS["seq_parallel"],
                       fuse_projections=OPTS["fuse_projections"])
        m = max(shape.global_batch // dp, 1)
        batch_abs = model_zoo.input_specs(cfg, shape, num_microbatches=m)
        batch_sh = batch_shardings(batch_abs, mesh, microbatched=True)
        step_fn = ts.make_train_step(cfg, run, ctx=ctx, num_microbatches=m)
        opt_dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[run.optimizer_dtype]
        opt_abs = adamw.abstract_state(params_abs, opt_dt)
        opt_sh = adamw.AdamWState(m=params_sh, v=params_sh, count=replicated(mesh))
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jax.jit(
            step_fn,
            in_shardings=(params_sh, opt_sh, batch_sh, replicated(mesh)),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
        ).lower(params_abs, opt_abs, batch_abs, step_abs)
        extra = {"num_microbatches": m}
    elif shape.kind == "prefill":
        ctx = ApplyCtx(mode="prefill", mesh_info=mi, q_chunk=OPTS["q_chunk"],
                       seq_shard_attention=OPTS["seq_shard_attention"])
        fn = ss.make_prefill_step(cfg, ctx=ctx)
        batch_abs = model_zoo.input_specs(cfg, shape)
        batch_sh = batch_shardings(batch_abs, mesh)
        cache_abs = model_zoo.abstract_cache(cfg, shape)
        cache_sh = shd.cache_shardings(
            cache_abs, transformer.cache_axes_tree(cfg), mesh
        )
        lowered = jax.jit(
            fn,
            in_shardings=(params_sh, batch_sh, cache_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(2,),
        ).lower(params_abs, batch_abs, cache_abs)
        extra = {}
    else:  # decode
        ctx = ApplyCtx(mode="decode", mesh_info=mi)
        fn = ss.make_decode_step(cfg, ctx=ctx)
        batch_abs = model_zoo.input_specs(cfg, shape)
        cache_abs = model_zoo.abstract_cache(cfg, shape)
        cache_sh = shd.cache_shardings(
            cache_abs, transformer.cache_axes_tree(cfg), mesh
        )
        tok_abs = batch_abs["token"]
        tok_sh = batch_shardings(tok_abs, mesh)
        lowered = jax.jit(
            fn,
            in_shardings=(params_sh, tok_sh, cache_sh),
            out_shardings=(tok_sh, cache_sh),
            donate_argnums=(2,),
        ).lower(params_abs, tok_abs, cache_abs)
        extra = {}

    t0 = time.time()
    compiled = lowered.compile()
    extra["compile_seconds"] = round(time.time() - t0, 1)
    result = {
        "memory": mem_dict(compiled),
        "full_cost_scan_body_once": cost_dict(compiled),
        "full_coll_scan_body_once": collective_bytes(compiled.as_text()),
        **extra,
    }
    return result, compiled


# ---------------------------------------------------------------------------
# roofline assembly
# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> float:
    n_act = model_zoo.param_count(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch  # decode: one token per sequence


def assemble(units: List[UnitResult], chips: int, shape, cfg) -> Dict[str, Any]:
    tot_flops = sum(u.scaled()["flops"] for u in units)
    tot_bytes = sum(u.scaled()["bytes"] for u in units)
    tot_coll: Dict[str, float] = {k: 0.0 for k in _COLL_KINDS}
    for u in units:
        for k, v in u.scaled()["coll"].items():
            tot_coll[k] += v
    coll_bytes = sum(tot_coll.values())

    compute_s = tot_flops / PEAK_FLOPS  # per-device quantities
    memory_s = tot_bytes / HBM_BW
    coll_s = coll_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_global = tot_flops * chips
    return {
        "per_device": {
            "flops": tot_flops,
            "bytes": tot_bytes,
            "collective_bytes": coll_bytes,
            "collective_breakdown": tot_coll,
        },
        "terms_seconds": terms,
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_flops_global,
        "model_over_hlo": mf / hlo_flops_global if hlo_flops_global else 0.0,
        "roofline_bound_s": max(terms.values()),
        "units": [
            {"name": u.name, "trips": u.trips, "flops": u.flops,
             "bytes": u.bytes, "coll": u.coll}
            for u in units
        ],
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    out_dir: pathlib.Path,
    *,
    with_units: bool = True,
    force: bool = False,
) -> Dict[str, Any]:
    cfg = get_arch(arch)
    if OPTS.get("capacity_factor"):
        import dataclasses as _dc

        cfg = _dc.replace(cfg, capacity_factor=OPTS["capacity_factor"])
    shape = get_shape(shape_name)
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    out_path = out_dir / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    if not applicable(cfg, shape):
        res = {"cell": tag, "skipped": "long_500k requires sub-quadratic decode"}
        out_path.write_text(json.dumps(res, indent=1))
        return res

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    opt_dtype = (
        "bfloat16" if model_zoo.param_count(cfg) > 2e11 else "float32"
    )
    run = RunConfig(model=cfg, shape=shape, optimizer_dtype=opt_dtype,
                    remat=OPTS.get("remat", "full"),
                    grad_dtype=OPTS.get("grad_dtype") or "float32")
    t0 = time.time()
    res: Dict[str, Any] = {"cell": tag, "chips": chips,
                           "mesh": dict(zip(mesh.axis_names, mesh.devices.shape))}
    with mesh:
        full, compiled = full_compile(cfg, run, shape, mesh)
        res["full"] = full
        del compiled
        if with_units and mesh_kind == "single":
            if shape.kind == "train":
                m = full.get("num_microbatches", 1)
                units = train_units(cfg, run, shape, mesh, m)
            else:
                units = serve_units(cfg, shape, mesh, shape.kind)
            res["roofline"] = assemble(units, chips, shape, cfg)
    res["wall_seconds"] = round(time.time() - t0, 1)
    out_path.write_text(json.dumps(res, indent=1))
    return res


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run + roofline")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-units", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--seq-shard-attention", action="store_true",
                    help="context-parallel attention chunks (perf A/B)")
    ap.add_argument("--q-chunk", type=int, default=2048)
    ap.add_argument("--remat", default="full",
                    choices=["full", "none", "dots", "outs"])
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate params over data axes (ZeRO-1; small models)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="Megatron sequence parallelism on the residual stream")
    ap.add_argument("--fuse-projections", action="store_true",
                    help="fused qkv + gate/up projections (1 dx all-reduce)")
    ap.add_argument("--capacity-factor", type=float, default=None,
                    help="override MoE capacity factor")
    ap.add_argument("--grad-dtype", default=None,
                    choices=["float32", "bfloat16"])
    args = ap.parse_args()

    OPTS["seq_shard_attention"] = args.seq_shard_attention
    OPTS["q_chunk"] = args.q_chunk
    OPTS["remat"] = args.remat
    OPTS["fsdp"] = not args.no_fsdp
    OPTS["seq_parallel"] = args.seq_parallel
    OPTS["fuse_projections"] = args.fuse_projections
    OPTS["capacity_factor"] = args.capacity_factor
    OPTS["grad_dtype"] = args.grad_dtype
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for a in archs:
        for s in shapes:
            for m in meshes:
                tag = f"{a}__{s}__{m}"
                try:
                    res = run_cell(
                        a, s, m, out_dir,
                        with_units=not args.no_units, force=args.force,
                    )
                    if "skipped" in res:
                        print(f"[skip] {tag}: {res['skipped']}", flush=True)
                        continue
                    mem = res["full"]["memory"]["peak_bytes_est"] / 2**30
                    dom = res.get("roofline", {}).get("dominant", "-")
                    bound = res.get("roofline", {}).get("roofline_bound_s", 0.0)
                    print(
                        f"[ok]   {tag}: peak/dev={mem:.2f}GiB "
                        f"dominant={dom} bound={bound*1e3:.2f}ms "
                        f"wall={res.get('wall_seconds', 0)}s",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001
                    failures.append(tag)
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print("dry-run complete: all cells compiled.")


if __name__ == "__main__":
    main()
