"""Serving driver: ``python -m repro.launch.serve --arch tinyllama-1.1b``

Runs prefill + N decode steps on a (reduced by default) model, batching
requests and reporting per-phase latency.  On real hardware the same driver
runs the full config under the production mesh with the TP-only serving
shardings from the dry-run; on this CPU container it demonstrates the whole
path (cache build, greedy decode, QoS batch split across replicas).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import model_zoo
from repro.models.layers import ApplyCtx
from repro.train import serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = model_zoo.init_model_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
        )
    }
    if cfg.vision_patches:
        batch["vision"] = jnp.zeros((args.batch, cfg.vision_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model))

    max_len = args.prompt_len + args.gen_len + 8
    cache = model_zoo.init_cache(cfg, args.batch, max_len, jnp.float32)

    prefill = jax.jit(serve_step.make_prefill_step(cfg, ctx=ApplyCtx(mode="prefill")))
    decode = jax.jit(serve_step.make_decode_step(cfg, ctx=ApplyCtx(mode="decode")))

    t0 = time.perf_counter()
    token, cache = prefill(params, batch, cache)
    jax.block_until_ready(token)
    t_prefill = time.perf_counter() - t0

    outs = [token]
    t0 = time.perf_counter()
    for _ in range(args.gen_len - 1):
        token, cache = decode(params, token, cache)
        outs.append(token)
    jax.block_until_ready(token)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(outs, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   "
          f"decode: {t_decode/max(args.gen_len-1,1)*1e3:.1f} ms/token")
    print("generated token ids (seq 0):", np.asarray(gen[0]))


if __name__ == "__main__":
    main()
