"""Serving driver: ``python -m repro.launch.serve --arch tinyllama-1.1b``

Two modes:

  * **single-shot latency demo** (default): prefill + N decode steps on a
    (reduced by default) model, reporting per-phase latency — the classic
    driver, unchanged.
  * **partitioned serving** (``--rounds N`` or ``--serve-smoke``): request
    batches are split across heterogeneous replicas by the always-on
    estimation service (``repro.serve.ServiceLoop``).  The driver never
    calls the scheduler inline — it reads the last-good fractions from the
    service's double-buffered slot (a host read that cannot block on a
    Gibbs sweep), serves, and pushes the measured telemetry back into the
    service's device-resident ring.  Observe runs on every drained batch;
    the split re-solves only when the posterior moves (``docs/serving.md``).

On real hardware the same driver runs the full config under the production
mesh with the TP-only serving shardings from the dry-run; on this CPU
container it demonstrates the whole path (cache build, greedy decode,
QoS batch split across replicas, drift-gated re-partitioning).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import model_zoo
from repro.models.layers import ApplyCtx
from repro.train import serve_step


def _latency_demo(cfg, args) -> None:
    """The original single-shot prefill/decode latency report."""
    params = model_zoo.init_model_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
        )
    }
    if cfg.vision_patches:
        batch["vision"] = jnp.zeros((args.batch, cfg.vision_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model))

    max_len = args.prompt_len + args.gen_len + 8
    cache = model_zoo.init_cache(cfg, args.batch, max_len, jnp.float32)

    prefill = jax.jit(serve_step.make_prefill_step(cfg, ctx=ApplyCtx(mode="prefill")))
    decode = jax.jit(serve_step.make_decode_step(cfg, ctx=ApplyCtx(mode="decode")))

    t0 = time.perf_counter()
    token, cache = prefill(params, batch, cache)
    jax.block_until_ready(token)
    t_prefill = time.perf_counter() - t0

    outs = [token]
    t0 = time.perf_counter()
    for _ in range(args.gen_len - 1):
        token, cache = decode(params, token, cache)
        outs.append(token)
    jax.block_until_ready(token)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(outs, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   "
          f"decode: {t_decode/max(args.gen_len-1,1)*1e3:.1f} ms/token")
    print("generated token ids (seq 0):", np.asarray(gen[0]))


def _partitioned_serving(cfg, args) -> None:
    """Replica-partitioned serving fed by the always-on estimator service."""
    from repro import sched, serve
    from repro.distributed.simulated_cluster import SimulatedCluster, WorkerSpec

    params = model_zoo.init_model_params(jax.random.PRNGKey(0), cfg)
    # Jitted model closures are hoisted out of the request loop — requests
    # hit the jit cache, never a re-trace (shape changes of the local shard
    # compile once per distinct count).
    prefill = jax.jit(serve_step.make_prefill_step(cfg, ctx=ApplyCtx(mode="prefill")))
    decode = jax.jit(serve_step.make_decode_step(cfg, ctx=ApplyCtx(mode="decode")))

    # Heterogeneous replica speeds the estimator must discover online.
    rng = np.random.default_rng(0)
    specs = [
        WorkerSpec(mu=float(m), sigma=0.1 * float(m))
        for m in np.linspace(2.0, 6.0, args.replicas)
    ]
    cluster = SimulatedCluster(specs, seed=0)

    config = serve.ServeConfig(
        sched=sched.SchedulerConfig(
            n_iters=4, grid_size=64, num_points=128, opt_steps=40,
            mu_guess=float(np.mean([s.mu for s in specs])),
        ),
        capacity=2 * args.drain_every,
        drift_threshold=args.drift_threshold,
        max_staleness=8,
    )
    loop = serve.ServiceLoop(args.replicas, config=config, seed=1)

    max_len = args.prompt_len + args.gen_len + 8
    print("round | requests/replica | batch latency | service")
    for rnd in range(args.rounds):
        # Non-blocking read of the last-good split; never waits on a sweep.
        fr = loop.fractions()
        counts = sched.quantize_fractions(
            fr, args.batch, sched.unit_params(loop.state.sched),
            objective=config.sched.objective,
        )
        fr_actual = counts / counts.sum()

        # Really serve replica 0's shard on the local model (semantics demo;
        # each real replica would run its own shard the same way).
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (int(counts[0]), args.prompt_len)),
            jnp.int32,
        )
        cache = model_zoo.init_cache(cfg, int(counts[0]), max_len, jnp.float32)
        token, cache = prefill(params, {"tokens": toks}, cache)
        for _ in range(args.gen_len - 1):
            token, cache = decode(params, token, cache)
        jax.block_until_ready(token)

        # Telemetry: measured (simulated) per-replica latency for its share.
        times = cluster.step_times(fr_actual)
        loop.push(fr_actual, times, valid=np.isfinite(times))
        note = ""
        if (rnd + 1) % args.drain_every == 0:
            info = loop.tick()
            note = (f"drained={int(info.drained)} drift={float(info.drift):.3f} "
                    f"proposed={bool(info.proposed)}")
        lat = float(np.max(times[np.isfinite(times)]))
        print(f"  {rnd:3d} | {counts} | {lat:6.2f}s | {note}")

    c = loop.counters()
    fr = loop.fractions()
    eq = cluster.oracle_makespan(np.full(args.replicas, 1.0 / args.replicas))
    lr = cluster.oracle_makespan(fr)
    print(f"learned split {np.round(fr, 3)}  "
          f"oracle makespan equal={eq:.2f}s learned={lr:.2f}s")
    print(f"service: {c['pushes']} pushes, {c['drains']} drains, "
          f"{c['proposes']} proposes "
          f"(skip rate {1.0 - c['proposes'] / max(c['drains'], 1):.2f}), "
          f"{c['dropped']} dropped")
    if args.serve_smoke:
        ok = c["proposes"] >= 1 and c["drains"] > c["proposes"]
        print(f"serve-smoke {'OK' if ok else 'FAILED'}")
        if not ok:
            raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=0,
                    help="partitioned-serving rounds via repro.serve "
                         "(0 = single-shot latency demo)")
    ap.add_argument("--drain-every", type=int, default=4,
                    help="service drain cadence in rounds")
    ap.add_argument("--drift-threshold", type=float, default=0.05,
                    help="posterior drift gate for re-solving the split")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="tiny fixed partitioned-serving run for CI: reduced "
                         "arch, few rounds, exit 1 unless the service "
                         "proposed at least once and skipped at least once")
    args = ap.parse_args()

    if args.serve_smoke:
        args.arch = "smollm-135m"
        args.reduced = True
        args.batch = 8
        args.prompt_len = 8
        args.gen_len = 4
        args.rounds = 12
        args.drain_every = 2
        args.replicas = 3
        # Steady-state skips must show up within few drains: gate a little
        # above the converged-posterior jitter of this fixed-seed workload.
        args.drift_threshold = 0.12

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    if args.rounds > 0:
        _partitioned_serving(cfg, args)
    else:
        _latency_demo(cfg, args)


if __name__ == "__main__":
    main()
