"""launch subpackage."""
