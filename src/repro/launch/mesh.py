"""Production mesh construction.

Functions (not module constants) so importing never touches jax device state.
Single pod: 16x16 = 256 chips, axes (data, model).
Multi pod:  2x16x16 = 512 chips, axes (pod, data, model) — the pod axis is an
additional pure-data-parallel dimension across ICI-disjoint pods (DCN).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist locally, as a 1-D data mesh (CPU tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh: jax.sharding.Mesh):
    return "model" if "model" in mesh.axis_names else None
