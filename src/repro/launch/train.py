"""Training driver: ``python -m repro.launch.train --arch smollm-135m ...``

On the CPU container this runs reduced configs end-to-end with a simulated
heterogeneous cluster (the paper's scheduler visibly rebalancing).  On real
hardware the same driver runs the full config under the production mesh
(``--production`` adds pjit shardings from repro.distributed.sharding).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import RunConfig, get_arch, get_shape, reduced
from repro.configs.base import ShapeConfig
from repro.distributed.simulated_cluster import SimulatedCluster, WorkerSpec
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8_ef", "topk_ef"])
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", seq_len=args.seq_len,
                        global_batch=args.global_batch, kind="train")
    run = RunConfig(
        model=cfg, shape=shape, checkpoint_dir=args.ckpt_dir,
        total_steps=max(args.steps, 1), warmup_steps=max(args.steps // 10, 1),
        checkpoint_every=max(args.steps // 2, 1),
        grad_compression=args.compression,
    )
    # heterogeneous simulated fleet: a fast, two mediums, one slow worker
    rng = np.random.default_rng(0)
    specs = [
        WorkerSpec(mu=float(m), sigma=float(s))
        for m, s in zip(
            rng.uniform(5.0, 20.0, args.workers),
            rng.uniform(0.5, 2.0, args.workers),
        )
    ]
    trainer = Trainer(run, cluster=SimulatedCluster(specs),
                      num_microbatches=args.microbatches)
    if args.resume and trainer.try_restore():
        print(f"resumed from step {trainer.step}")
    report = trainer.train(args.steps)
    print(f"steps={report.steps} loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")
    if report.splits:
        print("final microbatch split:", report.splits[-1])
    if report.makespans:
        k = max(len(report.makespans) // 4, 1)
        print(
            "mean simulated makespan: first-quarter %.2f -> last-quarter %.2f"
            % (float(np.mean(report.makespans[:k])), float(np.mean(report.makespans[-k:])))
        )


if __name__ == "__main__":
    main()
