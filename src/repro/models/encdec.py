"""Encoder-decoder assembly (whisper-style).

The audio/conv frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, encoder_seq, d_model) from ``input_specs``.
Encoder = full-attention blocks; decoder = causal self-attn + cross-attn
("xdec" blocks in transformer.py).  Rotary positions replace whisper's
sinusoidal embeddings (TPU-native simplification, noted in DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import ApplyCtx, rmsnorm, rmsnorm_spec
from .params import P, stack_spec
from .transformer import _run_stack, block_spec

Array = jax.Array


def encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, num_layers=cfg.encoder_layers, layer_pattern=("enc",)
    )


def encoder_spec(cfg: ModelConfig) -> Dict[str, Any]:
    ecfg = encoder_cfg(cfg)
    d = cfg.d_model
    return {
        "in_proj": P((d, d), ("embed", None)),
        "cycles": [stack_spec(block_spec(ecfg, "enc"), ecfg.num_layers)],
        "rest": [],
        "final_norm": rmsnorm_spec(d),
    }


def encode(
    cfg: ModelConfig,
    enc_params: Dict[str, Any],
    frames: Array,  # (B, encoder_seq, d_model) precomputed embeddings (stub)
    *,
    ctx: ApplyCtx,
) -> Array:
    ecfg = encoder_cfg(cfg)
    x = frames.astype(enc_params["in_proj"].dtype) @ enc_params["in_proj"]
    positions = jnp.arange(x.shape[1])
    # encoder always runs full-sequence (even when the decoder decodes)
    enc_ctx = dataclasses.replace(ctx, mode="train")
    x, _, _ = _run_stack(
        ecfg, enc_params, x, ctx=enc_ctx, positions=positions,
        length=None, cache=None,
    )
    return rmsnorm(enc_params["final_norm"], x, cfg.norm_eps)
