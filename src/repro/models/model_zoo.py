"""Model zoo: config -> spec/params/apply, analytic parameter counts, and the
input-spec factory used by smoke tests, the trainer, and the dry-run."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

from . import encdec, transformer
from .layers import ApplyCtx
from .params import (
    P,
    abstract_params,
    axes_tree,
    init_params,
    param_count as spec_param_count,
    tree_map_spec,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# spec / params
# ---------------------------------------------------------------------------


def model_spec(cfg: ModelConfig) -> Dict[str, Any]:
    spec = transformer.lm_spec(cfg)
    if cfg.family == "encdec":
        spec["encoder"] = encdec.encoder_spec(cfg)
    return spec


def model_dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def init_model_params(key: Array, cfg: ModelConfig):
    return init_params(key, model_spec(cfg), model_dtype(cfg))


def abstract_model_params(cfg: ModelConfig):
    return abstract_params(model_spec(cfg), model_dtype(cfg))


def model_axes(cfg: ModelConfig):
    return axes_tree(model_spec(cfg))


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count from the spec tree.

    active_only: count each MoE expert tensor at k/E of its size (the
    per-token-active parameters used for MODEL_FLOPS = 6 * N_active * D).
    """
    spec = model_spec(cfg)
    if not active_only or cfg.num_experts == 0:
        return spec_param_count(spec)

    frac = cfg.experts_per_token / cfg.num_experts

    def leaf_count(p: P) -> float:
        n = 1
        for s in p.shape:
            n *= s
        if "experts" in p.axes:
            return n * frac
        return n

    leaves = jax.tree_util.tree_leaves(
        tree_map_spec(leaf_count, spec)
    )
    return int(sum(leaves))


# ---------------------------------------------------------------------------
# unified apply (dispatches enc-dec vs decoder-only)
# ---------------------------------------------------------------------------


def forward_train(
    cfg: ModelConfig,
    params,
    batch: Dict[str, Array],
    *,
    ctx: ApplyCtx,
) -> Tuple[Array, Array]:
    """(logits, aux_loss) for a training batch dict."""
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encdec.encode(cfg, params["encoder"], batch["frames"], ctx=ctx)
    return transformer.forward_train(
        cfg, params, batch["tokens"], ctx=ctx,
        vision=batch.get("vision"), enc_out=enc_out,
    )


def prefill(
    cfg: ModelConfig,
    params,
    batch: Dict[str, Array],
    cache,
    *,
    ctx: ApplyCtx,
):
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encdec.encode(cfg, params["encoder"], batch["frames"], ctx=ctx)
    return transformer.prefill(
        cfg, params, batch["tokens"], cache, ctx=ctx,
        vision=batch.get("vision"), enc_out=enc_out,
    )


def decode_step(cfg: ModelConfig, params, token: Array, cache, *, ctx: ApplyCtx):
    return transformer.decode_step(cfg, params, token, cache, ctx=ctx)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or model_dtype(cfg)
    return transformer.init_cache(cfg, batch, max_len, dtype)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — dry-run / trainer plumbing)
# ---------------------------------------------------------------------------


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, num_microbatches: int = 1
) -> Dict[str, Any]:
    """Abstract inputs for one (arch, shape) cell.

    train:   {tokens, labels[, vision][, frames]} — shaped (M, B/M, ...) when
             num_microbatches=M > 1 (dim 1 is the data-sharded batch dim).
    prefill: {tokens[, vision][, frames]}
    decode:  {token} (+ the cache, built separately via ``abstract_cache``)
    """
    b = shape.global_batch
    t = shape.seq_len
    i32 = jnp.int32
    f = jnp.float32

    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        m = num_microbatches
        assert b % m == 0
        lead = (m, b // m)  # always microbatched: (M, B/M)
        text = t
        if cfg.vision_patches:
            text = t - cfg.vision_patches
            specs["vision"] = jax.ShapeDtypeStruct(
                (*lead, cfg.vision_patches, cfg.d_model), f
            )
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (*lead, cfg.encoder_seq, cfg.d_model), f
            )
        specs["tokens"] = jax.ShapeDtypeStruct((*lead, text), i32)
        specs["labels"] = jax.ShapeDtypeStruct((*lead, text), i32)
    elif shape.kind == "prefill":
        text = t
        if cfg.vision_patches:
            text = t - cfg.vision_patches
            specs["vision"] = jax.ShapeDtypeStruct((b, cfg.vision_patches, cfg.d_model), f)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), f)
        specs["tokens"] = jax.ShapeDtypeStruct((b, text), i32)
    elif shape.kind == "decode":
        specs["token"] = jax.ShapeDtypeStruct((b, 1), i32)
    else:
        raise ValueError(shape.kind)
    return specs


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    """Shape-only decode cache (seq_len-deep) for the decode cells."""
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    )
