"""Mixture-of-Experts FFN with capacity-based dispatch.

Distribution is explicit (shard_map), not left to GSPMD: sparse dispatch via
scatter lowers badly under automatic propagation, and the collective pattern
(all-to-all for EP) is exactly what the roofline analysis must see.

Two sharded modes, chosen by expert-count divisibility:
  * EP  (num_experts % model_axis == 0): experts live on model shards;
    dispatch buffers are exchanged with two all-to-alls per direction
    (GShard-style).
  * TP  (otherwise, e.g. granite's 40 experts on a 16-way axis): every shard
    holds all experts but only a 1/M slice of d_ff; the down-projection's
    partial sums are combined with a psum over the model axis.

On a single device (smoke tests) the same local math runs without shard_map.

Top-k routing uses k slot-wise top-1 dispatches: each slot scatters its token
into an (E, C, D) capacity buffer (local scatter — exact, deterministic,
token-dropping beyond capacity, GShard semantics).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.configs.base import ModelConfig

from .layers import ApplyCtx
from .params import P

Array = jax.Array


def moe_spec(cfg: ModelConfig) -> Dict[str, P]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    spec = {
        "router": P((d, e), ("embed", "experts"), scale=0.01),
        "wi": P((e, d, f), ("experts", "embed", "mlp")),
        "wg": P((e, d, f), ("experts", "embed", "mlp")),
        "wo": P((e, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.moe_residual:
        spec["res_wi"] = P((d, f), ("embed", "mlp"))
        spec["res_wg"] = P((d, f), ("embed", "mlp"))
        spec["res_wo"] = P((f, d), ("mlp", "embed"))
    return spec


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.num_experts)
    return max(cap, 1)


def _dispatch_local(
    x: Array,  # (T, D)
    gates: Array,  # (T, k) combine weights
    experts: Array,  # (T, k) int32 expert ids
    num_experts: int,
    capacity: int,
) -> Tuple[Array, Array, Array, Array]:
    """Scatter tokens into per-expert capacity buffers (local, exact).

    Returns (buffers (E, C, D), expert_ids (T,k), slot_pos (T,k), keep (T,k)).
    """
    t, k = gates.shape
    # position of each (token, slot) within its expert queue: cumulative count
    # over the flattened slot-major order (slot 0 of all tokens first — slot 0
    # carries the highest gate, so it wins capacity contention).
    e_flat = experts.T.reshape(-1)  # (k*T,) slot-major
    onehot = jax.nn.one_hot(e_flat, num_experts, dtype=jnp.int32)  # (kT, E)
    pos_flat = jnp.cumsum(onehot, axis=0) - 1  # (kT, E)
    pos_flat = jnp.take_along_axis(pos_flat, e_flat[:, None], axis=1)[:, 0]  # (kT,)
    keep_flat = pos_flat < capacity
    pos = pos_flat.reshape(k, t).T  # (T, k)
    keep = keep_flat.reshape(k, t).T  # (T, k)

    buffers = jnp.zeros((num_experts, capacity, x.shape[-1]), x.dtype)
    for slot in range(k):
        contrib = jnp.where(keep[:, slot, None], x, 0.0)
        idx_pos = jnp.where(keep[:, slot], pos[:, slot], 0)
        buffers = buffers.at[experts[:, slot], idx_pos].add(contrib)
    return buffers, experts, pos, keep


def _combine_local(
    y_buffers: Array,  # (E, C, D)
    gates: Array,  # (T, k)
    experts: Array,  # (T, k)
    pos: Array,  # (T, k)
    keep: Array,  # (T, k)
) -> Array:
    t, k = gates.shape
    out = jnp.zeros((t, y_buffers.shape[-1]), y_buffers.dtype)
    for slot in range(k):
        got = y_buffers[experts[:, slot], jnp.where(keep[:, slot], pos[:, slot], 0)]
        w = jnp.where(keep[:, slot], gates[:, slot], 0.0)
        out = out + got * w[:, None].astype(got.dtype)
    return out


def _expert_ffn(cfg: ModelConfig, wi, wg, wo, xs: Array) -> Array:
    """xs: (E_loc, C_tot, D) -> (E_loc, C_tot, D); weights (E_loc, D, F[...])."""
    up = jnp.einsum("ecd,edf->ecf", xs, wi)
    gate = jnp.einsum("ecd,edf->ecf", xs, wg)
    h = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _route(cfg: ModelConfig, router_w: Array, x_flat: Array) -> Tuple[Array, Array, Array]:
    """Router: softmax-then-topk-renormalize (Mixtral convention)."""
    logits = (x_flat.astype(jnp.float32)) @ router_w.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.experts_per_token)  # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return probs, gates.astype(x_flat.dtype), experts.astype(jnp.int32)


def _moe_local(cfg: ModelConfig, params, x_flat: Array) -> Tuple[Array, Array]:
    """Single-shard MoE (smoke tests / 1 device). Returns (y, router_probs)."""
    probs, gates, experts = _route(cfg, params["router"], x_flat)
    cap = _capacity(x_flat.shape[0], cfg)
    buffers, e_ids, pos, keep = _dispatch_local(
        x_flat, gates, experts, cfg.num_experts, cap
    )
    y_buf = _expert_ffn(cfg, params["wi"], params["wg"], params["wo"], buffers)
    y = _combine_local(y_buf, gates, e_ids, pos, keep)
    return y, probs


def _moe_ep_shard(cfg: ModelConfig, data_axes, model_axis,
                  router_w, wi, wg, wo, x_flat):
    """EP over the data axes x TP(d_ff) over the model axis.

    Tokens live on data shards; experts are sharded E/n_data per data shard
    (arctic: 128 experts / 16 = 8), with each expert's d_ff further split
    over the model axis (psum-combined) — this is the only layout that fits
    480B expert weights in 16 GB/chip HBM (954 GB bf16 / 256 chips).

    Collectives per layer: 2 all-to-alls over data (capacity buffers) +
    1 psum over model (down-projection partials).
    """
    probs, gates, experts = _route(cfg, router_w, x_flat)
    cap = _capacity(x_flat.shape[0], cfg)
    buffers, e_ids, pos, keep = _dispatch_local(
        x_flat, gates, experts, cfg.num_experts, cap
    )
    # (E, C, D) --a2a over the data axes--> (E/n_data, C*n_data, D): every
    # data shard receives the capacity buffers of its expert block.
    recv = jax.lax.all_to_all(
        buffers, data_axes, split_axis=0, concat_axis=1, tiled=True
    )
    recv = jax.ad_checkpoint.checkpoint_name(recv, "moe_recv")
    y_loc = _expert_ffn(cfg, wi, wg, wo, recv)  # F sliced over model
    if model_axis is not None:
        y_loc = jax.lax.psum(y_loc, model_axis)
    # inverse exchange: (E/n_data, C*n_data, D) -> (E, C, D)
    back = jax.lax.all_to_all(
        y_loc, data_axes, split_axis=1, concat_axis=0, tiled=True
    )
    back = jax.ad_checkpoint.checkpoint_name(back, "moe_back")
    y = _combine_local(back, gates, e_ids, pos, keep)
    return y, probs


def _moe_tp_shard(cfg: ModelConfig, model_axis, n_model: int,
                  router_w, wi, wg, wo, x_flat):
    """Inside shard_map: experts replicated, d_ff sharded (psum combine).

    Fallback for expert counts that don't divide the data axes (granite's 40
    experts on 16-way shards)."""
    probs, gates, experts = _route(cfg, router_w, x_flat)
    cap = _capacity(x_flat.shape[0], cfg)
    buffers, e_ids, pos, keep = _dispatch_local(
        x_flat, gates, experts, cfg.num_experts, cap
    )
    y_buf = _expert_ffn(cfg, wi, wg, wo, buffers)  # F sliced -> partial sums
    if model_axis is not None and n_model > 1:
        y_buf = jax.lax.psum(y_buf, model_axis)
    y = _combine_local(y_buf, gates, e_ids, pos, keep)
    return y, probs


def moe_ffn(
    cfg: ModelConfig,
    params: Dict[str, Array],
    x: Array,  # (B, T, D)
    ctx: ApplyCtx,
) -> Tuple[Array, Array]:
    """MoE FFN sublayer.  Returns (y (B,T,D), router_probs (B*T_local, E))."""
    b, t, d = x.shape
    mi = ctx.mesh_info

    n_data = 1
    if mi is not None:
        for a in mi.batch_axes:
            n_data *= mi.mesh.shape[a]

    if mi is None or (mi.model_axis is None and n_data == 1):
        x_flat = x.reshape(b * t, d)
        y, probs = _moe_local(cfg, params, x_flat)
        y = y.reshape(b, t, d)
    else:
        from jax.experimental.shard_map import shard_map

        n_model = mi.mesh.shape[mi.model_axis] if mi.model_axis else 1
        ep = n_data > 1 and cfg.num_experts % n_data == 0
        tp_f = (
            mi.model_axis is not None and cfg.d_ff % n_model == 0 and n_model > 1
        )
        f_ax = mi.model_axis if tp_f else None
        probs_spec = PS(mi.batch_axes, None)
        x_spec = PS(mi.batch_axes, None, None)
        if ep:
            fn = partial(
                _moe_ep_shard, cfg, mi.batch_axes, f_ax
            )
            w_specs = (
                PS(None, None),  # router replicated
                PS(mi.batch_axes, None, f_ax),  # wi: E over data, F over model
                PS(mi.batch_axes, None, f_ax),  # wg
                PS(mi.batch_axes, f_ax, None),  # wo: F contraction sharded
            )
        else:
            fn = partial(_moe_tp_shard, cfg, mi.model_axis, n_model)
            w_specs = (
                PS(None, None),
                PS(None, None, mi.model_axis),  # wi: d_ff sharded
                PS(None, None, mi.model_axis),  # wg
                PS(None, mi.model_axis, None),  # wo: d_ff sharded (contraction)
            )

        def wrapped(router_w, wi, wg, wo, xb):
            xf = xb.reshape(-1, d)
            y, probs = fn(router_w, wi, wg, wo, xf)
            return y.reshape(xb.shape), probs

        y, probs = shard_map(
            wrapped,
            mesh=mi.mesh,
            in_specs=(*w_specs, x_spec),
            out_specs=(x_spec, probs_spec),
            check_rep=False,
        )(params["router"], params["wi"], params["wg"], params["wo"], x)

    if cfg.moe_residual:
        up = x @ params["res_wi"]
        gate = x @ params["res_wg"]
        y = y + (jax.nn.silu(gate) * up) @ params["res_wo"]
    return y, probs


def load_balance_loss(cfg: ModelConfig, probs: Array) -> Array:
    """Switch-style auxiliary loss from router probabilities (T, E)."""
    probs = probs.astype(jnp.float32)
    e = cfg.num_experts
    # fraction of router mass per expert and fraction of top-1 dispatches
    mean_probs = jnp.mean(probs, axis=0)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=0)
    return e * jnp.sum(mean_probs * frac)
