"""Recurrent sequence mixers: mLSTM / sLSTM (xLSTM) and RG-LRU (Griffin).

Training/prefill forms:
  * mLSTM — stabilized *parallel* (quadratic, chunked like attention) form;
    mathematically equivalent to the recurrence (xLSTM paper App. A), maps to
    MXU matmuls on TPU.
  * sLSTM — inherently sequential (recurrent h feeds the gates): lax.scan
    over time.
  * RG-LRU — linear recurrence h_t = a_t h_{t-1} + b_t via associative scan
    (log-depth, parallel on TPU).

Decode: O(1)-state recurrent step for all three — this is what makes the
ssm/hybrid architectures run the long_500k cell.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import NEG_INF, ApplyCtx
from .params import P

Array = jax.Array


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_spec(cfg: ModelConfig) -> Dict[str, P]:
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h  # cell width == d_model (projection factor 1)
    return {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wv": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wif": P((d, 2 * h), ("embed", None), scale=0.01),  # i,f gate pre-acts
        "wog": P((d, h, hd), ("embed", "heads", "head_dim"), scale=0.01),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
        "bif": P((2 * h,), (None,), init="zeros"),
    }


def _mlstm_qkv(cfg, params, x):
    b, t, d = x.shape
    h = cfg.num_heads
    hd = d // h
    q = jnp.einsum("btd,dhk->bhtk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bhtk", x, params["wk"]) * (hd**-0.5)
    v = jnp.einsum("btd,dhk->bhtk", x, params["wv"])
    gates = x @ params["wif"] + params["bif"]  # (B, T, 2H)
    log_i = gates[..., :h].transpose(0, 2, 1).astype(jnp.float32)  # (B,H,T)
    log_f = jax.nn.log_sigmoid(gates[..., h:]).transpose(0, 2, 1).astype(jnp.float32)
    o = jax.nn.sigmoid(jnp.einsum("btd,dhk->bhtk", x, params["wog"]))
    return q, k, v, log_i, log_f, o


def _mlstm_parallel(cfg, params, x, ctx: ApplyCtx):
    """Stabilized quadratic form, chunked over queries."""
    b, t, d = x.shape
    h = cfg.num_heads
    q, k, v, log_i, log_f, o = _mlstm_qkv(cfg, params, x)
    fcum = jnp.cumsum(log_f, axis=-1)  # (B,H,T) F_t = sum_{s<=t} log f_s

    # decay matrix entries: D~[t,s] = F_t - F_s + log_i_s  (s <= t)
    def chunk_out(q_c, fcum_c, tpos_c):
        # q_c (B,H,qc,hd); fcum_c (B,H,qc); tpos_c (qc,)
        from .layers import _seq_shard

        q_c = _seq_shard(q_c, ctx, 2)
        dmat = fcum_c[..., :, None] - fcum[..., None, :] + log_i[..., None, :]
        causal = tpos_c[:, None] >= jnp.arange(t)[None, :]
        dmat = jnp.where(causal[None, None], dmat, NEG_INF)
        m = jnp.max(dmat, axis=-1, keepdims=True)  # (B,H,qc,1)
        m = jnp.maximum(m, -1e30)
        dec = jnp.exp(dmat - m)
        scores = jnp.einsum(
            "bhqk,bhsk->bhqs", q_c.astype(jnp.float32), k.astype(jnp.float32)
        ) * dec
        norm = jnp.maximum(jnp.abs(jnp.sum(scores, axis=-1, keepdims=True)), jnp.exp(-m))
        hh = jnp.einsum("bhqs,bhsk->bhqk", scores / norm, v.astype(jnp.float32))
        from .layers import _seq_shard

        return _seq_shard(hh, ctx, 2)

    chunk = min(ctx.q_chunk, t)
    if t % chunk != 0:
        chunk = t
    n_chunks = t // chunk
    if n_chunks == 1:
        hh = chunk_out(q, fcum, jnp.arange(t))
    else:
        qs = q.reshape(b, h, n_chunks, chunk, -1)
        fs = fcum.reshape(b, h, n_chunks, chunk)
        ts = jnp.arange(t).reshape(n_chunks, chunk)
        if ctx.unroll_chunks:
            hh = jnp.concatenate(
                [chunk_out(qs[:, :, i], fs[:, :, i], ts[i]) for i in range(n_chunks)],
                axis=2,
            )
        else:
            def body(_, inp):
                qc, fc, tc = inp
                return None, chunk_out(qc, fc, tc)

            _, hh = jax.lax.scan(
                body, None,
                (jnp.moveaxis(qs, 2, 0), jnp.moveaxis(fs, 2, 0), ts),
            )
            hh = jnp.moveaxis(hh, 0, 2).reshape(b, h, t, -1)
        hh = hh.reshape(b, h, t, -1)

    hh = (o.astype(jnp.float32) * hh).astype(x.dtype)  # (B,H,T,hd)
    y = jnp.einsum("bhtk,hkd->btd", hh, params["wo"])
    return y, (q, k, v, log_i, log_f, fcum)


def mlstm_final_state(cfg, k, v, log_i, fcum):
    """Final (C, n, m) after a parallel pass — fills the decode cache."""
    f_total = fcum[..., -1:]  # (B,H,1)
    w_log = f_total - fcum + log_i  # (B,H,T): weight of step s in C_T
    m = jnp.max(w_log, axis=-1)  # (B,H)
    w = jnp.exp(w_log - m[..., None])
    c = jnp.einsum("bht,bhtk,bhtl->bhkl", w, v.astype(jnp.float32), k.astype(jnp.float32))
    n = jnp.einsum("bht,bhtk->bhk", w, k.astype(jnp.float32))
    return {"C": c, "n": n, "m": m}


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Dict[str, Array]:
    h = cfg.num_heads
    hd = cfg.d_model // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_block(
    cfg: ModelConfig,
    params: Dict[str, Array],
    x: Array,
    *,
    ctx: ApplyCtx,
    cache: Optional[Dict[str, Array]] = None,
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    if ctx.mode == "train":
        y, _ = _mlstm_parallel(cfg, params, x, ctx)
        return y, None
    if ctx.mode == "prefill":
        y, (q, k, v, log_i, log_f, fcum) = _mlstm_parallel(cfg, params, x, ctx)
        return y, mlstm_final_state(cfg, k, v, log_i, fcum)
    # decode: one stabilized recurrent step
    assert cache is not None
    q, k, v, log_i, log_f, o = _mlstm_qkv(cfg, params, x)  # T == 1
    q1, k1, v1 = q[:, :, 0], k[:, :, 0], v[:, :, 0]  # (B,H,hd)
    li, lf = log_i[..., 0], log_f[..., 0]  # (B,H)
    m_prev = cache["m"]
    m_new = jnp.maximum(lf + m_prev, li)
    i_p = jnp.exp(li - m_new)[..., None]
    f_p = jnp.exp(lf + m_prev - m_new)[..., None]
    c_new = f_p[..., None] * cache["C"] + i_p[..., None] * (
        v1.astype(jnp.float32)[..., :, None] * k1.astype(jnp.float32)[..., None, :]
    )
    n_new = f_p * cache["n"] + i_p * k1.astype(jnp.float32)
    num = jnp.einsum("bhkl,bhl->bhk", c_new, q1.astype(jnp.float32))
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q1.astype(jnp.float32)))[..., None],
        jnp.exp(-m_new)[..., None],
    )
    hh = (o[:, :, 0].astype(jnp.float32) * num / den).astype(x.dtype)  # (B,H,hd)
    y = jnp.einsum("bhk,hkd->bd", hh, params["wo"])[:, None, :]
    return y, {"C": c_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_spec(cfg: ModelConfig) -> Dict[str, P]:
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    return {
        "wx": P((d, 4, h, hd), ("embed", None, "heads", "head_dim")),
        "r": P((4, h, hd, hd), (None, "heads", "head_dim", None), scale=0.01),
        "b": P((4, h, hd), (None, "heads", "head_dim"), init="zeros"),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Dict[str, Array]:
    h = cfg.num_heads
    hd = cfg.d_model // h
    z = lambda: jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, h, hd), -1e30, jnp.float32)}


def _slstm_step(params, state, xt):
    """One sLSTM step.  xt: (B, 4, H, hd) pre-activations from the input."""
    c, n, h_prev, m_prev = state["c"], state["n"], state["h"], state["m"]
    # recurrent contribution: block-diagonal per head
    rec = jnp.einsum("bhk,ghkl->bghl", h_prev, params["r"])  # (B,4,H,hd)
    pre = xt.astype(jnp.float32) + rec + params["b"].astype(jnp.float32)
    z = jnp.tanh(pre[:, 0])
    log_i = pre[:, 1]
    log_f = jax.nn.log_sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(log_f + m_prev, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + m_prev - m_new)
    c_new = f_p * c + i_p * z
    n_new = jnp.maximum(f_p * n + i_p, 1e-6)
    h_new = o * (c_new / n_new)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_block(
    cfg: ModelConfig,
    params: Dict[str, Array],
    x: Array,
    *,
    ctx: ApplyCtx,
    cache: Optional[Dict[str, Array]] = None,
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    b, t, d = x.shape
    pre = jnp.einsum("btd,dghk->btghk", x, params["wx"])  # (B,T,4,H,hd)

    if ctx.mode == "decode":
        assert cache is not None
        state = _slstm_step(params, cache, pre[:, 0])
        hh = state["h"].astype(x.dtype)
        y = jnp.einsum("bhk,hkd->bd", hh, params["wo"])[:, None, :]
        return y, state

    state = init_slstm_cache(cfg, b) if cache is None else cache

    def body(st, xt):
        st2 = _slstm_step(params, st, xt)
        return st2, st2["h"]

    final, hs = jax.lax.scan(body, state, jnp.moveaxis(pre, 1, 0))
    hh = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B,T,H,hd)
    y = jnp.einsum("bthk,hkd->btd", hh, params["wo"])
    new_cache = final if ctx.mode == "prefill" else None
    return y, new_cache


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0
_CONV_W = 4


def rglru_spec(cfg: ModelConfig) -> Dict[str, P]:
    d = cfg.d_model
    r = d  # lru width == d_model for recurrentgemma
    return {
        "w_in": P((d, r), ("embed", "rnn")),
        "w_gate": P((d, r), ("embed", "rnn")),
        "conv_w": P((_CONV_W, r), (None, "rnn"), scale=0.1),
        "conv_b": P((r,), ("rnn",), init="zeros"),
        "w_a": P((r, r), ("rnn", None), scale=0.01),
        "w_x": P((r, r), ("rnn", None), scale=0.01),
        "lam": P((r,), ("rnn",), init="ones"),  # softplus(lam) -> decay
        "w_out": P((r, d), ("rnn", "embed")),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Dict[str, Array]:
    r = cfg.d_model
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, r), jnp.float32),
    }


def _rglru_gates(params, u: Array):
    """a_t (decay) and b_t (input) of the linear recurrence, from u (B,T,R)."""
    r_gate = jax.nn.sigmoid(u @ params["w_a"])  # recurrence gate
    i_gate = jax.nn.sigmoid(u @ params["w_x"])  # input gate
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r_gate.astype(jnp.float32)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i_gate.astype(jnp.float32) * u.astype(jnp.float32))
    return a, b


def _causal_conv(params, u: Array, state: Optional[Array]):
    """Depthwise causal conv, width 4.  u: (B,T,R); state: (B,3,R) history."""
    b, t, r = u.shape
    if state is None:
        hist = jnp.zeros((b, _CONV_W - 1, r), u.dtype)
    else:
        hist = state.astype(u.dtype)
    ext = jnp.concatenate([hist, u], axis=1)  # (B, T+3, R)
    out = jnp.zeros_like(u)
    for w in range(_CONV_W):
        out = out + ext[:, w : w + t] * params["conv_w"][_CONV_W - 1 - w]
    out = out + params["conv_b"]
    new_state = ext[:, -(_CONV_W - 1):].astype(jnp.float32)
    return out, new_state


def rglru_block(
    cfg: ModelConfig,
    params: Dict[str, Array],
    x: Array,
    *,
    ctx: ApplyCtx,
    cache: Optional[Dict[str, Array]] = None,
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    b, t, d = x.shape
    u = x @ params["w_in"]  # (B,T,R)
    gate = jax.nn.gelu(x @ params["w_gate"])

    conv_state = None if cache is None else cache["conv"]
    u, new_conv = _causal_conv(params, u, conv_state)
    a, bb = _rglru_gates(params, u)  # (B,T,R) f32

    if ctx.mode == "decode":
        assert cache is not None
        h_new = a[:, 0] * cache["h"] + bb[:, 0]
        y_rnn = h_new[:, None, :]
        new_cache = {"h": h_new, "conv": new_conv}
    else:
        h0 = None if cache is None else cache["h"]
        if h0 is not None:
            # fold carried state into the first step: h_1 = a_1 h_0 + b_1
            bb = bb.at[:, 0].add(a[:, 0] * h0)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        a_s, h_s = jax.lax.associative_scan(combine, (a, bb), axis=1)
        y_rnn = h_s
        new_cache = (
            {"h": h_s[:, -1], "conv": new_conv} if ctx.mode == "prefill" else None
        )

    y = (gate.astype(jnp.float32) * y_rnn).astype(x.dtype) @ params["w_out"]
    return y, new_cache
