"""Shared transformer layers: norms, RoPE, GQA attention (causal / local /
full / cross; train / prefill / decode), gated MLPs.

Attention memory discipline: for long sequences the (T, T) logits never
materialize — queries are processed in chunks.  The chunk loop runs as
``lax.scan`` in normal execution (small HLO, VMEM-bounded working set) or as
an unrolled Python loop (``unroll_chunks=True``) in the dry-run's unit-cost
compiles, where XLA's cost model must see every chunk (while-loop bodies are
counted once by HLO cost analysis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .params import P

Array = jax.Array

NEG_INF = -1e30


class MeshInfo(NamedTuple):
    """Distribution context threaded through the model (None on 1 device)."""

    mesh: Any  # jax.sharding.Mesh
    batch_axes: Tuple[str, ...]  # ("pod", "data") or ("data",)
    model_axis: Optional[str]  # "model"


@dataclasses.dataclass(frozen=True)
class ApplyCtx:
    """Per-call context: execution mode and distribution info."""

    mode: str = "train"  # train | prefill | decode
    mesh_info: Optional[MeshInfo] = None
    unroll_chunks: bool = False  # dry-run unit-cost compiles
    q_chunk: int = 2048
    remat: str = "none"  # layer-cycle remat: none | full | dots
    # ("dots" saves weight-matmul outputs — backward does NOT recompute the
    #  TP collectives — while attention internals/elementwise are recomputed)
    # Beyond-paper perf options (EXPERIMENTS.md §Perf):
    # shard attention query-chunks over the model axis — turns the replicated
    # attention of unshardable-head models (smollm 9H, xlstm 4H) into 1/M
    # work per shard (context parallelism); K/V stay replicated (small w/ GQA)
    seq_shard_attention: bool = False
    # Megatron-style sequence parallelism: the residual stream between blocks
    # is sharded over (model, seq); GSPMD turns the TP all-reduces into
    # bf16 all-gather + reduce-scatter pairs (half the f32-all-reduce bytes,
    # and norms/elementwise run 1/M per shard)
    seq_parallel: bool = False
    # fuse q/k/v (and mlp gate/up) projections at apply time: the backward
    # dx partial-sums are added BEFORE the tensor-parallel all-reduce —
    # one (B,T,D) reduction instead of three (resp. two)
    fuse_projections: bool = False


def constrain_batch(x: Array, ctx: "ApplyCtx", tail=None) -> Array:
    """Pin the batch dim to the data axes (activation sharding constraint).

    Without this GSPMD is free to re-shard activations after the embedding
    gather (it tends to follow the table's embed-dim sharding), replicating
    the batch across data shards — catastrophic for attention temps.
    """
    mi = ctx.mesh_info
    if mi is None or not mi.batch_axes:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as PS

    spec_tail = tail if tail is not None else [None] * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mi.mesh, PS(mi.batch_axes, *spec_tail))
    )


# ---------------------------------------------------------------------------
# norms / activations / rope
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> Dict[str, P]:
    return {"scale": P((d,), ("embed",), init="ones")}


import functools as _functools


@_functools.lru_cache(maxsize=None)
def _make_rmsnorm(eps: float, dtype_name: str):
    """custom_vjp rmsnorm specialized on (eps, activation dtype).

    Backward runs in f32 math but dx is RETURNED in the activation dtype —
    the tensor-parallel dx all-reduces then move bf16, not f32 (standard
    mixed-precision practice; halves the dominant collective payload).
    """
    dt = jnp.dtype(dtype_name)

    def fwd_math(scale, x):
        x32 = x.astype(jnp.float32)
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps)
        y = (x32 * inv * scale.astype(jnp.float32)).astype(dt)
        return y, (x32, inv)

    @jax.custom_vjp
    def f(scale, x):
        return fwd_math(scale, x)[0]

    def f_fwd(scale, x):
        y, (x32, inv) = fwd_math(scale, x)
        return y, (scale, x32, inv)

    def f_bwd(res, dy):
        scale, x32, inv = res
        dy32 = dy.astype(jnp.float32)
        xhat = x32 * inv
        dscale = jnp.sum(dy32 * xhat, axis=tuple(range(dy.ndim - 1)))
        g = dy32 * scale.astype(jnp.float32)
        dx = inv * (g - xhat * jnp.mean(g * xhat, axis=-1, keepdims=True))
        return dscale.astype(scale.dtype), dx.astype(dt)

    f.defvjp(f_fwd, f_bwd)
    return f


def rmsnorm(params: Dict[str, Array], x: Array, eps: float) -> Array:
    return _make_rmsnorm(float(eps), jnp.dtype(x.dtype).name)(params["scale"], x)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (..., T, H, hd); positions: (..., T) or (T,)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def activate(act: str, gate: Array, up: Array) -> Array:
    if act == "swiglu":
        return jax.nn.silu(gate) * up
    if act == "geglu":
        return jax.nn.gelu(gate) * up
    if act == "gelu":
        return jax.nn.gelu(gate)  # non-gated: 'up' unused by caller
    raise ValueError(act)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_spec(cfg: ModelConfig) -> Dict[str, P]:
    d, f = cfg.d_model, cfg.d_ff
    gated = cfg.act in ("swiglu", "geglu")
    spec = {
        "wi": P((d, f), ("embed", "mlp")),
        "wo": P((f, d), ("mlp", "embed")),
    }
    if gated:
        spec["wg"] = P((d, f), ("embed", "mlp"))
    if cfg.use_bias:
        spec["bi"] = P((f,), ("mlp",), init="zeros")
        spec["bo"] = P((d,), ("embed",), init="zeros")
    return spec


def mlp(
    cfg: ModelConfig, params: Dict[str, Array], x: Array,
    ctx: Optional["ApplyCtx"] = None,
) -> Array:
    gated = cfg.act in ("swiglu", "geglu")
    if ctx is not None and ctx.fuse_projections and gated:
        f = cfg.d_ff
        both = x @ jnp.concatenate([params["wi"], params["wg"]], axis=1)
        up, gate = both[..., :f], both[..., f:]
        if cfg.use_bias:
            up = up + params["bi"]
        h = activate(cfg.act, gate, up)
    else:
        up = x @ params["wi"]
        if cfg.use_bias:
            up = up + params["bi"]
        if gated:
            h = activate(cfg.act, x @ params["wg"], up)
        else:
            h = activate(cfg.act, up, up)
    y = h @ params["wo"]
    if cfg.use_bias:
        y = y + params["bo"]
    return y


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_spec(cfg: ModelConfig, cross: bool = False) -> Dict[str, P]:
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    spec = {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.use_bias:
        spec["bq"] = P((h, hd), ("heads", "head_dim"), init="zeros")
        spec["bk"] = P((kvh, hd), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = P((kvh, hd), ("kv_heads", "head_dim"), init="zeros")
    return spec


def _seq_shard(x: Array, ctx: "ApplyCtx", dim: int) -> Array:
    """Constrain dim to be sharded over the model axis (context parallelism),
    when enabled and divisible."""
    mi = ctx.mesh_info
    if not ctx.seq_shard_attention or mi is None or mi.model_axis is None:
        return x
    m = mi.mesh.shape[mi.model_axis]
    if x.shape[dim] % m != 0:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as PS

    spec = [None] * x.ndim
    spec[0] = mi.batch_axes if x.shape[0] % max(
        1, _prod(mi.mesh.shape[a] for a in mi.batch_axes)
    ) == 0 else None
    spec[dim] = mi.model_axis
    return jax.lax.with_sharding_constraint(x, NamedSharding(mi.mesh, PS(*spec)))


def _prod(it):
    n = 1
    for v in it:
        n *= v
    return n


def _attn_chunk(
    q: Array,  # (B, qc, KVH, G, hd) f32-scaled
    k: Array,  # (B, S, KVH, hd)
    v: Array,  # (B, S, KVH, hd)
    mask: Array,  # (qc, S) or (B, qc, S) additive
    ctx: Optional["ApplyCtx"] = None,
) -> Array:
    if ctx is not None:
        q = _seq_shard(q, ctx, 1)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32
    )
    while mask.ndim < logits.ndim:
        mask = mask[None]
    logits = logits + mask
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v)
    if ctx is not None:
        out = _seq_shard(out, ctx, 1)
    return out


def _full_attention(
    cfg: ModelConfig,
    q: Array,  # (B, T, H, hd) post-rope
    k: Array,  # (B, S, KVH, hd) post-rope
    v: Array,
    *,
    causal: bool,
    window: int,
    q_positions: Array,  # (T,)
    kv_positions: Array,  # (S,)
    ctx: ApplyCtx,
) -> Array:
    """Chunked-query attention; returns (B, T, H, hd)."""
    b, t, h, hd = q.shape
    s = k.shape[1]
    kvh = cfg.num_kv_heads
    g = h // kvh
    scale = hd**-0.5

    qg = (q * scale).reshape(b, t, kvh, g, hd).astype(jnp.float32)
    k32 = k.astype(jnp.float32)

    def mask_for(qpos: Array) -> Array:
        rel = qpos[:, None] - kv_positions[None, :]  # (qc, S)
        ok = jnp.ones(rel.shape, bool)
        if causal:
            ok &= rel >= 0
        if window > 0:
            ok &= rel < window
        return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)

    chunk = min(ctx.q_chunk, t)
    if t % chunk != 0:
        chunk = t  # fall back to single chunk for ragged tiny cases
    n_chunks = t // chunk

    if n_chunks == 1:
        out = _attn_chunk(qg, k32, v, mask_for(q_positions), ctx)
        return out.reshape(b, t, h, hd).astype(q.dtype)

    qg_c = qg.reshape(b, n_chunks, chunk, kvh, g, hd)
    qpos_c = q_positions.reshape(n_chunks, chunk)

    if ctx.unroll_chunks:
        outs = [
            _attn_chunk(qg_c[:, i], k32, v, mask_for(qpos_c[i]), ctx)
            for i in range(n_chunks)
        ]
        out = jnp.stack(outs, axis=1)
    else:
        def body(_, inp):
            qc, qp = inp
            return None, _attn_chunk(qc, k32, v, mask_for(qp), ctx)

        _, out = jax.lax.scan(
            body, None, (jnp.moveaxis(qg_c, 1, 0), qpos_c)
        )
        out = jnp.moveaxis(out, 0, 1)
    return out.reshape(b, t, h, hd).astype(q.dtype)


def init_attention_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype, window: int = 0
) -> Dict[str, Array]:
    s = min(window, max_len) if window > 0 else max_len
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, s, kvh, hd), dtype),
        "v": jnp.zeros((batch, s, kvh, hd), dtype),
    }


def attention(
    cfg: ModelConfig,
    params: Dict[str, Array],
    x: Array,  # (B, T, D)
    *,
    ctx: ApplyCtx,
    causal: bool = True,
    window: int = 0,
    positions: Optional[Array] = None,  # (T,) absolute positions
    length: Optional[Array] = None,  # scalar: tokens already in cache
    cache: Optional[Dict[str, Array]] = None,
    kv_x: Optional[Array] = None,  # cross-attention source (B, Senc, D)
    use_rope: bool = True,
    is_cross: bool = False,  # explicit: decode reads the prefilled cross cache
) -> Tuple[Array, Optional[Dict[str, Array]]]:
    """GQA attention for all modes.  Returns (y, updated_cache)."""
    b, t, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    cross = is_cross or (kv_x is not None)

    if (
        ctx.fuse_projections
        and not cross
        and params["wq"].shape[-1] == params["wk"].shape[-1]
    ):
        # fused qkv: single column-parallel matmul -> one dx all-reduce
        wqkv = jnp.concatenate(
            [params["wq"], params["wk"], params["wv"]], axis=1
        )  # (D, H + 2*KVH, hd)
        qkv = jnp.einsum("btd,dhk->bthk", x, wqkv)
        q = qkv[:, :, :h]
        k = qkv[:, :, h : h + kvh]
        v = qkv[:, :, h + kvh :]
        if cfg.use_bias:
            q = q + params["bq"]
            k = k + params["bk"]
            v = v + params["bv"]
    else:
        q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
        if cfg.use_bias:
            q = q + params["bq"]

        if cross and kv_x is None:
            # decode against a prefilled cross cache: no new k/v are produced
            k = v = None
        else:
            kv_src = x if kv_x is None else kv_x
            k = jnp.einsum("btd,dhk->bthk", kv_src, params["wk"])
            v = jnp.einsum("btd,dhk->bthk", kv_src, params["wv"])
            if cfg.use_bias:
                k = k + params["bk"]
                v = v + params["bv"]

    if positions is None:
        positions = jnp.arange(t)
    if use_rope and not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    elif use_rope and cross:
        q = rope(q, positions, cfg.rope_theta)
        # cross keys keep the encoder's own (non-rotary) representation

    if ctx.mode != "decode" and cross and kv_x is None:
        raise ValueError("cross attention outside decode requires kv_x (enc_out)")

    new_cache = cache
    if ctx.mode == "train":
        kv_pos = jnp.arange(k.shape[1])
        out = _full_attention(
            cfg, q, k, v, causal=causal and not cross, window=window,
            q_positions=positions, kv_positions=kv_pos, ctx=ctx,
        )
    elif ctx.mode == "prefill":
        kv_pos = jnp.arange(k.shape[1])
        out = _full_attention(
            cfg, q, k, v, causal=causal and not cross, window=window,
            q_positions=positions, kv_positions=kv_pos, ctx=ctx,
        )
        if cache is not None and not cross:
            s_cache = cache["k"].shape[1]
            if window > 0 and k.shape[1] > s_cache:
                # keep the trailing window, placed at ring slots pos % s_cache
                shift = (k.shape[1] - s_cache) % s_cache
                k_w = jnp.roll(k[:, -s_cache:], shift, axis=1)
                v_w = jnp.roll(v[:, -s_cache:], shift, axis=1)
                new_cache = {"k": k_w.astype(cache["k"].dtype), "v": v_w.astype(cache["v"].dtype)}
            else:
                pad = s_cache - k.shape[1]
                new_cache = {
                    "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["k"].dtype),
                    "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["v"].dtype),
                }
        elif cache is not None and cross:
            new_cache = {"k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype)}
    elif ctx.mode == "decode":
        assert cache is not None and length is not None
        if cross:
            k_all, v_all = cache["k"], cache["v"]
            s = k_all.shape[1]
            valid = jnp.ones((s,), bool)
            kv_pos = jnp.arange(s)
        else:
            s = cache["k"].shape[1]
            if window > 0:
                slot = length % s
                write_pos = slot
            else:
                write_pos = length
            k_new = k[:, 0].astype(cache["k"].dtype)  # (B, KVH, hd)
            v_new = v[:, 0].astype(cache["v"].dtype)
            k_all = jax.lax.dynamic_update_index_in_dim(cache["k"], k_new, write_pos, 1)
            v_all = jax.lax.dynamic_update_index_in_dim(cache["v"], v_new, write_pos, 1)
            new_cache = {"k": k_all, "v": v_all}
            if window > 0:
                # ring buffer: slot i holds absolute position derived from length
                idx = jnp.arange(s)
                slot = length % s
                kv_pos = jnp.where(idx <= slot, length - (slot - idx), length - (slot - idx) - s)
                valid = (kv_pos >= 0) & (kv_pos >= length - window + 1)
            else:
                kv_pos = jnp.arange(s)
                valid = kv_pos <= length
        # single-token attention over the cache
        g = h // kvh
        scale = hd**-0.5
        qg = (q[:, 0] * scale).reshape(b, kvh, g, hd).astype(jnp.float32)
        logits = jnp.einsum(
            "bkgd,bskd->bkgs", qg, k_all.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", w.astype(v_all.dtype), v_all)
        out = out.reshape(b, 1, h, hd)
    else:
        raise ValueError(ctx.mode)

    y = jnp.einsum("bthk,hkd->btd", out.astype(x.dtype), params["wo"])
    return y, new_cache
