"""Lightweight parameter-spec system (no flax dependency).

A model is described by a nested dict of ``P`` leaves (shape + logical axes +
init).  From one spec tree we derive:

  * materialized parameters          (``init_params``)
  * abstract ShapeDtypeStructs       (``abstract_params`` — dry-run, no alloc)
  * the logical-axes tree            (``axes_tree`` — sharding rules input)

Logical axis names (consumed by ``repro.distributed.sharding``):
  vocab, embed, mlp, heads, kv_heads, head_dim, experts, rnn, cell, layers
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
SpecTree = Any  # nested dict of P
ParamTree = Any  # nested dict of arrays


class P(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.02

    def stacked(self, n: int, axis_name: str = "layers") -> "P":
        return P((n, *self.shape), (axis_name, *self.axes), self.init, self.scale)


def _is_leaf(x) -> bool:
    return isinstance(x, P)


def tree_map_spec(fn, spec: SpecTree):
    return jax.tree_util.tree_map(fn, spec, is_leaf=_is_leaf)


def stack_spec(spec: SpecTree, n: int, axis_name: str = "layers") -> SpecTree:
    """Add a leading stacked axis to every leaf (scan-over-layers storage)."""
    return tree_map_spec(lambda p: p.stacked(n, axis_name), spec)


def init_params(key: Array, spec: SpecTree, dtype=jnp.float32) -> ParamTree:
    leaves, treedef = jax.tree_util.tree_flatten(spec, is_leaf=_is_leaf)
    keys = jax.random.split(key, max(len(leaves), 1))

    def make(p: P, k: Array) -> Array:
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, dtype)
        return (p.scale * jax.random.normal(k, p.shape)).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [make(p, k) for p, k in zip(leaves, keys)]
    )


def abstract_params(spec: SpecTree, dtype=jnp.float32) -> ParamTree:
    """ShapeDtypeStruct stand-ins — used by the dry-run (no allocation)."""
    return tree_map_spec(lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec)


def axes_tree(spec: SpecTree):
    """Tree of logical-axes tuples, parallel to the param tree."""
    return tree_map_spec(lambda p: p.axes, spec)


def param_bytes(spec: SpecTree, bytes_per_elem: int = 2) -> int:
    leaves = jax.tree_util.tree_leaves(spec, is_leaf=_is_leaf)
    total = 0
    for p in leaves:
        n = 1
        for s in p.shape:
            n *= s
        total += n * bytes_per_elem
    return total


def param_count(spec: SpecTree) -> int:
    leaves = jax.tree_util.tree_leaves(spec, is_leaf=_is_leaf)
    total = 0
    for p in leaves:
        n = 1
        for s in p.shape:
            n *= s
        total += n
    return total
