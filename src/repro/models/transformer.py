"""Unified LM assembly: embed -> layer-pattern cycles (scan) -> norm -> head.

One code path serves every assigned family:
  dense / moe        — homogeneous attention+FFN blocks
  vlm                — same, with precomputed patch embeddings prepended (stub
                       frontend per the assignment)
  ssm (xlstm)        — mLSTM/sLSTM pattern, no FFN
  hybrid (rglru)     — RG-LRU + local-attention pattern
  encdec (whisper)   — encoder stack (full attn) + decoder with cross-attn
                       (see encdec.py for the encoder driver)

Layers are stored *stacked per pattern position* and executed with
``lax.scan`` over cycles (HLO size O(pattern), not O(depth) — essential for
512-device compiles); remainder layers (depth % pattern) are unrolled.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import moe as moe_lib
from . import recurrent as rec
from .layers import (
    ApplyCtx,
    attention,
    attention_spec,
    constrain_batch,
    init_attention_cache,
    mlp,
    mlp_spec,
    rmsnorm,
    rmsnorm_spec,
)
from .params import P, stack_spec

Array = jax.Array

ATTN_KINDS = ("dense", "moe", "localattn", "enc", "xdec")


# ---------------------------------------------------------------------------
# per-block spec / apply / cache
# ---------------------------------------------------------------------------


def block_spec(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d = cfg.d_model
    if kind in ("dense", "moe", "localattn", "enc", "xdec"):
        spec = {"ln1": rmsnorm_spec(d), "attn": attention_spec(cfg)}
        if kind == "xdec":
            spec["lnx"] = rmsnorm_spec(d)
            spec["xattn"] = attention_spec(cfg, cross=True)
        if kind == "moe":
            spec["ln2"] = rmsnorm_spec(d)
            spec["ffn"] = moe_lib.moe_spec(cfg)
        elif cfg.d_ff > 0:
            spec["ln2"] = rmsnorm_spec(d)
            spec["ffn"] = mlp_spec(cfg)
        return spec
    if kind == "mlstm":
        return {"ln1": rmsnorm_spec(d), "mix": rec.mlstm_spec(cfg)}
    if kind == "slstm":
        return {"ln1": rmsnorm_spec(d), "mix": rec.slstm_spec(cfg)}
    if kind == "rglru":
        spec = {"ln1": rmsnorm_spec(d), "mix": rec.rglru_spec(cfg)}
        if cfg.d_ff > 0:
            spec["ln2"] = rmsnorm_spec(d)
            spec["ffn"] = mlp_spec(cfg)
        return spec
    raise ValueError(kind)


def init_block_cache(
    cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype
) -> Optional[Dict[str, Any]]:
    if kind in ("dense", "moe", "enc"):
        return init_attention_cache(cfg, batch, max_len, dtype)
    if kind == "localattn":
        return init_attention_cache(cfg, batch, max_len, dtype, window=cfg.local_window)
    if kind == "xdec":
        return {
            "self": init_attention_cache(cfg, batch, max_len, dtype),
            "cross": init_attention_cache(cfg, batch, cfg.encoder_seq, dtype),
        }
    if kind == "mlstm":
        return rec.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return rec.init_slstm_cache(cfg, batch)
    if kind == "rglru":
        cache = rec.init_rglru_cache(cfg, batch)
        return cache
    raise ValueError(kind)


def block_apply(
    cfg: ModelConfig,
    kind: str,
    params: Dict[str, Any],
    x: Array,
    *,
    ctx: ApplyCtx,
    positions: Array,
    length: Optional[Array],
    cache: Optional[Dict[str, Any]],
    enc_out: Optional[Array] = None,
) -> Tuple[Array, Optional[Dict[str, Any]], Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps

    if kind in ("dense", "moe", "localattn", "enc", "xdec"):
        window = cfg.local_window if kind == "localattn" else 0
        causal = kind != "enc"
        h = rmsnorm(params["ln1"], x, eps)
        self_cache = cache["self"] if kind == "xdec" and cache is not None else cache
        y, new_self = attention(
            cfg, params["attn"], h, ctx=ctx, causal=causal, window=window,
            positions=positions, length=length, cache=self_cache,
        )
        y = jax.ad_checkpoint.checkpoint_name(y, "attn_out")
        x = x + y
        new_cache = new_self
        if kind == "xdec":
            hx = rmsnorm(params["lnx"], x, eps)
            cross_cache = cache["cross"] if cache is not None else None
            # decode reads the prefilled cross cache; prefill builds it
            y, new_cross = attention(
                cfg, params["xattn"], hx, ctx=ctx, causal=False,
                positions=positions, length=length, cache=cross_cache,
                kv_x=enc_out if ctx.mode != "decode" else None,
                use_rope=False, is_cross=True,
            )
            x = x + y
            new_cache = {"self": new_self, "cross": new_cross}
        if "ffn" in params:
            h = rmsnorm(params["ln2"], x, eps)
            if kind == "moe":
                y, probs = moe_lib.moe_ffn(cfg, params["ffn"], h, ctx)
                aux = moe_lib.load_balance_loss(cfg, probs.reshape(-1, cfg.num_experts))
            else:
                y = mlp(cfg, params["ffn"], h, ctx)
            x = x + jax.ad_checkpoint.checkpoint_name(y, "mlp_out")
        return x, new_cache, aux

    if kind in ("mlstm", "slstm", "rglru"):
        h = rmsnorm(params["ln1"], x, eps)
        fn = {"mlstm": rec.mlstm_block, "slstm": rec.slstm_block, "rglru": rec.rglru_block}[kind]
        y, new_cache = fn(cfg, params["mix"], h, ctx=ctx, cache=cache)
        x = x + y
        if "ffn" in params:
            h = rmsnorm(params["ln2"], x, eps)
            x = x + jax.ad_checkpoint.checkpoint_name(
                mlp(cfg, params["ffn"], h, ctx), "mlp_out"
            )
        return x, new_cache, aux

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# full-model spec
# ---------------------------------------------------------------------------


def _cycles_and_rest(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    pattern = cfg.pattern
    n_cycles = cfg.num_layers // len(pattern)
    rest = pattern[: cfg.num_layers % len(pattern)]
    return n_cycles, rest


def lm_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    n_cycles, rest = _cycles_and_rest(cfg)
    spec: Dict[str, Any] = {
        "embed": P((v, d), ("vocab", "embed"), scale=1.0 / (d**0.5)),
        "final_norm": rmsnorm_spec(d),
        "cycles": [
            stack_spec(block_spec(cfg, kind), n_cycles) for kind in cfg.pattern
        ],
        "rest": [block_spec(cfg, kind) for kind in rest],
    }
    if not cfg.tie_embeddings:
        spec["head"] = P((d, v), ("embed", "vocab"), scale=0.02)
    if cfg.vision_patches:
        spec["vision_proj"] = P((d, d), ("embed", None))
    return spec


# ---------------------------------------------------------------------------
# full-model apply
# ---------------------------------------------------------------------------


def _embed(
    cfg: ModelConfig, params, tokens: Array, vision: Optional[Array],
    ctx: Optional[ApplyCtx] = None,
) -> Array:
    x = params["embed"][tokens] * jnp.asarray(
        cfg.d_model**0.5, params["embed"].dtype
    )
    if vision is not None:
        vproj = vision.astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([vproj, x], axis=1)
    if ctx is not None:
        x = constrain_batch(x, ctx)
    return x


def _head(cfg: ModelConfig, params, x: Array, ctx: Optional[ApplyCtx] = None) -> Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"])
    else:
        logits = jnp.einsum("btd,dv->btv", x, params["head"])
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    if ctx is not None and ctx.mesh_info is not None:
        mi = ctx.mesh_info
        v_ax = (
            mi.model_axis
            if mi.model_axis and cfg.vocab_size % mi.mesh.shape[mi.model_axis] == 0
            else None
        )
        logits = constrain_batch(logits, ctx, tail=[None] * (logits.ndim - 2) + [v_ax])
    return logits


def apply_cycle(
    cfg: ModelConfig,
    cycle_params,
    x: Array,
    *,
    ctx: ApplyCtx,
    positions: Array,
    length: Optional[Array] = None,
    caches=None,
    enc_out: Optional[Array] = None,
):
    """One pattern cycle (the scan body / the dry-run's per-layer cost unit).

    Returns (x, new_caches, aux); when caches is None, new_caches are scalar
    placeholders so the scan carries a consistent pytree.
    """
    use_cache = caches is not None
    mi = ctx.mesh_info
    if (
        ctx.seq_parallel
        and mi is not None
        and mi.model_axis is not None
        and x.shape[1] % mi.mesh.shape[mi.model_axis] == 0
    ):
        # sequence-parallel residual stream (see ApplyCtx.seq_parallel)
        x = constrain_batch(x, ctx, tail=[mi.model_axis, None])
    else:
        x = constrain_batch(x, ctx)
    new_caches: List[Any] = []
    aux = jnp.zeros((), jnp.float32)
    for j, kind in enumerate(cfg.pattern):
        x, nc, a = block_apply(
            cfg, kind, cycle_params[j], x, ctx=ctx, positions=positions,
            length=length, cache=caches[j] if use_cache else None,
            enc_out=enc_out,
        )
        new_caches.append(nc if use_cache else jnp.zeros((), jnp.float32))
        aux = aux + a
    return x, new_caches, aux


def _run_stack(
    cfg: ModelConfig,
    params,
    x: Array,
    *,
    ctx: ApplyCtx,
    positions: Array,
    length: Optional[Array],
    cache: Optional[Dict[str, Any]],
    enc_out: Optional[Array] = None,
) -> Tuple[Array, Optional[Dict[str, Any]], Array]:
    """The layer loop: scan over cycles + unrolled remainder."""
    n_cycles, rest = _cycles_and_rest(cfg)
    pattern = cfg.pattern
    use_cache = cache is not None

    def cycle_fn(x, cycle_params, cycle_caches):
        return apply_cycle(
            cfg, cycle_params, x, ctx=ctx, positions=positions, length=length,
            caches=cycle_caches if use_cache else None, enc_out=enc_out,
        )

    body = cycle_fn
    if ctx.mode == "train" and ctx.remat == "full":
        body = jax.checkpoint(cycle_fn, prevent_cse=False)
    elif ctx.mode == "train" and ctx.remat == "dots":
        body = jax.checkpoint(
            cycle_fn, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    elif ctx.mode == "train" and ctx.remat == "outs":
        # save exactly the post-collective sublayer outputs: backward never
        # re-runs a tensor-parallel all-reduce, at 2 x (B,T,D) saved per layer
        body = jax.checkpoint(
            cycle_fn, prevent_cse=False,
            policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out", "moe_recv", "moe_back"
            ),
        )

    if n_cycles > 0:
        def scan_body(carry, xs):
            x, aux_acc = carry
            cyc_params, cyc_caches = xs
            x, new_caches, aux = body(x, cyc_params, cyc_caches)
            return (x, aux_acc + aux), new_caches

        caches_in = (
            cache["cycles"]
            if use_cache
            else [jnp.zeros((n_cycles,), jnp.float32) for _ in pattern]
        )
        (x, aux_total), new_cycle_caches = jax.lax.scan(
            scan_body,
            (x, jnp.zeros((), jnp.float32)),
            (params["cycles"], caches_in),
        )
    else:
        aux_total = jnp.zeros((), jnp.float32)
        new_cycle_caches = []

    new_rest = []
    for j, kind in enumerate(rest):
        x, nc, a = block_apply(
            cfg, kind, params["rest"][j], x, ctx=ctx, positions=positions,
            length=length, cache=cache["rest"][j] if use_cache else None,
            enc_out=enc_out,
        )
        new_rest.append(nc)
        aux_total = aux_total + a

    new_cache = None
    if use_cache:
        new_cache = dict(cache)
        new_cache["cycles"] = new_cycle_caches
        new_cache["rest"] = new_rest
    return x, new_cache, aux_total


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> Dict[str, Any]:
    """Decode cache for the whole stack + position counter."""
    n_cycles, rest = _cycles_and_rest(cfg)

    def stacked(kind):
        one = init_block_cache(cfg, kind, batch, max_len, dtype)
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (n_cycles, *l.shape)).copy(), one
        )

    return {
        "length": jnp.zeros((), jnp.int32),
        "cycles": [stacked(kind) for kind in cfg.pattern],
        "rest": [init_block_cache(cfg, kind, batch, max_len, dtype) for kind in rest],
    }


def _block_cache_axes(cfg: ModelConfig, kind: str):
    """Logical axes tree parallel to ``init_block_cache`` (sharding rules)."""
    kv = {"k": ("batch", "seq", "kv_heads", "head_dim"),
          "v": ("batch", "seq", "kv_heads", "head_dim")}
    if kind in ("dense", "moe", "enc", "localattn"):
        return dict(kv)
    if kind == "xdec":
        return {"self": dict(kv), "cross": dict(kv)}
    if kind == "mlstm":
        return {
            "C": ("batch", "heads", "head_dim", None),
            "n": ("batch", "heads", "head_dim"),
            "m": ("batch", "heads"),
        }
    if kind == "slstm":
        ax = ("batch", "heads", "head_dim")
        return {"c": ax, "n": ax, "h": ax, "m": ax}
    if kind == "rglru":
        return {"h": ("batch", "rnn"), "conv": ("batch", None, "rnn")}
    raise ValueError(kind)


def cache_axes_tree(cfg: ModelConfig):
    """Axes tree with the same structure as ``init_cache`` output."""
    n_cycles, rest = _cycles_and_rest(cfg)
    is_axes = lambda x: isinstance(x, tuple)

    def stacked(kind):
        one = _block_cache_axes(cfg, kind)
        return jax.tree_util.tree_map(
            lambda ax: ("layers", *ax), one, is_leaf=is_axes
        )

    return {
        "length": (),
        "cycles": [stacked(kind) for kind in cfg.pattern],
        "rest": [_block_cache_axes(cfg, kind) for kind in rest],
    }


def forward_train(
    cfg: ModelConfig,
    params,
    tokens: Array,  # (B, T)
    *,
    ctx: ApplyCtx,
    vision: Optional[Array] = None,
    enc_out: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Full-sequence forward.  Returns (logits (B,T,V), aux_loss)."""
    x = _embed(cfg, params, tokens, vision, ctx)
    positions = jnp.arange(x.shape[1])
    x, _, aux = _run_stack(
        cfg, params, x, ctx=ctx, positions=positions, length=None,
        cache=None, enc_out=enc_out,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _head(cfg, params, x, ctx), aux


def prefill(
    cfg: ModelConfig,
    params,
    tokens: Array,
    cache: Dict[str, Any],
    *,
    ctx: ApplyCtx,
    vision: Optional[Array] = None,
    enc_out: Optional[Array] = None,
) -> Tuple[Array, Dict[str, Any]]:
    """Prefill the cache; returns (last-position logits (B,V), cache)."""
    x = _embed(cfg, params, tokens, vision, ctx)
    t = x.shape[1]
    positions = jnp.arange(t)
    x, new_cache, _ = _run_stack(
        cfg, params, x, ctx=ctx, positions=positions, length=None,
        cache=cache, enc_out=enc_out,
    )
    x = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = _head(cfg, params, x, ctx)[:, 0]
    new_cache["length"] = jnp.asarray(t, jnp.int32)
    return logits, new_cache


def decode_step(
    cfg: ModelConfig,
    params,
    token: Array,  # (B, 1)
    cache: Dict[str, Any],
    *,
    ctx: ApplyCtx,
) -> Tuple[Array, Dict[str, Any]]:
    """One decode step.  Returns (logits (B,V), cache)."""
    length = cache["length"]
    x = _embed(cfg, params, token, None, ctx)
    positions = jnp.full((1,), length, jnp.int32)
    x, new_cache, _ = _run_stack(
        cfg, params, x, ctx=ctx, positions=positions, length=length,
        cache=cache, enc_out=None,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _head(cfg, params, x, ctx)[:, 0]
    new_cache["length"] = length + 1
    return logits, new_cache
