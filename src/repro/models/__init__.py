"""Model substrate: layers, MoE, recurrent mixers, unified assembly, zoo."""
from . import encdec, layers, model_zoo, moe, params, recurrent, transformer
from .layers import ApplyCtx, MeshInfo

__all__ = [
    "ApplyCtx",
    "MeshInfo",
    "encdec",
    "layers",
    "model_zoo",
    "moe",
    "params",
    "recurrent",
    "transformer",
]
