"""Simulated heterogeneous cluster (this container has one CPU device).

Each worker has ground-truth paper-model parameters (mu, sigma, alpha, beta):
processing a workload fraction f takes N(f^alpha * mu, (f^beta * sigma)^2)
seconds.  The framework must *recover* these online (Gibbs) and partition
work accordingly — reproducing the paper's experiments end to end.

Supports drift (dynamic environments, the paper's motivation for chained
priors), stragglers (a worker's mu inflates), and failures (a worker stops
responding — heartbeat timeout)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.frontier import UnitParams


@dataclasses.dataclass
class WorkerSpec:
    mu: float
    sigma: float
    alpha: float = 0.9
    beta: float = 0.8
    alive: bool = True


class SimulatedCluster:
    def __init__(self, specs: List[WorkerSpec], seed: int = 0):
        self.specs = list(specs)
        self.rng = np.random.default_rng(seed)
        self.clock = 0.0

    @property
    def num_workers(self) -> int:
        return len(self.specs)

    def step_times(self, fracs: np.ndarray) -> np.ndarray:
        """Observed completion times for one parallel step with split fracs."""
        out = np.zeros(len(self.specs))
        for i, (spec, f) in enumerate(zip(self.specs, fracs)):
            if not spec.alive:
                out[i] = np.inf  # heartbeat timeout
                continue
            f = max(float(f), 1e-6)
            mean = f**spec.alpha * spec.mu
            std = f**spec.beta * spec.sigma
            out[i] = max(self.rng.normal(mean, std), 1e-6)
        self.clock += np.max(out[np.isfinite(out)]) if np.isfinite(out).any() else 0.0
        return out

    # -- dynamic events -----------------------------------------------------
    def degrade(self, worker: int, mu_factor: float = 3.0) -> None:
        """Make a worker a straggler (thermal throttle, noisy neighbor...)."""
        self.specs[worker].mu *= mu_factor

    def fail(self, worker: int) -> None:
        self.specs[worker].alive = False

    def recover(self, worker: int) -> None:
        self.specs[worker].alive = True

    def true_params(self) -> UnitParams:
        return UnitParams.of(
            [s.mu for s in self.specs],
            [s.sigma for s in self.specs],
            [s.alpha for s in self.specs],
            [s.beta for s in self.specs],
        )

    def oracle_makespan(self, fracs: np.ndarray) -> float:
        """Expected makespan under the TRUE parameters (evaluation metric)."""
        from repro.core.frontier import mean_var_completion
        import jax.numpy as jnp

        alive = [i for i, s in enumerate(self.specs) if s.alive]
        p = self.true_params()
        pa = UnitParams(*(jnp.asarray(np.asarray(x)[alive]) for x in p))
        e, _ = mean_var_completion(jnp.asarray(fracs[alive]), pa)
        return float(e)
