"""distributed subpackage."""
