"""Distributed-systems layer: model sharding, fault tolerance, compression.

Two sharding concerns live in this repo and are easy to conflate:

  * **Model-tensor sharding** (``repro.distributed.sharding``): logical-axis
    rules mapping parameter/cache tensors onto TP/FSDP meshes for the
    training and serving stacks.
  * **Estimator fleet sharding** (``repro.core.sharding``, re-exported here
    as :class:`ShardingConfig`): partitioning the Bayesian estimation
    engine's worker axis K across a ``workers`` device mesh via
    ``shard_map`` — see ``docs/scaling.md``.  Thread it through
    ``sched.SchedulerConfig(mesh=...)`` or ``core.gibbs.*(sharding=...)``.
"""
from repro.core.sharding import ShardingConfig

__all__ = ["ShardingConfig"]
