"""Fault tolerance: heartbeats, Bayesian straggler detection, elastic resize.

The paper integration: each worker's step-time posterior (from the Gibbs
estimator) gives a *predictive distribution* for its next step time.  A
worker whose observed times are persistently improbable under its own
posterior is flagged:

  soft anomaly  (slow but alive)  -> partitioner shifts work away (rebalance)
  hard anomaly  (heartbeat lost)  -> evict; elastic re-mesh; checkpoint resume

This replaces fixed timeout heuristics with calibrated, per-worker,
workload-aware thresholds — exactly the paper's "dynamically fast changing
environment" argument.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.sched import Scheduler


@dataclasses.dataclass
class WorkerHealth:
    alive: bool = True
    last_heartbeat: float = 0.0
    anomaly_score: float = 0.0
    flagged: bool = False


class FaultToleranceMonitor:
    def __init__(
        self,
        partitioner: Scheduler,
        *,
        heartbeat_timeout: float = 60.0,
        straggler_sigma: float = 3.0,
    ):
        self.partitioner = partitioner
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_sigma = straggler_sigma
        self.health = [WorkerHealth() for _ in range(partitioner.num_workers)]
        self.events: List[Dict] = []

    def observe_step(
        self, fracs: np.ndarray, times: np.ndarray, now: Optional[float] = None
    ) -> Dict[str, np.ndarray]:
        """Feed one step's telemetry; returns {stragglers, failures} masks."""
        now = time.monotonic() if now is None else now
        finite = np.isfinite(times)
        for i, ok in enumerate(finite):
            if ok:
                self.health[i].last_heartbeat = now

        # hard failures: heartbeat timeout, or no completion reported at all
        # (an infinite/missing step time IS a missed heartbeat)
        failures = np.array(
            [
                h.alive
                and (
                    not finite[i]
                    or (now - h.last_heartbeat) > self.heartbeat_timeout
                )
                for i, h in enumerate(self.health)
            ]
        )
        # soft stragglers: posterior-predictive anomaly (paper's model).
        # Hard failures carry non-finite times — they are handled above by
        # eviction and must NEVER enter the soft-anomaly statistics: a
        # fabricated placeholder time would permanently corrupt the dead
        # worker's EWMA and skew the median/MAD baseline the whole live
        # fleet is judged against.  The validity mask keeps them out
        # (``anomaly`` substitutes interior dummies for masked slots itself).
        scores = self.partitioner.anomaly_scores(fracs, times, valid=finite)
        alive = np.array([h.alive for h in self.health])
        flags = self.partitioner.flag_stragglers(
            self.straggler_sigma, valid=finite & alive
        )
        for i, h in enumerate(self.health):
            h.anomaly_score = float(scores[i]) if i < len(scores) else 0.0
            h.flagged = bool(flags[i]) if i < len(flags) else False

        if failures.any():
            self.events.append(
                {"type": "failure", "workers": np.where(failures)[0].tolist()}
            )
        if flags.any():
            self.events.append(
                {"type": "straggler", "workers": np.where(flags)[0].tolist()}
            )
        return {"stragglers": flags, "failures": failures}

    def evict(self, failures: np.ndarray) -> None:
        """Elastic down-scale: drop failed workers from the fleet."""
        self.partitioner.remove_workers(failures)
        self.health = [h for h, f in zip(self.health, failures) if not f]
        self.events.append({"type": "evict", "count": int(failures.sum())})

    def admit(self, count: int, seed: int = 0) -> None:
        """Elastic up-scale: add fresh workers with uninformed priors."""
        self.partitioner.add_workers(count, seed=seed)
        self.health.extend(WorkerHealth() for _ in range(count))
        self.events.append({"type": "admit", "count": count})
