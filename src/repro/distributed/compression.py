"""Gradient compression with error feedback (distributed-optimization trick).

Two schemes, both with EF memory so compression error is re-injected next
step (required for convergence — Karimireddy et al. 2019):

  * int8_ef — per-tensor symmetric int8 quantization: 4x less DP all-reduce
    traffic (gradients cross the pod/DCN boundary quantized; the EF residual
    stays local).
  * topk_ef — magnitude top-k sparsification (k = compress_ratio of entries).

The hook composes with ``train_step.make_train_step(compression=...)``: it
runs after microbatch accumulation, before clipping/AdamW — i.e. exactly at
the reduce boundary where traffic matters.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def init_error_feedback(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _quant_dequant_int8(g: Array) -> Array:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_mask(g: Array, ratio: float) -> Array:
    flat = jnp.abs(g.reshape(-1))
    k = max(int(flat.shape[0] * ratio), 1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def make_compressor(
    kind: str, error_feedback: Any, *, ratio: float = 0.01
) -> Tuple[Callable, Callable]:
    """Returns (compress_fn(grads, ef) -> (grads, ef), init_ef)."""

    def compress(grads: Any, ef: Any) -> Tuple[Any, Any]:
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            if kind == "int8_ef":
                sent = _quant_dequant_int8(g32)
            elif kind == "topk_ef":
                sent = g32 * _topk_mask(g32, ratio)
            else:
                raise ValueError(kind)
            return sent, g32 - sent

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(ef)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        sent = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_ef = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return sent, new_ef

    return compress, init_error_feedback
