"""Logical-axis sharding rules for MODEL tensors, with divisibility fallback.

(The estimation engine's fleet-axis sharding is a separate, much simpler
concern — a 1-D ``workers`` mesh over an embarrassingly parallel axis — and
lives in ``repro.core.sharding.ShardingConfig``; see ``docs/scaling.md``.)

Every parameter/cache tensor carries logical axis names (see
``repro.models.params``).  ``spec_for`` maps them to mesh axes greedily:
each logical axis tries its candidate mesh axes in order; a candidate is
taken only if (a) it is not already used by another dim of the same tensor
and (b) the dim size is divisible by the mesh-axis size.  Anything that
fails degrades to replication — this is what lets e.g. smollm's 9 heads or
granite's 40 experts compile cleanly on a 16-way model axis.

Default ruleset (TP on 'model', FSDP/ZeRO on 'data'(+'pod')):
  vocab/mlp/heads/kv_heads/experts/rnn/cell -> model   (tensor/expert parallel)
  embed  -> fsdp axes  (ZeRO-3: params+optimizer sharded over data parallels)
  head_dim -> model    (fallback TP when the head axes were indivisible)
  batch  -> (pod, data)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

AxisCandidates = Tuple[str, ...]
Rules = Dict[str, Tuple[AxisCandidates, ...]]


def default_rules(mesh: Mesh, *, fsdp: bool = True) -> Rules:
    fsdp_axes: Tuple[AxisCandidates, ...] = ()
    if fsdp:
        if "pod" in mesh.axis_names:
            fsdp_axes = (("pod", "data"), ("data",))
        else:
            fsdp_axes = (("data",),)
    batch: Tuple[AxisCandidates, ...] = (
        (("pod", "data"), ("data",))
        if "pod" in mesh.axis_names
        else (("data",),)
    )
    return {
        "vocab": (("model",),),
        "mlp": (("model",),),
        "heads": (("model",),),
        "kv_heads": (("model",),),
        # experts shard over the DATA axes (EP): the model axis is reserved
        # for the per-expert d_ff TP split (see repro.models.moe) — the only
        # layout that fits 480B-class MoE weights in per-chip HBM.
        "experts": (
            (("pod", "data"), ("data",))
            if "pod" in mesh.axis_names
            else (("data",),)
        ),
        "rnn": (("model",),),
        "cell": (("model",),),
        # NOTE: head_dim deliberately NOT sharded for parameters — contracting
        # a sharded head_dim turns attention logits into partial sums and
        # all-reduces (B,H,T,S)-sized tensors.  It remains a fallback for
        # decode-cache *storage* (see cache_rules), where it shards the big
        # KV buffers and only small per-step logits need reducing.
        "embed": fsdp_axes,
        "batch": batch,
        "seq": (),
        "layers": (),
    }


def cache_rules(mesh: Mesh) -> Rules:
    """Decode-cache rules: prefer kv_heads -> model; else shard the cache's
    seq dim over model (flash-decode: per-shard partial softmax + tiny
    combines); recurrent-state feature dims (head_dim/rnn) as last resort."""
    r = dict(default_rules(mesh))
    r["seq"] = (("model",),)
    r["head_dim"] = (("model",),)
    return r


# Lower number = assigned first (per-tensor greedy order).
_PRIORITY = {
    "vocab": 0, "mlp": 0, "heads": 0, "kv_heads": 0, "experts": 0,
    "rnn": 0, "cell": 0, "batch": 0,
    "embed": 1,
    "seq": 2,
    "head_dim": 3,
}


def _axis_size(mesh: Mesh, axes: AxisCandidates) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Rules,
) -> PS:
    """Greedy logical->mesh assignment with divisibility fallback.

    Dims are visited in _PRIORITY order (not positional order) so that e.g. a
    divisible kv_heads dim claims the model axis before the seq fallback.
    """
    used: set = set()
    out: list = [None] * len(tuple(shape))
    order = sorted(
        range(len(out)), key=lambda i: _PRIORITY.get(logical[i] or "", 1)
    )
    for i in order:
        dim, name = shape[i], logical[i]
        for cand in rules.get(name or "", ()):
            cand_t = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a in used for a in cand_t):
                continue
            if any(a not in mesh.axis_names for a in cand_t):
                continue
            if dim % _axis_size(mesh, cand_t) != 0:
                continue
            out[i] = cand_t if len(cand_t) > 1 else cand_t[0]
            used.update(cand_t)
            break
    return PS(*out)


def tree_shardings(
    abstract_tree: Any,
    axes_tree_: Any,
    mesh: Mesh,
    rules: Optional[Rules] = None,
):
    """NamedShardings for a parallel (abstract-values, logical-axes) tree."""
    rules = rules or default_rules(mesh)

    def one(aval, axes):
        return NamedSharding(mesh, spec_for(aval.shape, axes, mesh, rules))

    return jax.tree_util.tree_map(one, abstract_tree, axes_tree_)


# ---------------------------------------------------------------------------
# activation / batch specs
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh) -> PS:
    return PS(tuple(a for a in ("pod", "data") if a in mesh.axis_names))


def data_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """Shard dim0 (batch) over the data axes, replicate the rest."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return NamedSharding(mesh, PS(axes, *([None] * (ndim - 1))))


def cache_shardings(
    cache_abstract: Any, cache_axes: Any, mesh: Mesh, rules: Optional[Rules] = None
):
    """Shardings for a decode cache from its exact logical-axes tree
    (``repro.models.transformer.cache_axes_tree``): batch over the data axes,
    kv-heads/feature dims over model with divisibility fallback."""
    rules = rules or cache_rules(mesh)
    is_axes = lambda x: isinstance(x, tuple)

    def one(aval, axes):
        return NamedSharding(mesh, spec_for(aval.shape, axes, mesh, rules))

    return jax.tree_util.tree_map(one, cache_abstract, cache_axes, is_leaf=None)
