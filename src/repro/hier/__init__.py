"""Fleet hyperprior: hierarchical empirical-Bayes pooling across workers.

The inference layer between the per-worker estimator (``repro.core.gibbs``)
and the scheduler (``repro.sched``): :func:`fit_hyperprior` pools the
per-worker posteriors into fleet-level hyperparameters, :func:`shrink`
blends cold workers toward the fleet mean with an effective-sample-size
weight, and :func:`surprise` scores each worker against the pooled prior —
the drift statistic behind the self-calibrating serve gate.  Opt in via
``sched.SchedulerConfig(hierarchical=True)``; derivations in
``docs/hierarchy.md``.

>>> import jax, jax.numpy as jnp
>>> from repro import hier
>>> from repro.core import gibbs
>>> key = jax.random.PRNGKey(0)
>>> f = jax.random.uniform(key, (8, 48), minval=0.1, maxval=0.9)
>>> t = f**0.9 * 4.0                         # 8 near-identical workers
>>> fleet, _ = gibbs.fit_fleet(key, t, f, n_iters=3, grid_size=64)
>>> hyper = hier.fit_hyperprior(fleet)       # pooled fleet prior
>>> bool(abs(float(hyper.ng.mu0) - 4.0) < 1.0)
True
>>> cold = gibbs.init_state(jax.random.PRNGKey(1), mu_guess=1.0)
>>> cold_fleet = jax.tree_util.tree_map(lambda x: x[None], cold)
>>> warm = hier.shrink(cold_fleet, hyper)    # ess 0 -> lands on the pool
>>> bool(abs(float(warm.ng.mu0[0]) - float(hyper.ng.mu0)) < 1e-5)
True
>>> s = hier.surprise(fleet, hyper)          # (K,) drift scores, all small
>>> s.shape
(8,)
>>> noop = hier.shrink(fleet, hyper, weight=0.0)   # weight 0: bitwise no-op
>>> bool(jnp.all(noop.ng.mu0 == fleet.ng.mu0))
True
"""
from .hyperprior import (
    DEFAULT_STRENGTH,
    Hyperprior,
    HyperStats,
    effective_sample_size,
    fit_hyperprior,
    fit_hyperprior_sharded,
    hyper_from_stats,
    hyper_init,
    hyper_stats,
    init_from_hyperprior,
    shrink,
    shrinkage_weight,
    surprise,
)

__all__ = [
    "DEFAULT_STRENGTH",
    "Hyperprior",
    "HyperStats",
    "effective_sample_size",
    "fit_hyperprior",
    "fit_hyperprior_sharded",
    "hyper_from_stats",
    "hyper_init",
    "hyper_stats",
    "init_from_hyperprior",
    "shrink",
    "shrinkage_weight",
    "surprise",
]
