"""Empirical-Bayes fleet hyperprior: cold-start, transfer, drift scoring.

The paper infers each processing unit's characteristics independently, so
every worker that joins the fleet starts from the same vague global prior
and burns its first N observations re-learning what the fleet already
knows — the costly-experimentation problem the paper set out to avoid,
re-created at the fleet level.  This module pools statistical strength
across the fleet (the Lotaru local-estimation-with-transfer argument)
without touching the per-worker estimator:

  * :func:`fit_hyperprior` — fit fleet-level hyperparameters from the
    current per-worker posteriors by moment matching: a pooled
    Normal-Gamma ``(mu0, kappa0, a0, b0)`` over each worker's ``(mu,
    lambda)`` and pooled Beta summaries of the ``(K, 2, G)`` exponent
    posteriors (the per-worker Beta moment fits ARE the grid's first two
    moments, Eqs 12-18, so pooling them pools the grids).  Pure,
    jit/vmap-compatible; the per-shard reduction is a handful of scalar
    sums, so under ``shard_map`` the refit is one ``psum`` of O(1)
    sufficient statistics (:func:`hyper_stats` / :func:`hyper_from_stats`).
  * :func:`shrink` — blend each worker's posterior toward the fleet prior
    with an effective-sample-size weight ``w_k = tau / (tau + ess_k)``:
    a cold worker (ess 0) lands exactly on the fleet prior, a mature
    worker keeps its own data, and weight 0 is a bitwise no-op.
  * :func:`surprise` — score each worker's posterior point estimates
    against the pooled prior: the log marginal-likelihood ratio between
    the hyperprior evaluated at its own typical parameters and at the
    worker's, a per-worker ``(K,)`` device-resident statistic that grows
    as a worker's posterior escapes the pooled prior.  Its distribution
    under the null does not depend on which worker you ask, which is what
    makes an online-calibrated gate over it fleet-size-invariant
    (``repro.serve.gate``) — unlike a fixed threshold on the
    max-over-workers drift, whose null level grows with K.

``shrink`` and ``surprise`` are strictly per-worker (no cross-fleet ops),
so both run per-shard under ``shard_map`` unchanged; only the O(1)-sized
hyperparameters are replicated.  Derivations in ``docs/hierarchy.md``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.distributions import (
    EPS,
    TINY,
    beta_logpdf,
    gamma_logpdf,
    normal_logpdf,
)
from repro.core.gibbs import GibbsState
from repro.core.moments import BetaParams
from repro.core.posterior import NormalGammaParams
from repro.core.sharding import ShardingConfig, shard_fleet_call

Array = jax.Array

# The Normal-Gamma pseudo-count floor all per-worker chains start from
# (``NormalGammaParams.default`` / ``fit_fleet``): nu0 = 1.  Effective
# sample size is measured as observations accumulated past that floor.
_NU_INIT = 1.0
# Default pseudo-observation strength of the fleet prior in ``shrink``:
# a worker needs ~tau of its own observations to outvote the fleet.
DEFAULT_STRENGTH = 8.0


class Hyperprior(NamedTuple):
    """Fleet-level hyperparameters; a tiny (all-scalar) pytree.

    ``ng`` is the pooled Normal-Gamma over each worker's ``(mu, lambda)``
    — its ``(mu0, kappa0, nu0, psi0)`` are the fleet's ``(mu0, kappa0,
    a0, b0)`` — and ``alpha_prior`` / ``beta_prior`` are the pooled Beta
    summaries of the per-worker exponent posteriors.  ``n_workers`` is
    the (masked) worker count the fit pooled, for observability.
    """

    ng: NormalGammaParams
    alpha_prior: BetaParams
    beta_prior: BetaParams
    n_workers: Array  # float32 scalar


class HyperStats(NamedTuple):
    """Per-shard sufficient statistics of the hyperprior refit.

    Thirteen scalars — sums over (masked) workers — so a sharded refit
    moves O(1) data per shard: ``psum`` these, then :func:`hyper_from_stats`.
    ``m*``: posterior means of mu; ``l*``: posterior means of lambda;
    ``a*`` / ``b*``: posterior means of the alpha / beta exponents; the
    ``v*`` entries are the summed *within-worker* posterior variances that
    keep the pooled prior honest about estimation noise.
    """

    n: Array
    m1: Array
    m2: Array
    vm: Array
    l1: Array
    l2: Array
    vl: Array
    a1: Array
    a2: Array
    va: Array
    b1: Array
    b2: Array
    vb: Array


def hyper_init(mu_guess: float = 1.0) -> Hyperprior:
    """The global prior as a degenerate hyperprior (nothing pooled yet)."""
    return Hyperprior(
        ng=NormalGammaParams.default(mu_guess),
        alpha_prior=BetaParams.default(),
        beta_prior=BetaParams.default(),
        n_workers=jnp.zeros((), jnp.float32),
    )


def _beta_mean_var(p: BetaParams) -> Tuple[Array, Array]:
    s = p.a + p.b
    mean = p.a / jnp.maximum(s, TINY)
    var = p.a * p.b / jnp.maximum(s * s * (s + 1.0), TINY)
    return mean, var


def hyper_stats(fleet: GibbsState, mask: Optional[Array] = None) -> HyperStats:
    """Sufficient statistics of the refit from a (K, ...)-leaf fleet state.

    ``mask`` optionally excludes workers (shard-padding dummies, evicted
    rows) with weight 0.  Strictly a per-worker map followed by a sum over
    the fleet axis, so per-shard calls compose by addition (``psum``).
    """
    ng = fleet.ng
    m_k = jnp.asarray(ng.mu0, jnp.float32)
    lam_k = jnp.asarray(ng.nu0 / jnp.maximum(ng.psi0, TINY), jnp.float32)
    # Within-worker posterior variances: Var[mu] = psi/(kappa (nu-1))
    # (guarded for vague nu), Var[lambda] = nu/psi^2.
    vmu_k = ng.psi0 / jnp.maximum(ng.kappa0 * jnp.maximum(ng.nu0 - 1.0, 0.1), TINY)
    vlam_k = ng.nu0 / jnp.maximum(ng.psi0 * ng.psi0, TINY)
    a_mean, a_var = _beta_mean_var(fleet.alpha_prior)
    b_mean, b_var = _beta_mean_var(fleet.beta_prior)

    w = jnp.ones_like(m_k) if mask is None else jnp.asarray(mask, m_k.dtype)
    s = lambda x: jnp.sum(w * x, axis=-1)
    return HyperStats(
        n=s(jnp.ones_like(m_k)),
        m1=s(m_k), m2=s(m_k * m_k), vm=s(vmu_k),
        l1=s(lam_k), l2=s(lam_k * lam_k), vl=s(vlam_k),
        a1=s(a_mean), a2=s(a_mean * a_mean), va=s(a_var),
        b1=s(b_mean), b2=s(b_mean * b_mean), vb=s(b_var),
    )


def _pool_beta(m1: Array, m2: Array, vw: Array, n: Array) -> BetaParams:
    """Moment-match a Beta to a population of Beta posteriors.

    Total predictive variance = between-worker spread of the posterior
    means + mean within-worker variance (law of total variance), so a
    fleet of vague posteriors yields a vague pool, never false confidence.
    """
    mean = jnp.clip(m1 / n, EPS, 1.0 - EPS)
    var = jnp.maximum(m2 / n - mean * mean, 0.0) + vw / n
    var = jnp.maximum(var, 1e-6)
    conc = jnp.clip(mean * (1.0 - mean) / var - 1.0, 0.5, 1e4)
    return BetaParams(a=mean * conc, b=(1.0 - mean) * conc)


def hyper_from_stats(stats: HyperStats) -> Hyperprior:
    """Moment-match the pooled hyperprior from (psum-ed) sufficient stats.

    * ``mu0 = mean_k E[mu_k]``; ``kappa0`` solves ``Var(mu | lambda) =
      1/(kappa0 lambda_bar) = V_mu`` where ``V_mu`` is the fleet's total
      (between + within) mu variance — a tight fleet pools hard, a
      heterogeneous fleet stays honest about its spread;
    * ``Gamma(a0, b0)`` over lambda matches the fleet's mean and total
      variance of the per-worker precision means, with ``b0 = a0 /
      lambda_bar`` so clipping ``a0`` never biases ``E[lambda]``;
    * the exponent pools are Beta moment matches of the per-worker Beta
      posteriors (themselves the Eqs 12-15 moment fits of the grids).
    """
    n = jnp.maximum(stats.n, 1.0)
    mu0 = stats.m1 / n
    v_mu = (
        jnp.maximum(stats.m2 / n - mu0 * mu0, 0.0) + stats.vm / n + 1e-8
    )
    lam_bar = jnp.maximum(stats.l1 / n, TINY)
    kappa0 = jnp.clip(1.0 / (v_mu * lam_bar), 1e-3, 1e6)
    v_lam = (
        jnp.maximum(stats.l2 / n - lam_bar * lam_bar, 0.0)
        + stats.vl / n + 1e-8
    )
    a0 = jnp.clip(lam_bar * lam_bar / v_lam, 0.51, 1e6)
    b0 = a0 / lam_bar
    return Hyperprior(
        ng=NormalGammaParams(
            mu0=jnp.asarray(mu0, jnp.float32),
            kappa0=jnp.asarray(kappa0, jnp.float32),
            nu0=jnp.asarray(a0, jnp.float32),
            psi0=jnp.asarray(b0, jnp.float32),
        ),
        alpha_prior=_pool_beta(stats.a1, stats.a2, stats.va, n),
        beta_prior=_pool_beta(stats.b1, stats.b2, stats.vb, n),
        n_workers=jnp.asarray(stats.n, jnp.float32),
    )


def _fit_hyperprior_body(
    fleet: GibbsState,
    mask: Optional[Array] = None,
    axis_name: Optional[str] = None,
) -> Hyperprior:
    stats = hyper_stats(fleet, mask)
    if axis_name is not None:
        stats = jax.lax.psum(stats, axis_name)
    return hyper_from_stats(stats)


@functools.partial(jax.jit, static_argnames=("axis_name",))
def fit_hyperprior(
    fleet: GibbsState,
    mask: Optional[Array] = None,
    *,
    axis_name: Optional[str] = None,
) -> Hyperprior:
    """Empirical-Bayes refit of the fleet hyperprior from per-worker posteriors.

    Pure and jit/vmap-compatible; hand it the ``gibbs`` leaf of a
    ``SchedulerState`` (leaves ``(K, ...)``).  Inside a ``shard_map``-ped
    program pass ``axis_name`` and the sufficient statistics are ``psum``-ed
    across shards — the refit then moves 13 scalars per shard, never a
    K-sized array (:func:`fit_hyperprior_sharded` wraps exactly this).
    """
    return _fit_hyperprior_body(fleet, mask, axis_name)


def fit_hyperprior_sharded(
    fleet: GibbsState,
    sharding: ShardingConfig,
    mask: Optional[Array] = None,
) -> Hyperprior:
    """The refit as one ``shard_map``-ped program over the fleet mesh.

    Each shard reduces its K/n_shards workers to 13 scalars, one ``psum``
    combines them, and every shard returns the identical (replicated)
    hyperprior.  K not divisible by the shard count is padded with
    mask-0 dummy workers, which contribute nothing to any statistic.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    k = jax.tree_util.tree_leaves(fleet)[0].shape[0]
    m = jnp.ones((k,), jnp.float32) if mask is None else jnp.asarray(mask)
    pad = sharding.pad(k)
    if pad:
        from repro.core.sharding import pad_fleet_axis

        fleet = pad_fleet_axis(fleet, pad)
        m = jnp.concatenate([m, jnp.zeros((pad,), m.dtype)])

    # NOTE: the body must stay unjitted and the eval_shape axis-free — a
    # psum traced outside the shard_map (eval_shape runs on full shapes,
    # no mesh context) raises "unbound axis name".
    fn = lambda fl, mm: _fit_hyperprior_body(fl, mm, sharding.axis)
    spec_of = lambda tree: jax.tree_util.tree_map(
        lambda _: P(sharding.axis), tree
    )
    out_spec = jax.tree_util.tree_map(
        lambda _: P(), jax.eval_shape(_fit_hyperprior_body, fleet, m)
    )
    return shard_map(
        fn,
        mesh=sharding.mesh,
        in_specs=(spec_of(fleet), P(sharding.axis)),
        out_specs=out_spec,
        check_rep=False,
    )(fleet, m)


# --------------------------------------------------------------------------
# shrinkage
# --------------------------------------------------------------------------
def effective_sample_size(fleet: GibbsState) -> Array:
    """Observations each worker's posterior has absorbed, (K,).

    The Normal-Gamma ``nu`` grows by n/2 per batch from its ``nu0 = 1``
    birth value (and decays under power-prior forgetting), so ``2 (nu -
    1)`` counts the evidence currently alive in the posterior — exactly
    the quantity shrinkage should weigh against the fleet prior.
    """
    return jnp.maximum(2.0 * (jnp.asarray(fleet.ng.nu0) - _NU_INIT), 0.0)


def shrinkage_weight(
    fleet: GibbsState, strength: float = DEFAULT_STRENGTH
) -> Array:
    """Fleet-prior weight ``w = tau / (tau + ess)`` per worker, (K,) in [0, 1]."""
    tau = jnp.asarray(strength, jnp.float32)
    return tau / (tau + effective_sample_size(fleet))


def _log_blend(own: Array, pool: Array, w: Array) -> Array:
    """Geometric interpolation for positive scale/pseudo-count parameters."""
    return jnp.exp(
        (1.0 - w) * jnp.log(jnp.maximum(own, TINY))
        + w * jnp.log(jnp.maximum(pool, TINY))
    )


def _shrink_body(fleet: GibbsState, hyper: Hyperprior, w: Array) -> GibbsState:
    """Blend one shard's workers toward the (replicated) fleet prior."""
    guard = lambda own, blended: jnp.where(w > 0.0, blended, own)
    ng, h = fleet.ng, hyper.ng
    new_ng = NormalGammaParams(
        mu0=guard(ng.mu0, ng.mu0 + w * (h.mu0 - ng.mu0)),
        kappa0=guard(ng.kappa0, _log_blend(ng.kappa0, h.kappa0, w)),
        nu0=guard(ng.nu0, _log_blend(ng.nu0, h.nu0, w)),
        psi0=guard(ng.psi0, _log_blend(ng.psi0, h.psi0, w)),
    )
    blend_beta = lambda own, pool: BetaParams(
        a=guard(own.a, _log_blend(own.a, pool.a, w)),
        b=guard(own.b, _log_blend(own.b, pool.b, w)),
    )
    # The chain's current samples feed the next sweep's Normal-Gamma
    # weights (f^{alpha-2beta}), so a cold worker's wild prior draws are
    # pulled to the fleet's typical parameters along with its prior.
    lam_pool = h.nu0 / jnp.maximum(h.psi0, TINY)
    a_pool, _ = _beta_mean_var(hyper.alpha_prior)
    b_pool, _ = _beta_mean_var(hyper.beta_prior)
    return fleet._replace(
        ng=new_ng,
        alpha_prior=blend_beta(fleet.alpha_prior, hyper.alpha_prior),
        beta_prior=blend_beta(fleet.beta_prior, hyper.beta_prior),
        mu=guard(fleet.mu, fleet.mu + w * (h.mu0 - fleet.mu)),
        lam=guard(fleet.lam, _log_blend(fleet.lam, lam_pool, w)),
        alpha=guard(
            fleet.alpha,
            jnp.clip(fleet.alpha + w * (a_pool - fleet.alpha), EPS, 1.0 - EPS),
        ),
        beta=guard(
            fleet.beta,
            jnp.clip(fleet.beta + w * (b_pool - fleet.beta), EPS, 1.0 - EPS),
        ),
    )


def shrink(
    fleet: GibbsState,
    hyper: Hyperprior,
    weight: Optional[Array] = None,
    *,
    strength: float = DEFAULT_STRENGTH,
    sharding: Optional[ShardingConfig] = None,
) -> GibbsState:
    """Blend each worker's posterior toward the fleet prior; pure, jittable.

    ``weight`` (scalar or (K,)) overrides the effective-sample-size rule
    ``w = strength / (strength + ess)``.  Properties the tests pin:

      * ``weight=0`` is a bitwise no-op on every leaf (cheap to call
        unconditionally);
      * a cold worker (ess 0) lands exactly on the fleet hyperprior;
      * a mature worker (ess >> strength) barely moves.

    The blend is strictly per-worker, so with ``sharding`` it runs
    per-shard under ``shard_map`` with the O(1) hyperprior replicated.
    The PRNG key leaf is never touched.
    """
    k = jnp.asarray(fleet.ng.mu0).shape
    if weight is None:
        w = shrinkage_weight(fleet, strength)
    else:
        w = jnp.broadcast_to(jnp.asarray(weight, jnp.float32), k)
    if sharding is None or len(k) == 0:
        return _shrink_body(fleet, hyper, w)
    return shard_fleet_call(
        lambda fl, ww: _shrink_body(fl, hyper, ww), sharding, (fleet, w)
    )


# --------------------------------------------------------------------------
# surprise
# --------------------------------------------------------------------------
def _hyper_logpdf(
    hyper: Hyperprior, mu: Array, lam: Array, alpha: Array, beta: Array
) -> Array:
    """Log-density of worker parameters under the pooled hyperprior."""
    h = hyper.ng
    scale_mu = 1.0 / jnp.sqrt(jnp.maximum(h.kappa0 * lam, TINY))
    return (
        normal_logpdf(mu, h.mu0, scale_mu)
        + gamma_logpdf(lam, h.nu0, h.psi0)
        + beta_logpdf(alpha, hyper.alpha_prior.a, hyper.alpha_prior.b)
        + beta_logpdf(beta, hyper.beta_prior.a, hyper.beta_prior.b)
    )


def _surprise_body(fleet: GibbsState, hyper: Hyperprior) -> Array:
    lam_k = fleet.ng.nu0 / jnp.maximum(fleet.ng.psi0, TINY)
    a_k, _ = _beta_mean_var(fleet.alpha_prior)
    b_k, _ = _beta_mean_var(fleet.beta_prior)
    logp_k = _hyper_logpdf(hyper, fleet.ng.mu0, lam_k, a_k, b_k)

    # The reference point: the hyperprior's own typical parameters.
    lam_t = hyper.ng.nu0 / jnp.maximum(hyper.ng.psi0, TINY)
    a_t, _ = _beta_mean_var(hyper.alpha_prior)
    b_t, _ = _beta_mean_var(hyper.beta_prior)
    logp_t = _hyper_logpdf(hyper, hyper.ng.mu0, lam_t, a_t, b_t)
    return (logp_t - logp_k).astype(jnp.float32)


@jax.jit
def _surprise_jit(fleet: GibbsState, hyper: Hyperprior) -> Array:
    return _surprise_body(fleet, hyper)


def surprise(
    fleet: GibbsState,
    hyper: Hyperprior,
    *,
    sharding: Optional[ShardingConfig] = None,
) -> Array:
    """Per-worker drift score against the pooled prior; (K,) device-resident.

    The log marginal-likelihood ratio ``log p(theta_typical | hyper) -
    log p(theta_k | hyper)`` where ``theta_k`` are worker k's posterior
    point estimates (Normal-Gamma means for ``(mu, lambda)``, Beta means
    for the exponents) and ``theta_typical`` are the hyperprior's own
    means: ~0 for a worker the fleet prior explains well, large and
    growing as the posterior escapes the pooled prior.  Unlike the raw
    max-over-workers KL drift, the per-worker null distribution does not
    depend on K, so one online-calibrated gate handles any fleet size
    (``repro.serve.gate``).

    Strictly per-worker; with ``sharding`` it runs per-shard under
    ``shard_map`` with only the O(1) hyperprior replicated.
    """
    if sharding is None or jnp.asarray(fleet.ng.mu0).ndim == 0:
        return _surprise_jit(fleet, hyper)
    return shard_fleet_call(
        lambda fl: _surprise_body(fl, hyper), sharding, (fleet,)
    )


# --------------------------------------------------------------------------
# cold-start admission
# --------------------------------------------------------------------------
def init_from_hyperprior(key: Array, count: int, hyper: Hyperprior) -> GibbsState:
    """Fresh per-worker states born from the fleet prior (not the global one).

    The cold-start path of ``sched.add_workers(hierarchical=True)``: the
    newcomers' Normal-Gamma and exponent priors ARE the pooled fleet
    hyperparameters, and their initial chain draws come from those
    distributions — so their very first ``propose`` already reflects what
    the fleet knows, instead of a vague guess the first N observations
    must correct.
    """
    from repro.core import gibbs

    keys = jax.random.split(key, count)
    return jax.vmap(
        lambda k: gibbs.init_state(
            k,
            ng=hyper.ng,
            alpha_prior=hyper.alpha_prior,
            beta_prior=hyper.beta_prior,
        )
    )(keys)
