"""Monte-Carlo workflow simulator: the independent oracle for composition.

Every closed-form composition rule in ``repro.core.frontier`` — worker-max
quadrature, serial sums, PERT branch-max, and the stochastic transforms
(:func:`~repro.core.frontier.mixture_moments`,
:func:`~repro.core.frontier.truncated_geometric_moments`,
:func:`~repro.core.frontier.compound_sum_moments`) — is an analytic claim
about a generative process.  This module IS that generative process, written
once, directly from the model's definition:

  * per-attempt stage makespan = max over workers of
    ``N(f_k^alpha mu_k, (f_k^beta sigma_k)^2)`` (the paper's per-unit model,
    via the same ``component_mean_std`` the analytic path uses, so floors
    match bit-for-bit);
  * rework loops: each stage re-runs until an attempt succeeds (per-attempt
    rework probability ``r_s``) or the ``max_retries`` cap is hit — attempt
    counts are truncated-geometric by construction, and the stage's duration
    is the EXACT sum over its sampled attempts;
  * conditional branches: each stage fires an independent Bernoulli
    ``exec_probs`` indicator per sample; a skipped stage contributes zero
    duration but still forwards its predecessors' finish times (the same
    semantics the mixture-moment transform encodes);
  * composition: exact max over predecessor finish times at joins, exact sum
    along chains, exact max over sinks — no Normal moment-matching anywhere.

Because the simulator shares NO composition code with the analytic path
(only the per-unit parameterization), agreement within Monte-Carlo error is
evidence, not tautology.  ``tests/test_stochastic.py`` pins every rule to it
at >= 2e5 samples; the telemetry generator below doubles as the fixture
factory for scenario tests.

Topology is duck-typed: anything with ``.preds`` (plus optional
``.exec_probs`` / ``.rework_probs`` / ``.max_retries``, e.g.
``repro.sched.WorkflowDAG``) or a bare ``preds`` tuple-of-tuples works —
this layer sits below ``sched`` and never imports it.

Sampling is batched: the per-batch draw tensor is (batch, S, R_max, K), so
``batch_size`` bounds peak memory while ``jax.lax.map`` streams batches;
each batch consumes its own key from one ``jax.random.split`` (RL006).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.frontier import UnitParams, component_mean_std

Array = jax.Array

DEFAULT_NUM_SAMPLES = 200_000
DEFAULT_BATCH_SIZE = 8_192


def topology_spec(
    topology,
) -> Tuple[
    Tuple[Tuple[int, ...], ...],
    Tuple[float, ...],
    Tuple[float, ...],
    Tuple[int, ...],
]:
    """Normalize a duck-typed topology into hashable (jit-static) tuples.

    Accepts a bare ``preds`` tuple-of-tuples or any object exposing
    ``.preds`` and optionally ``.exec_probs`` / ``.rework_probs`` /
    ``.max_retries`` (absent/None annotations mean the degenerate
    deterministic values: always execute, never rework).
    """
    preds = getattr(topology, "preds", topology)
    preds = tuple(tuple(int(p) for p in ps) for ps in preds)
    s = len(preds)
    exec_probs = getattr(topology, "exec_probs", None)
    rework_probs = getattr(topology, "rework_probs", None)
    max_retries = getattr(topology, "max_retries", None)
    exec_probs = (1.0,) * s if exec_probs is None else tuple(map(float, exec_probs))
    rework_probs = (
        (0.0,) * s if rework_probs is None else tuple(map(float, rework_probs))
    )
    max_retries = (
        (1,) * s if max_retries is None else tuple(int(r) for r in max_retries)
    )
    if not (len(exec_probs) == len(rework_probs) == len(max_retries) == s):
        raise ValueError("stochastic annotations must have one entry per stage")
    return preds, exec_probs, rework_probs, max_retries


def _stage_durations(
    key: Array,
    mean: Array,
    std: Array,
    exec_probs: Tuple[float, ...],
    rework_probs: Tuple[float, ...],
    max_retries: Tuple[int, ...],
    num_samples: int,
) -> Array:
    """(num_samples, S) sampled effective stage durations (rework + branch)."""
    s = mean.shape[0]
    r_max = max(max_retries)
    k_dur, k_rework, k_branch = jax.random.split(key, 3)

    # Every attempt is an independent worker-max draw: (n, S, R_max, K).
    z = jax.random.normal(k_dur, (num_samples, s, r_max) + mean.shape[1:])
    attempts = jnp.max(mean[None, :, None, :] + std[None, :, None, :] * z, axis=-1)

    # Truncated-geometric attempt counts by inverse CDF.  log(r) = -inf at
    # r = 0 sends the ratio to -0.0 -> exactly one attempt, no NaN.
    r = jnp.asarray(rework_probs, jnp.float32)
    caps = jnp.asarray(max_retries, jnp.float32)
    u = jax.random.uniform(k_rework, (num_samples, s))
    n_attempts = jnp.minimum(
        1.0 + jnp.floor(jnp.log1p(-u) / jnp.log(jnp.maximum(r, 1e-38))), caps
    )
    n_attempts = jnp.where(r <= 0.0, 1.0, n_attempts)
    taken = (
        jnp.arange(r_max, dtype=jnp.float32)[None, None, :]
        < n_attempts[..., None]
    )
    duration = jnp.sum(attempts * taken, axis=-1)  # (n, S)

    # Bernoulli path activation: skipped stages contribute zero duration.
    p = jnp.asarray(exec_probs, jnp.float32)
    active = jax.random.bernoulli(k_branch, p, (num_samples, s))
    return duration * active


@functools.partial(
    jax.jit,
    static_argnames=(
        "preds",
        "exec_probs",
        "rework_probs",
        "max_retries",
        "num_samples",
        "batch_size",
    ),
)
def _simulate(
    key: Array,
    fracs: Array,
    params: UnitParams,
    *,
    preds: Tuple[Tuple[int, ...], ...],
    exec_probs: Tuple[float, ...],
    rework_probs: Tuple[float, ...],
    max_retries: Tuple[int, ...],
    num_samples: int,
    batch_size: int,
) -> Array:
    mean, std = component_mean_std(fracs, params)  # (S, K) — shared floors
    num_batches = -(-num_samples // batch_size)
    keys = jax.random.split(key, num_batches)

    def one_batch(k: Array) -> Array:
        contrib = _stage_durations(
            k, mean, std, exec_probs, rework_probs, max_retries, batch_size
        )
        # Exact topological composition per sample: start at the max over
        # predecessor finishes, finish after this stage's sampled duration.
        fin: list = [None] * len(preds)
        for i, ps in enumerate(preds):
            start = functools.reduce(
                jnp.maximum,
                [fin[q] for q in ps],
                jnp.zeros((batch_size,), jnp.float32),
            )
            fin[i] = start + contrib[:, i]
        has_succ = {q for ps in preds for q in ps}
        sinks = [i for i in range(len(preds)) if i not in has_succ]
        return functools.reduce(jnp.maximum, [fin[i] for i in sinks])

    return jax.lax.map(one_batch, keys).reshape(-1)


def simulate_workflow(
    key: Array,
    topology,
    fracs: Array,
    params: UnitParams,
    *,
    num_samples: int = DEFAULT_NUM_SAMPLES,
    batch_size: int = DEFAULT_BATCH_SIZE,
) -> Array:
    """Sampled end-to-end completion times of a (stochastic) workflow.

    ``topology`` is duck-typed (see :func:`topology_spec`); ``fracs`` and the
    ``UnitParams`` leaves are (S, K) — pass the TRUE worker parameters to use
    the simulator as an oracle, or posterior point estimates to stress a
    proposal under the scheduler's own beliefs.  Returns at least
    ``num_samples`` samples (rounded up to whole batches of ``batch_size``).

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.frontier import UnitParams
    >>> params = UnitParams.of(mu=jnp.full((2, 2), 8.0),
    ...                        sigma=jnp.full((2, 2), 0.2))
    >>> fracs = jnp.full((2, 2), 0.5)
    >>> t = simulate_workflow(jax.random.PRNGKey(0), ((), (0,)), fracs,
    ...                       params, num_samples=4096, batch_size=2048)
    >>> t.shape                       # chain of two stages, ~2 * 0.5 * 8
    (4096,)
    >>> bool(abs(float(jnp.mean(t)) - 8.0) < 0.5)
    True
    """
    preds, exec_probs, rework_probs, max_retries = topology_spec(topology)
    fracs = jnp.asarray(fracs, jnp.float32)
    if fracs.ndim != 2 or fracs.shape[0] != len(preds):
        raise ValueError(
            f"fracs must be (S, K) with S == {len(preds)}, got {fracs.shape}"
        )
    return _simulate(
        key,
        fracs,
        params,
        preds=preds,
        exec_probs=exec_probs,
        rework_probs=rework_probs,
        max_retries=max_retries,
        num_samples=int(num_samples),
        batch_size=int(min(batch_size, num_samples)),
    )


def simulate_moments(
    key: Array,
    topology,
    fracs: Array,
    params: UnitParams,
    *,
    num_samples: int = DEFAULT_NUM_SAMPLES,
    batch_size: int = DEFAULT_BATCH_SIZE,
) -> Tuple[Array, Array]:
    """(E, Var) of the end-to-end completion time, straight from samples."""
    t = simulate_workflow(
        key,
        topology,
        fracs,
        params,
        num_samples=num_samples,
        batch_size=batch_size,
    )
    return jnp.mean(t), jnp.var(t)


@functools.partial(jax.jit, static_argnames=("num_obs",))
def simulate_telemetry(
    key: Array,
    fracs: Array,
    params: UnitParams,
    *,
    num_obs: int = 16,
    noise: Optional[Array] = None,
) -> Array:
    """Per-worker telemetry times from the true generative model.

    Returns ``fracs.shape + (num_obs,)`` completion times — (K, N) for a flat
    fleet, (S, K, N) for a stage-stacked DAG — each
    ``t = f^alpha mu + f^beta sigma z`` with fresh standard-normal ``z``
    (floored at a small positive so degenerate draws stay physical).  The
    fixture generator for scenario tests: feed the result to
    ``sched.Telemetry`` / ``observe_dag`` and the estimator should recover
    ``params``.  ``noise`` optionally scales the per-draw std (stress tests).
    """
    mean, std = component_mean_std(jnp.asarray(fracs, jnp.float32), params)
    if noise is not None:
        std = std * noise
    z = jax.random.normal(key, mean.shape + (num_obs,))
    return jnp.maximum(mean[..., None] + std[..., None] * z, 1e-6)
