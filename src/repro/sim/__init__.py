"""Monte-Carlo workflow simulation — the oracle layer for composition rules.

``repro.sim`` sits between ``core`` (whose analytic moment-composition rules
it independently checks) and ``sched`` (whose topologies it consumes
duck-typed, never by import).  One generative process, written directly from
the model definition, backs three uses:

  * **oracle** — every closed-form rule in ``repro.core.frontier`` is pinned
    against :func:`simulate_moments` in ``tests/test_stochastic.py``;
  * **evaluator** — :func:`simulate_workflow` measures a proposal's TRUE
    expected completion time under known worker parameters (how the
    stochastic-aware partitioner is shown to beat the deterministic one);
  * **fixture factory** — :func:`simulate_telemetry` draws per-worker
    telemetry from the same model the estimator assumes.

>>> import jax, jax.numpy as jnp
>>> from repro import sim
>>> from repro.core.frontier import UnitParams
>>> params = UnitParams.of(mu=jnp.full((1, 2), 6.0),
...                        sigma=jnp.full((1, 2), 0.3))
>>> e, v = sim.simulate_moments(jax.random.PRNGKey(0), ((),),
...                             jnp.full((1, 2), 0.5), params,
...                             num_samples=8192, batch_size=4096)
>>> bool(abs(float(e) - 3.0) < 0.1)     # one stage, two workers at f=0.5
True
"""
from .workflow import (
    DEFAULT_BATCH_SIZE,
    DEFAULT_NUM_SAMPLES,
    simulate_moments,
    simulate_telemetry,
    simulate_workflow,
    topology_spec,
)

__all__ = [
    "DEFAULT_BATCH_SIZE",
    "DEFAULT_NUM_SAMPLES",
    "simulate_moments",
    "simulate_telemetry",
    "simulate_workflow",
    "topology_spec",
]
