"""recurrentgemma-2b — Griffin-style hybrid: RG-LRU + local attention, 2:1.
[arXiv:2402.19427; hf]
26L d_model=2560 10H (GQA kv=1, i.e. MQA) d_ff=7680 vocab=256000

Pattern (recurrent, recurrent, local-attention) repeated; 26 = 8*3 + 2, the
two remainder layers are recurrent (matches Griffin's tail).  Local attention
window 2048 + O(1) RG-LRU state -> long_500k RUNS (window-bounded cache).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "localattn"),
    local_window=2048,
    tie_embeddings=True,
    act="geglu",
    logit_softcap=30.0,
)
