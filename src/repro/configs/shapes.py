"""The assigned input-shape set (same four for every LM-family architecture)."""
from __future__ import annotations

from typing import Dict, List

from .base import ModelConfig, ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode")

ALL_SHAPES: List[ShapeConfig] = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]
SHAPES: Dict[str, ShapeConfig] = {s.name: s for s in ALL_SHAPES}


def applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic decode (SSM/hybrid); others always apply.

    Full-attention architectures skip long_500k (O(seq) KV cache at 524288
    positions is architecturally quadratic-cost serving) — recorded in
    DESIGN.md §Arch-applicability.
    """
    if shape.name == "long_500k":
        return model.is_subquadratic
    return True
