"""whisper-medium — encoder-decoder audio transformer (conv frontend STUB).
[arXiv:2212.04356; unverified]
24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865

The audio/conv frontend is a stub per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, encoder_seq, d_model).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,  # decoder layers
    encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,  # whisper is MHA
    d_ff=4096,
    vocab_size=51865,
    tie_embeddings=True,
    act="gelu",
)
