"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from typing import Dict

from . import (
    arctic_480b,
    command_r_35b,
    granite_moe_3b,
    internvl2_1b,
    recurrentgemma_2b,
    smollm_135m,
    tinyllama_1_1b,
    whisper_medium,
    xlstm_1_3b,
    yi_9b,
)
from .base import ModelConfig, RunConfig, ShapeConfig, reduced
from .shapes import ALL_SHAPES, SHAPES, applicable

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        whisper_medium,
        granite_moe_3b,
        arctic_480b,
        command_r_35b,
        smollm_135m,
        tinyllama_1_1b,
        yi_9b,
        xlstm_1_3b,
        internvl2_1b,
        recurrentgemma_2b,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ARCHS",
    "ALL_SHAPES",
    "SHAPES",
    "ModelConfig",
    "RunConfig",
    "ShapeConfig",
    "applicable",
    "get_arch",
    "get_shape",
    "reduced",
]
