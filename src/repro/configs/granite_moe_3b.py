"""granite-moe-3b-a800m — fine-grained MoE LM.
[hf:ibm-granite (3.0 MoE family); hf]
32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, 40 experts top-8.

Assignment header says "MoE 40e top-8"; the inline note "32 experts" matches
the smaller granite-1b-a400m — we follow the 40e/top-8 header (matches the
3b-a800m scale).  Noted in DESIGN.md §Arch-applicability.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    tie_embeddings=True,
    act="swiglu",
)
