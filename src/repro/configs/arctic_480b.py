"""arctic-480b — 128-expert top-2 MoE with a parallel dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 + dense residual
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_residual=True,  # dense FFN in parallel with the MoE (dense-MoE hybrid)
    tie_embeddings=False,
    act="swiglu",
)
