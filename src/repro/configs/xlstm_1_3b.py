"""xlstm-1.3b — sLSTM + mLSTM recurrent LM (attention-free).
[arXiv:2405.04517; unverified]
48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304

d_ff=0: no separate FFN — the xLSTM blocks carry their own projections.
Pattern: one sLSTM block per ``slstm_every`` (=8) layers, mLSTM otherwise.
Sub-quadratic: O(1)-size recurrent state -> long_500k RUNS.
"""
from .base import ModelConfig

_PATTERN = tuple(["mlstm"] * 7 + ["slstm"])  # repeated 6x -> 48 layers

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    layer_pattern=_PATTERN,
    slstm_every=8,
    tie_embeddings=False,
    act="gelu",
)
