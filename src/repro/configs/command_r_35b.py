"""command-r-35b — dense GQA LM, no-bias, 256k vocab.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    tie_embeddings=True,  # command-r ties input/output embeddings
    use_bias=False,
    act="swiglu",
    rope_theta=8000000.0,
)
