"""Config dataclasses: model architecture, input shapes, run/mesh settings."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per assigned architecture."""

    name: str
    family: str  # dense | moe | encdec | ssm | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- attention ---
    local_window: int = 0  # >0 for local (sliding-window) attention layers
    rope_theta: float = 10000.0

    # --- layer pattern (hybrid / ssm families) ---
    # Cycle of block kinds, repeated num_layers//len(pattern) times with the
    # remainder unrolled.  Empty -> homogeneous ("dense" or "moe" by family).
    layer_pattern: Tuple[str, ...] = ()

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500  # precomputed audio-frame embeddings (stub frontend)

    # --- vlm (internvl) ---
    vision_patches: int = 0  # precomputed patch embeddings (stub frontend)

    # --- ssm (xlstm) ---
    slstm_every: int = 8  # one sLSTM block per this many layers

    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    use_bias: bool = False
    act: str = "swiglu"  # swiglu | gelu | geglu
    dtype: str = "bfloat16"
    # logit softcap (gemma-style); 0 disables
    logit_softcap: float = 0.0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern:
            return self.layer_pattern
        if self.family == "encdec":
            return ("xdec",)
        return ("moe",) if self.num_experts > 0 else ("dense",)

    @property
    def is_subquadratic(self) -> bool:
        """True if decode cost is O(1)/window-bounded in context length."""
        quad = {"dense", "moe", "xdec", "enc"}
        return not (set(self.pattern) & quad)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D reporting)."""
        from repro.models import model_zoo

        return model_zoo.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import model_zoo

        return model_zoo.param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark input shape: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving run settings (driver-level)."""

    model: ModelConfig
    shape: ShapeConfig
    microbatch_per_device: int = 1
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    remat: str = "full"  # none | full | dots
    # AdamW moment dtype: bfloat16 for 100B+ models (HBM-fitting trade)
    optimizer_dtype: str = "float32"
    # gradient accumulation dtype (bfloat16 halves grad buffers; error is
    # bounded by the later f32 optimizer math)
    grad_dtype: str = "float32"
    seed: int = 0
    # distribution
    multi_pod: bool = False
    # partitioner (the paper's feature)
    partitioner_enabled: bool = True
    partitioner_risk_aversion: float = 0.0
    partitioner_refit_every: int = 16  # drain cadence (steps per ring drain)
    # propose cadence (repro.serve drift gate): re-solve the split only when
    # the posterior moved more than the threshold since the last solve, or
    # after max_staleness drains — whichever comes first.  None opts into
    # the self-calibrating EWMA gate (repro.serve.gate): the drift statistic
    # is scored against its own observed steady-state level instead of a
    # hand-tuned constant.
    partitioner_drift_threshold: Optional[float] = 0.02
    partitioner_max_staleness: int = 4
    # fault tolerance
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    straggler_threshold_sigma: float = 3.0
    # gradient compression: none | int8_ef | topk_ef
    grad_compression: str = "none"


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests."""
    base = dict(
        num_layers=max(2, len(cfg.pattern)),
        d_model=64,
        num_heads=max(2, min(cfg.num_heads, 4)),
        num_kv_heads=1,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_layers else cfg.encoder_seq,
        vision_patches=8 if cfg.vision_patches else 0,
        num_experts=4 if cfg.num_experts else 0,
        experts_per_token=min(2, cfg.experts_per_token) if cfg.num_experts else 0,
        # effectively dropless at smoke scale so prefill/decode token routing
        # matches teacher-forced training exactly
        capacity_factor=4.0 if cfg.num_experts else cfg.capacity_factor,
        local_window=8 if cfg.local_window else 0,
        slstm_every=cfg.slstm_every,
        dtype="float32",
    )
    # keep the structural pattern (e.g. rglru/localattn cycle) intact
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
