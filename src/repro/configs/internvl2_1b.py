"""internvl2-1b — VLM: InternViT frontend STUB + Qwen2-0.5B-like LM backbone.
[arXiv:2404.16821; hf]
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655

The vision frontend is a stub per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, vision_patches, d_model) which are prepended
to the token embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    vision_patches=256,  # one 448x448 tile -> 256 patch embeddings
    tie_embeddings=True,
    use_bias=True,  # qwen2 uses qkv bias
    act="swiglu",
)
