"""optim subpackage."""
