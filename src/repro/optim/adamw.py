"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

Self-contained (no optax).  Optimizer state is a pytree parallel to params
(m, v in f32) — it inherits the params' FSDP sharding, making the update
collective-free and purely memory-bound (the roofline's optimizer unit).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: Array


def init(params: Any, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def abstract_state(abstract_params: Any, dtype=jnp.float32) -> AdamWState:
    mk = lambda p: jax.ShapeDtypeStruct(p.shape, dtype)
    return AdamWState(
        m=jax.tree_util.tree_map(mk, abstract_params),
        v=jax.tree_util.tree_map(mk, abstract_params),
        count=jax.ShapeDtypeStruct((), jnp.int32),
    )


def global_norm(tree: Any) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply(
    params: Any,
    grads: Any,
    state: AdamWState,
    lr: Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, AdamWState, Array]:
    """One AdamW update.  Returns (params, state, grad_norm)."""
    if grad_clip > 0:
        grads, norm = clip_by_global_norm(grads, grad_clip)
    else:
        norm = global_norm(grads)
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1.0 - b1) * g32
        v2 = b2 * v.astype(jnp.float32) + (1.0 - b2) * g32 * g32
        mh = m2 / c1
        vh = v2 / c2
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        # moments stored in state dtype (bf16 for 100B+ models — the
        # memory-fitting production trade; see EXPERIMENTS.md)
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, count), norm


def cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int
) -> Callable[[Array], Array]:
    def lr(step: Array) -> Array:
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip(
            (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup_steps, warm, cos)

    return lr
