"""Streaming serving loop: the estimator as an always-on service.

Push-mode path for fleets that feed telemetry continuously and cannot block
on a Gibbs sweep: a device-resident ``TelemetryRing`` buffers observations,
``tick`` drains whole batches through the fleet-native estimator, and the
simplex solve re-runs only when the posterior actually moved (drift-gated
cadence with a hard staleness cap).  The gate self-calibrates by default —
an online EWMA baseline of the drift statistic (``repro.serve.gate``)
replaces the fleet-size-dependent fixed threshold; pass an explicit
``drift_threshold`` for the legacy fixed gate.  See ``docs/serving.md``
and ``docs/hierarchy.md``.

>>> import jax, jax.numpy as jnp
>>> from repro import serve, sched
>>> config = serve.ServeConfig(
...     sched=sched.SchedulerConfig(n_iters=2, grid_size=32, num_points=64,
...                                 opt_steps=10),
...     capacity=8, drift_threshold=0.05, max_staleness=4)
>>> loop = serve.ServiceLoop(3, config=config, seed=0)
>>> import numpy as np
>>> bool(np.allclose(loop.fractions(), 1 / 3))  # placeholder until learned
True
>>> rng = jax.random.PRNGKey(1)
>>> for i in range(8):                          # 8 telemetry rows buffered
...     f = jax.random.uniform(jax.random.fold_in(rng, i), (3,), minval=0.1,
...                            maxval=0.9)
...     loop.push(f, f**0.9 * jnp.asarray([5.0, 10.0, 20.0]))
>>> info = loop.tick()                          # drain -> observe -> propose
>>> (int(info.drained), bool(info.proposed))
(8, True)
>>> bool(abs(float(loop.fractions().sum()) - 1.0) < 1e-5)
True
"""
from .gate import GateState, gate_init, gate_threshold, gate_update
from .ring import DrainedBatch, TelemetryRing, drain, push, ring_init
from .service import (
    ServeConfig,
    ServeState,
    ServiceLoop,
    TickInfo,
    init,
    posterior_drift,
    solve_published,
    tick,
    tick_with_params,
)

__all__ = [
    "DrainedBatch",
    "GateState",
    "ServeConfig",
    "ServeState",
    "ServiceLoop",
    "TelemetryRing",
    "TickInfo",
    "drain",
    "gate_init",
    "gate_threshold",
    "gate_update",
    "init",
    "posterior_drift",
    "push",
    "ring_init",
    "solve_published",
    "tick",
    "tick_with_params",
]
