"""Online-calibrated drift gate: EWMA baseline instead of a fixed threshold.

The PR 6 drift gate compared a max-over-workers statistic against a fixed
``drift_threshold``.  That number is fleet-size-dependent twice over: the
max of K per-worker scores grows like the K-th extreme value, and the
worst-worker jitter is environment-sensitive (reduction-order float shifts
steer the chaotic Gibbs chains) — which is why ``bench_serve`` had to
hand-tune ``0.75`` at K < 10^4 and ``10.0`` above.  This module replaces
the constant with an *online estimate of the steady-state drift level*:

  * ``GateState`` tracks an EWMA mean and an EWMA squared deviation of the
    gate statistic (three scalars — checkpointable, donation-friendly);
  * :func:`gate_update` fires when the statistic exceeds
    ``mean + z * (sd + rel_floor * |mean| + abs_floor)`` — a z-score test
    against the *observed* null level, so the same configuration yields a
    stable skip rate at K = 10^2 and K = 10^4 (regression-tested);
  * fired statistics are NOT absorbed into the baseline (a regime change
    must not teach the gate that drift is normal), and the first
    ``warmup`` statistics only calibrate — the staleness backstop owns
    proposing until the baseline exists.

Pure jnp throughout: the serve ``tick`` runs it inside jit, the Trainer
runs the identical functions host-side.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

DEFAULT_GATE_Z = 4.0
DEFAULT_GATE_WARMUP = 3
DEFAULT_GATE_DECAY = 0.9
_REL_FLOOR = 0.05
_ABS_FLOOR = 1e-6


class GateState(NamedTuple):
    """EWMA baseline of the drift statistic; a tiny all-scalar pytree."""

    mean: Array  # float32, EWMA of the statistic
    var: Array  # float32, EWMA of squared deviation from the mean
    count: Array  # int32, statistics folded into the baseline


def gate_init() -> GateState:
    return GateState(
        mean=jnp.zeros((), jnp.float32),
        var=jnp.zeros((), jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


def gate_threshold(gate: GateState, *, z: float = DEFAULT_GATE_Z) -> Array:
    """Current firing level: ``mean + z * (sd + floors)``.

    The relative floor keeps a near-deterministic steady state (EWMA
    variance ~ 0) from firing on the first ulp of jitter; the absolute
    floor does the same for a statistic that sits at zero.
    """
    sd = jnp.sqrt(jnp.maximum(gate.var, 0.0))
    return gate.mean + z * (sd + _REL_FLOOR * jnp.abs(gate.mean) + _ABS_FLOOR)


def gate_update(
    gate: GateState,
    stat: Array,
    *,
    z: float = DEFAULT_GATE_Z,
    warmup: int = DEFAULT_GATE_WARMUP,
    decay: float = DEFAULT_GATE_DECAY,
    update: Array = True,
) -> Tuple[Array, GateState]:
    """Score one statistic against the calibrated baseline; returns (fire, gate).

    ``update`` masks the whole call (e.g. an empty drain carries no
    statistic): when false, nothing fires and nothing is absorbed.  A
    fired statistic never updates the baseline; the first observed
    statistic seeds the EWMA directly (the ``anomaly`` freshness trick).
    Pure and jit-compatible; also usable with host floats.
    """
    stat = jnp.asarray(stat, jnp.float32)
    update = jnp.asarray(update, bool)
    warm = gate.count >= warmup
    fire = update & warm & (stat > gate_threshold(gate, z=z))

    fresh = gate.count == 0
    dev = stat - gate.mean
    mean_next = jnp.where(fresh, stat, decay * gate.mean + (1.0 - decay) * stat)
    var_next = jnp.where(fresh, 0.0, decay * gate.var + (1.0 - decay) * dev * dev)
    absorb = update & ~fire
    return fire, GateState(
        mean=jnp.where(absorb, mean_next, gate.mean),
        var=jnp.where(absorb, var_next, gate.var),
        count=gate.count + absorb.astype(jnp.int32),
    )
