"""Device-resident telemetry ring buffer: fixed capacity, jit-native.

A fleet serving heavy traffic produces telemetry continuously; the estimator
consumes it in batches.  ``TelemetryRing`` decouples the two rates without
ever leaving the device or changing a shape:

  * every leaf is a fixed-capacity array — ``push`` writes one slot with a
    dynamic-index ``.at[slot].set`` and ``drain`` reads the whole buffer with
    a masked tail, so both compile once and never host-sync;
  * the buffer is a plain pytree (NamedTuple of arrays): it rides through
    ``jax.jit`` (with buffer donation for zero-copy advance), checkpoints
    through ``CheckpointManager``, and vmaps for multi-tenant deployments;
  * overflow drops the OLDEST entries (the freshest telemetry is the most
    informative for a drifting system) and counts them in ``dropped`` — a
    monitorable signal that the drain cadence is too slow, never a silent
    truncation.

Drains preserve push order (oldest first) and pad the tail with masked
slots — exactly the layout ``core.gibbs.fit`` feeds its ``lax.scan``, so a
sequence of ring drains advanced through ``gibbs_batch`` reproduces the
synchronous ``fit`` over the same observations bitwise (``tests/test_serve``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class TelemetryRing(NamedTuple):
    """Fixed-capacity ring of (fracs, times) observations; a pytree.

    Leaves are ``(capacity,)`` for a single unit or ``(capacity, K)`` for a
    K-worker fleet (slot-major so one ``push`` writes one row).  ``head`` is
    the next write slot (monotone, wrapped at use), ``count`` the number of
    un-drained entries (saturates at capacity), ``dropped`` / ``total`` the
    lifetime overflow and push counters.
    """

    fracs: Array  # (C,) or (C, K)
    times: Array  # (C,) or (C, K)
    valid: Array  # (C,) or (C, K) float32 — per-element validity
    head: Array  # int32 scalar, next write slot (mod capacity)
    count: Array  # int32 scalar, entries buffered since last drain
    dropped: Array  # int32 scalar, lifetime entries overwritten un-drained
    total: Array  # int32 scalar, lifetime pushes

    @property
    def capacity(self) -> int:
        return int(self.times.shape[0])

    @property
    def num_workers(self) -> Optional[int]:
        return int(self.times.shape[1]) if self.times.ndim == 2 else None


class DrainedBatch(NamedTuple):
    """One whole-buffer drain in estimator layout: gibbs-ready, masked tail.

    ``times`` / ``fracs`` / ``mask`` are ``(K, capacity)`` for a fleet ring
    (``(capacity,)`` for a single unit) with observations in push order and
    ``mask`` zero on empty/invalid slots — the exact (t, f, mask) triple
    ``gibbs_batch`` and ``sched.observe`` accept.  ``count`` is how many
    slots carry real telemetry.
    """

    times: Array
    fracs: Array
    mask: Array
    count: Array  # int32 scalar


def ring_init(
    capacity: int, num_workers: Optional[int] = None, dtype=jnp.float32
) -> TelemetryRing:
    """An empty ring; ``num_workers=None`` builds a single-unit (C,) ring."""
    if capacity < 1:
        raise ValueError("capacity must be >= 1")
    shape = (capacity,) if num_workers is None else (capacity, num_workers)
    z = jnp.zeros(shape, dtype)
    zero = jnp.zeros((), jnp.int32)
    # Empty slots carry interior dummy values (f=0.5, t=1.0) so a fully
    # masked drain is an exact no-op on every masked reduction downstream.
    return TelemetryRing(
        fracs=jnp.full(shape, 0.5, dtype),
        times=jnp.full(shape, 1.0, dtype),
        valid=z,
        head=zero,
        count=zero,
        dropped=zero,
        total=zero,
    )


def push(
    ring: TelemetryRing,
    fracs: Array,
    times: Array,
    valid: Optional[Array] = None,
) -> TelemetryRing:
    """Append one observation row; jit-compatible, no host sync.

    ``fracs`` / ``times`` are scalars for a single-unit ring or ``(K,)`` for
    a fleet ring.  ``valid`` optionally marks elements invalid (non-finite
    telemetry from a failed worker) so they never reach the estimator.  When
    the ring is full the oldest un-drained entry is overwritten and counted
    in ``dropped``.
    """
    cap = ring.capacity
    slot = ring.head % cap
    f = jnp.asarray(fracs, ring.fracs.dtype)
    t = jnp.asarray(times, ring.times.dtype)
    if valid is None:
        v = jnp.ones(t.shape, ring.valid.dtype)
    else:
        v = jnp.broadcast_to(jnp.asarray(valid, ring.valid.dtype), t.shape)
    # Invalid elements get interior dummies: inf/nan must never be stored
    # (0 * inf = nan would leak through the drain mask).
    f = jnp.where(v > 0, f, 0.5)
    t = jnp.where(v > 0, t, 1.0)
    full = (ring.count == cap).astype(jnp.int32)
    return TelemetryRing(
        fracs=ring.fracs.at[slot].set(f),
        times=ring.times.at[slot].set(t),
        valid=ring.valid.at[slot].set(v),
        head=(ring.head + 1) % cap,
        count=jnp.minimum(ring.count + 1, cap),
        dropped=ring.dropped + full,
        total=ring.total + 1,
    )


def drain(ring: TelemetryRing) -> Tuple[DrainedBatch, TelemetryRing]:
    """Empty the ring into one gibbs-ready batch; jit-compatible.

    The batch is whole-buffer (static shape = capacity) with observations in
    push order — oldest first — and a masked tail, matching the padded-batch
    layout of ``core.gibbs.fit``.  The returned ring is logically empty
    (``count=0``); buffers are reused in place by the next pushes.
    """
    cap = ring.capacity
    start = (ring.head - ring.count) % cap
    order = (start + jnp.arange(cap)) % cap  # oldest -> newest
    slot_mask = (jnp.arange(cap) < ring.count).astype(ring.valid.dtype)
    t = jnp.take(ring.times, order, axis=0)
    f = jnp.take(ring.fracs, order, axis=0)
    v = jnp.take(ring.valid, order, axis=0)
    if t.ndim == 2:  # fleet ring: slot-major storage -> worker-major batch
        mask = (slot_mask[:, None] * v).T
        t, f = t.T, f.T
    else:
        mask = slot_mask * v
    batch = DrainedBatch(times=t, fracs=f, mask=mask, count=ring.count)
    return batch, ring._replace(count=jnp.zeros((), jnp.int32))
