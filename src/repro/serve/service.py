"""The estimator as an always-on service: decoupled observe/propose cadence.

The paper's pitch is replacing offline controlled experiments with online
inference — but a synchronous observe->propose call chain is still the
offline posture: every caller blocks on a Gibbs sweep AND a simplex solve.
This module splits the two rates:

  * **observe on every drained batch** — telemetry lands in a
    ``TelemetryRing`` (push-mode, device-resident) and each ``tick`` drains
    the whole buffer through the fleet-native ``gibbs_batch`` via
    ``sched.advance_fleet`` (masked tail, identical semantics to
    ``sched.observe``);
  * **propose only when posteriors move** — a drift statistic (the
    symmetrized-KL metric, or the max per-worker ``hier.surprise`` when
    hierarchical pooling is on) gates the simplex solve (``lax.cond``)
    against a self-calibrating EWMA baseline (``repro.serve.gate``; a
    fixed ``drift_threshold`` remains available), with a hard
    ``max_staleness`` so a slowly-drifting fleet can never pin a stale
    split forever;
  * **readers never block** — the last-good fractions live in a
    double-buffered host slot (``ServiceLoop.fractions()``); a reader dips
    into whichever buffer is active while the ticker fills the other.

The whole per-tick program — drain, Gibbs update, drift test, conditional
solve — is ONE jitted function with the service state donated
(``donate_argnums``), so steady-state serving re-uses the state buffers in
place instead of allocating a fresh fleet posterior every batch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.frontier import UnitParams
from repro.sched.objectives import Objective
from repro.sched.scheduler import (
    ProposeStats,
    SchedulerConfig,
    SchedulerState,
    advance_fleet,
    solve_fractions,
    unit_params,
)
from repro.sched import scheduler as _sched
from repro.core.compress import select_active
from repro.hier.hyperprior import (
    Hyperprior,
    fit_hyperprior,
    hyper_init,
    shrink,
    _surprise_body,
)

from .gate import (
    DEFAULT_GATE_DECAY,
    DEFAULT_GATE_WARMUP,
    DEFAULT_GATE_Z,
    GateState,
    gate_init,
    gate_update,
)
from .ring import TelemetryRing, drain, push, ring_init

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static service knobs; hashable, jit-static like ``SchedulerConfig``.

    The drift gate decides when ``tick`` re-solves the split.  With
    ``drift_threshold=None`` (the default) the gate is SELF-CALIBRATING:
    each tick's drift statistic is scored against an online EWMA baseline
    of its own steady-state level (``repro.serve.gate``), so the same
    configuration yields a stable skip rate at K = 10^2 and K = 10^4.
    Set ``drift_threshold`` to a float to keep the fixed-threshold PR 6
    behavior (the gate state is then never touched).

    The statistic itself depends on ``sched.hierarchical``: the legacy
    max-over-workers posterior KL (:func:`posterior_drift`) by default, or
    the max per-worker ``hier.surprise`` against the pooled fleet
    hyperprior when hierarchical pooling is on — the latter's per-worker
    null level does not grow with K.  ``max_staleness`` is the hard cap on
    drains between proposes either way, and owns proposing during the
    calibrated gate's ``gate_warmup`` ticks.
    """

    sched: SchedulerConfig = SchedulerConfig()
    capacity: int = 64  # ring slots buffered between drains
    drift_threshold: Optional[float] = None  # None = self-calibrating gate
    max_staleness: int = 8  # hard cap: drains between proposes
    gate_z: float = DEFAULT_GATE_Z  # z-score the calibrated gate fires at
    gate_warmup: int = DEFAULT_GATE_WARMUP  # stats observed before firing
    gate_decay: float = DEFAULT_GATE_DECAY  # EWMA decay of the baseline
    active_size: Optional[int] = None  # compressed-posterior active set: per
    # drain only the top-M workers (young / surprising / anomalous / stale —
    # ``core.compress.select_active``) run the full exponent-grid program;
    # the rest advance through the grid-free moment-matched surrogate.
    # None = dense legacy (every worker, every drain).
    async_propose: bool = False  # publish proposals asynchronously: the tick
    # only marks the propose (ref/staleness bookkeeping) and the
    # ``ServiceLoop`` dispatches the simplex solve OFF the tick path,
    # publishing into the double-buffered slot when the solve completes
    # (version bump preserved).  False = legacy in-tick synchronous solve.


class ServeState(NamedTuple):
    """Everything the service owns; one checkpointable pytree."""

    sched: SchedulerState  # fleet posteriors (K, ...) leaves
    ring: TelemetryRing  # buffered telemetry
    fractions: Array  # (K,) last-published split
    stats: ProposeStats  # frontier stats at the last propose
    ref: UnitParams  # posterior point estimates at the last propose
    staleness: Array  # int32, drains since the last propose
    n_drains: Array  # int32, lifetime non-empty drains
    n_proposes: Array  # int32, lifetime proposes
    last_drift: Array  # float32, drift measured at the last tick
    gate: GateState  # EWMA baseline of the drift statistic
    hyper: Hyperprior  # pooled fleet prior (refit every hyper_refit_every)
    hyper_age: Array  # int32, drains since the last hyperprior refit
    refresh_age: Optional[Array] = None  # (K,) int32, drains since each
    # worker's last full grid refresh; allocated only under
    # ``config.active_size`` (None = dense legacy, structurally unchanged)


class TickInfo(NamedTuple):
    """Per-tick observability (small, cheap to host-sync)."""

    ll: Array  # (K,) per-worker log-likelihood of the drained batch
    proposed: Array  # bool: did this tick re-solve the split?
    drift: Array  # float32 gate statistic (KL drift or max surprise)
    drained: Array  # int32 observations consumed from the ring


def posterior_drift(ref: UnitParams, cur: UnitParams) -> Array:
    """How far the fleet's posterior point estimates moved; scalar >= 0.

    Per worker: the symmetrized KL divergence between the completion-time
    Normals N(mu_ref, sigma_ref^2) and N(mu_cur, sigma_cur^2) — scale-free,
    so a 10ms shift matters on a 50ms worker and vanishes on a 5s one —
    plus the squared shifts of the exponent posterior means (alpha, beta
    live in [0, 1]; weight 4 makes a 0.15 exponent jump comparable to a
    one-sigma mean shift).  The fleet drift is the max over workers: one
    worker changing regime must trigger a re-solve even if the other 9999
    are steady.
    """
    s2r = ref.sigma**2 + 1e-12
    s2c = cur.sigma**2 + 1e-12
    d2 = (ref.mu - cur.mu) ** 2
    kl_sym = 0.25 * ((s2r + d2) / s2c + (s2c + d2) / s2r) - 0.5
    expo = (ref.alpha - cur.alpha) ** 2 + (ref.beta - cur.beta) ** 2
    return jnp.max(kl_sym + 4.0 * expo)


@functools.partial(jax.jit, static_argnames=("config", "num_workers"))
def init(config: ServeConfig, num_workers: int, key: Array) -> ServeState:
    """Fresh service state: empty ring, uniform split, max staleness.

    Staleness starts saturated so the FIRST data-carrying tick always
    proposes — the uniform placeholder split is published, never trusted.
    """
    sched_state = _sched.init(config.sched, num_workers, key)
    k = num_workers
    return ServeState(
        sched=sched_state,
        ring=ring_init(config.capacity, num_workers),
        fractions=jnp.full((k,), 1.0 / k, jnp.float32),
        stats=ProposeStats(
            e_t=jnp.asarray(jnp.inf, jnp.float32),
            var=jnp.asarray(jnp.inf, jnp.float32),
            score=jnp.asarray(jnp.inf, jnp.float32),
        ),
        ref=unit_params(sched_state),
        staleness=jnp.asarray(config.max_staleness, jnp.int32),
        n_drains=jnp.zeros((), jnp.int32),
        n_proposes=jnp.zeros((), jnp.int32),
        last_drift=jnp.zeros((), jnp.float32),
        gate=gate_init(),
        # Global prior as a structurally-stable hyperprior placeholder
        # (canonical float32 so both lax.cond refit branches agree), with
        # the age saturated so the first data tick refits immediately.
        hyper=jax.tree_util.tree_map(
            lambda x: jnp.asarray(x, jnp.float32),
            hyper_init(config.sched.mu_guess),
        ),
        hyper_age=jnp.asarray(config.sched.hyper_refit_every, jnp.int32),
        # Ages start saturated so the first drains cycle every worker
        # through a full grid refresh before any surrogate is trusted.
        refresh_age=(
            None
            if config.active_size is None
            else jnp.full((k,), 1_000_000, jnp.int32)
        ),
    )


@functools.partial(jax.jit, static_argnames=("config",))
def solve_published(
    cur: UnitParams,
    config: ServeConfig = ServeConfig(),
    live: Optional[Array] = None,
) -> Tuple[Array, ProposeStats]:
    """The publish-grade simplex solve, as its own dispatchable program.

    Exactly the solve the synchronous tick runs inline; split out so
    ``async_propose`` can launch it OFF the tick path (JAX dispatch is
    asynchronous — the call returns as soon as the program is enqueued) and
    publish on completion.
    """
    fr, st = solve_fractions(
        cur,
        objective=config.sched.objective,
        steps=config.sched.opt_steps,
        lr=config.sched.opt_lr,
        num_points=config.sched.num_points,
        min_fraction=config.sched.min_fraction,
        live=live,
    )
    return fr.astype(jnp.float32), ProposeStats(
        e_t=st.e_t.astype(jnp.float32),
        var=st.var.astype(jnp.float32),
        score=st.score.astype(jnp.float32),
    )


def _tick_body(
    state: ServeState, config: ServeConfig
) -> Tuple[ServeState, TickInfo, UnitParams]:
    """One service beat: drain -> observe -> drift-gated propose.

    An empty ring is a true no-op on the beliefs (the Gibbs advance is
    skipped under ``lax.cond``, so not even the PRNG key moves); the
    propose branch runs only on posterior drift or staleness expiry.
    Also returns the post-advance point estimates so the async shell can
    hand them to the off-path solve without re-deriving them.
    """
    drained = state.ring.count
    has_data = drained > 0
    batch, ring = drain(state.ring)

    # -- active-set selection (static branch; shapes fixed by active_size) --
    k = state.fractions.shape[0]
    active_idx = None
    refresh_age = state.refresh_age
    if config.active_size is not None and config.active_size < k:
        m = config.active_size
        active_idx, _ = select_active(
            m,
            age=state.refresh_age,
            nu=state.sched.gibbs.ng.nu0,
            surprise=(
                _surprise_body(state.sched.gibbs, state.hyper)
                if config.sched.hierarchical
                else None
            ),
            anomaly=state.sched.ewma_ll,
            live=state.sched.live,
        )
        refresh_age = jnp.where(
            has_data,
            (state.refresh_age + 1).at[active_idx].set(0),
            state.refresh_age,
        )

    def advance(sched_state):
        fleet, ll = advance_fleet(
            sched_state.gibbs,
            batch.times,
            batch.fracs,
            config.sched,
            mask=batch.mask,
            active_idx=active_idx,
        )
        return (
            sched_state._replace(gibbs=fleet, step=sched_state.step + 1),
            ll.astype(jnp.float32),
        )

    def hold(sched_state):
        return sched_state, jnp.zeros_like(sched_state.ewma_ll)

    new_sched, ll = jax.lax.cond(has_data, advance, hold, state.sched)

    # -- gate statistic (static branch: config is jit-static) ---------------
    if config.sched.hierarchical:
        # Refit the pooled fleet prior every hyper_refit_every drains,
        # then score each worker against it; fleet drift = max surprise.
        refit_due = has_data & (
            state.hyper_age >= config.sched.hyper_refit_every
        )
        hyper = jax.lax.cond(
            refit_due,
            lambda _: fit_hyperprior(new_sched.gibbs),
            lambda _: state.hyper,
            None,
        )
        hyper_age = jnp.where(
            refit_due,
            jnp.zeros((), jnp.int32),
            state.hyper_age + has_data.astype(jnp.int32),
        )
        drift = jnp.max(_surprise_body(new_sched.gibbs, hyper)).astype(
            jnp.float32
        )
        # Mid-life shrinkage on the refit cadence (ROADMAP PR 7 follow-up):
        # drift is scored on the UN-shrunk posteriors (shrinking first would
        # blunt the very statistic that detects the drifter), then every
        # worker is blended toward the fresh pool, ESS-weighted — converged
        # workers barely move, cold/drifting ones are pulled in.
        new_sched = jax.lax.cond(
            refit_due,
            lambda s: s._replace(
                gibbs=shrink(
                    s.gibbs, hyper, strength=config.sched.hyper_strength
                )
            ),
            lambda s: s,
            new_sched,
        )
    else:
        hyper, hyper_age = state.hyper, state.hyper_age
        drift = posterior_drift(
            state.ref, unit_params(new_sched)
        ).astype(jnp.float32)

    cur = unit_params(new_sched)

    staleness = state.staleness + has_data.astype(jnp.int32)
    # -- gate decision (static branch on the configured threshold) ----------
    if config.drift_threshold is None:
        fire, gate = gate_update(
            state.gate,
            drift,
            z=config.gate_z,
            warmup=config.gate_warmup,
            decay=config.gate_decay,
            update=has_data,
        )
        should = has_data & (fire | (staleness >= config.max_staleness))
    else:
        gate = state.gate  # fixed threshold: the baseline is never touched
        should = has_data & (
            (drift > config.drift_threshold)
            | (staleness >= config.max_staleness)
        )

    if config.async_propose:
        # The solve leaves the tick: only the bookkeeping happens here
        # (ref/staleness/counters); the shell dispatches ``solve_published``
        # and flips the double buffer when it completes.
        fractions, stats = state.fractions, state.stats
        ref = jax.tree_util.tree_map(
            lambda old, new: jnp.where(should, new, old), state.ref, cur
        )
        staleness = jnp.where(should, 0, staleness)
    else:

        def do_propose(_):
            fr, st = solve_published(cur, config, new_sched.live)
            return fr, st, cur, jnp.zeros((), jnp.int32)

        def skip(_):
            return state.fractions, state.stats, state.ref, staleness

        fractions, stats, ref, staleness = jax.lax.cond(
            should, do_propose, skip, None
        )

    new_state = ServeState(
        sched=new_sched,
        ring=ring,
        fractions=fractions,
        stats=stats,
        ref=ref,
        staleness=staleness,
        n_drains=state.n_drains + has_data.astype(jnp.int32),
        n_proposes=state.n_proposes + should.astype(jnp.int32),
        last_drift=drift,
        gate=gate,
        hyper=hyper,
        hyper_age=hyper_age,
        refresh_age=refresh_age,
    )
    return new_state, TickInfo(
        ll=ll, proposed=should, drift=drift, drained=drained
    ), cur


@functools.partial(
    jax.jit, static_argnames=("config",), donate_argnums=(0,)
)
def tick(
    state: ServeState, config: ServeConfig = ServeConfig()
) -> Tuple[ServeState, TickInfo]:
    """One service beat (see ``_tick_body``).

    The input state is DONATED: its buffers are reused for the output state
    (zero-copy advance — a regression test pins the no-growth invariant).
    """
    new_state, info, _ = _tick_body(state, config)
    return new_state, info


@functools.partial(
    jax.jit, static_argnames=("config",), donate_argnums=(0,)
)
def tick_with_params(
    state: ServeState, config: ServeConfig = ServeConfig()
) -> Tuple[ServeState, TickInfo, UnitParams]:
    """``tick`` that also returns the post-advance point estimates.

    The async shell's entry: when ``info.proposed`` fires it hands the
    returned ``UnitParams`` straight to ``solve_published`` — no second
    derivation from (donated) state.
    """
    return _tick_body(state, config)


class ServiceLoop:
    """Imperative shell of the push-mode service: jit closures built ONCE.

    The loop owns a ``ServeState`` and three compiled entry points — a
    donated ``push``, the donated fused ``tick``, and nothing else; no
    request ever triggers a re-trace.  Published fractions live in a
    double-buffered host slot: ``fractions()`` reads whichever buffer is
    active without taking a lock or touching a device, so request threads
    never wait on a Gibbs sweep (``docs/serving.md``).

    ``state`` is the checkpointable pytree — hand it to
    ``CheckpointManager.save`` and assign it back after restore.
    """

    def __init__(
        self,
        num_workers: int,
        *,
        config: Optional[ServeConfig] = None,
        seed: int = 0,
        state: Optional[ServeState] = None,
    ):
        self.config = config or ServeConfig()
        self.state = (
            state
            if state is not None
            else init(self.config, num_workers, jax.random.PRNGKey(seed))
        )
        # Donated push: the ring's slot buffers advance in place.
        self._push = jax.jit(push, donate_argnums=(0,))
        self._slots = [
            np.asarray(self.state.fractions).copy(),
            np.asarray(self.state.fractions).copy(),
        ]
        self._active = 0
        self._version = 0
        self._pending: Optional[Tuple[Array, ProposeStats]] = None

    # -- ingestion (producer side) -----------------------------------------
    def push(self, fracs, times, valid=None) -> None:
        """Buffer one telemetry row; returns immediately (device-async)."""
        ring = self._push(
            self.state.ring,
            jnp.asarray(fracs, jnp.float32),
            jnp.asarray(times, jnp.float32),
            None if valid is None else jnp.asarray(valid, jnp.float32),
        )
        self.state = self.state._replace(ring=ring)

    # -- the service beat (estimator side) ---------------------------------
    def tick(self) -> TickInfo:
        """Drain + observe (+ propose iff the posterior moved); publish.

        With ``config.async_propose`` the solve never runs inside this call:
        a fired gate dispatches ``solve_published`` (async JAX dispatch —
        enqueue and return) and each subsequent beat polls for completion,
        publishing into the inactive buffer and bumping ``version`` exactly
        as the synchronous path does.  A solve already in flight suppresses
        re-dispatch; the gate refires on a later beat if drift persists.
        """
        if self.config.async_propose:
            self.poll()
            self.state, info, cur = tick_with_params(self.state, self.config)
            if bool(info.proposed) and self._pending is None:
                self._pending = solve_published(
                    cur, self.config, self.state.sched.live
                )
            return info
        self.state, info = tick(self.state, self.config)
        if bool(info.proposed):  # host-syncs the tiny flag, not the fleet
            self._publish(self.state.fractions)
        return info

    def poll(self) -> bool:
        """Publish a completed async solve, if any; never blocks.

        Returns True iff a new split was published.  ``jax.Array.is_ready``
        is the non-blocking completion probe; an unfinished solve leaves
        everything untouched.
        """
        if self._pending is None:
            return False
        fr, st = self._pending
        if not fr.is_ready():
            return False
        self._pending = None
        self.state = self.state._replace(fractions=fr, stats=st)
        self._publish(fr)
        return True

    def _publish(self, fractions) -> None:
        inactive = 1 - self._active
        self._slots[inactive][:] = np.asarray(fractions)
        self._active = inactive  # atomic flip: readers see old or new
        self._version += 1

    # -- publication (reader side; never blocks) ---------------------------
    def fractions(self) -> np.ndarray:
        """Last-good published split — a host read, no device, no lock."""
        return self._slots[self._active]

    @property
    def version(self) -> int:
        """Bumps once per accepted propose; readers can poll for change."""
        return self._version

    # -- observability ------------------------------------------------------
    def counters(self) -> dict:
        """Lifetime drain/propose/drop counters (host-syncs four scalars)."""
        return {
            "drains": int(self.state.n_drains),
            "proposes": int(self.state.n_proposes),
            "dropped": int(self.state.ring.dropped),
            "pushes": int(self.state.ring.total),
        }

    @property
    def num_workers(self) -> int:
        return int(self.state.fractions.shape[0])
