"""The estimator as an always-on service: decoupled observe/propose cadence.

The paper's pitch is replacing offline controlled experiments with online
inference — but a synchronous observe->propose call chain is still the
offline posture: every caller blocks on a Gibbs sweep AND a simplex solve.
This module splits the two rates:

  * **observe on every drained batch** — telemetry lands in a
    ``TelemetryRing`` (push-mode, device-resident) and each ``tick`` drains
    the whole buffer through the fleet-native ``gibbs_batch`` via
    ``sched.advance_fleet`` (masked tail, identical semantics to
    ``sched.observe``);
  * **propose only when posteriors move** — a symmetrized-KL drift metric
    between the posterior point estimates at the last propose and now gates
    the simplex solve (``lax.cond``), with a hard ``max_staleness`` so a
    slowly-drifting fleet can never pin a stale split forever;
  * **readers never block** — the last-good fractions live in a
    double-buffered host slot (``ServiceLoop.fractions()``); a reader dips
    into whichever buffer is active while the ticker fills the other.

The whole per-tick program — drain, Gibbs update, drift test, conditional
solve — is ONE jitted function with the service state donated
(``donate_argnums``), so steady-state serving re-uses the state buffers in
place instead of allocating a fresh fleet posterior every batch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.frontier import UnitParams
from repro.sched.objectives import Objective
from repro.sched.scheduler import (
    ProposeStats,
    SchedulerConfig,
    SchedulerState,
    advance_fleet,
    solve_fractions,
    unit_params,
)
from repro.sched import scheduler as _sched

from .ring import TelemetryRing, drain, push, ring_init

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static service knobs; hashable, jit-static like ``SchedulerConfig``.

    ``drift_threshold`` gates re-solving the split: ``tick`` re-runs
    ``propose`` only when the posterior drift since the last solve exceeds
    it (or the split is ``max_staleness`` drains old).  Drift is the max
    over workers of a symmetrized Normal KL on (mu, sigma) plus squared
    shifts of the exponent means — see :func:`posterior_drift`.
    """

    sched: SchedulerConfig = SchedulerConfig()
    capacity: int = 64  # ring slots buffered between drains
    drift_threshold: float = 0.1
    max_staleness: int = 8  # hard cap: drains between proposes


class ServeState(NamedTuple):
    """Everything the service owns; one checkpointable pytree."""

    sched: SchedulerState  # fleet posteriors (K, ...) leaves
    ring: TelemetryRing  # buffered telemetry
    fractions: Array  # (K,) last-published split
    stats: ProposeStats  # frontier stats at the last propose
    ref: UnitParams  # posterior point estimates at the last propose
    staleness: Array  # int32, drains since the last propose
    n_drains: Array  # int32, lifetime non-empty drains
    n_proposes: Array  # int32, lifetime proposes
    last_drift: Array  # float32, drift measured at the last tick


class TickInfo(NamedTuple):
    """Per-tick observability (small, cheap to host-sync)."""

    ll: Array  # (K,) per-worker log-likelihood of the drained batch
    proposed: Array  # bool: did this tick re-solve the split?
    drift: Array  # float32 posterior drift vs the last propose
    drained: Array  # int32 observations consumed from the ring


def posterior_drift(ref: UnitParams, cur: UnitParams) -> Array:
    """How far the fleet's posterior point estimates moved; scalar >= 0.

    Per worker: the symmetrized KL divergence between the completion-time
    Normals N(mu_ref, sigma_ref^2) and N(mu_cur, sigma_cur^2) — scale-free,
    so a 10ms shift matters on a 50ms worker and vanishes on a 5s one —
    plus the squared shifts of the exponent posterior means (alpha, beta
    live in [0, 1]; weight 4 makes a 0.15 exponent jump comparable to a
    one-sigma mean shift).  The fleet drift is the max over workers: one
    worker changing regime must trigger a re-solve even if the other 9999
    are steady.
    """
    s2r = ref.sigma**2 + 1e-12
    s2c = cur.sigma**2 + 1e-12
    d2 = (ref.mu - cur.mu) ** 2
    kl_sym = 0.25 * ((s2r + d2) / s2c + (s2c + d2) / s2r) - 0.5
    expo = (ref.alpha - cur.alpha) ** 2 + (ref.beta - cur.beta) ** 2
    return jnp.max(kl_sym + 4.0 * expo)


@functools.partial(jax.jit, static_argnames=("config", "num_workers"))
def init(config: ServeConfig, num_workers: int, key: Array) -> ServeState:
    """Fresh service state: empty ring, uniform split, max staleness.

    Staleness starts saturated so the FIRST data-carrying tick always
    proposes — the uniform placeholder split is published, never trusted.
    """
    sched_state = _sched.init(config.sched, num_workers, key)
    k = num_workers
    return ServeState(
        sched=sched_state,
        ring=ring_init(config.capacity, num_workers),
        fractions=jnp.full((k,), 1.0 / k, jnp.float32),
        stats=ProposeStats(
            e_t=jnp.asarray(jnp.inf, jnp.float32),
            var=jnp.asarray(jnp.inf, jnp.float32),
            score=jnp.asarray(jnp.inf, jnp.float32),
        ),
        ref=unit_params(sched_state),
        staleness=jnp.asarray(config.max_staleness, jnp.int32),
        n_drains=jnp.zeros((), jnp.int32),
        n_proposes=jnp.zeros((), jnp.int32),
        last_drift=jnp.zeros((), jnp.float32),
    )


@functools.partial(
    jax.jit, static_argnames=("config",), donate_argnums=(0,)
)
def tick(
    state: ServeState, config: ServeConfig = ServeConfig()
) -> Tuple[ServeState, TickInfo]:
    """One service beat: drain -> observe -> drift-gated propose.

    The input state is DONATED: its buffers are reused for the output state
    (zero-copy advance — a regression test pins the no-growth invariant).
    An empty ring is a true no-op on the beliefs (the Gibbs advance is
    skipped under ``lax.cond``, so not even the PRNG key moves); the
    propose branch runs only on posterior drift or staleness expiry.
    """
    drained = state.ring.count
    has_data = drained > 0
    batch, ring = drain(state.ring)

    def advance(sched_state):
        fleet, ll = advance_fleet(
            sched_state.gibbs,
            batch.times,
            batch.fracs,
            config.sched,
            mask=batch.mask,
        )
        return (
            sched_state._replace(gibbs=fleet, step=sched_state.step + 1),
            ll.astype(jnp.float32),
        )

    def hold(sched_state):
        return sched_state, jnp.zeros_like(sched_state.ewma_ll)

    new_sched, ll = jax.lax.cond(has_data, advance, hold, state.sched)

    cur = unit_params(new_sched)
    drift = posterior_drift(state.ref, cur).astype(jnp.float32)
    staleness = state.staleness + has_data.astype(jnp.int32)
    should = has_data & (
        (drift > config.drift_threshold) | (staleness >= config.max_staleness)
    )

    def do_propose(_):
        fr, st = solve_fractions(
            cur,
            objective=config.sched.objective,
            steps=config.sched.opt_steps,
            lr=config.sched.opt_lr,
            num_points=config.sched.num_points,
            min_fraction=config.sched.min_fraction,
        )
        return (
            fr.astype(jnp.float32),
            ProposeStats(
                e_t=st.e_t.astype(jnp.float32),
                var=st.var.astype(jnp.float32),
                score=st.score.astype(jnp.float32),
            ),
            cur,
            jnp.zeros((), jnp.int32),
        )

    def skip(_):
        return state.fractions, state.stats, state.ref, staleness

    fractions, stats, ref, staleness = jax.lax.cond(
        should, do_propose, skip, None
    )

    new_state = ServeState(
        sched=new_sched,
        ring=ring,
        fractions=fractions,
        stats=stats,
        ref=ref,
        staleness=staleness,
        n_drains=state.n_drains + has_data.astype(jnp.int32),
        n_proposes=state.n_proposes + should.astype(jnp.int32),
        last_drift=drift,
    )
    return new_state, TickInfo(
        ll=ll, proposed=should, drift=drift, drained=drained
    )


class ServiceLoop:
    """Imperative shell of the push-mode service: jit closures built ONCE.

    The loop owns a ``ServeState`` and three compiled entry points — a
    donated ``push``, the donated fused ``tick``, and nothing else; no
    request ever triggers a re-trace.  Published fractions live in a
    double-buffered host slot: ``fractions()`` reads whichever buffer is
    active without taking a lock or touching a device, so request threads
    never wait on a Gibbs sweep (``docs/serving.md``).

    ``state`` is the checkpointable pytree — hand it to
    ``CheckpointManager.save`` and assign it back after restore.
    """

    def __init__(
        self,
        num_workers: int,
        *,
        config: Optional[ServeConfig] = None,
        seed: int = 0,
        state: Optional[ServeState] = None,
    ):
        self.config = config or ServeConfig()
        self.state = (
            state
            if state is not None
            else init(self.config, num_workers, jax.random.PRNGKey(seed))
        )
        # Donated push: the ring's slot buffers advance in place.
        self._push = jax.jit(push, donate_argnums=(0,))
        self._slots = [
            np.asarray(self.state.fractions).copy(),
            np.asarray(self.state.fractions).copy(),
        ]
        self._active = 0
        self._version = 0

    # -- ingestion (producer side) -----------------------------------------
    def push(self, fracs, times, valid=None) -> None:
        """Buffer one telemetry row; returns immediately (device-async)."""
        ring = self._push(
            self.state.ring,
            jnp.asarray(fracs, jnp.float32),
            jnp.asarray(times, jnp.float32),
            None if valid is None else jnp.asarray(valid, jnp.float32),
        )
        self.state = self.state._replace(ring=ring)

    # -- the service beat (estimator side) ---------------------------------
    def tick(self) -> TickInfo:
        """Drain + observe (+ propose iff the posterior moved); publish."""
        self.state, info = tick(self.state, self.config)
        if bool(info.proposed):  # host-syncs the tiny flag, not the fleet
            inactive = 1 - self._active
            self._slots[inactive][:] = np.asarray(self.state.fractions)
            self._active = inactive  # atomic flip: readers see old or new
            self._version += 1
        return info

    # -- publication (reader side; never blocks) ---------------------------
    def fractions(self) -> np.ndarray:
        """Last-good published split — a host read, no device, no lock."""
        return self._slots[self._active]

    @property
    def version(self) -> int:
        """Bumps once per accepted propose; readers can poll for change."""
        return self._version

    # -- observability ------------------------------------------------------
    def counters(self) -> dict:
        """Lifetime drain/propose/drop counters (host-syncs four scalars)."""
        return {
            "drains": int(self.state.n_drains),
            "proposes": int(self.state.n_proposes),
            "dropped": int(self.state.ring.dropped),
            "pushes": int(self.state.ring.total),
        }

    @property
    def num_workers(self) -> int:
        return int(self.state.fractions.shape[0])
