"""``python -m tools.reprolint`` — the command-line entry point.

Usage::

    python -m tools.reprolint src tests benchmarks
    python -m tools.reprolint src --format=json
    python -m tools.reprolint src --baseline tools/reprolint/baseline.json
    python -m tools.reprolint --write-baseline tools/reprolint/baseline.json src
    python -m tools.reprolint --check-layer-docs    # architecture.md in sync?
    python -m tools.reprolint --sync-layer-docs     # rewrite the doc section

Exit codes: 0 clean, 1 findings (or doc drift), 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from .engine import Linter, apply_baseline, load_baseline, write_baseline
from .layers import LayerMap
from .rules import all_rules

DEFAULT_DOC = Path("docs/architecture.md")


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="AST-based JAX/Pallas invariant checker (rules RL001-RL007)",
    )
    parser.add_argument("paths", nargs="*", help="files or directories to lint")
    parser.add_argument(
        "--format", choices=("text", "json"), default="text", dest="fmt"
    )
    parser.add_argument(
        "--baseline",
        type=Path,
        default=None,
        help="JSON baseline of accepted findings (filtered out of the report)",
    )
    parser.add_argument(
        "--write-baseline",
        type=Path,
        default=None,
        help="write the current findings as a new baseline and exit 0",
    )
    parser.add_argument(
        "--rules",
        default=None,
        help="comma-separated subset of rule ids to run (default: all)",
    )
    parser.add_argument(
        "--check-layer-docs",
        action="store_true",
        help="verify docs/architecture.md matches layers.toml",
    )
    parser.add_argument(
        "--sync-layer-docs",
        action="store_true",
        help="rewrite the generated layer-map section of docs/architecture.md",
    )
    parser.add_argument(
        "--layer-doc", type=Path, default=DEFAULT_DOC, help=argparse.SUPPRESS
    )
    return parser


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.check_layer_docs or args.sync_layer_docs:
        layer_map = LayerMap.load()
        in_sync = layer_map.sync_doc(args.layer_doc, write=args.sync_layer_docs)
        if args.sync_layer_docs:
            print(f"{args.layer_doc}: layer-map section synced")
        elif in_sync:
            print(f"{args.layer_doc}: layer-map section in sync with layers.toml")
        else:
            print(
                f"{args.layer_doc}: layer-map section is STALE — run "
                "`python -m tools.reprolint --sync-layer-docs`",
                file=sys.stderr,
            )
            return 1
        if not args.paths:
            return 0

    if not args.paths:
        print("error: no paths given", file=sys.stderr)
        return 2

    rules = all_rules()
    if args.rules:
        wanted = {r.strip().upper() for r in args.rules.split(",")}
        unknown = wanted - {r.id for r in rules}
        if unknown:
            print(f"error: unknown rule ids {sorted(unknown)}", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.id in wanted]

    linter = Linter(rules=rules)
    findings, n_files = linter.lint_paths([Path(p) for p in args.paths])

    if args.write_baseline is not None:
        write_baseline(args.write_baseline, findings)
        print(f"wrote {len(findings)} entries to {args.write_baseline}")
        return 0

    stale: list = []
    if args.baseline is not None and args.baseline.exists():
        findings, stale = apply_baseline(findings, load_baseline(args.baseline))

    if args.fmt == "json":
        print(
            json.dumps(
                {
                    "checked_files": n_files,
                    "findings": [f.to_json() for f in findings],
                    "stale_baseline_entries": stale,
                },
                indent=2,
            )
        )
    else:
        for finding in findings:
            print(finding.format_text())
        for entry in stale:
            print(
                f"warning: stale baseline entry {entry['fingerprint']} "
                f"({entry['rule']} {entry['path']}) — remove it",
                file=sys.stderr,
            )
        summary = f"{n_files} files checked, {len(findings)} finding(s)"
        print(summary if not findings else f"\n{summary}", file=sys.stderr)

    return 1 if findings else 0
