"""False-positive guards: the split/rebind idioms."""
import jax


def split_products(key, shape):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, shape)
    b = jax.random.uniform(k2, shape)  # clean: each product used once
    return a + b


def loop_carried(key, n):
    total = 0.0
    for i in range(n):
        key, sub = jax.random.split(key)  # clean: key rebound every pass
        total = total + jax.random.normal(sub, ())
    return total


def fold_in_streams(key, ids):
    # Clean: fold_in derives independent streams from one key by design,
    # so the repeated `key` argument is not a reuse.
    a = jax.random.fold_in(key, 0)
    b = jax.random.fold_in(key, 1)
    return [a, b] + [jax.random.fold_in(key, i) for i in ids]
