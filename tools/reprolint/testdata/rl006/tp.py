"""True positive: the same PRNG key consumed twice."""
import jax
import jax.numpy as jnp


def correlated_draws(key, shape):
    noise = jax.random.normal(key, shape)
    jitter = jax.random.uniform(key, shape)  # RL006: key reused, not split
    return noise + jitter


def split_then_reuse_parent(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (3,))
    b = jax.random.normal(key, (3,))  # RL006: parent key already consumed
    return a + b + jnp.sum(k2 * 0)
