"""Historical bug (PR 7): the hyperprior serve-tick refit ran under
``lax.cond``, but the refit branch produced float32 scalars while the hold
branch returned the weakly-typed python-float init — structurally different
pytrees, a trace-time error the moment the cadence first fired.  The shipped
fix canonicalizes the init hyperprior to float32 so both branches agree
(see ``src/repro/serve/service.py``, "canonical float32 so both lax.cond
refit branches agree").

This fixture reproduces the pre-fix shape of the code; reprolint must flag
it (RL003) so the bug class cannot ship again.
"""
import jax
import jax.numpy as jnp


def _refit(stats):
    pooled = jnp.mean(stats)
    return (jnp.asarray(pooled, jnp.float32), jnp.zeros((), jnp.float32))


def tick(do_refit, stats):
    return jax.lax.cond(
        do_refit,
        lambda s: (jnp.asarray(1.0, jnp.float64), jnp.zeros((), jnp.float64)),
        lambda s: _refit(s),  # RL003: float64 hold branch vs float32 refit
        stats,
    )
