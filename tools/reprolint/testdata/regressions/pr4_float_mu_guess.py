"""Historical bug (PR 4): ``gibbs.fit`` called ``float(mu_guess)`` on a
traced mean, raising TracerConversionError the moment ``fit`` ran under
``jit``/``vmap``.  The shipped fix keeps the guess as a traced 0-d array
(see ``src/repro/core/gibbs.py``, "Keep the guess as a traced array").

This fixture reproduces the pre-fix shape of the code; reprolint must flag
it (RL001) so the bug class cannot ship again.
"""
import jax
import jax.numpy as jnp


def _init_state(key, mu_guess):
    return {"mu": jnp.asarray(mu_guess), "key": key}


def fit(key, f, t, mu_guess=None):
    if mu_guess is None:
        mu_guess = jnp.mean(t) / jnp.maximum(jnp.mean(f), 1e-6)
    # RL001: the pre-PR4 bug — float() forces a host sync on the traced mean.
    state = _init_state(key, float(mu_guess))
    return state


@jax.jit
def refit_fleet(keys, f, t):
    # Per-chain refit exactly as PR 4 shipped it: fit runs under jit+vmap,
    # so f/t/mu_guess are tracers when float() fires.
    return jax.vmap(lambda k: fit(k, f, t))(keys)
