"""True positive: Python control flow on traced values."""
import jax
import jax.numpy as jnp


@jax.jit
def data_dependent_branch(x):
    if jnp.sum(x) > 0:  # RL007: TracerBoolConversionError under jit
        return x
    return -x


def clip_body(carry, t):
    while carry > 1.0:  # RL007: while on a traced carry inside scan
        carry = carry * 0.5
    return carry, t


def run(ts):
    return jax.lax.scan(clip_body, 10.0, ts)
