"""False-positive guards: static branches inside traced code."""
import jax
import jax.numpy as jnp
from functools import partial


@jax.jit
def shape_polymorphic(x, mask=None):
    if mask is None:  # clean: structural `is None` check is static
        mask = jnp.ones_like(x)
    if x.ndim == 2:  # clean: rank is static metadata
        x = x[None]
    return x * mask


@partial(jax.jit, static_argnames=("use_fast",))
def static_dispatch(x, use_fast):
    if use_fast:  # clean: jit-static argument
        return x * 2.0
    return x + x


def config_branch(x, *, steps=3):
    @jax.jit
    def inner(v):
        out = v
        for _ in range(steps):  # clean: python loop over a static closure
            out = out * 2.0
        return out

    return inner(x)
