"""True positive: reading a buffer after donating it."""
import functools

import jax
import jax.numpy as jnp


def _tick(state, x):
    return state + x


tick = jax.jit(_tick, donate_argnums=(0,))


def leak_after_donation(state, x):
    new_state = tick(state, x)
    stale = state + 1.0  # RL004: `state` was donated to tick
    return new_state, stale


@functools.partial(jax.jit, donate_argnums=(0,))
def advance(ring, item):
    return ring.at[0].set(item)


def push_twice(ring, a, b):
    advance(ring, a)
    return advance(ring, b)  # RL004: `ring` already donated on the line above
