"""False-positive guards: the rebind idiom, and non-donating jits."""
import jax
import jax.numpy as jnp


def _tick(state, x):
    return state + x


tick = jax.jit(_tick, donate_argnums=(0,))
plain = jax.jit(_tick)


def rebind_idiom(state, xs):
    for x in xs:
        state = tick(state, x)  # clean: the donated name is rebound
    return state


def read_before_donation(state, x):
    checksum = jnp.sum(state)  # clean: read happens before the donating call
    state = tick(state, x)
    return state, checksum


def non_donating(state, x):
    out = plain(state, x)
    return out, state + 1.0  # clean: no donation without donate_argnums
