"""False-positive guards: vmap of plain jnp code; pallas batched via grid."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def fleet_native(xs):
    # Clean: the batch axis rides the pallas grid, not vmap.
    return pl.pallas_call(_kernel, out_shape=xs, grid=(xs.shape[0],))(xs)


def vmapped_math(xs):
    # Clean: vmap over pure jnp code is the intended use.
    return jax.vmap(lambda x: jnp.tanh(x) * 2.0)(xs)


def vmapped_helper(xs):
    def body(x):
        return jnp.sum(x**2)

    return jax.vmap(body)(xs)
