"""True positive: vmap over a pallas_call launcher."""
import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def single_unit(x):
    return pl.pallas_call(_kernel, out_shape=x)(x)


def fleet(xs):
    return jax.vmap(single_unit)(xs)  # RL002: one launch per batch element


def fleet_indirect(xs):
    def wrapper(x):
        return single_unit(x)

    return jax.vmap(wrapper)(xs)  # RL002: reaches pallas_call via wrapper
