"""True positive: cond/switch branches with structurally different returns."""
import jax
import jax.numpy as jnp


def dtype_mismatch(pred, x):
    return jax.lax.cond(
        pred,
        lambda v: (v, jnp.zeros((), jnp.int32)),
        lambda v: (v, jnp.zeros(())),  # RL003: int32 vs float32 counter
        x,
    )


def arity_mismatch(pred, x):
    return jax.lax.cond(
        pred,
        lambda v: (v, v),
        lambda v: (v, v, v),  # RL003: 2-tuple vs 3-tuple
        x,
    )


def weak_literal_mismatch(pred, x):
    return jax.lax.cond(
        pred,
        lambda v: (v, 0),
        lambda v: (v, 0.0),  # RL003: python int vs float literal
        x,
    )


def switch_mismatch(i, x):
    return jax.lax.switch(
        i,
        [
            lambda v: jnp.zeros((3,), jnp.float32),
            lambda v: jnp.zeros((4,), jnp.float32),  # RL003: shape 3 vs 4
        ],
        x,
    )
