"""False-positive guards: agreeing branches, and computed returns."""
import jax
import jax.numpy as jnp


def agreeing_literals(pred, x):
    # Clean: explicit float32 and the float32 default are the same aval.
    return jax.lax.cond(
        pred,
        lambda v: (v, jnp.zeros((), jnp.float32)),
        lambda v: (v, jnp.zeros(())),
        x,
    )


def _advance(state):
    return jax.tree_util.tree_map(lambda l: l * 2.0, state)


def computed_branches(pred, state):
    # Clean: both branches return computed pytrees the rule cannot (and must
    # not pretend to) prove anything about.
    return jax.lax.cond(pred, _advance, lambda s: s, state)


def same_shapes(i, x):
    return jax.lax.switch(
        i,
        [
            lambda v: jnp.ones((4,), jnp.float32),
            lambda v: jnp.zeros((4,), jnp.float32),
        ],
        x,
    )
