"""True positive: a `core` module importing upward from `sched`.

The test lints this source under the synthetic path
``src/repro/core/bad_upward.py`` (RL005 keys on the path, so the fixture
must be relocated to be meaningful).  This mirrors the live violation this
rule shipped against: ``repro/core/partitioner.py`` importing ``repro.sched``
at module level.
"""
from repro.sched.scheduler import Scheduler  # RL005: core -> sched is upward
import repro.serve  # RL005: core -> serve is two layers up
from ..sched import quantize  # RL005: relative spelling of the same jump


def delegate(*args):
    return Scheduler(*args)
