"""False-positive guards: downward, same-layer, and deferred imports.

Linted under the synthetic path ``src/repro/serve/good_imports.py``.
"""
from repro.core.frontier import UnitParams  # clean: serve -> core is downward
from repro.sched.scheduler import Scheduler  # clean: serve -> sched is downward
from repro.hier.hyperprior import fit_hyperprior  # clean: serve <-> hier share a layer
from .ring import TelemetryRing  # clean: same package


def lazy_app_hook():
    # Clean: deferred imports are the sanctioned acyclic escape hatch, even
    # when they point upward.
    from repro.train.trainer import Trainer

    return Trainer
