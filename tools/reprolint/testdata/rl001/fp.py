"""False-positive guards: casts that are static or outside the trace."""
import jax
import jax.numpy as jnp
import numpy as np
from functools import partial


@jax.jit
def static_metadata(x):
    n = int(x.shape[0])  # clean: shape is static metadata, not a tracer
    return x / float(n)  # clean: n is a python int


@partial(jax.jit, static_argnames=("scale",))
def static_arg(x, scale):
    return x * float(scale)  # clean: scale is jit-static


def host_shell(xs):
    total = jax.jit(jnp.sum)(xs)
    return float(total)  # clean: the readout happens outside the trace


def eager_helper(values):
    arr = np.asarray(values)  # clean: this function is never traced
    return bool(arr.any())
