"""True positive: host-sync casts on traced values inside jitted code."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def bad_cast(x):
    scale = float(jnp.mean(x))  # RL001: float() on a traced mean
    return x * scale


def bad_scan(carry, t):
    total = carry + t.item()  # RL001: .item() inside a scan body
    return total, total


def run(ts):
    return jax.lax.scan(bad_scan, 0.0, ts)


@jax.jit
def bad_numpy(x):
    host = np.asarray(x)  # RL001: np.asarray pulls the tracer to host
    return jnp.asarray(host.sum())
