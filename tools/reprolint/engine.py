"""reprolint engine: file collection, rule dispatch, suppressions, baseline.

Suppression syntax (same line as the finding)::

    x = float(mu)  # reprolint: disable=RL001 -- host readout happens post-fit

The justification after ``--`` is **required**: a bare ``disable`` both fails
to suppress and raises the meta-finding RL000, so every exception is
documented where it lives.

The baseline is a JSON file of line-number-insensitive fingerprints
(``rule | path | source-line``) for findings that are accepted for now;
``--write-baseline`` emits one, ``--baseline`` filters against it.  Stale
entries are reported so the file shrinks instead of rotting.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .context import ModuleContext

SKIP_DIR_NAMES = {"__pycache__", "testdata", ".git", ".venv", "node_modules"}

_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*disable=(?P<rules>RL\d{3}(?:\s*,\s*RL\d{3})*)"
    r"(?:\s*--\s*(?P<why>\S.*))?"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    snippet: str

    @property
    def fingerprint(self) -> str:
        digest = hashlib.sha256(
            "|".join((self.rule, self.path, self.snippet)).encode()
        ).hexdigest()
        return digest[:16]

    def format_text(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def to_json(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "snippet": self.snippet,
            "fingerprint": self.fingerprint,
        }


@dataclasses.dataclass
class Suppression:
    rules: Tuple[str, ...]
    justified: bool
    used: bool = False


def parse_suppressions(source_lines: Sequence[str]) -> Dict[int, Suppression]:
    """Line number (1-based) -> suppression directive on that line.

    Directives are read from COMMENT tokens only, so the text
    ``# reprolint: disable=...`` inside a string literal (docs, fixture
    generators, this test suite) is not a directive.  If tokenization fails
    the line-based regex is the fallback.
    """
    try:
        text = "\n".join(source_lines) + "\n"
        comments = [
            (tok.start[0], tok.string)
            for tok in tokenize.generate_tokens(io.StringIO(text).readline)
            if tok.type == tokenize.COMMENT
        ]
    except (tokenize.TokenError, IndentationError, SyntaxError):
        comments = list(enumerate(source_lines, start=1))
    out: Dict[int, Suppression] = {}
    for lineno, line in comments:
        m = _SUPPRESS_RE.search(line)
        if m:
            rules = tuple(r.strip() for r in m.group("rules").split(","))
            out[lineno] = Suppression(rules=rules, justified=bool(m.group("why")))
    return out


def collect_files(paths: Iterable[Path]) -> List[Path]:
    files: List[Path] = []
    for path in paths:
        if path.is_file() and path.suffix == ".py":
            files.append(path)
        elif path.is_dir():
            for sub in sorted(path.rglob("*.py")):
                parts = set(sub.parts)
                if parts & SKIP_DIR_NAMES:
                    continue
                files.append(sub)
    return files


class Linter:
    """Runs a rule set (default: the full registry) over files."""

    def __init__(self, rules: Optional[Sequence] = None, repo_root: Optional[Path] = None):
        if rules is None:
            from .rules import all_rules

            rules = all_rules()
        self.rules = list(rules)
        self.repo_root = repo_root or Path.cwd()

    def _relpath(self, path: Path) -> str:
        try:
            return path.resolve().relative_to(self.repo_root.resolve()).as_posix()
        except ValueError:
            return path.as_posix()

    def lint_source(self, source: str, path: str) -> List[Finding]:
        """Lint one module given as text (fixture tests use this directly)."""
        try:
            ctx = ModuleContext(path, source)
        except SyntaxError as exc:
            return [
                Finding(
                    rule="RL000",
                    path=path,
                    line=exc.lineno or 1,
                    col=exc.offset or 0,
                    message=f"could not parse file: {exc.msg}",
                    snippet="",
                )
            ]
        raw: List[Finding] = []
        for rule in self.rules:
            raw.extend(rule.check(ctx))

        suppressions = parse_suppressions(ctx.source_lines)
        kept: List[Finding] = []
        for finding in sorted(raw, key=lambda f: (f.line, f.col, f.rule)):
            directive = suppressions.get(finding.line)
            if directive and finding.rule in directive.rules:
                directive.used = True
                if directive.justified:
                    continue
                kept.append(
                    dataclasses.replace(
                        finding,
                        rule="RL000",
                        message=(
                            f"suppression of {finding.rule} lacks a "
                            "justification: write `# reprolint: "
                            f"disable={finding.rule} -- <why>`"
                        ),
                    )
                )
                continue
            kept.append(finding)
        for lineno, directive in suppressions.items():
            if not directive.used:
                snippet = (
                    ctx.source_lines[lineno - 1].strip()
                    if lineno <= len(ctx.source_lines)
                    else ""
                )
                kept.append(
                    Finding(
                        rule="RL000",
                        path=path,
                        line=lineno,
                        col=0,
                        message=(
                            "unused suppression "
                            f"(disable={','.join(directive.rules)}): nothing "
                            "to suppress here — delete it"
                        ),
                        snippet=snippet,
                    )
                )
        return sorted(kept, key=lambda f: (f.path, f.line, f.col, f.rule))

    def lint_file(self, path: Path) -> List[Finding]:
        return self.lint_source(path.read_text(), self._relpath(path))

    def lint_paths(self, paths: Iterable[Path]) -> Tuple[List[Finding], int]:
        findings: List[Finding] = []
        files = collect_files([Path(p) for p in paths])
        for f in files:
            findings.extend(self.lint_file(f))
        return findings, len(files)


# ------------------------------------------------------------------ baseline
def load_baseline(path: Path) -> Dict[str, Dict[str, object]]:
    doc = json.loads(path.read_text())
    if doc.get("version") != 1:
        raise ValueError(f"{path}: unsupported baseline version {doc.get('version')!r}")
    return {entry["fingerprint"]: entry for entry in doc.get("entries", [])}


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    entries = [
        {
            "fingerprint": f.fingerprint,
            "rule": f.rule,
            "path": f.path,
            "snippet": f.snippet,
        }
        for f in findings
    ]
    path.write_text(json.dumps({"version": 1, "entries": entries}, indent=2) + "\n")


def apply_baseline(
    findings: Sequence[Finding], baseline: Dict[str, Dict[str, object]]
) -> Tuple[List[Finding], List[Dict[str, object]]]:
    """(non-baselined findings, stale baseline entries)."""
    seen: set = set()
    kept: List[Finding] = []
    for f in findings:
        if f.fingerprint in baseline:
            seen.add(f.fingerprint)
        else:
            kept.append(f)
    stale = [entry for fp, entry in baseline.items() if fp not in seen]
    return kept, stale
