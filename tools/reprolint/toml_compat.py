"""Minimal TOML-subset loader for ``layers.toml`` (CI pins Python 3.10, which
predates :mod:`tomllib`, and the no-new-dependencies rule forbids ``tomli``).

Supported subset — exactly what the layer map needs, nothing more:

  * ``[[table]]`` array-of-tables headers;
  * ``key = "string"`` and ``key = ["a", "b"]`` (single-line arrays of strings);
  * ``key = 123`` integers, ``key = true/false`` booleans;
  * ``#`` comments and blank lines.

Anything else raises ``TomlError`` loudly rather than mis-parsing silently.
When real :mod:`tomllib` is available it is preferred, so the subset parser is
only ever the fallback — and a unit test pins the two against each other on
the shipped ``layers.toml``.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List

try:  # Python >= 3.11
    import tomllib as _tomllib
except ModuleNotFoundError:  # pragma: no cover - depends on interpreter
    _tomllib = None


class TomlError(ValueError):
    """Raised when the file uses TOML outside the supported subset."""


_ARRAY_HEADER = re.compile(r"^\[\[([A-Za-z0-9_.-]+)\]\]$")
_KEY_VALUE = re.compile(r"^([A-Za-z0-9_-]+)\s*=\s*(.+)$")


def _strip_comment(line: str) -> str:
    # A ``#`` outside quotes starts a comment.
    out, in_str = [], False
    for ch in line:
        if ch == '"':
            in_str = not in_str
        if ch == "#" and not in_str:
            break
        out.append(ch)
    return "".join(out).strip()


def _parse_value(raw: str, lineno: int) -> Any:
    raw = raw.strip()
    if raw.startswith('"') and raw.endswith('"') and len(raw) >= 2:
        return raw[1:-1]
    if raw.startswith("[") and raw.endswith("]"):
        inner = raw[1:-1].strip()
        if not inner:
            return []
        items: List[Any] = []
        for part in inner.split(","):
            part = part.strip()
            if not part:
                continue
            items.append(_parse_value(part, lineno))
        return items
    if raw in ("true", "false"):
        return raw == "true"
    if re.fullmatch(r"-?\d+", raw):
        return int(raw)
    raise TomlError(f"line {lineno}: unsupported TOML value {raw!r}")


def parse_subset(text: str) -> Dict[str, Any]:
    """Parse the supported TOML subset into a plain dict."""
    doc: Dict[str, Any] = {}
    current: Dict[str, Any] = doc
    for lineno, line in enumerate(text.splitlines(), start=1):
        line = _strip_comment(line)
        if not line:
            continue
        m = _ARRAY_HEADER.match(line)
        if m:
            current = {}
            doc.setdefault(m.group(1), []).append(current)
            continue
        if line.startswith("["):
            raise TomlError(f"line {lineno}: only [[array-of-tables]] headers "
                            f"are supported, got {line!r}")
        m = _KEY_VALUE.match(line)
        if m:
            current[m.group(1)] = _parse_value(m.group(2), lineno)
            continue
        raise TomlError(f"line {lineno}: cannot parse {line!r}")
    return doc


def loads(text: str) -> Dict[str, Any]:
    """Parse TOML text, preferring stdlib ``tomllib`` when present."""
    if _tomllib is not None:
        return _tomllib.loads(text)
    return parse_subset(text)
