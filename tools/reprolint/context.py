"""Shared per-module analysis: import aliasing, traced-context discovery, and
value taint.

Every rule visitor runs over one :class:`ModuleContext`, which computes three
things once per file:

  * **alias resolution** — ``jnp.asarray`` -> ``jax.numpy.asarray``,
    ``pl.pallas_call`` -> ``jax.experimental.pallas.pallas_call`` and so on,
    from the module's own imports, so rules match canonical names rather than
    guessing at spellings;
  * **traced functions** — the set of local functions whose bodies execute
    under a JAX trace: decorated with ``jit``/``pmap``, passed to
    ``jit``/``vmap``/``grad``/``shard_map``, used as a ``lax`` control-flow
    body (``scan``/``cond``/``switch``/``while_loop``/``fori_loop``/``map``\\,
    ``pallas_call``), nested inside a traced function, or — transitively —
    called by one (module-local call graph fixpoint);
  * **taint** — per traced function, which local names (may) hold traced
    values: parameters seed the set (minus ``static_argnums``/``argnames``
    when they can be read off the transform site) and assignments propagate
    it.  Structural reads (``.shape``/``.ndim``/``.dtype``/``len``/
    ``isinstance``/``is None``) yield *untraced* values — that distinction is
    what keeps RL001/RL007 from flagging the legal static-metadata branches
    JAX code leans on.

The analysis is deliberately module-local and approximate: it never imports
the code under inspection and prefers missing an exotic violation (aliasing
through containers, cross-module reachability) over false-flagging idiomatic
code.  Fixture tests pin both directions per rule.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

# Canonical prefixes understood by the rules.
_CANONICAL_MODULE_ALIASES = {
    "jax.numpy": "jax.numpy",
    "numpy": "numpy",
    "jax.lax": "jax.lax",
    "jax.random": "jax.random",
    "jax.experimental.pallas": "jax.experimental.pallas",
    "jax.experimental.shard_map": "jax.experimental.shard_map",
}

# Transform callables whose *function argument(s)* execute traced.  Maps the
# canonical callee name to the positions holding functions ("*" = every
# positional argument, for switch's branch list).
TRACED_FUNC_ARGS: Dict[str, Tuple[int, ...]] = {
    "jax.jit": (0,),
    "jax.pmap": (0,),
    "jax.vmap": (0,),
    "jax.grad": (0,),
    "jax.value_and_grad": (0,),
    "jax.checkpoint": (0,),
    "jax.remat": (0,),
    "jax.lax.scan": (0,),
    "jax.lax.map": (0,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2),
    "jax.lax.switch": (),  # branch *list* in position 1, handled specially
    "jax.lax.associative_scan": (0,),
    "jax.experimental.pallas.pallas_call": (0,),
    "jax.experimental.shard_map.shard_map": (0,),
}

TRACED_DECORATORS = ("jax.jit", "jax.pmap", "jax.checkpoint", "jax.remat")

# Attribute reads that yield static (untraced) metadata even on traced values.
STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "sharding", "aval"})

# Calls whose result is static regardless of argument taint.
STATIC_CALLS = frozenset({"len", "isinstance", "type", "hasattr", "getattr"})


def resolve_static_fields(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    """``static_argnums``/``static_argnames`` literals from a jit call site."""
    nums: Set[int] = set()
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and isinstance(node.value, int):
                    nums.add(node.value)
        elif kw.arg == "static_argnames":
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and isinstance(node.value, str):
                    names.add(node.value)
    return nums, names


@dataclasses.dataclass
class FunctionInfo:
    node: ast.AST  # FunctionDef | AsyncFunctionDef | Lambda
    name: str  # "<lambda>" for lambdas
    parent: Optional["FunctionInfo"]
    traced: bool = False
    traced_reason: str = ""
    # True when the only evidence of tracedness is the module-local call
    # graph ("called from traced f").  Such functions get *call-site-aware*
    # parameter taint: only parameters that receive a tainted argument at
    # some traced call site are seeded, which is what keeps static config
    # objects threaded through helper calls from lighting up RL001/RL007.
    traced_via_call: bool = False
    # Parameters that are jit-static at every observed transform site.
    static_params: Set[str] = dataclasses.field(default_factory=set)

    @property
    def is_lambda(self) -> bool:
        return isinstance(self.node, ast.Lambda)

    def body_statements(self) -> Sequence[ast.stmt]:
        if isinstance(self.node, ast.Lambda):
            return [ast.Expr(self.node.body)]
        return self.node.body

    def param_names(self) -> List[str]:
        a = self.node.args
        params = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
        if a.vararg:
            params.append(a.vararg.arg)
        if a.kwarg:
            params.append(a.kwarg.arg)
        return params


class ModuleContext:
    """One parsed module plus the shared analyses rules build on."""

    def __init__(self, path: str, source: str, tree: Optional[ast.Module] = None):
        self.path = path
        self.source = source
        self.source_lines = source.splitlines()
        self.tree = tree if tree is not None else ast.parse(source, filename=path)
        self.aliases = self._collect_aliases()
        self.functions: List[FunctionInfo] = []
        self.info_by_node: Dict[ast.AST, FunctionInfo] = {}
        self._collect_functions(self.tree, parent=None)
        self._functions_by_name: Dict[str, List[FunctionInfo]] = {}
        for info in self.functions:
            self._functions_by_name.setdefault(info.name, []).append(info)
        self._taint_cache: Dict[ast.AST, Set[str]] = {}
        self._taint_in_progress: Set[ast.AST] = set()
        self._call_site_index: Optional[Dict[ast.AST, List[Tuple["FunctionInfo", ast.Call]]]] = None
        self._mark_traced()

    # ------------------------------------------------------------ aliases
    def _collect_aliases(self) -> Dict[str, str]:
        """Local name -> canonical dotted prefix (``jnp`` -> ``jax.numpy``)."""
        aliases: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    aliases[alias.asname or alias.name.split(".")[0]] = (
                        alias.name if alias.asname else alias.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
                for alias in node.names:
                    aliases[alias.asname or alias.name] = f"{node.module}.{alias.name}"
        return aliases

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name of a Name/Attribute chain, or None."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.aliases.get(node.id, node.id)
        return ".".join([head, *reversed(parts)])

    def resolve_call(self, call: ast.Call) -> Optional[str]:
        return self.resolve(call.func)

    # ---------------------------------------------------------- functions
    def _collect_functions(self, node: ast.AST, parent: Optional[FunctionInfo]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                name = getattr(child, "name", "<lambda>")
                info = FunctionInfo(node=child, name=name, parent=parent)
                self.functions.append(info)
                self.info_by_node[child] = info
                self._collect_functions(child, parent=info)
            else:
                self._collect_functions(child, parent=parent)

    def local_function(self, node: ast.AST) -> Optional[FunctionInfo]:
        """FunctionInfo for a function reference (Name or inline Lambda)."""
        if isinstance(node, ast.Lambda):
            return self.info_by_node.get(node)
        if isinstance(node, ast.Name):
            candidates = self._functions_by_name.get(node.id)
            if candidates:
                return candidates[-1]
        return None

    # -------------------------------------------------------- tracedness
    def _mark(self, info: Optional[FunctionInfo], reason: str,
              static_params: Optional[Set[str]] = None, via_call: bool = False):
        if info is None:
            return
        if static_params:
            info.static_params |= static_params
        if not info.traced:
            info.traced = True
            info.traced_reason = reason
            info.traced_via_call = via_call
        elif not via_call:
            # A direct trace reason (decorator/transform site/nesting) is
            # stronger evidence than the call-graph closure.
            info.traced_via_call = False

    def _mark_traced(self):
        # 1. decorators
        for info in self.functions:
            for deco in getattr(info.node, "decorator_list", []):
                target = deco.func if isinstance(deco, ast.Call) else deco
                resolved = self.resolve(target)
                if resolved in TRACED_DECORATORS or resolved == "jit":
                    self._mark(info, f"decorated with {resolved}")
                elif resolved in ("functools.partial", "partial") and isinstance(
                    deco, ast.Call
                ):
                    inner = self.resolve(deco.args[0]) if deco.args else None
                    if inner in TRACED_DECORATORS or inner == "jit":
                        nums, names = resolve_static_fields(deco)
                        params = info.param_names()
                        names |= {params[i] for i in nums if i < len(params)}
                        self._mark(info, f"decorated with partial({inner})", names)

        # 2. transform call sites
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = self.resolve_call(node)
            if resolved is None:
                continue
            key = resolved if resolved in TRACED_FUNC_ARGS else None
            if key is None and "." not in resolved:
                # Unimported bare spellings (fixture snippets, conftest shims).
                key = {
                    "jit": "jax.jit", "vmap": "jax.vmap", "pmap": "jax.pmap",
                    "grad": "jax.grad", "scan": "jax.lax.scan",
                    "cond": "jax.lax.cond", "switch": "jax.lax.switch",
                    "while_loop": "jax.lax.while_loop",
                    "fori_loop": "jax.lax.fori_loop",
                    "pallas_call": "jax.experimental.pallas.pallas_call",
                    "shard_map": "jax.experimental.shard_map.shard_map",
                }.get(resolved)
            if key is None or key not in TRACED_FUNC_ARGS:
                continue
            nums: Set[int] = set()
            static_names: Set[str] = set()
            if key == "jax.jit":
                nums, static_names = resolve_static_fields(node)
            for pos in TRACED_FUNC_ARGS[key]:
                if pos < len(node.args):
                    target = self.local_function(node.args[pos])
                    if target is not None:
                        extra = set(static_names)
                        if key == "jax.jit" and not target.is_lambda:
                            params = target.param_names()
                            extra |= {params[i] for i in nums if i < len(params)}
                        self._mark(target, f"passed to {key}", extra)
            if key == "jax.lax.switch" and len(node.args) > 1:
                branches = node.args[1]
                if isinstance(branches, (ast.List, ast.Tuple)):
                    for elt in branches.elts:
                        self._mark(self.local_function(elt), "lax.switch branch")

        # 3. nesting: functions defined inside a traced function run traced
        changed = True
        while changed:
            changed = False
            for info in self.functions:
                if not info.traced and info.parent is not None and info.parent.traced:
                    self._mark(info, f"nested in traced {info.parent.name}")
                    changed = True
            # 4. module-local call-graph closure: f traced and f's body calls g
            for info in self.functions:
                if not info.traced:
                    continue
                for node in self._walk_own_body(info):
                    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                        callee = self.local_function(node.func)
                        if callee is not None and not callee.traced:
                            self._mark(
                                callee,
                                f"called from traced {info.name}",
                                via_call=True,
                            )
                            changed = True

    def _walk_own_body(self, info: FunctionInfo):
        """Walk a function body without descending into nested defs/lambdas."""
        stack: List[ast.AST] = list(info.body_statements())
        while stack:
            node = stack.pop()
            yield node
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                    continue
                stack.append(child)

    def traced_functions(self) -> List[FunctionInfo]:
        return [f for f in self.functions if f.traced]

    # -------------------------------------------------------------- taint
    def tainted_names(self, info: FunctionInfo) -> Set[str]:
        """Names that (may) hold traced values inside a traced function.

        Entry points (decorated / passed to a transform) seed with their
        parameters minus jit-static ones.  Functions traced only via the
        call graph seed with the parameters that actually *receive* a
        tainted argument at some traced call site — a helper that only ever
        gets the static config threaded through stays clean.  Nested traced
        functions additionally inherit the enclosing function's taint, so
        closure reads flow.  The seed is closed over assignments in two
        passes so loop-carried rebindings converge; results are memoized
        per function, with recursion through the call graph falling back to
        the conservative all-params seed.
        """
        key = info.node
        cached = self._taint_cache.get(key)
        if cached is not None:
            return cached
        if key in self._taint_in_progress:
            return {p for p in info.param_names() if p not in info.static_params}
        self._taint_in_progress.add(key)
        try:
            tainted = self._seed_taint(info)
            self._propagate_taint(info, tainted)
        finally:
            self._taint_in_progress.discard(key)
        self._taint_cache[key] = tainted
        return tainted

    def _seed_taint(self, info: FunctionInfo) -> Set[str]:
        if info.traced_via_call:
            sites = self._call_sites_for(info)
            if sites:
                seed: Set[str] = set()
                for caller, call in sites:
                    caller_taint = self.tainted_names(caller)
                    seed |= self._call_param_taint(info, call, caller_taint)
            else:
                seed = {
                    p for p in info.param_names() if p not in info.static_params
                }
        else:
            seed = {p for p in info.param_names() if p not in info.static_params}
        if info.parent is not None and info.parent.traced:
            seed |= self.tainted_names(info.parent)
            seed -= info.static_params
        return seed

    def _call_sites_for(self, callee: FunctionInfo) -> List[Tuple[FunctionInfo, ast.Call]]:
        """Call sites of ``callee`` inside traced functions (indexed lazily)."""
        if self._call_site_index is None:
            index: Dict[ast.AST, List[Tuple[FunctionInfo, ast.Call]]] = {}
            for info in self.traced_functions():
                for node in self._walk_own_body(info):
                    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                        target = self.local_function(node.func)
                        if target is not None and target.traced and target is not info:
                            index.setdefault(target.node, []).append((info, node))
            self._call_site_index = index
        return self._call_site_index.get(callee.node, [])

    def _call_param_taint(
        self, callee: FunctionInfo, call: ast.Call, caller_taint: Set[str]
    ) -> Set[str]:
        """Parameters of ``callee`` bound to a tainted argument at ``call``."""
        a = callee.node.args
        positional = [p.arg for p in (*a.posonlyargs, *a.args)]
        kw_capable = set(positional) | {p.arg for p in a.kwonlyargs}
        tainted: Set[str] = set()
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred):
                if self.expression_tainted(arg.value, caller_taint):
                    tainted.update(positional[i:])
                    if a.vararg:
                        tainted.add(a.vararg.arg)
                continue
            if self.expression_tainted(arg, caller_taint):
                if i < len(positional):
                    tainted.add(positional[i])
                elif a.vararg:
                    tainted.add(a.vararg.arg)
        for kwnode in call.keywords:
            if not self.expression_tainted(kwnode.value, caller_taint):
                continue
            if kwnode.arg is None:  # **kwargs: binding unknown, be conservative
                tainted |= kw_capable
            elif kwnode.arg in kw_capable:
                tainted.add(kwnode.arg)
            elif a.kwarg:
                tainted.add(a.kwarg.arg)
        return tainted - callee.static_params

    def _propagate_taint(self, info: FunctionInfo, tainted: Set[str]) -> None:
        for _ in range(2):
            for node in self._walk_own_body(info):
                targets: List[ast.expr] = []
                if isinstance(node, ast.Assign):
                    targets, value = node.targets, node.value
                elif isinstance(node, ast.AugAssign):
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.AnnAssign) and node.value is not None:
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.For):
                    targets, value = [node.target], node.iter
                else:
                    continue
                if self.expression_tainted(value, tainted):
                    for t in targets:
                        for name_node in ast.walk(t):
                            if isinstance(name_node, ast.Name):
                                tainted.add(name_node.id)

    def expression_tainted(self, expr: ast.AST, tainted: Set[str]) -> bool:
        """Does ``expr`` (possibly) evaluate to a traced value?

        Structural reads are pruned: ``x.shape``/``len(x)``/``x is None`` are
        static even when ``x`` is traced.
        """
        if isinstance(expr, ast.Attribute) and expr.attr in STATIC_ATTRS:
            return False
        if isinstance(expr, ast.Call):
            callee = self.resolve_call(expr)
            if callee in STATIC_CALLS:
                return False
        if isinstance(expr, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops):
                return False
            # String equality/membership is a config-kind dispatch, not a
            # value read: traced arrays are never compared against strings.
            operands = [expr.left, *expr.comparators]
            for operand in operands:
                if isinstance(operand, ast.Constant) and isinstance(
                    operand.value, str
                ):
                    return False
                if (
                    isinstance(operand, (ast.Tuple, ast.List, ast.Set))
                    and operand.elts
                    and all(
                        isinstance(e, ast.Constant) and isinstance(e.value, str)
                        for e in operand.elts
                    )
                ):
                    return False
        if isinstance(expr, ast.Name):
            return expr.id in tainted
        return any(
            self.expression_tainted(child, tainted)
            for child in ast.iter_child_nodes(expr)
        )

    # ------------------------------------------------------------- helpers
    def line(self, node: ast.AST) -> str:
        lineno = getattr(node, "lineno", None)
        if lineno is None or lineno > len(self.source_lines):
            return ""
        return self.source_lines[lineno - 1].strip()
