"""The machine-readable layer map: loading, queries, and doc generation.

``layers.toml`` is the single source of truth.  RL005 asks :class:`LayerMap`
whether an import goes *upward*; ``--sync-layer-docs`` renders the same data
into the ``docs/architecture.md`` section between the markers below so the
prose can never drift from the enforced rules.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional

from . import toml_compat

DEFAULT_LAYERS_FILE = Path(__file__).resolve().parent / "layers.toml"

DOC_BEGIN = "<!-- reprolint:layers:begin -->"
DOC_END = "<!-- reprolint:layers:end -->"


@dataclasses.dataclass(frozen=True)
class Layer:
    name: str
    rank: int
    packages: tuple
    description: str


class LayerMap:
    """Ordered layers over the first-level packages of ``repro``."""

    def __init__(self, layers: List[Layer], root_package: str = "repro"):
        self.layers = layers
        self.root_package = root_package
        self._rank_of_pkg: Dict[str, int] = {}
        self._layer_of_pkg: Dict[str, Layer] = {}
        for layer in layers:
            for pkg in layer.packages:
                if pkg in self._rank_of_pkg:
                    raise ValueError(f"package {pkg!r} appears in two layers")
                self._rank_of_pkg[pkg] = layer.rank
                self._layer_of_pkg[pkg] = layer

    @classmethod
    def load(cls, path: Optional[Path] = None) -> "LayerMap":
        path = Path(path) if path is not None else DEFAULT_LAYERS_FILE
        doc = toml_compat.loads(path.read_text())
        raw = doc.get("layers")
        if not raw:
            raise ValueError(f"{path}: no [[layers]] entries")
        layers = [
            Layer(
                name=entry["name"],
                rank=rank,
                packages=tuple(entry["packages"]),
                description=entry.get("description", ""),
            )
            for rank, entry in enumerate(raw)
        ]
        return cls(layers)

    def package_of_module(self, module: str) -> Optional[str]:
        """``repro.sched.quantize`` -> ``sched``; non-repro modules -> None."""
        parts = module.split(".")
        if parts[0] != self.root_package or len(parts) < 2:
            return None
        return parts[1]

    def rank(self, package: str) -> Optional[int]:
        return self._rank_of_pkg.get(package)

    def layer(self, package: str) -> Optional[Layer]:
        return self._layer_of_pkg.get(package)

    def violation(self, importer_module: str, imported_module: str) -> Optional[str]:
        """Message when ``importer_module`` imports ``imported_module`` upward."""
        src_pkg = self.package_of_module(importer_module)
        dst_pkg = self.package_of_module(imported_module)
        if src_pkg is None or dst_pkg is None:
            return None
        src_rank, dst_rank = self.rank(src_pkg), self.rank(dst_pkg)
        if src_rank is None or dst_rank is None or dst_rank <= src_rank:
            return None
        src_layer, dst_layer = self.layer(src_pkg), self.layer(dst_pkg)
        return (
            f"upward import: {self.root_package}.{src_pkg} "
            f"(layer '{src_layer.name}') must not import {imported_module} "
            f"(layer '{dst_layer.name}'); move the dependency down, invert it, "
            f"or defer the import into the using function"
        )

    # -------------------------------------------------------------- doc sync
    def render_doc_section(self) -> str:
        """The generated architecture.md block (markers included)."""
        lines = [
            DOC_BEGIN,
            "*Generated from [`tools/reprolint/layers.toml`]"
            "(../tools/reprolint/layers.toml) by `python -m tools.reprolint "
            "--sync-layer-docs` — edit the TOML, not this table.  Rule RL005 "
            "rejects any module-level import that targets a higher layer; "
            "deferred in-function imports are the sanctioned escape hatch for "
            "acyclic back-references.*",
            "",
            "| rank | layer | packages | may import |",
            "|------|-------|----------|------------|",
        ]
        for layer in self.layers:
            below = [l.name for l in self.layers if l.rank < layer.rank]
            allowed = ", ".join(reversed(below)) if below else "(nothing)"
            pkgs = ", ".join(f"`repro.{p}`" for p in layer.packages)
            lines.append(
                f"| {layer.rank} | {layer.name} | {pkgs} | "
                f"{layer.name} (same layer), {allowed} |"
                if below
                else f"| {layer.rank} | {layer.name} | {pkgs} | {layer.name} (same layer) |"
            )
        lines.append(DOC_END)
        return "\n".join(lines)

    def sync_doc(self, doc_path: Path, write: bool) -> bool:
        """True when the doc section already matches (or was rewritten)."""
        text = doc_path.read_text()
        begin, end = text.find(DOC_BEGIN), text.find(DOC_END)
        if begin == -1 or end == -1 or end < begin:
            raise ValueError(
                f"{doc_path}: missing {DOC_BEGIN} / {DOC_END} markers"
            )
        current = text[begin : end + len(DOC_END)]
        rendered = self.render_doc_section()
        if current == rendered:
            return True
        if write:
            doc_path.write_text(text[:begin] + rendered + text[end + len(DOC_END) :])
            return True
        return False
