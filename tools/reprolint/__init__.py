"""reprolint — an AST-based invariant checker for this repo's JAX/Pallas code.

Every correctness claim in the reproduction rests on fragile trace-time
invariants (bitwise parity, donation, PRNG stream coherence, structural
agreement across ``lax.cond`` branches), and PRs 4-8 each shipped a bugfix
for a violated one.  reprolint turns those recurring bug classes into
machine-checked rules:

========  ==============================================================
RL001     host sync (``float()``/``.item()``/``np.asarray``) in traced code
RL002     ``vmap`` applied to a function containing ``pallas_call``
RL003     ``lax.cond``/``switch`` branches that disagree structurally
RL004     donated-buffer reuse after a ``donate_argnums`` jitted call
RL005     import layering (from ``layers.toml``, the single source of truth)
RL006     PRNG key consumed twice without an intervening ``split``
RL007     Python ``if``/``while`` on a traced value
========  ==============================================================

Run ``python -m tools.reprolint src tests benchmarks``; see
``docs/static-analysis.md`` for rule rationale, suppression syntax and the
recipe for adding a rule.
"""
from .context import ModuleContext
from .engine import Finding, Linter
from .layers import LayerMap
from .rules import Rule, all_rules

__all__ = ["Finding", "LayerMap", "Linter", "ModuleContext", "Rule", "all_rules"]
