"""RL004 — reading a buffer after donating it to a jitted call.

``jit(..., donate_argnums=...)`` hands the argument's device buffer to the
callee; the caller's reference is dead the moment the call dispatches, and
touching it afterwards raises "Array has been deleted" — but only at runtime,
only on backends that actually reuse the buffer, which is why the serve
tick's donated path (PR 6) pins this with a live-arrays regression test.

The rule tracks, per enclosing function, names passed in donated positions
and flags any later read before rebinding.  The idiomatic
``state = tick(state)`` rebinds and is clean.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from ..context import ModuleContext, resolve_static_fields
from ..engine import Finding
from . import Rule

_JIT_NAMES = {"jax.jit", "jit", "jax.pmap", "pmap"}


def _donated_positions(call: ast.Call) -> Set[int]:
    out: Set[int] = set()
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and isinstance(node.value, int):
                    out.add(node.value)
    return out


class DonatedBufferReuse(Rule):
    id = "RL004"
    title = "donated buffer read after a donate_argnums jitted call"

    def check(self, ctx: ModuleContext) -> List[Finding]:
        # 1. jitted callables with donation: `g = jax.jit(f, donate_argnums=...)`
        #    and `@partial(jax.jit, donate_argnums=...)` defs.
        donating: Dict[str, Set[int]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                resolved = ctx.resolve_call(node.value)
                if resolved in _JIT_NAMES:
                    positions = _donated_positions(node.value)
                    if positions:
                        for target in node.targets:
                            if isinstance(target, ast.Name):
                                donating[target.id] = positions
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for deco in node.decorator_list:
                    if isinstance(deco, ast.Call):
                        head = ctx.resolve(deco.func)
                        inner = (
                            ctx.resolve(deco.args[0])
                            if head in ("functools.partial", "partial") and deco.args
                            else head
                        )
                        if inner in _JIT_NAMES:
                            positions = _donated_positions(deco)
                            if positions:
                                donating[node.name] = positions
        if not donating:
            return []

        findings: List[Finding] = []
        scopes = [info.body_statements() for info in ctx.functions] + [ctx.tree.body]
        for body in scopes:
            findings.extend(self._check_scope(ctx, body, donating))
        return findings

    def _check_scope(self, ctx, body, donating: Dict[str, Set[int]]) -> List[Finding]:
        dead: Dict[str, Tuple[str, int]] = {}  # name -> (callee, donation line)
        findings: List[Finding] = []
        simple = (ast.Assign, ast.AugAssign, ast.AnnAssign, ast.Expr,
                  ast.Return, ast.Raise, ast.Assert, ast.Delete)

        def rebind(target: ast.AST):
            for node in ast.walk(target):
                if isinstance(node, ast.Name):
                    dead.pop(node.id, None)

        def scan_reads(expr: ast.AST):
            for node in ast.walk(expr):
                if (
                    isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)
                    and node.id in dead
                ):
                    callee, line = dead[node.id]
                    findings.append(
                        self.finding(
                            ctx,
                            node,
                            f"`{node.id}` was donated to `{callee}` on line "
                            f"{line} (donate_argnums); its buffer may already "
                            "be reused — rebind the result or copy before "
                            "donating",
                        )
                    )
                    dead.pop(node.id, None)  # report once

        def mark_donations(stmt: ast.AST):
            for call in ast.walk(stmt):
                if (
                    isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Name)
                    and call.func.id in donating
                ):
                    for pos in donating[call.func.id]:
                        if pos < len(call.args) and isinstance(call.args[pos], ast.Name):
                            dead[call.args[pos].id] = (call.func.id, call.lineno)

        def visit_stmt(stmt: ast.stmt):
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                return
            if isinstance(stmt, simple):
                scan_reads(stmt)  # reads evaluate before the call donates
                mark_donations(stmt)
                if isinstance(stmt, ast.Assign):
                    for target in stmt.targets:
                        rebind(target)
                elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                    rebind(stmt.target)
                return
            # Compound statement: header expressions first, then the bodies.
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    scan_reads(child)
                    mark_donations(child)
            if isinstance(stmt, ast.For):
                rebind(stmt.target)
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.stmt):
                    visit_stmt(child)

        for stmt in body:
            visit_stmt(stmt)
        return findings
