"""RL001 — host synchronization inside traced code.

``float(x)`` / ``int(x)`` / ``bool(x)`` / ``x.item()`` / ``np.asarray(x)``
applied to a value that flows from a traced parameter forces a device->host
readout: under ``jit`` it raises ``TracerConversionError`` at best, and on
the async-dispatch serve path it silently serializes the pipeline.  PR 4
shipped exactly this bug — ``gibbs.fit`` called ``float(mu_guess)`` on a
traced mean and broke under ``jit``/``vmap``.

Clean alternatives: keep the value as a 0-d array (``jnp.asarray``), or do
the readout in the imperative shell after the jitted call returns.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Tuple

from ..context import ModuleContext
from ..engine import Finding
from . import Rule

_BUILTIN_CASTS = {"float", "int", "bool", "complex"}
_NUMPY_SINKS = {"asarray", "array", "float64", "float32", "int64", "int32", "bool_"}
_METHOD_SINKS = {"item", "tolist"}


class HostSyncInTracedCode(Rule):
    id = "RL001"
    title = "host sync (float()/.item()/np.asarray) on a traced value"

    def check(self, ctx: ModuleContext) -> List[Finding]:
        findings: List[Finding] = []
        for info in ctx.traced_functions():
            tainted = ctx.tainted_names(info)
            if not tainted:
                continue
            for node in ctx._walk_own_body(info):
                if not isinstance(node, ast.Call):
                    continue
                sink = self._sink(ctx, node)
                if sink is None:
                    continue
                label, operands = sink
                if any(ctx.expression_tainted(a, tainted) for a in operands):
                    findings.append(
                        self.finding(
                            ctx,
                            node,
                            f"{label} forces a host sync on a traced value "
                            f"inside `{info.name}` ({info.traced_reason}); "
                            "keep it on device (jnp.asarray) or read it out "
                            "after the jitted call returns",
                        )
                    )
        return findings

    @staticmethod
    def _sink(
        ctx: ModuleContext, call: ast.Call
    ) -> Optional[Tuple[str, Sequence[ast.expr]]]:
        """(sink label, expressions whose taint makes it a violation)."""
        func = call.func
        if (
            isinstance(func, ast.Name)
            and func.id in _BUILTIN_CASTS
            and ctx.aliases.get(func.id, func.id) == func.id  # not shadowed
            and len(call.args) == 1
        ):
            return f"{func.id}()", call.args
        if isinstance(func, ast.Attribute):
            if func.attr in _METHOD_SINKS and not call.args:
                return f".{func.attr}()", [func.value]
            resolved = ctx.resolve(func)
            if (
                resolved
                and resolved.startswith("numpy.")
                and resolved.rsplit(".", 1)[-1] in _NUMPY_SINKS
            ):
                return resolved, call.args
        return None
