"""RL006 — PRNG key consumed twice without an intervening split.

JAX keys are not stateful seeds: passing the same key to two
``jax.random.*`` draws yields *identical* (or worse, silently correlated)
randomness.  The estimator's correctness claims lean on stream coherence —
``gibbs_batch`` reproduces the legacy per-worker chains bitwise precisely
because every consumer gets its own ``split`` product, and PR 8's
active/inactive alternation keeps the ``_split5`` stream aligned for the
same reason.

A variable is "consumed" when it appears as the first positional argument of
a ``jax.random.*`` call (``split``/``fold_in`` included — their results must
be rebound).  Rebinding the name resets the count, so the loop-carried
``key, sub = jax.random.split(key)`` idiom is clean.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from ..context import FunctionInfo, ModuleContext
from ..engine import Finding
from . import Rule


class PrngKeyReuse(Rule):
    id = "RL006"
    title = "PRNG key consumed twice without an intervening split"

    def check(self, ctx: ModuleContext) -> List[Finding]:
        findings: List[Finding] = []
        scopes: List = [info for info in ctx.functions]
        for info in scopes:
            findings.extend(self._check_body(ctx, info))
        findings.extend(self._check_statements(ctx, ctx.tree.body))
        return findings

    def _check_body(self, ctx: ModuleContext, info: FunctionInfo) -> List[Finding]:
        return self._check_statements(ctx, list(info.body_statements()))

    def _check_statements(self, ctx: ModuleContext, body: List[ast.stmt]) -> List[Finding]:
        findings: List[Finding] = []
        consumed: Dict[str, int] = {}  # key name -> line of first consumption

        def rebind(target: ast.AST):
            for node in ast.walk(target):
                if isinstance(node, ast.Name):
                    consumed.pop(node.id, None)

        def is_random_call(call: ast.Call) -> bool:
            resolved = ctx.resolve_call(call)
            if not resolved:
                return False
            if resolved.rsplit(".", 1)[-1] == "fold_in":
                # fold_in derives independent streams from one key by design;
                # reusing the key with different data is the intended pattern.
                return False
            return resolved.startswith("jax.random.") or resolved.startswith(
                "random."  # `from jax import random`
            )

        def consume_in(node: ast.AST):
            for sub in ast.walk(node):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                    continue  # nested scopes are checked on their own
                if not (isinstance(sub, ast.Call) and is_random_call(sub)):
                    continue
                if not sub.args or not isinstance(sub.args[0], ast.Name):
                    continue
                name = sub.args[0].id
                if name in consumed:
                    findings.append(
                        self.finding(
                            ctx,
                            sub,
                            f"key `{name}` was already consumed on line "
                            f"{consumed[name]} — draws from a reused key are "
                            "identical; split first "
                            "(`key, sub = jax.random.split(key)`)",
                        )
                    )
                else:
                    consumed[name] = sub.lineno

        simple = (ast.Assign, ast.AugAssign, ast.AnnAssign, ast.Expr,
                  ast.Return, ast.Raise, ast.Assert)

        def visit(stmt: ast.stmt):
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                return  # nested scopes are checked on their own
            if isinstance(stmt, simple):
                consume_in(stmt)
                if isinstance(stmt, ast.Assign):
                    for target in stmt.targets:
                        rebind(target)
                elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                    rebind(stmt.target)
                return
            # Compound statement: header expressions, then the bodies.
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    consume_in(child)
            if isinstance(stmt, ast.For):
                rebind(stmt.target)
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.stmt):
                    visit(child)

        for stmt in body:
            visit(stmt)
        return findings
