"""RL007 — Python ``if``/``while`` on a traced value.

Inside a traced function, ``if x > 0:`` calls ``bool()`` on a tracer — a
``TracerBoolConversionError`` under ``jit``, or, when the value happens to be
concrete (interpret mode, eager debugging), a silent *retrace per branch
direction* that bakes data into the compiled program.  Use ``lax.cond`` /
``lax.select`` / ``jnp.where`` instead.

Static branches stay legal and un-flagged: ``if mask is None:``,
``if x.ndim == 2:``, ``if config.use_pallas:`` (jit-static argument or
closure) — the taint analysis prunes structural reads and static params, so
the shape-polymorphic dispatch idiom the repo uses everywhere is clean.
"""
from __future__ import annotations

import ast
from typing import List

from ..context import ModuleContext
from ..engine import Finding
from . import Rule


class TracedValueBranch(Rule):
    id = "RL007"
    title = "Python if/while branches on a traced value"

    def check(self, ctx: ModuleContext) -> List[Finding]:
        findings: List[Finding] = []
        for info in ctx.traced_functions():
            tainted = ctx.tainted_names(info)
            if not tainted:
                continue
            for node in ctx._walk_own_body(info):
                if not isinstance(node, (ast.If, ast.While)):
                    continue
                if ctx.expression_tainted(node.test, tainted):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    findings.append(
                        self.finding(
                            ctx,
                            node,
                            f"`{kind}` branches on a traced value inside "
                            f"`{info.name}` ({info.traced_reason}) — "
                            "TracerBoolConversionError under jit; use "
                            "lax.cond / lax.select / jnp.where",
                        )
                    )
        return findings
