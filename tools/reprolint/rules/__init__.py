"""Rule registry.  Each rule is one module exporting a single Rule subclass;
``all_rules()`` instantiates the full set in id order.

Adding a rule (see docs/static-analysis.md for the worked example):

  1. create ``rlNNN_short_name.py`` with a class deriving :class:`Rule`,
     setting ``id``/``title`` and implementing ``check(ctx)``;
  2. register it in ``_RULE_MODULES`` below;
  3. add at least one true-positive and one false-positive fixture under
     ``tools/reprolint/testdata/<rlNNN>/`` — ``tests/test_reprolint.py``
     discovers them by directory name and fails if either is missing.
"""
from __future__ import annotations

import importlib
from typing import List

from ..context import ModuleContext
from ..engine import Finding

_RULE_MODULES = (
    "rl001_host_sync",
    "rl002_vmap_pallas",
    "rl003_cond_structure",
    "rl004_donated_reuse",
    "rl005_layering",
    "rl006_key_reuse",
    "rl007_traced_branch",
)


class Rule:
    """Base class: one invariant, one visitor over a shared ModuleContext."""

    id: str = "RL000"
    title: str = ""

    def check(self, ctx: ModuleContext) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, node, message: str) -> Finding:
        return Finding(
            rule=self.id,
            path=ctx.path,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            message=message,
            snippet=ctx.line(node),
        )


def all_rules() -> List[Rule]:
    rules: List[Rule] = []
    for module_name in _RULE_MODULES:
        module = importlib.import_module(f".{module_name}", __package__)
        classes = [
            obj
            for obj in vars(module).values()
            if isinstance(obj, type) and issubclass(obj, Rule) and obj is not Rule
        ]
        assert len(classes) == 1, f"{module_name}: expected exactly one Rule class"
        rules.append(classes[0]())
    return sorted(rules, key=lambda r: r.id)
