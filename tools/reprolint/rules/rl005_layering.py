"""RL005 — import-layering enforcement from the machine-readable layer map.

``tools/reprolint/layers.toml`` orders the first-level packages of
``repro`` bottom -> top; a *module-level* import may only target the same or
a lower layer.  Deferred in-function imports are exempt by design: they
cannot create import cycles and are the repo's sanctioned escape hatch for
acyclic back-references (``kernels/ops.py``'s duck-typed ShardingConfig
import, ``sched``'s lazy hierarchical path).

The rule caught ``repro.core.partitioner`` importing ``repro.sched`` at
module level (core -> sched is upward); the legacy wrapper now lives in
``repro.sched.compat`` with a lazy PEP 562 shim left behind.
"""
from __future__ import annotations

import ast
from pathlib import PurePosixPath
from typing import List, Optional

from ..context import ModuleContext
from ..engine import Finding
from ..layers import LayerMap
from . import Rule


def _module_name_for_path(path: str, root_package: str):
    """``src/repro/core/partitioner.py`` -> (``repro.core.partitioner``, False);
    an ``__init__.py`` maps to its package name with ``is_package=True``."""
    parts = list(PurePosixPath(path.replace("\\", "/")).parts)
    if root_package not in parts:
        return None, False
    parts = parts[parts.index(root_package):]
    if parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    is_package = parts[-1] == "__init__"
    if is_package:
        parts = parts[:-1]
    return ".".join(parts), is_package


class LayeringViolation(Rule):
    id = "RL005"
    title = "module-level import targets a higher layer"

    def __init__(self, layer_map: Optional[LayerMap] = None):
        self.layer_map = layer_map if layer_map is not None else LayerMap.load()

    def check(self, ctx: ModuleContext) -> List[Finding]:
        importer, is_package = _module_name_for_path(
            ctx.path, self.layer_map.root_package
        )
        if importer is None:
            return []
        importer_pkg = self.layer_map.package_of_module(importer)
        if importer_pkg is None or self.layer_map.rank(importer_pkg) is None:
            return []

        findings: List[Finding] = []
        for node in ctx.tree.body:  # module level only: deferred imports exempt
            for imported in self._imported_modules(node, importer, is_package):
                message = self.layer_map.violation(importer, imported)
                if message:
                    findings.append(self.finding(ctx, node, message))
        return findings

    def _imported_modules(
        self, node: ast.stmt, importer: str, is_package: bool
    ) -> List[str]:
        root = self.layer_map.root_package
        if isinstance(node, ast.Import):
            return [a.name for a in node.names if a.name.startswith(f"{root}.")]
        if isinstance(node, ast.ImportFrom):
            if node.level == 0:
                if node.module == root:
                    return [f"{root}.{a.name}" for a in node.names]
                if node.module and node.module.startswith(f"{root}."):
                    return [node.module]
                return []
            # Relative import: resolve against the importer's package.
            package = importer.split(".") if is_package else importer.split(".")[:-1]
            base = package[: len(package) - (node.level - 1)]
            if node.module:
                base = base + node.module.split(".")
            target = ".".join(base)
            return [target] if target == root or target.startswith(f"{root}.") else []
        return []
