"""RL003 — ``lax.cond``/``lax.switch`` branches that disagree structurally.

Both branches of a traced conditional must return pytrees with identical
structure, shapes and dtypes; a mismatch is a trace-time error at best and a
silent weak-type promotion at worst.  PR 7 shipped this bug: the hyperprior
serve-tick refit branch produced float32 scalars while the hold branch
carried the python-float init — the fix canonicalized the init to float32.

The rule compares *literal* return skeletons (tuple arity, constructor
dtypes/shapes, int-vs-float python scalars).  Anything it cannot prove is a
wildcard, so computed returns never false-flag.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from ..context import ModuleContext
from ..engine import Finding
from . import Rule

_COND_NAMES = {"jax.lax.cond", "lax.cond", "cond"}
_SWITCH_NAMES = {"jax.lax.switch", "lax.switch", "switch"}

_DTYPES = {
    "float16", "bfloat16", "float32", "float64",
    "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_",
}
# constructor name -> (dtype positional index, has float default)
_CONSTRUCTORS = {
    "zeros": (1, True),
    "ones": (1, True),
    "empty": (1, True),
    "full": (2, True),
    "asarray": (1, False),
    "array": (1, False),
    "zeros_like": (None, False),
    "ones_like": (None, False),
    "full_like": (None, False),
}

ANY = ("any",)


class CondBranchStructureMismatch(Rule):
    id = "RL003"
    title = "lax.cond/switch branches return structurally different literals"

    def check(self, ctx: ModuleContext) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = ctx.resolve_call(node)
            if resolved in _COND_NAMES and len(node.args) >= 3:
                branches = [node.args[1], node.args[2]]
            elif resolved in _SWITCH_NAMES and len(node.args) >= 2 and isinstance(
                node.args[1], (ast.List, ast.Tuple)
            ):
                branches = list(node.args[1].elts)
            else:
                continue
            skeletons = [self._branch_skeleton(ctx, b) for b in branches]
            for i in range(len(skeletons)):
                for j in range(i + 1, len(skeletons)):
                    why = _mismatch(skeletons[i], skeletons[j])
                    if why:
                        findings.append(
                            self.finding(
                                ctx,
                                node,
                                f"branches {i} and {j} return structurally "
                                f"different pytrees ({why}); all branches "
                                "must agree in treedef, shape and dtype",
                            )
                        )
                        break
                else:
                    continue
                break
        return findings

    # ---------------------------------------------------------- skeletons
    def _branch_skeleton(self, ctx: ModuleContext, branch: ast.AST, depth: int = 0):
        if depth > 4:
            return ANY
        info = ctx.local_function(branch)
        if info is not None:
            if isinstance(info.node, ast.Lambda):
                return self._expr_skeleton(ctx, info.node.body, depth)
            for node in ctx._walk_own_body(info):
                if isinstance(node, ast.Return) and node.value is not None:
                    skel = self._expr_skeleton(ctx, node.value, depth)
                    if skel != ANY:
                        return skel
        return ANY

    def _expr_skeleton(self, ctx: ModuleContext, expr: ast.AST, depth: int):
        if isinstance(expr, (ast.Tuple, ast.List)):
            return (
                "tuple",
                tuple(self._expr_skeleton(ctx, e, depth) for e in expr.elts),
            )
        if isinstance(expr, ast.Constant):
            if isinstance(expr.value, bool):
                return ANY
            if isinstance(expr.value, int):
                return ("pyint",)
            if isinstance(expr.value, float):
                return ("pyfloat",)
            return ANY
        if isinstance(expr, ast.Call):
            resolved = ctx.resolve_call(expr)
            if resolved is not None:
                tail = resolved.rsplit(".", 1)[-1]
                if tail in _CONSTRUCTORS:
                    return self._constructor_leaf(ctx, expr, tail)
                if tail in _DTYPES:
                    return ("array", tail, None)
            # A branch that just forwards to a local helper: use its returns.
            callee = ctx.local_function(expr.func)
            if callee is not None:
                return self._branch_skeleton(ctx, expr.func, depth + 1)
        return ANY

    def _constructor_leaf(self, ctx: ModuleContext, call: ast.Call, name: str):
        dtype_pos, has_default = _CONSTRUCTORS[name]
        dtype: Optional[str] = "float32" if has_default else None
        dtype_node: Optional[ast.AST] = None
        if dtype_pos is not None and len(call.args) > dtype_pos:
            dtype_node = call.args[dtype_pos]
        for kw in call.keywords:
            if kw.arg == "dtype":
                dtype_node = kw.value
        if dtype_node is not None:
            resolved = ctx.resolve(dtype_node)
            label = resolved.rsplit(".", 1)[-1] if resolved else None
            if label is None and isinstance(dtype_node, ast.Constant):
                label = str(dtype_node.value)
            if label in _DTYPES or (label and label.rstrip("_") in _DTYPES):
                dtype = label.rstrip("_") if label != "bool_" else label
            else:
                dtype = None  # computed dtype: unknown, matches anything
        shape = self._literal_shape(call, name)
        return ("array", dtype, shape)

    @staticmethod
    def _literal_shape(call: ast.Call, name: str) -> Optional[Tuple]:
        if name in ("zeros", "ones", "empty", "full") and call.args:
            node = call.args[0]
            if isinstance(node, ast.Constant) and isinstance(node.value, int):
                return (node.value,)
            if isinstance(node, ast.Tuple) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in node.elts
            ):
                return tuple(e.value for e in node.elts)
        return None


def _mismatch(a, b) -> Optional[str]:
    """Reason the two skeletons cannot carry equal avals, or None."""
    if a == ANY or b == ANY:
        return None
    if a[0] == "tuple" and b[0] == "tuple":
        if len(a[1]) != len(b[1]):
            return f"tuple arity {len(a[1])} vs {len(b[1])}"
        for child_a, child_b in zip(a[1], b[1]):
            why = _mismatch(child_a, child_b)
            if why:
                return why
        return None
    if a[0] == "tuple" or b[0] == "tuple":
        return "tuple vs scalar leaf"
    if a[0] == "array" and b[0] == "array":
        dtype_a, shape_a = a[1], a[2]
        dtype_b, shape_b = b[1], b[2]
        if dtype_a and dtype_b and dtype_a != dtype_b:
            return f"dtype {dtype_a} vs {dtype_b}"
        if shape_a and shape_b and shape_a != shape_b:
            return f"shape {shape_a} vs {shape_b}"
        return None
    if {a[0], b[0]} == {"pyint", "pyfloat"}:
        return "python int vs float literal (weak-dtype mismatch)"
    return None
