"""RL002 — ``vmap`` over a function that launches a Pallas kernel.

``jax.vmap`` of a ``pallas_call`` lowers to one kernel launch per batch
element (or fails outright on some backends) instead of one fused launch —
the repo's standing rule since PR 3 is "never vmap-of-pallas_call": fold the
batch axis into the kernel grid instead (``ops.posterior_grid_fleet`` reshapes
stacked leading axes for exactly this reason; the DAG path folds S into K).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from ..context import FunctionInfo, ModuleContext
from ..engine import Finding
from . import Rule

_VMAP_NAMES = {"jax.vmap", "vmap"}
_PALLAS_CALL = "pallas_call"


class VmapOfPallasCall(Rule):
    id = "RL002"
    title = "vmap applied to a function containing pallas_call"

    def check(self, ctx: ModuleContext) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = ctx.resolve_call(node)
            if resolved not in _VMAP_NAMES or not node.args:
                continue
            target = node.args[0]
            reason = self._launches_pallas(ctx, target, seen=set())
            if reason:
                findings.append(
                    self.finding(
                        ctx,
                        node,
                        f"vmap over {reason}: this lowers to one kernel launch "
                        "per batch element — fold the batch axis into the "
                        "kernel grid instead (see ops.posterior_grid_fleet)",
                    )
                )
        return findings

    def _launches_pallas(
        self, ctx: ModuleContext, target: ast.AST, seen: Set[int]
    ) -> Optional[str]:
        """Human-readable reason when ``target`` (transitively) hits pallas."""
        if isinstance(target, ast.Call):
            resolved = ctx.resolve_call(target)
            if resolved and resolved.rsplit(".", 1)[-1] == _PALLAS_CALL:
                return "a pallas_call(...) result"
        info = ctx.local_function(target)
        if info is not None:
            return self._body_launches_pallas(ctx, info, seen)
        return None

    def _body_launches_pallas(
        self, ctx: ModuleContext, info: FunctionInfo, seen: Set[int]
    ) -> Optional[str]:
        if id(info) in seen:
            return None
        seen.add(id(info))
        for node in ctx._walk_own_body(info):
            if not isinstance(node, ast.Call):
                continue
            resolved = ctx.resolve_call(node)
            if resolved and resolved.rsplit(".", 1)[-1] == _PALLAS_CALL:
                return f"`{info.name}`, which calls pallas_call"
            if isinstance(node.func, ast.Name):
                callee = ctx.local_function(node.func)
                if callee is not None:
                    nested = self._body_launches_pallas(ctx, callee, seen)
                    if nested:
                        return (
                            f"`{info.name}`, which reaches pallas_call via "
                            f"`{callee.name}`"
                        )
        return None
