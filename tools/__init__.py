"""Repository tooling (not shipped with ``repro``): static analysis, CI helpers."""
