"""Fault-tolerance demo: mid-training worker failure -> Bayesian detection ->
eviction -> elastic re-partition -> checkpoint resume -> hyperprior
cold-start (a replacement worker admitted from the fleet prior converges
in measurably fewer observations than one from the global prior).

    PYTHONPATH=src python examples/elastic_failover.py
"""
import dataclasses

import numpy as np

from repro.configs import RunConfig, get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.distributed.simulated_cluster import SimulatedCluster, WorkerSpec
from repro.train.trainer import Trainer

cfg = reduced(get_arch("tinyllama-1.1b"))
shape = ShapeConfig("demo", seq_len=32, global_batch=12, kind="train")
run = RunConfig(
    model=cfg, shape=shape, checkpoint_dir="/tmp/repro_failover_ckpt",
    total_steps=60, warmup_steps=3, checkpoint_every=10,
    partitioner_refit_every=8, straggler_threshold_sigma=2.5,
)

cluster = SimulatedCluster(
    [WorkerSpec(5.0, 0.4), WorkerSpec(5.5, 0.4), WorkerSpec(6.0, 0.5)], seed=0
)
tr = Trainer(run, cluster=cluster, num_microbatches=6)

print("phase 1: healthy fleet (3 workers)")
rep1 = tr.train(16)
print(f"  loss {rep1.losses[0]:.3f} -> {rep1.losses[-1]:.3f}; "
      f"split {np.bincount(tr._worker_of_mb, minlength=3)}")

print("phase 2: worker 1 degrades (straggler) ...")
cluster.degrade(1, mu_factor=5.0)
rep2 = tr.train(16)
strag = [e for e in tr.monitor.events if e["type"] == "straggler"]
print(f"  straggler events: {strag[-1] if strag else 'none'}")
print(f"  rebalanced split {np.bincount(tr._worker_of_mb, minlength=3)} "
      "(work shifted off worker 1)")

print("phase 3: worker 2 dies (heartbeat lost) ...")
cluster.fail(2)
rep3 = tr.train(16)
print(f"  fleet size now {tr.partitioner.num_workers} "
      f"(events: {[e['type'] for e in tr.monitor.events]})")
print(f"  training continued: loss {rep3.losses[0]:.3f} -> {rep3.losses[-1]:.3f}")

print("phase 4: restart from checkpoint (crash-resume)")
tr.save()
tr.ckpt.wait()
tr2 = Trainer(run, cluster=cluster, num_microbatches=6)
assert tr2.try_restore()
# the scheduler's Bayesian beliefs are part of the checkpoint pytree now:
# the restarted trainer proposes from the LEARNED posteriors, not fresh priors
mu_saved = np.asarray(tr.partitioner.state.gibbs.mu)
mu_restored = np.asarray(tr2.partitioner.state.gibbs.mu)
np.testing.assert_array_equal(mu_saved, mu_restored)
print(f"  resumed at step {tr2.step}; beliefs restored bit-exactly "
      f"(mu={np.round(mu_restored, 2)}); continuing 8 more steps")
rep4 = tr2.train(8)
print(f"  post-resume loss: {rep4.losses[-1]:.3f} (finite={np.isfinite(rep4.losses[-1])})")

print("phase 5: hyperprior cold-start (replacing the dead worker)")
# Elastic recovery eventually admits a REPLACEMENT.  With hierarchical
# pooling the newcomer is born from the fleet's empirical-Bayes hyperprior
# (repro.hier) instead of the vague global prior, so it converges to its
# fair share of work in measurably fewer observations — shown here on the
# scheduler directly (docs/hierarchy.md; same scenario as bench_hier).
import jax.numpy as jnp

from repro import sched

TRUE_MU, K = 600.0, 8


def telemetry(rng, fracs=None, n=8):
    if fracs is None:  # exploration rounds: varied f identifies (mu, alpha)
        fmat = rng.uniform(0.05, 0.9, (K, n)).astype(np.float32)
    else:
        fmat = np.tile(np.asarray(fracs, np.float32)[:, None], (1, n))
    tmat = fmat**0.9 * TRUE_MU * (1.0 + 0.02 * rng.standard_normal(fmat.shape))
    return sched.Telemetry(jnp.asarray(fmat), jnp.asarray(tmat, jnp.float32))


def obs_to_fair_share(scheduler, rng, n=4, max_cycles=15):
    """Newcomer observations until its fraction is within 10% of oracle."""
    oracle = 1.0 / (K + 1)
    for cycle in range(max_cycles + 1):
        fr, _, _ = scheduler.propose_fractions()
        if abs(fr[-1] - oracle) <= 0.1 * oracle:
            return cycle * n
        scheduler.observe(telemetry(rng, fr, n=n))
    return (max_cycles + 1) * n


cfg5 = sched.SchedulerConfig(
    n_iters=3, grid_size=32, num_points=64, opt_steps=30, mu_guess=1.0
)
rng5 = np.random.default_rng(0)
fleet = sched.Scheduler(K, config=cfg5, seed=0)
for _ in range(6):
    fleet.observe(telemetry(rng5))

obs = {}
for label, hierarchical in (("pooled", True), ("global", False)):
    s = sched.Scheduler(1, config=dataclasses.replace(cfg5, hierarchical=hierarchical))
    s.state = fleet.state  # immutable pytree: share, then diverge
    s.add_workers(1, seed=7)
    obs[label] = obs_to_fair_share(s, np.random.default_rng(1))
    print(f"  {label} prior admit: {obs[label]} observations to fair share")

# self-check: the ISSUE's acceptance gap, not just a demo print
assert obs["pooled"] <= obs["global"] / 2, obs
print(f"  cold-start transfer: {obs['pooled']} vs {obs['global']} obs "
      f"({obs['global'] - obs['pooled']} saved by pooling)")
