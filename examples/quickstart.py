"""Quickstart: learn two processing units' characteristics from passive
telemetry and pick the frontier-optimal split (the whole paper in ~60 lines).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fit, optimal_two_way_fraction, sweep_two_way, pareto_mask
from repro.core.frontier import UnitParams

# ---------------------------------------------------------------------------
# 1. Two heterogeneous processing units (ground truth UNKNOWN to the system).
#    Unit i is slow but steady; unit j is fast but noisy (paper's Fig 1 setup).
# ---------------------------------------------------------------------------
TRUE = dict(i=dict(mu=30.0, sigma=2.0, alpha=0.92, beta=0.85),
            j=dict(mu=20.0, sigma=6.0, alpha=0.88, beta=0.80))

rng = np.random.default_rng(0)
N = 384


def observe(unit, f):
    p = TRUE[unit]
    return np.maximum(
        f ** p["alpha"] * p["mu"] + f ** p["beta"] * p["sigma"] * rng.normal(size=f.shape),
        1e-3,
    )

# Telemetry from ACTUAL workloads — no controlled experiments (paper §1).
f_seen = rng.uniform(0.05, 0.95, N).astype(np.float32)
t_i = observe("i", f_seen).astype(np.float32)
t_j = observe("j", 1.0 - f_seen).astype(np.float32)

# ---------------------------------------------------------------------------
# 2. Gibbs-estimate each unit (Algorithm 1, chained priors).
# ---------------------------------------------------------------------------
st_i, _ = fit(jax.random.PRNGKey(1), jnp.asarray(t_i), jnp.asarray(f_seen),
              batch_size=64, n_iters=15, grid_size=256)
st_j, _ = fit(jax.random.PRNGKey(2), jnp.asarray(t_j), jnp.asarray(1.0 - f_seen),
              batch_size=64, n_iters=15, grid_size=256)

print("learned unit i:", {k: round(float(v), 3) for k, v in
      dict(mu=st_i.mu, sigma=st_i.sigma, alpha=st_i.alpha, beta=st_i.beta).items()})
print("true    unit i:", TRUE["i"])
print("learned unit j:", {k: round(float(v), 3) for k, v in
      dict(mu=st_j.mu, sigma=st_j.sigma, alpha=st_j.alpha, beta=st_j.beta).items()})
print("true    unit j:", TRUE["j"])

# ---------------------------------------------------------------------------
# 3. Frontier: choose f for min expected time / risk-averse / var-budget QoS.
# ---------------------------------------------------------------------------
params = UnitParams.of(
    [float(st_i.mu), float(st_j.mu)], [float(st_i.sigma), float(st_j.sigma)],
    [float(st_i.alpha), float(st_j.alpha)], [float(st_i.beta), float(st_j.beta)],
)
fg, mu_f, var_f = sweep_two_way(params, num_f=101)
mask = np.asarray(pareto_mask(mu_f, var_f))

print("\n  f      mu(f)  var(f)  frontier")
for k in range(0, 101, 10):
    star = "*" if mask[k] else ""
    print(f"  {float(fg[k]):.2f}   {float(mu_f[k]):6.2f} {float(var_f[k]):7.2f}  {star}")

for obj, kw in [("mean", {}), ("mean_var", dict(risk_aversion=1.0)),
                ("constrained", dict(var_budget=6.0))]:
    f_opt, m, v = optimal_two_way_fraction(params, objective=obj, **kw)
    print(f"objective={obj:11s} -> f*={float(f_opt):.3f} "
          f"E[t]={float(m):.2f} Var[t]={float(v):.2f}")
