"""Serving with QoS-aware batch partitioning: a request batch is split across
heterogeneous replicas using the learned frontier, with the QoS target
expressed as a pluggable ``repro.sched.Objective`` (min latency, risk-averse
mean+var, or a deadline quantile P(t <= eps) for tail-latency control).

    PYTHONPATH=src python examples/serve_partitioned.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import sched
from repro.configs import get_arch, reduced
from repro.distributed.simulated_cluster import SimulatedCluster, WorkerSpec
from repro.models import model_zoo
from repro.models.layers import ApplyCtx
from repro.train import serve_step

# --- a small real model to serve ------------------------------------------
cfg = reduced(get_arch("tinyllama-1.1b"))
params = model_zoo.init_model_params(jax.random.PRNGKey(0), cfg)

# --- three serving replicas with different (unknown) speeds ----------------
cluster = SimulatedCluster(
    [WorkerSpec(2.0, 0.2, 0.95, 0.9), WorkerSpec(5.0, 0.8, 0.9, 0.85),
     WorkerSpec(3.0, 0.3, 0.92, 0.88)],
    seed=0,
)

# --- pure-functional scheduler: explicit state, pure transitions ------------
config = sched.SchedulerConfig(
    objective=sched.Objective.mean(), n_iters=12, grid_size=128, mu_guess=3.0
)
state = sched.init(config, 3, jax.random.PRNGKey(1))

# --- online phase: serve batches, learn, re-split ---------------------------
BATCH = 24
rng = np.random.default_rng(0)
print("round | split (requests/replica) | batch latency (simulated)")
for rnd in range(8):
    fracs_prop, _ = sched.propose(state, config)  # jitted
    counts = sched.quantize_fractions(
        np.asarray(fracs_prop), BATCH, sched.unit_params(state),
        objective=config.objective,
    )
    fracs = counts / counts.sum()

    # actually run the model for one replica's shard (semantics demo)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (int(counts[0]), 12)),
                       jnp.int32)
    out = serve_step.generate(
        cfg, params, {"tokens": toks}, max_len=16, steps=3,
        ctx_prefill=ApplyCtx(mode="prefill"), ctx_decode=ApplyCtx(mode="decode"),
    )
    assert out.shape == (int(counts[0]), 3)

    # telemetry: measured (simulated) per-replica latency for its fraction
    times = np.stack([cluster.step_times(fracs) for _ in range(8)], axis=1)
    fmat = np.tile(fracs[:, None], (1, 8))
    state, _ = sched.observe(
        state, sched.Telemetry(jnp.asarray(fmat), jnp.asarray(times)), config
    )
    lat = float(np.max(times.mean(axis=1)))
    print(f"  {rnd}   | {counts} | {lat:.2f}s")

fr, stats = sched.propose(state, config)
fr = np.asarray(fr)
print(f"\nlearned split {np.round(fr, 3)}  "
      f"E[latency]={float(stats.e_t):.2f}s  Var={float(stats.var):.3f}")
eq = cluster.oracle_makespan(np.full(3, 1 / 3))
lr = cluster.oracle_makespan(fr)
print(f"true expected batch latency: equal={eq:.2f}s learned={lr:.2f}s "
      f"({100 * (eq - lr) / eq:.0f}% faster)")

# tail-latency mode: same beliefs, different objective — spend a little mean
# latency to buy predictability.  Pure API: just score under a new Objective.
risk_cfg = sched.SchedulerConfig(objective=sched.Objective.mean_var(5.0))
fr_r, st_r = sched.propose(state, risk_cfg)
print(f"risk-averse split {np.round(np.asarray(fr_r), 3)}  "
      f"E={float(st_r.e_t):.2f}s Var={float(st_r.var):.3f} "
      f"(vs Var={float(stats.var):.3f} at min-mean)")

# deadline mode: maximize P(batch completes within eps)
eps = 1.2 * float(stats.e_t)
dl_cfg = sched.SchedulerConfig(objective=sched.Objective.deadline_quantile(eps))
fr_d, st_d = sched.propose(state, dl_cfg)
print(f"deadline({eps:.2f}s) split {np.round(np.asarray(fr_d), 3)}  "
      f"P(t<=eps)={-float(st_d.score):.3f}")
