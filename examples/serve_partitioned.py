"""Serving with QoS-aware batch partitioning: a request batch is split across
heterogeneous replicas using the learned frontier (min latency, or a variance
budget for tail-latency control).

    PYTHONPATH=src python examples/serve_partitioned.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.partitioner import (
    HeterogeneityAwarePartitioner,
    WorkerTelemetry,
    quantize_fractions,
)
from repro.distributed.simulated_cluster import SimulatedCluster, WorkerSpec
from repro.models import model_zoo
from repro.models.layers import ApplyCtx
from repro.train import serve_step

# --- a small real model to serve ------------------------------------------
cfg = reduced(get_arch("tinyllama-1.1b"))
params = model_zoo.init_model_params(jax.random.PRNGKey(0), cfg)

# --- three serving replicas with different (unknown) speeds ----------------
cluster = SimulatedCluster(
    [WorkerSpec(2.0, 0.2, 0.95, 0.9), WorkerSpec(5.0, 0.8, 0.9, 0.85),
     WorkerSpec(3.0, 0.3, 0.92, 0.88)],
    seed=0,
)
part = HeterogeneityAwarePartitioner(3, seed=1, n_iters=12, grid_size=128,
                                     mu_guess=3.0)

# --- online phase: serve batches, learn, re-split ---------------------------
BATCH = 24
rng = np.random.default_rng(0)
print("round | split (requests/replica) | batch latency (simulated)")
for rnd in range(8):
    counts = part.propose_microbatches(BATCH)
    fracs = counts / counts.sum()

    # actually run the model for one replica's shard (semantics demo)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (int(counts[0]), 12)),
                       jnp.int32)
    out = serve_step.generate(
        cfg, params, {"tokens": toks}, max_len=16, steps=3,
        ctx_prefill=ApplyCtx(mode="prefill"), ctx_decode=ApplyCtx(mode="decode"),
    )
    assert out.shape == (int(counts[0]), 3)

    # telemetry: measured (simulated) per-replica latency for its fraction
    times = np.stack([cluster.step_times(fracs) for _ in range(8)], axis=1)
    fmat = np.tile(fracs[:, None], (1, 8))
    part.observe(WorkerTelemetry(jnp.asarray(fmat), jnp.asarray(times)))
    lat = float(np.max(times.mean(axis=1)))
    print(f"  {rnd}   | {counts} | {lat:.2f}s")

fr, e, v = part.propose_fractions()
print(f"\nlearned split {np.round(fr, 3)}  E[latency]={e:.2f}s  Var={v:.3f}")
eq = cluster.oracle_makespan(np.full(3, 1 / 3))
lr = cluster.oracle_makespan(fr)
print(f"true expected batch latency: equal={eq:.2f}s learned={lr:.2f}s "
      f"({100 * (eq - lr) / eq:.0f}% faster)")

# tail-latency mode: spend a little mean latency to buy predictability
part.risk_aversion = 5.0
fr_r, e_r, v_r = part.propose_fractions()
print(f"risk-averse split {np.round(fr_r, 3)}  E={e_r:.2f}s Var={v_r:.3f} "
      f"(vs Var={v:.3f} at min-mean)")
