"""Serving with QoS-aware batch partitioning — push-mode edition: a request
batch is split across heterogeneous replicas by the always-on estimation
service (``repro.serve.ServiceLoop``).  The request loop never calls the
scheduler inline: it reads the last-good split from the service's
double-buffered slot (non-blocking), serves, and pushes measured telemetry
into the device-resident ring; the service re-solves the split only when the
posterior actually moves (drift-gated cadence, ``docs/serving.md``).

The QoS target stays a pluggable ``repro.sched.Objective`` (min latency,
risk-averse mean+var, or a deadline quantile for tail-latency control).

    PYTHONPATH=src python examples/serve_partitioned.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import sched, serve
from repro.configs import get_arch, reduced
from repro.distributed.simulated_cluster import SimulatedCluster, WorkerSpec
from repro.models import model_zoo
from repro.models.layers import ApplyCtx
from repro.train import serve_step

# --- a small real model to serve ------------------------------------------
cfg = reduced(get_arch("tinyllama-1.1b"))
params = model_zoo.init_model_params(jax.random.PRNGKey(0), cfg)

# Jitted model closures are built ONCE, outside the request loop — each
# request hits the jit cache instead of re-tracing prefill/decode per call
# (the old ``serve_step.generate`` convenience rebuilt them every round).
prefill = jax.jit(serve_step.make_prefill_step(cfg, ctx=ApplyCtx(mode="prefill")))
decode = jax.jit(serve_step.make_decode_step(cfg, ctx=ApplyCtx(mode="decode")))

# --- three serving replicas with different (unknown) speeds ----------------
cluster = SimulatedCluster(
    [WorkerSpec(2.0, 0.2, 0.95, 0.9), WorkerSpec(5.0, 0.8, 0.9, 0.85),
     WorkerSpec(3.0, 0.3, 0.92, 0.88)],
    seed=0,
)

# --- the always-on service: ring-buffered observe, drift-gated propose ------
config = serve.ServeConfig(
    sched=sched.SchedulerConfig(
        objective=sched.Objective.mean(), n_iters=12, grid_size=128,
        mu_guess=3.0,
    ),
    capacity=8,          # telemetry rows buffered between drains
    drift_threshold=0.05,
    max_staleness=6,
)
loop = serve.ServiceLoop(3, config=config, seed=1)

# --- online phase: serve batches, push telemetry, tick the service ----------
BATCH = 24
rng = np.random.default_rng(0)
print("round | split (requests/replica) | batch latency | service")
for rnd in range(8):
    fr = loop.fractions()                       # non-blocking slot read
    counts = sched.quantize_fractions(
        fr, BATCH, sched.unit_params(loop.state.sched),
        objective=config.sched.objective,
    )
    fracs = counts / counts.sum()

    # actually run the model for one replica's shard (semantics demo)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (int(counts[0]), 12)),
                       jnp.int32)
    cache = model_zoo.init_cache(cfg, int(counts[0]), 16, jnp.float32)
    token, cache = prefill(params, {"tokens": toks}, cache)
    for _ in range(2):
        token, cache = decode(params, token, cache)

    # telemetry: measured (simulated) per-replica latency, 8 rows per round
    for _ in range(8):
        loop.push(fracs, cluster.step_times(fracs))
    info = loop.tick()                          # drain -> observe -> propose?
    lat = float(np.max(cluster.step_times(fracs)))
    print(f"  {rnd}   | {counts} | {lat:.2f}s | drift={float(info.drift):.3f} "
          f"proposed={bool(info.proposed)}")

c = loop.counters()
fr = loop.fractions()
stats = loop.state.stats
print(f"\nlearned split {np.round(fr, 3)}  "
      f"E[latency]={float(stats.e_t):.2f}s  Var={float(stats.var):.3f}")
print(f"service counters: {c['drains']} drains, {c['proposes']} proposes "
      f"(skip rate {1.0 - c['proposes'] / max(c['drains'], 1):.2f})")
eq = cluster.oracle_makespan(np.full(3, 1 / 3))
lr = cluster.oracle_makespan(fr)
print(f"true expected batch latency: equal={eq:.2f}s learned={lr:.2f}s "
      f"({100 * (eq - lr) / eq:.0f}% faster)")

# tail-latency mode: same beliefs, different objective — spend a little mean
# latency to buy predictability.  Pure API: score under a new Objective.
state = loop.state.sched
risk_cfg = sched.SchedulerConfig(objective=sched.Objective.mean_var(5.0))
fr_r, st_r = sched.propose(state, risk_cfg)
print(f"risk-averse split {np.round(np.asarray(fr_r), 3)}  "
      f"E={float(st_r.e_t):.2f}s Var={float(st_r.var):.3f} "
      f"(vs Var={float(stats.var):.3f} at min-mean)")

# deadline mode: maximize P(batch completes within eps)
eps = 1.2 * float(stats.e_t)
dl_cfg = sched.SchedulerConfig(objective=sched.Objective.deadline_quantile(eps))
fr_d, st_d = sched.propose(state, dl_cfg)
print(f"deadline({eps:.2f}s) split {np.round(np.asarray(fr_d), 3)}  "
      f"P(t<=eps)={-float(st_d.score):.3f}")
