"""Multi-stage pipeline demo: stage-wise Bayesian splits vs uniform splits.

A 3-stage workflow (ingest -> transform -> publish), each stage partitioned
across 4 heterogeneous workers whose speeds the system does NOT know.  The
whole pipeline's telemetry advances as ONE stacked (S, K, N) estimation
program — the stage axis folds into the fleet axis, so even S stages of K
workers cost a single fused launch per Gibbs sweep — and ``propose_dag``
then partitions stage by stage against the shared objective, composing the
per-stage makespan moments into end-to-end completion statistics.

    PYTHONPATH=src python examples/pipeline_dag.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import sched
from repro.core.frontier import UnitParams

S, K, N = 3, 4, 96
STAGES = ("ingest", "transform", "publish")

# Ground truth (unknown to the scheduler): every stage has a 4-6x speed
# spread across its workers, and the spreads do not line up across stages.
TRUE_MU = np.array(
    [
        [4.0, 9.0, 16.0, 24.0],   # ingest
        [20.0, 5.0, 12.0, 30.0],  # transform (different worker is fastest)
        [8.0, 8.0, 3.0, 18.0],    # publish
    ],
    np.float32,
)
TRUE_SIGMA = np.full((S, K), 1.0, np.float32)
ALPHA = BETA = 0.9

rng = np.random.default_rng(0)


def telemetry(fracs: np.ndarray, n: int = N) -> sched.Telemetry:
    """Passive telemetry: each stage works its current split, but request
    sizes vary (paper §1: observations come from actual diverse workloads,
    not controlled experiments), which is what identifies the scaling
    exponents — a worker only ever seen at one fixed fraction confounds
    (alpha, mu)."""
    jitter = rng.uniform(0.3, 1.7, size=(S, K, n))
    f = np.clip(fracs[..., None] * jitter, 0.02, 0.98).astype(np.float32)
    noise = rng.normal(size=(S, K, n))
    t = np.maximum(
        f**ALPHA * TRUE_MU[..., None] + f**BETA * TRUE_SIGMA[..., None] * noise,
        1e-3,
    ).astype(np.float32)
    return sched.Telemetry(fracs=jnp.asarray(f), times=jnp.asarray(t))


# ---------------------------------------------------------------------------
# 1. Learn the whole pipeline online: one stacked program per observe call.
# ---------------------------------------------------------------------------
dag = sched.WorkflowDAG.chain(S, K)
config = sched.SchedulerConfig(n_iters=10, grid_size=128, mu_guess=12.0)
state = sched.init_dag(config, dag, jax.random.PRNGKey(0))

fracs = np.asarray(sched.uniform_fractions(dag))  # start naive
for round_ in range(5):
    state, ll = sched.observe_dag(state, telemetry(fracs), config)
    fracs, stats = sched.propose_dag(state, dag, config)
    fracs = np.asarray(fracs)
    print(
        f"round {round_}: mean ll={float(jnp.mean(ll)):8.2f}   "
        f"E[end-to-end]={float(stats.e_t):6.2f}  Var={float(stats.var):.3f}"
    )

learned = sched.stage_params(state)
print("\nlearned stage speeds (posterior mean mu, true in parens):")
for si, name in enumerate(STAGES):
    row = "  ".join(
        f"{float(learned.mu[si, k]):5.1f} ({TRUE_MU[si, k]:4.1f})" for k in range(K)
    )
    print(f"  {name:10s} {row}")

# ---------------------------------------------------------------------------
# 2. Evaluate the proposal vs the uniform baseline at the TRUE parameters.
# ---------------------------------------------------------------------------
true_params = UnitParams.of(
    TRUE_MU, TRUE_SIGMA, np.full((S, K), ALPHA), np.full((S, K), BETA)
)
st_bayes = sched.dag_stats(dag, jnp.asarray(fracs), true_params)
st_uni = sched.dag_stats(dag, sched.uniform_fractions(dag), true_params)

print("\nend-to-end completion (at TRUE parameters):")
print(f"  uniform splits   E[t]={float(st_uni.e_t):6.2f}  Var={float(st_uni.var):6.3f}")
print(f"  Bayesian splits  E[t]={float(st_bayes.e_t):6.2f}  Var={float(st_bayes.var):6.3f}")
gain = 100.0 * (1.0 - float(st_bayes.e_t) / float(st_uni.e_t))
print(f"  -> {gain:.1f}% lower expected end-to-end latency")

print("\nper-stage splits (workers sorted fast->slow get more->less):")
for si, name in enumerate(STAGES):
    print(f"  {name:10s} " + "  ".join(f"{fracs[si, k]:.3f}" for k in range(K)))

# ---------------------------------------------------------------------------
# 3. Monte-Carlo sanity check of the composed moments.
# ---------------------------------------------------------------------------
n_mc = 200_000
total = np.zeros(n_mc)
for si in range(S):
    mean = fracs[si] ** ALPHA * TRUE_MU[si]
    std = fracs[si] ** BETA * TRUE_SIGMA[si]
    total += rng.normal(mean, std, size=(n_mc, K)).max(axis=1)
print(
    f"\ncomposed E[t]={float(st_bayes.e_t):.2f} vs Monte-Carlo {total.mean():.2f}  "
    f"(Var {float(st_bayes.var):.3f} vs {total.var():.3f})"
)

assert float(st_bayes.e_t) < float(st_uni.e_t), "Bayesian splits must beat uniform"
print("\nOK: stage-wise Bayesian splits beat uniform splits end-to-end.")
