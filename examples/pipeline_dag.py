"""Multi-stage pipeline demo: stage-wise Bayesian splits vs uniform splits.

A 3-stage workflow (ingest -> transform -> publish), each stage partitioned
across 4 heterogeneous workers whose speeds the system does NOT know.  The
whole pipeline's telemetry advances as ONE stacked (S, K, N) estimation
program — the stage axis folds into the fleet axis, so even S stages of K
workers cost a single fused launch per Gibbs sweep — and ``propose_dag``
then partitions stage by stage against the shared objective, composing the
per-stage makespan moments into end-to-end completion statistics.

    PYTHONPATH=src python examples/pipeline_dag.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import sched
from repro.core.frontier import UnitParams

S, K, N = 3, 4, 96
STAGES = ("ingest", "transform", "publish")

# Ground truth (unknown to the scheduler): every stage has a 4-6x speed
# spread across its workers, and the spreads do not line up across stages.
TRUE_MU = np.array(
    [
        [4.0, 9.0, 16.0, 24.0],   # ingest
        [20.0, 5.0, 12.0, 30.0],  # transform (different worker is fastest)
        [8.0, 8.0, 3.0, 18.0],    # publish
    ],
    np.float32,
)
TRUE_SIGMA = np.full((S, K), 1.0, np.float32)
ALPHA = BETA = 0.9

rng = np.random.default_rng(0)


def telemetry(fracs: np.ndarray, n: int = N) -> sched.Telemetry:
    """Passive telemetry: each stage works its current split, but request
    sizes vary (paper §1: observations come from actual diverse workloads,
    not controlled experiments), which is what identifies the scaling
    exponents — a worker only ever seen at one fixed fraction confounds
    (alpha, mu)."""
    jitter = rng.uniform(0.3, 1.7, size=(S, K, n))
    f = np.clip(fracs[..., None] * jitter, 0.02, 0.98).astype(np.float32)
    noise = rng.normal(size=(S, K, n))
    t = np.maximum(
        f**ALPHA * TRUE_MU[..., None] + f**BETA * TRUE_SIGMA[..., None] * noise,
        1e-3,
    ).astype(np.float32)
    return sched.Telemetry(fracs=jnp.asarray(f), times=jnp.asarray(t))


# ---------------------------------------------------------------------------
# 1. Learn the whole pipeline online: one stacked program per observe call.
# ---------------------------------------------------------------------------
dag = sched.WorkflowDAG.chain(S, K)
config = sched.SchedulerConfig(n_iters=10, grid_size=128, mu_guess=12.0)
state = sched.init_dag(config, dag, jax.random.PRNGKey(0))

fracs = np.asarray(sched.uniform_fractions(dag))  # start naive
for round_ in range(5):
    state, ll = sched.observe_dag(state, telemetry(fracs), config)
    fracs, stats = sched.propose_dag(state, dag, config)
    fracs = np.asarray(fracs)
    print(
        f"round {round_}: mean ll={float(jnp.mean(ll)):8.2f}   "
        f"E[end-to-end]={float(stats.e_t):6.2f}  Var={float(stats.var):.3f}"
    )

learned = sched.stage_params(state)
print("\nlearned stage speeds (posterior mean mu, true in parens):")
for si, name in enumerate(STAGES):
    row = "  ".join(
        f"{float(learned.mu[si, k]):5.1f} ({TRUE_MU[si, k]:4.1f})" for k in range(K)
    )
    print(f"  {name:10s} {row}")

# ---------------------------------------------------------------------------
# 2. Evaluate the proposal vs the uniform baseline at the TRUE parameters.
# ---------------------------------------------------------------------------
true_params = UnitParams.of(
    TRUE_MU, TRUE_SIGMA, np.full((S, K), ALPHA), np.full((S, K), BETA)
)
st_bayes = sched.dag_stats(dag, jnp.asarray(fracs), true_params)
st_uni = sched.dag_stats(dag, sched.uniform_fractions(dag), true_params)

print("\nend-to-end completion (at TRUE parameters):")
print(f"  uniform splits   E[t]={float(st_uni.e_t):6.2f}  Var={float(st_uni.var):6.3f}")
print(f"  Bayesian splits  E[t]={float(st_bayes.e_t):6.2f}  Var={float(st_bayes.var):6.3f}")
gain = 100.0 * (1.0 - float(st_bayes.e_t) / float(st_uni.e_t))
print(f"  -> {gain:.1f}% lower expected end-to-end latency")

print("\nper-stage splits (workers sorted fast->slow get more->less):")
for si, name in enumerate(STAGES):
    print(f"  {name:10s} " + "  ".join(f"{fracs[si, k]:.3f}" for k in range(K)))

# ---------------------------------------------------------------------------
# 3. Monte-Carlo sanity check of the composed moments.
# ---------------------------------------------------------------------------
n_mc = 200_000
total = np.zeros(n_mc)
for si in range(S):
    mean = fracs[si] ** ALPHA * TRUE_MU[si]
    std = fracs[si] ** BETA * TRUE_SIGMA[si]
    total += rng.normal(mean, std, size=(n_mc, K)).max(axis=1)
print(
    f"\ncomposed E[t]={float(st_bayes.e_t):.2f} vs Monte-Carlo {total.mean():.2f}  "
    f"(Var {float(st_bayes.var):.3f} vs {total.var():.3f})"
)

assert float(st_bayes.e_t) < float(st_uni.e_t), "Bayesian splits must beat uniform"
print("\nOK: stage-wise Bayesian splits beat uniform splits end-to-end.")

# ---------------------------------------------------------------------------
# 4. Stochastic topology: a conditional branch + a rework loop.
#
# Real workflows do not always run every stage exactly once.  Annotate a
# 4-stage diamond so stage 1 fires only 30% of the time and stage 2 retries
# on failure (40% per-attempt, up to 4 attempts), then compare a proposal
# that KNOWS this against one that assumes the deterministic topology.
# Under an end-to-end variance budget the deterministic-assumption
# allocator misprices stage variances — the branch thins them x0.3, the
# rework loop amplifies them x E[N] — and pays expected time where it buys
# nothing.  The Monte-Carlo simulator (repro.sim), which shares no
# composition code with the analytic path, referees on common random
# numbers so the paired gap is far above the MC noise floor.
# ---------------------------------------------------------------------------
from repro import sim

S4, K4 = 4, 8
diamond = sched.WorkflowDAG.from_edges(
    S4, ((0, 1), (0, 2), (1, 3), (2, 3)), num_workers=K4
)
diamond_sto = diamond.with_stochastic(
    exec_probs=(1.0, 0.3, 1.0, 1.0),    # stage 1 is conditional
    rework_probs=(0.0, 0.0, 0.4, 0.0),  # stage 2 loops on failure
    max_retries=(1, 1, 4, 1),
)
# Fast-but-noisy workers 0-3 vs slow-but-precise workers 4-7.
base_mu = np.asarray([5.0] * 4 + [9.0] * 4, np.float32)
base_sig = np.asarray([6.0] * 4 + [0.3] * 4, np.float32)
stage_scale = np.asarray([0.4, 1.6, 0.5, 0.4], np.float32)
true4 = UnitParams.of(
    stage_scale[:, None] * base_mu[None, :],
    stage_scale[:, None] * base_sig[None, :],
    np.full((S4, K4), 0.9, np.float32),
    np.full((S4, K4), 0.55, np.float32),
)
cfg4 = sched.SchedulerConfig(
    objective=sched.Objective.variance_budget(2.0), opt_steps=200, num_points=256
)
st4 = sched.init_dag(cfg4, diamond, jax.random.PRNGKey(0))
f_det, _ = sched.propose_dag(st4, diamond, cfg4, params=true4)      # topology-blind
f_sto, _ = sched.propose_dag(st4, diamond_sto, cfg4, params=true4)  # topology-aware

key = jax.random.PRNGKey(42)  # common random numbers: one sampled world
n_mc4 = 200_000
t_det = sim.simulate_workflow(key, diamond_sto, f_det, true4, num_samples=n_mc4)
t_sto = sim.simulate_workflow(key, diamond_sto, f_sto, true4, num_samples=n_mc4)
t_uni = sim.simulate_workflow(
    key, diamond_sto, sched.uniform_fractions(diamond), true4, num_samples=n_mc4
)

print("\nstochastic diamond (p=0.3 branch, 40% rework), simulator-measured E[t]:")
print(f"  uniform splits            {float(jnp.mean(t_uni)):7.3f}")
print(f"  deterministic-assumption  {float(jnp.mean(t_det)):7.3f}")
print(f"  stochastic-aware          {float(jnp.mean(t_sto)):7.3f}")
gap = float(jnp.mean(t_det - t_sto))
print(f"  -> knowing the topology saves {gap:+.4f} E[t] vs assuming it away")

assert gap > 0.0, "stochastic-aware proposal must beat the deterministic assumption"
assert float(jnp.mean(t_uni - t_sto)) > 0.0
print("\nOK: stochastic-aware splits beat both baselines on the simulator.")
