"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
Bayesian partitioner balancing a simulated heterogeneous 4-worker fleet.

    PYTHONPATH=src python examples/train_hetero.py [--steps 300] [--small]

--small uses a reduced config for a fast demo; the default trains the REAL
smollm-135m architecture (135M params) at short sequence length so a few
hundred steps are feasible on CPU.
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import RunConfig, get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.distributed.simulated_cluster import SimulatedCluster, WorkerSpec
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="reduced config (fast demo)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_hetero_ckpt")
    args = ap.parse_args()

    cfg = get_arch("smollm-135m")
    if args.small:
        cfg = reduced(cfg)
        shape = ShapeConfig("demo", seq_len=64, global_batch=8, kind="train")
        microbatches = 8
    else:
        # full 135M-param architecture, short sequences for CPU feasibility
        cfg = dataclasses.replace(cfg, dtype="float32")
        shape = ShapeConfig("demo", seq_len=64, global_batch=8, kind="train")
        microbatches = 8

    run = RunConfig(
        model=cfg, shape=shape, checkpoint_dir=args.ckpt_dir,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        learning_rate=1e-3, checkpoint_every=max(args.steps // 3, 1),
        partitioner_refit_every=12,
    )

    # a fast, two medium, one slow worker — the partitioner must discover this
    cluster = SimulatedCluster(
        [WorkerSpec(4.0, 0.4), WorkerSpec(9.0, 0.8),
         WorkerSpec(10.0, 0.9), WorkerSpec(22.0, 2.0)],
        seed=0,
    )
    tr = Trainer(run, cluster=cluster, num_microbatches=microbatches)
    if tr.try_restore():
        print(f"resumed from checkpoint at step {tr.step}")

    print(f"training {cfg.name}: ~{tr.cfg.num_layers}L d={tr.cfg.d_model} "
          f"steps={args.steps} microbatches={microbatches}")
    rep = tr.train(args.steps, log_every=25)

    q = max(len(rep.losses) // 10, 1)
    print(f"\nloss: {np.mean(rep.losses[:q]):.3f} -> {np.mean(rep.losses[-q:]):.3f}")
    if rep.splits:
        print("microbatch split trajectory (1 row per refit):")
        for s in rep.splits:
            print("   ", s, " (true speeds ~ [4, 9, 10, 22] s/unit)")
    k = max(len(rep.makespans) // 4, 1)
    first, last = np.mean(rep.makespans[:k]), np.mean(rep.makespans[-k:])
    print(f"simulated step makespan: {first:.2f}s -> {last:.2f}s "
          f"({100 * (first - last) / first:.0f}% faster than the initial equal split)")


if __name__ == "__main__":
    main()
